//! The paper's neural-network motivating example (§2, eq 3-5): a dense
//! layer + batch normalization + nonlinearity, fused into ONE Pallas
//! kernel at build time and served from rust through the coordinator —
//! no Python anywhere at run time, no temporaries between the three steps.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example fused_nn_layer`

use hofdla::coordinator::{Config, Coordinator, Request, Response};
use hofdla::util::Rng;

/// Reference computation in rust (mirrors python/compile/kernels/ref.py).
fn nn_layer_ref(w: &[f32], x: &[f32], beta: &[f32], b: usize, i: usize, k: usize) -> Vec<f32> {
    let mut y = vec![0f64; b * k];
    for bb in 0..b {
        for kk in 0..k {
            let mut acc = 0f64;
            for ii in 0..i {
                acc += x[bb * i + ii] as f64 * w[ii * k + kk] as f64;
            }
            y[bb * k + kk] = acc + beta[kk] as f64;
        }
    }
    // batch-norm per feature over the batch, then tanh
    let mut out = vec![0f32; b * k];
    for kk in 0..k {
        let col: Vec<f64> = (0..b).map(|bb| y[bb * k + kk]).collect();
        let mean = col.iter().sum::<f64>() / b as f64;
        let var = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / b as f64;
        for bb in 0..b {
            out[bb * k + kk] = ((y[bb * k + kk] - mean) / (var + 1e-5).sqrt()).tanh() as f32;
        }
    }
    out
}

fn main() -> hofdla::Result<()> {
    let artifact = "nn_layer_32x64x128";
    if !hofdla::runtime::artifact_path(artifact).exists() {
        eprintln!("artifact '{artifact}' missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let (batch, i, k) = (32usize, 64, 128);
    let mut rng = Rng::new(11);
    let w: Vec<f32> = (0..i * k).map(|_| rng.range_f64(-0.5, 0.5) as f32).collect();
    let x: Vec<f32> = (0..batch * i).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let beta: Vec<f32> = (0..k).map(|_| rng.range_f64(-0.1, 0.1) as f32).collect();

    let c = Coordinator::start(Config::default())?;
    let t = std::time::Instant::now();
    let Response::Executed { output } = c.call(Request::ExecArtifact {
        name: artifact.into(),
        inputs: vec![
            (w.clone(), vec![i, k]),
            (x.clone(), vec![batch, i]),
            (beta.clone(), vec![k]),
        ],
    })?
    else {
        unreachable!()
    };
    let dt = t.elapsed();

    let reference = nn_layer_ref(&w, &x, &beta, batch, i, k);
    let max_err = output
        .iter()
        .zip(&reference)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!(
        "fused dense+batchnorm+tanh layer [{batch}x{i}] @ [{i}x{k}]: served in {dt:?}, \
         max |err| vs rust reference = {max_err:.2e}"
    );
    assert!(max_err < 1e-3, "fused kernel diverges from reference");

    // Throughput through the batching path.
    let reqs = 32;
    let t = std::time::Instant::now();
    let handles: Vec<_> = (0..reqs)
        .map(|_| {
            c.submit(Request::ExecArtifact {
                name: artifact.into(),
                inputs: vec![
                    (w.clone(), vec![i, k]),
                    (x.clone(), vec![batch, i]),
                    (beta.clone(), vec![k]),
                ],
            })
            .unwrap()
        })
        .collect();
    for h in handles {
        let Response::Executed { output } = h.wait()? else {
            unreachable!()
        };
        assert_eq!(output.len(), batch * k);
    }
    let dt = t.elapsed();
    println!(
        "{reqs} batched requests in {dt:?} ({:.0} req/s); {}",
        reqs as f64 / dt.as_secs_f64(),
        c.metrics.summary()
    );
    Ok(())
}
