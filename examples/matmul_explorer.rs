//! Matmul explorer: enumerate every rearrangement of the (optionally
//! subdivided) matrix product, rank them three ways — analytical cost
//! model, simulated cache hierarchy, and measured wallclock — and show how
//! well the cheap predictors track reality.
//!
//! Run: `cargo run --release --example matmul_explorer -- [n] [b]`

use hofdla::bench_support::{bench, fmt_duration, BenchConfig};
use hofdla::cachesim::{simulate, HierarchyConfig};
use hofdla::costmodel::estimate;
use hofdla::enumerate::{enumerate_all, starts};
use hofdla::exec::{execute, lower, order_inputs};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;
use hofdla::util::Rng;

fn main() -> hofdla::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(192);
    let b: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(16);

    let env = Env::new()
        .with("A", Layout::row_major(&[n, n]))
        .with("B", Layout::row_major(&[n, n]));
    let ctx = Ctx::new(env.clone());

    let mut rng = Rng::new(3);
    let a = rng.fill_vec(n * n);
    let bm = rng.fill_vec(n * n);

    for (name, start) in [
        ("naive (Table 1)", starts::matmul_naive_variant()),
        (
            "rnz subdivided (Table 2)",
            starts::matmul_rnz_subdivided_variant(b),
        ),
    ] {
        println!("\n##### family: {name}, n={n}, b={b}");
        let variants = enumerate_all(&start, &ctx, 4096)?;
        println!(
            "{:<26} {:>10} {:>12} {:>12} {:>10}",
            "HoF order", "cost", "sim Mcycles", "L1 miss%", "time"
        );
        let mut rows: Vec<(String, f64, f64, f64, std::time::Duration)> = Vec::new();
        for v in &variants {
            let prog = lower(&v.expr, &env)?;
            let cost = estimate(&prog).score();
            let sim = simulate(&prog, &HierarchyConfig::cpu_i5_7300hq())?;
            let bufs = order_inputs(&prog, &[("A", &a), ("B", &bm)])?;
            let mut out = vec![0.0; prog.out_size];
            let t = bench(&v.display_key(), &BenchConfig::quick(), || {
                execute(&prog, &bufs, &mut out).unwrap();
                std::hint::black_box(&out);
            });
            rows.push((
                v.display_key(),
                cost,
                sim.cost_cycles() / 1e6,
                100.0 * sim.levels[0].miss_ratio(),
                t.median,
            ));
        }
        rows.sort_by_key(|r| r.4);
        for (key, cost, mcyc, miss, time) in &rows {
            println!(
                "{key:<26} {cost:>10.0} {mcyc:>12.1} {miss:>11.2}% {:>10}",
                fmt_duration(*time)
            );
        }
        // Rank agreement: does the cost model pick the measured winner's
        // neighbourhood?
        let measured_best = &rows[0].0;
        let mut by_cost = rows.clone();
        by_cost.sort_by(|x, y| x.1.total_cmp(&y.1));
        let cost_rank = by_cost.iter().position(|r| &r.0 == measured_best).unwrap();
        println!(
            "measured winner '{measured_best}' is rank {} of {} under the cost model",
            cost_rank + 1,
            by_cost.len()
        );
    }
    Ok(())
}
