//! Quickstart: build a DSL expression, fuse it, exchange it, execute both
//! forms and check they agree.
//!
//! Run: `cargo run --release --example quickstart`

use hofdla::dsl::{self, parse, pretty};
use hofdla::exec::run;
use hofdla::layout::Layout;
use hofdla::rewrite::{exchange, fusion, normalize, Ctx};
use hofdla::typecheck::{infer, Env};
use hofdla::util::Rng;

fn main() -> hofdla::Result<()> {
    // 1. A matrix-vector product with a fusable pipeline inside
    //    (paper eq 1 flavour): u_i = Σ_j A_ij * (v_j + w_j)
    let src = "(map (lam (r) (rnz + * r (zip + (in v) (in w)))) (in A))";
    let expr = parse(src)?;
    println!("source:     {}", pretty(&expr));

    // 2. Shapes live in the environment; the typechecker verifies extents.
    let (n, m) = (6usize, 8);
    let env = Env::new()
        .with("A", Layout::row_major(&[n, m]))
        .with("v", Layout::row_major(&[m]))
        .with("w", Layout::row_major(&[m]));
    let ty = infer(&expr, &env)?;
    println!("type:       {ty}");

    // 3. Fusion eliminates the temporary vector (paper eq 27-28).
    let fused = fusion::fuse(&expr);
    println!("fused:      {}", pretty(&fused));

    // 4. The map-rnz exchange (paper eq 42) flips the traversal: columns
    //    of A scaled and accumulated — note the flip and the lifted (+).
    let ctx = Ctx::new(env.clone());
    let flipped = normalize(&exchange::map_rnz(&fused, &ctx).expect("exchange applies"));
    println!("exchanged:  {}", pretty(&flipped));

    // 5. Execute both forms natively and compare.
    let mut rng = Rng::new(1);
    let a = rng.fill_vec(n * m);
    let v = rng.fill_vec(m);
    let w = rng.fill_vec(m);
    let inputs: &[(&str, &[f64])] = &[("A", &a), ("v", &v), ("w", &w)];
    let out1 = run(&fused, &env, inputs)?;
    let out2 = run(&flipped, &env, inputs)?;
    assert!(hofdla::util::allclose(&out1, &out2, 1e-12));
    println!("row-form and column-form agree: {out1:.3?}");

    // 6. The same expression can also be built with combinators:
    let built = dsl::map(
        dsl::lam1(
            "r",
            dsl::rnz(
                dsl::add(),
                dsl::mul(),
                vec![
                    dsl::var("r"),
                    dsl::zip(dsl::add(), dsl::input("v"), dsl::input("w")),
                ],
            ),
        ),
        dsl::input("A"),
    );
    assert!(built.alpha_eq(&expr));
    println!("combinator construction is alpha-equivalent to the parse");
    Ok(())
}
