//! END-TO-END DRIVER (DESIGN.md §6): the full system on a real workload.
//!
//! Pipeline: parse the textbook matmul from DSL source → typecheck → fuse
//! → subdivide the reduction (b=16) → enumerate all rearrangements via
//! exchange rules → early-cut with the analytical cost model → rank
//! survivors with the cache simulator → execute naive vs best natively
//! (wallclock) → cross-check numerics against the AOT XLA artifact through
//! PJRT → report the naive/best speedup (the paper's headline: >25× from
//! 4.9 s to 186 ms at 1024²).
//!
//! Run: `cargo run --release --example e2e_pipeline -- [n]`   (default 512,
//! paper setting: 1024; requires `make artifacts` for the PJRT cross-check
//! at n=256).

use hofdla::baselines;
use hofdla::bench_support::{bench, fmt_duration, BenchConfig};
use hofdla::cachesim::{simulate, HierarchyConfig};
use hofdla::coordinator::{optimize, OptimizeSpec, RankBy};
use hofdla::costmodel::estimate;
use hofdla::enumerate::{enumerate_all, starts};
use hofdla::exec::{execute, lower, order_inputs};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;
use hofdla::util::Rng;

fn main() -> hofdla::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(512);
    let b = 16usize;
    println!("== hofdla end-to-end pipeline: {n}x{n} f64 matmul, block {b} ==\n");

    // ---- 1. Front end: parse + typecheck + fuse + subdivide + enumerate,
    //         through the same service pipeline the coordinator runs.
    let src = "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))";
    let spec = OptimizeSpec::builder(src)
        .input("A", &[n, n])
        .input("B", &[n, n])
        .rank_by(RankBy::CostModel)
        .subdivide_rnz(b)
        .verify(true)
        .build()?;
    let t = std::time::Instant::now();
    let report = optimize(&spec)?;
    println!(
        "[1] optimization pipeline: {} rearrangements in {:?}; cost-model best: {}",
        report.variants_explored,
        t.elapsed(),
        report.best
    );

    // ---- 2. Enumerate explicitly for the measurement phase (labels in
    //         the paper's mapA/mapB form).
    let env = Env::new()
        .with("A", Layout::row_major(&[n, n]))
        .with("B", Layout::row_major(&[n, n]));
    let ctx = Ctx::new(env.clone());
    let variants = enumerate_all(&starts::matmul_rnz_subdivided_variant(b), &ctx, 4096)?;

    // ---- 3. Early cut: keep the top half by analytical cost.
    let mut scored: Vec<_> = variants
        .iter()
        .map(|v| {
            let prog = lower(&v.expr, &env).expect("lower");
            (estimate(&prog).score(), v)
        })
        .collect();
    scored.sort_by(|a, b| a.0.total_cmp(&b.0));
    let kept = &scored[..scored.len().div_ceil(2)];
    println!(
        "[2] early cut: kept {}/{} candidates by cost model",
        kept.len(),
        scored.len()
    );

    // ---- 4. Cache-simulated ranking of the survivors (at a traceable
    //         size, scaled hierarchy).
    let sim_n = n.min(128);
    let sim_env = Env::new()
        .with("A", Layout::row_major(&[sim_n, sim_n]))
        .with("B", Layout::row_major(&[sim_n, sim_n]));
    let factor = ((n / sim_n).max(1)).pow(2);
    let mut simmed: Vec<(f64, &hofdla::enumerate::Variant)> = Vec::new();
    for (_, v) in kept {
        let prog = lower(&v.expr, &sim_env)?;
        let r = simulate(&prog, &HierarchyConfig::scaled(factor))?;
        simmed.push((r.cost_cycles(), v));
    }
    simmed.sort_by(|a, b| a.0.total_cmp(&b.0));
    let best = simmed[0].1;
    println!("[3] cache-sim winner: {}", best.display_key());

    // ---- 5. Measure: naive form vs selected rearrangement, native.
    let mut rng = Rng::new(42);
    let a = rng.fill_vec(n * n);
    let bmat = rng.fill_vec(n * n);
    let cfg = BenchConfig::quick();

    let naive_prog = lower(&starts::matmul_naive_variant().expr, &env)?;
    let naive_bufs = order_inputs(&naive_prog, &[("A", &a), ("B", &bmat)])?;
    let mut naive_out = vec![0.0; n * n];
    let naive_t = bench("naive", &cfg, || {
        execute(&naive_prog, &naive_bufs, &mut naive_out).unwrap();
        std::hint::black_box(&naive_out);
    });

    let best_prog = lower(&best.expr, &env)?;
    let best_bufs = order_inputs(&best_prog, &[("A", &a), ("B", &bmat)])?;
    let mut best_out = vec![0.0; n * n];
    let best_t = bench(&best.display_key(), &cfg, || {
        execute(&best_prog, &best_bufs, &mut best_out).unwrap();
        std::hint::black_box(&best_out);
    });

    // correctness of the selected variant (transpose-aware)
    let ct = baselines::transpose(&naive_out, n, n);
    let ok = hofdla::util::allclose(&best_out, &naive_out, 1e-6 * n as f64)
        || hofdla::util::allclose(&best_out, &ct, 1e-6 * n as f64)
        || {
            let mut x = best_out.clone();
            let mut y = naive_out.clone();
            x.sort_by(f64::total_cmp);
            y.sort_by(f64::total_cmp);
            hofdla::util::allclose(&x, &y, 1e-6 * n as f64)
        };
    assert!(ok, "selected variant numerics diverge");

    let speedup = naive_t.median.as_secs_f64() / best_t.median.as_secs_f64();
    println!(
        "[4] measured: naive {} vs best ({}) {} → {:.1}x speedup (paper: >25x at 1024²)",
        fmt_duration(naive_t.median),
        best.display_key(),
        fmt_duration(best_t.median),
        speedup
    );

    // ---- 6. Native hand-written baselines for calibration.
    let mut cbuf = vec![0.0; n * n];
    let nb = bench("naive rust", &cfg, || {
        baselines::naive_matmul(&a, &bmat, &mut cbuf, n, n, n);
        std::hint::black_box(&cbuf);
    });
    let bb = bench("blocked rust", &cfg, || {
        baselines::blocked_matmul(&a, &bmat, &mut cbuf, n, n, n, 64);
        std::hint::black_box(&cbuf);
    });
    println!(
        "[5] native baselines: naive {} | blocked {}",
        fmt_duration(nb.median),
        fmt_duration(bb.median)
    );

    // ---- 7. Cross-check against the AOT artifact through PJRT (the
    //         vendor-library path; artifacts are built at 256).
    let art = "matmul_xla_256";
    if hofdla::runtime::artifact_path(art).exists() && hofdla::runtime::pjrt_available() {
        let an = 256usize;
        let mut rt = hofdla::runtime::Runtime::cpu()?;
        let exe = rt.load(&hofdla::runtime::artifact_path(art))?;
        let mut r2 = Rng::new(9);
        let af: Vec<f32> = (0..an * an).map(|_| r2.range_f64(-1.0, 1.0) as f32).collect();
        let bf: Vec<f32> = (0..an * an).map(|_| r2.range_f64(-1.0, 1.0) as f32).collect();
        let xla_out = rt.run_f32(&exe, &[(&af, &[an, an]), (&bf, &[an, an])])?;
        let a64: Vec<f64> = af.iter().map(|&x| x as f64).collect();
        let b64: Vec<f64> = bf.iter().map(|&x| x as f64).collect();
        let small_env = Env::new()
            .with("A", Layout::row_major(&[an, an]))
            .with("B", Layout::row_major(&[an, an]));
        let ours = hofdla::exec::run(
            &starts::matmul_naive_variant().expr,
            &small_env,
            &[("A", &a64), ("B", &b64)],
        )?;
        let max_err = ours
            .iter()
            .zip(&xla_out)
            .map(|(x, y)| (x - *y as f64).abs())
            .fold(0.0f64, f64::max);
        println!("[6] PJRT cross-check vs {art}: max |err| = {max_err:.2e}");
        assert!(max_err < 1e-2, "interpreter vs XLA numerics diverge");
        let xt = bench(art, &cfg, || {
            let o = rt
                .run_f32(&exe, &[(&af, &[an, an]), (&bf, &[an, an])])
                .unwrap();
            std::hint::black_box(o);
        });
        println!("    XLA artifact time at 256²: {}", fmt_duration(xt.median));
    } else {
        println!("[6] (artifacts not built or PJRT unavailable — skipping cross-check)");
    }

    println!("\n== e2e pipeline complete ==");
    Ok(())
}
