"""AOT pipeline: lower every Layer-2 model to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py.

Usage (from python/): ``python -m compile.aot --out-dir ../artifacts``
Idempotent: artifacts are only rewritten when the HLO changes.
"""

import argparse
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, arg_specs) -> str:
    """Lower a jax function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: pathlib.Path, n: int) -> list:
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for name, (fn, arg_specs) in model.specs(n).items():
        path = out_dir / f"{name}.hlo.txt"
        text = to_hlo_text(fn, arg_specs)
        if path.exists() and path.read_text() == text:
            written.append((name, path, "unchanged"))
            continue
        path.write_text(text)
        written.append((name, path, "written"))
    return written


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    p.add_argument("--n", type=int, default=256, help="square matmul size")
    args = p.parse_args(argv)
    out_dir = pathlib.Path(args.out_dir)
    for name, path, status in build(out_dir, args.n):
        print(f"{status:>9}  {name:<28} -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
