"""Layer-2: the JAX compute graphs that the AOT pipeline lowers.

Each model is a jitted function calling the Layer-1 Pallas kernels; the
whole graph (kernel included, thanks to ``interpret=True``) lowers into a
single HLO module per variant, which the rust runtime loads and executes.
Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import fused, matmul, ref


def matmul_xla(a, b):
    """The vendor-library baseline (the paper's Eigen role): XLA's own dot."""
    return (jnp.dot(a, b),)


def matmul_pallas(a, b, *, bm=32, bk=32, bn=32):
    """The paper's blocked matmul as a Pallas grid (subdivided spine)."""
    return (matmul.matmul(a, b, bm=bm, bk=bk, bn=bn),)


def fused_matvec(a, b, v, u):
    """Paper eq 1, fused end to end."""
    return (fused.fused_matvec_eq1(a, b, v, u),)


def weighted_matmul(a, b, g):
    """Paper eq 2, fused end to end."""
    return (fused.weighted_matmul_eq2(a, b, g),)


def nn_layer(w, x, beta):
    """Paper eq 3-5, the fused dense + batchnorm + tanh layer."""
    return (fused.nn_layer_eq345(w, x, beta),)


def tensor_contraction(a, b, c, g, f):
    """Paper eq 7 (pure XLA; the contraction structure is the point)."""
    return (ref.tensor_contraction_eq7(a, b, c, g, f),)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def specs(n=256):
    """The artifact catalogue: name → (function, example argument specs).

    ``n`` is the square matmul size; the fused examples use fixed small
    shapes matching the rust integration tests and examples.
    """
    return {
        f"matmul_xla_{n}": (matmul_xla, (f32(n, n), f32(n, n))),
        f"matmul_pallas_{n}": (matmul_pallas, (f32(n, n), f32(n, n))),
        "fused_matvec_64x96": (fused_matvec, (f32(64, 96), f32(64, 96), f32(96), f32(96))),
        "weighted_matmul_64": (weighted_matmul, (f32(64, 64), f32(64, 64), f32(64))),
        "nn_layer_32x64x128": (nn_layer, (f32(64, 128), f32(32, 64), f32(128))),
        "tensor_contraction_8": (
            tensor_contraction,
            (f32(8, 8, 8), f32(8, 8), f32(8, 8), f32(8), f32(8)),
        ),
    }
