"""Layer-1 Pallas kernels for the paper's motivating fusion examples (§2).

Each kernel fuses a whole pipeline into a single traversal — the DSL-side
``nzip``/``rnz`` fusion rules (eq 24-28) performed here at the Pallas
level, so the rust runtime can execute the fused artifacts the same way
the interpreter executes the fused DSL forms.

All kernels use ``interpret=True`` (CPU PJRT cannot run Mosaic
custom-calls; see ``matmul.py``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_matvec_kernel(a_ref, b_ref, v_ref, u_ref, o_ref):
    """Paper eq 1 in one pass: w = (A + B) (v + u), row block at a time."""
    vu = v_ref[...] + u_ref[...]
    o_ref[...] = (a_ref[...] + b_ref[...]) @ vu


@functools.partial(jax.jit, static_argnames=("bm",))
def fused_matvec_eq1(a, b, v, u, *, bm=32):
    """w_i = sum_j (A_ij + B_ij)(v_j + u_j); a, b: [m, j]; v, u: [j]."""
    m, j = a.shape
    assert m % bm == 0, f"bm={bm} must divide m={m}"
    return pl.pallas_call(
        _fused_matvec_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, j), lambda i: (i, 0)),
            pl.BlockSpec((bm, j), lambda i: (i, 0)),
            pl.BlockSpec((j,), lambda i: (0,)),
            pl.BlockSpec((j,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, b, v, u)


def _weighted_matmul_kernel(a_ref, b_ref, g_ref, o_ref):
    """Paper eq 2: one (i,k) tile of C = (A ⊙ g) B, full-j blocks."""
    o_ref[...] = (a_ref[...] * g_ref[...][None, :]) @ b_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def weighted_matmul_eq2(a, b, g, *, bm=32, bn=32):
    """C_ik = sum_j A_ij B_jk g_j; a: [m, j], b: [j, n], g: [j]."""
    m, j = a.shape
    j2, n = b.shape
    assert j == j2
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        _weighted_matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, j), lambda i, k: (i, 0)),
            pl.BlockSpec((j, bn), lambda i, k: (0, k)),
            pl.BlockSpec((j,), lambda i, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b, g)


def _nn_layer_kernel(w_ref, x_ref, beta_ref, o_ref, *, eps):
    """Paper eq 3-5 fused: dense + batch-norm + tanh for one feature block.

    The grid splits the feature (k) dimension; the batch statistics E/V
    are per-feature over the full batch, so each grid step sees the whole
    batch (x) and one block of W columns — the low-arithmetic-density
    normalisation and nonlinearity never touch memory as separate passes.
    """
    y = x_ref[...] @ w_ref[...] + beta_ref[...][None, :]
    mean = jnp.mean(y, axis=0, keepdims=True)
    var = jnp.mean((y - mean) ** 2, axis=0, keepdims=True)
    o_ref[...] = jnp.tanh((y - mean) * jax.lax.rsqrt(var + eps))


@functools.partial(jax.jit, static_argnames=("bk", "eps"))
def nn_layer_eq345(w, x, beta, *, bk=32, eps=1e-5):
    """r = tanh(batchnorm(x @ w + beta)); w: [i, k], x: [b, i], beta: [k]."""
    i, k = w.shape
    b, i2 = x.shape
    assert i == i2
    assert k % bk == 0
    return pl.pallas_call(
        functools.partial(_nn_layer_kernel, eps=eps),
        grid=(k // bk,),
        in_specs=[
            pl.BlockSpec((i, bk), lambda kb: (0, kb)),
            pl.BlockSpec((b, i), lambda kb: (0, 0)),
            pl.BlockSpec((bk,), lambda kb: (kb,)),
        ],
        out_specs=pl.BlockSpec((b, bk), lambda kb: (0, kb)),
        out_shape=jax.ShapeDtypeStruct((b, k), x.dtype),
        interpret=True,
    )(w, x, beta)
