"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels (and, transitively, the AOT
artifacts executed from rust) are validated against. Each mirrors one of
the paper's motivating computations (§2, eq 1-7) or the matmul evaluation
workload (§4).
"""

import jax.numpy as jnp


def matmul(a, b):
    """C[i,k] = sum_j A[i,j] B[j,k] — the paper's eq 50 workload."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def fused_matvec_eq1(a, b, v, u):
    """Paper eq 1: w_i = sum_j (A_ij + B_ij) * (v_j + u_j).

    The point of the DSL's fusion rules: a single traversal, no
    temporaries.
    """
    return (a + b) @ (v + u)


def weighted_matmul_eq2(a, b, g):
    """Paper eq 2/6: C_ik = sum_j A_ij * B_jk * g_j."""
    return (a * g[None, :]) @ b


def nn_layer_eq345(w, x, beta, eps=1e-5):
    """Paper eq 3-5: dense transform + batch normalization + nonlinearity.

    y_k^b = sum_i W_ik x_i^b + beta_k           (eq 3)
    z_k   = (y_k^b - E[y^b]) / sqrt(V[y^b]+eps) (eq 4)
    r_k   = tanh(z_k)                           (eq 5)

    x: [batch, in], w: [in, out], beta: [out] → r: [batch, out].
    E/V are taken over the batch dimension, per feature.
    """
    y = x @ w + beta[None, :]
    mean = jnp.mean(y, axis=0, keepdims=True)
    var = jnp.var(y, axis=0, keepdims=True)
    z = (y - mean) / jnp.sqrt(var + eps)
    return jnp.tanh(z)


def tensor_contraction_eq7(a, b, c, g, f):
    """Paper eq 7: C_ipq = sum_jk A_ijk B_jp C_kq g_j f_k.

    The PDE-style multi-index contraction motivating hierarchical
    partitioning.
    """
    t = a * g[None, :, None] * f[None, None, :]  # [i, j, k]
    return jnp.einsum("ijk,jp,kq->ipq", t, b, c)
