"""Layer-1 Pallas kernel: tiled matrix multiplication.

This is the paper's subdivision insight expressed in TPU terms (DESIGN.md
§5, Hardware adaptation): the DSL's ``subdiv d b`` of the HoF spine
corresponds one-to-one to the ``BlockSpec`` grid tiling here —

- subdividing the two maps (rows of A / columns of B) → the ``(i, k)``
  grid with ``(bm, bn)`` output tiles staged in VMEM;
- subdividing the ``rnz`` (the j reduction) → the ``j`` grid dimension
  with a VMEM accumulator carried across grid steps.

The block sizes ``(bm, bk, bn)`` are exactly the paper's block size ``b``,
exposed as parameters so the rust coordinator can select variants the same
way the enumerator selects subdivided spines.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the AOT
artifacts need (and numerics are identical).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k_blocks):
    """One (i, k, j) grid step: o += a_tile @ b_tile, zero-init at j == 0."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )
    del n_k_blocks


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(a, b, *, bm=32, bk=32, bn=32):
    """Tiled ``a @ b`` via Pallas. Shapes must divide by the block sizes.

    a: [m, k], b: [k, n] → [m, n]; float32.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"block sizes ({bm},{bk},{bn}) must divide shapes ({m},{k},{n})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def vmem_footprint_bytes(bm, bk, bn, dtype_bytes=4):
    """Estimated VMEM residency of one grid step: an A tile, a B tile and
    the output accumulator tile. Used by DESIGN.md §Perf to pick block
    sizes under the ~16 MiB/core VMEM budget."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(bm, bk, bn, mxu=128):
    """Fraction of MXU lanes a (bm, bk)×(bk, bn) tile occupies — 1.0 when
    every tile dimension is a multiple of the 128×128 systolic array."""
    def frac(d):
        return min(1.0, d / mxu) if d % mxu else 1.0
    return min(frac(bm), frac(bk), frac(bn))
