"""Layer-1 Pallas kernels (build-time only; never on the request path)."""

from . import fused, matmul, ref  # noqa: F401
