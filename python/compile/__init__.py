"""Build-time compile path: JAX/Pallas -> HLO-text artifacts."""
