"""AOT pipeline tests: every model lowers to parseable HLO text, and the
artifact build is idempotent."""

import pathlib

import numpy as np
import pytest

from compile import aot, model


@pytest.mark.parametrize("name", list(model.specs(n=32).keys()))
def test_every_model_lowers_to_hlo_text(name):
    fn, arg_specs = model.specs(n=32)[name]
    text = aot.to_hlo_text(fn, arg_specs)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # interpret-mode pallas must not leave TPU custom-calls behind
    assert "mosaic" not in text.lower()


def test_lowered_matmul_executes_correctly_in_jax():
    # The HLO we ship corresponds to a function whose jax execution matches
    # the oracle — executed here once as an end-to-end sanity check.
    fn, _ = model.specs(n=32)["matmul_pallas_32"]
    rng = np.random.default_rng(1)
    a = rng.standard_normal((32, 32)).astype(np.float32)
    b = rng.standard_normal((32, 32)).astype(np.float32)
    (got,) = fn(a, b)
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


def test_build_is_idempotent(tmp_path: pathlib.Path):
    first = aot.build(tmp_path, n=32)
    assert all(status == "written" for _, _, status in first)
    second = aot.build(tmp_path, n=32)
    assert all(status == "unchanged" for _, _, status in second)
    names = {p.name for _, p, _ in second}
    assert f"matmul_xla_32.hlo.txt" in names
    assert all((tmp_path / n).stat().st_size > 200 for n in names)
