"""Layer-1 correctness: every Pallas kernel against its pure-jnp oracle.

hypothesis sweeps shapes and block sizes; fixed-seed numpy draws values.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused, matmul, ref

RNG = np.random.default_rng(0)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------- matmul

BLOCKS = st.sampled_from([1, 2, 4, 8])
MULTIPLES = st.integers(min_value=1, max_value=4)


@settings(max_examples=20, deadline=None)
@given(bm=BLOCKS, bk=BLOCKS, bn=BLOCKS, mi=MULTIPLES, ki=MULTIPLES, ni=MULTIPLES)
def test_matmul_kernel_matches_ref_across_shapes(bm, bk, bn, mi, ki, ni):
    m, k, n = bm * mi, bk * ki, bn * ni
    a, b = rand(m, k), rand(k, n)
    got = matmul.matmul(a, b, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-5, atol=1e-5)


def test_matmul_kernel_rejects_indivisible_blocks():
    with pytest.raises(AssertionError):
        matmul.matmul(rand(6, 6), rand(6, 6), bm=4, bk=2, bn=2)


def test_matmul_block_sweep_fixed_shape():
    a, b = rand(32, 32), rand(32, 32)
    want = ref.matmul(a, b)
    for bs in [4, 8, 16, 32]:
        got = matmul.matmul(a, b, bm=bs, bk=bs, bn=bs)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_vmem_footprint_and_mxu_model():
    # 3 tiles of 128² f32 = 192 KiB — well inside the 16 MiB budget
    assert matmul.vmem_footprint_bytes(128, 128, 128) == 3 * 128 * 128 * 4
    assert matmul.mxu_utilization(128, 128, 128) == 1.0
    assert matmul.mxu_utilization(32, 128, 128) == pytest.approx(0.25)


# ----------------------------------------------------------- fused eq 1


@settings(max_examples=10, deadline=None)
@given(bm=st.sampled_from([1, 2, 4]), mi=MULTIPLES, j=st.integers(2, 24))
def test_fused_matvec_eq1(bm, mi, j):
    m = bm * mi
    a, b, v, u = rand(m, j), rand(m, j), rand(j), rand(j)
    got = fused.fused_matvec_eq1(a, b, v, u, bm=bm)
    np.testing.assert_allclose(
        got, ref.fused_matvec_eq1(a, b, v, u), rtol=1e-5, atol=1e-5
    )


# ----------------------------------------------------------- fused eq 2


@settings(max_examples=10, deadline=None)
@given(bm=st.sampled_from([1, 2, 4]), bn=st.sampled_from([1, 2, 4]),
       mi=MULTIPLES, ni=MULTIPLES, j=st.integers(2, 16))
def test_weighted_matmul_eq2(bm, bn, mi, ni, j):
    m, n = bm * mi, bn * ni
    a, b, g = rand(m, j), rand(j, n), rand(j)
    got = fused.weighted_matmul_eq2(a, b, g, bm=bm, bn=bn)
    np.testing.assert_allclose(
        got, ref.weighted_matmul_eq2(a, b, g), rtol=1e-4, atol=1e-5
    )


# --------------------------------------------------------- fused eq 3-5


@settings(max_examples=10, deadline=None)
@given(bk=st.sampled_from([1, 2, 4]), ki=MULTIPLES,
       b=st.integers(2, 12), i=st.integers(2, 16))
def test_nn_layer_eq345(bk, ki, b, i):
    k = bk * ki
    w, x, beta = rand(i, k), rand(b, i), rand(k)
    got = fused.nn_layer_eq345(w, x, beta, bk=bk)
    np.testing.assert_allclose(
        got, ref.nn_layer_eq345(w, x, beta), rtol=1e-4, atol=1e-4
    )


def test_nn_layer_output_is_normalized():
    # batch-norm property: tanh-input per-feature mean ≈ 0
    w, x, beta = rand(16, 8), rand(64, 16), rand(8)
    z = np.arctanh(np.clip(np.asarray(fused.nn_layer_eq345(w, x, beta, bk=8)), -0.999999, 0.999999))
    np.testing.assert_allclose(z.mean(axis=0), 0.0, atol=1e-2)


# ------------------------------------------------------------------ eq 7


def test_tensor_contraction_eq7_against_loops():
    i, j, k, p, q = 3, 4, 5, 2, 3
    a, b, c = rand(i, j, k), rand(j, p), rand(k, q)
    g, f = rand(j), rand(k)
    want = np.zeros((i, p, q), dtype=np.float64)
    for ii in range(i):
        for jj in range(j):
            for kk in range(k):
                for pp in range(p):
                    for qq in range(q):
                        want[ii, pp, qq] += (
                            a[ii, jj, kk] * b[jj, pp] * c[kk, qq] * g[jj] * f[kk]
                        )
    got = ref.tensor_contraction_eq7(a, b, c, g, f)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
