//! Paper §4 GPU note: naive vs the all-subdivided `mapA mapB rnz mapA
//! mapB rnz` arrangement on a GPU-like (HD7970-class) cache hierarchy.
//! The paper reports ~40% improvement; we compare simulated memory cost.
use hofdla::bench_support::env_size;

fn main() {
    let n = env_size(256).min(512);
    let e = hofdla::experiments::gpu_sim(n, 16).expect("gpu_sim");
    print!("{}", e.render());
    let rows = e.sorted_rows();
    let naive = e.rows[0].sim.as_ref().unwrap().cost_cycles();
    let tiled = e.rows[1].sim.as_ref().unwrap().cost_cycles();
    println!(
        "tiled/naive memory-cost ratio: {:.2} (paper: ~0.6 on HD7970)",
        tiled / naive
    );
    let _ = rows;
}
