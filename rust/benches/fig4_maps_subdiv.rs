//! Paper Figure 4: rearrangements with the two maps subdivided — the
//! paper's finding: no improvement over the naive form.
use hofdla::experiments::{self, MatmulOpts};

fn main() {
    // Default smaller than the paper's 1024: this family has many
    // variants; HOFDLA_N overrides.
    let mut opts = MatmulOpts::default();
    if std::env::var("HOFDLA_N").is_err() {
        opts.n = 256;
    }
    if opts.n % (opts.b * opts.b) != 0 {
        opts.b = 4;
    }
    let e = experiments::fig4(&opts).expect("fig4");
    print!("{}", e.render());
}
