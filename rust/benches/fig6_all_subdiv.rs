//! Paper Figure 6: rearrangements with every HoF subdivided once — the
//! paper's finding: no gain over subdividing just the reduction.
use hofdla::experiments::{self, MatmulOpts};

fn main() {
    // Default smaller than the paper's 1024: this family has many
    // variants; HOFDLA_N overrides.
    let mut opts = MatmulOpts::default();
    if std::env::var("HOFDLA_N").is_err() {
        opts.n = 256;
    }
    if opts.n % (opts.b * opts.b) != 0 {
        opts.b = 4;
    }
    let e = experiments::fig6(&opts).expect("fig6");
    print!("{}", e.render());
}
