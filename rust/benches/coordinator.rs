//! Coordinator throughput: optimize-job latency and artifact-execution
//! batching overhead (L3 §Perf driver).
use hofdla::bench_support::{bench, fmt_duration, BenchConfig};
use hofdla::coordinator::{Config, Coordinator, OptimizeSpec, RankBy, Request, Response};

fn main() {
    let c = Coordinator::start(Config::default()).expect("start");
    let spec = OptimizeSpec {
        source: "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))"
            .into(),
        inputs: vec![("A".into(), vec![64, 64]), ("B".into(), vec![64, 64])],
        rank_by: RankBy::CostModel,
        subdivide_rnz: None,
        top_k: 6,
    };
    let cfg = BenchConfig::quick();
    let m = bench("optimize 64x64 (cost model)", &cfg, || {
        let Response::Optimized(r) = c.call(Request::Optimize(spec.clone())).unwrap() else {
            unreachable!()
        };
        std::hint::black_box(r.variants_explored);
    });
    println!("optimize-job median latency: {}", fmt_duration(m.median));

    // Pipelined submission throughput (the batching path).
    let t = std::time::Instant::now();
    let jobs = 64;
    let handles: Vec<_> = (0..jobs)
        .map(|_| c.submit(Request::Optimize(spec.clone())).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    let dt = t.elapsed();
    println!(
        "{} concurrent optimize jobs: {} total ({:.1} jobs/s); metrics: {}",
        jobs,
        fmt_duration(dt),
        jobs as f64 / dt.as_secs_f64(),
        c.metrics.summary()
    );

    if hofdla::runtime::artifact_path("matmul_xla_256").exists() {
        let n = 256usize;
        let a = vec![1f32; n * n];
        let mk = || Request::ExecArtifact {
            name: "matmul_xla_256".into(),
            inputs: vec![(a.clone(), vec![n, n]), (a.clone(), vec![n, n])],
        };
        let m = bench("exec artifact matmul_xla_256", &cfg, || {
            let Response::Executed { output } = c.call(mk()).unwrap() else {
                unreachable!()
            };
            std::hint::black_box(output.len());
        });
        println!("artifact exec median latency: {}", fmt_duration(m.median));
    }
}
