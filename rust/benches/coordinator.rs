//! Coordinator throughput: optimize-job latency and artifact-execution
//! batching overhead (L3 §Perf driver).
//!
//! The headline workload is the ISSUE 1 acceptance case: the subdivided
//! matmul (n=64, `subdivide_rnz: Some(4)`, Table 2's 12 rearrangements).
//! Three numbers are reported:
//!
//! - the *cold* pipeline latency (no result cache in front) — improved by
//!   the hash-consing arena + memoized normalize,
//! - the *warm* service latency — repeated traffic hits the coordinator's
//!   result LRU and never re-runs the pipeline,
//! - pipelined submission throughput over the worker pool.

use hofdla::bench_support::{bench, fmt_duration, BenchConfig};
use hofdla::coordinator::{self, Config, Coordinator, OptimizeSpec, RankBy, Request, Response};

fn subdivided_matmul_spec() -> OptimizeSpec {
    OptimizeSpec {
        source: "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))"
            .into(),
        inputs: vec![("A".into(), vec![64, 64]), ("B".into(), vec![64, 64])],
        rank_by: RankBy::CostModel,
        subdivide_rnz: Some(4),
        top_k: 12,
    }
}

fn main() {
    let cfg = BenchConfig::quick();
    let spec = subdivided_matmul_spec();

    // Cold path: the pipeline itself, bypassing the coordinator's LRU.
    let m = bench("pipeline optimize 64x64 subdiv=4 (cold)", &cfg, || {
        let r = coordinator::optimize(&spec).expect("optimize");
        std::hint::black_box(r.variants_explored);
    });
    println!("pipeline (cold) median latency: {}", fmt_duration(m.median));

    let c = Coordinator::start(Config::default()).expect("start");

    // Warm path: repeated identical service traffic short-circuits in the
    // result LRU.
    let m = bench("coordinator optimize (warm LRU)", &cfg, || {
        let Response::Optimized(r) = c.call(Request::Optimize(spec.clone())).expect("call")
        else {
            panic!("wrong response type")
        };
        std::hint::black_box(r.variants_explored);
    });
    println!("service (warm) median latency: {}", fmt_duration(m.median));

    // Pipelined submission throughput (the batching path).
    let t = std::time::Instant::now();
    let jobs = 64;
    let handles: Vec<_> = (0..jobs)
        .map(|_| c.submit(Request::Optimize(spec.clone())).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    let dt = t.elapsed();
    println!(
        "{} concurrent optimize jobs (subdivided matmul): {} total ({:.1} jobs/s); metrics: {}",
        jobs,
        fmt_duration(dt),
        jobs as f64 / dt.as_secs_f64(),
        c.metrics.summary()
    );

    if hofdla::runtime::artifact_path("matmul_xla_256").exists()
        && hofdla::runtime::pjrt_available()
    {
        let n = 256usize;
        let a = vec![1f32; n * n];
        let mk = || Request::ExecArtifact {
            name: "matmul_xla_256".into(),
            inputs: vec![(a.clone(), vec![n, n]), (a.clone(), vec![n, n])],
        };
        let m = bench("exec artifact matmul_xla_256", &cfg, || {
            let Response::Executed { output } = c.call(mk()).unwrap() else {
                panic!("wrong response type")
            };
            std::hint::black_box(output.len());
        });
        println!("artifact exec median latency: {}", fmt_duration(m.median));
    }
}
