//! Coordinator throughput: optimize-job latency and artifact-execution
//! batching overhead (L3 §Perf driver).
//!
//! The headline workload is the ISSUE 1/2 acceptance case: the subdivided
//! matmul (n=64, `subdivide_rnz: Some(4)`, Table 2's 12 rearrangements).
//! Four numbers are reported:
//!
//! - the *cold* pipeline latency (no result cache in front) — the
//!   id-native sharded search path, exhaustive mode,
//! - the *pruned* pipeline latency — same, with the branch-and-bound
//!   cost cut enabled,
//! - the *warm* service latency — repeated traffic hits the coordinator's
//!   result LRU and never re-runs the pipeline,
//! - the *warm-canonical* service latency — α-renamed resubmissions of
//!   cached traffic hit through the canonical key (ISSUE 8),
//! - the *coalesced* burst latency — 8 identical concurrent submissions
//!   against a flushed cache collapse onto one search (single-flight),
//! - a *service* load-generator phase (ISSUE 9): per-request p50/p99
//!   latency and the shed rate at 8 concurrent clients through the typed
//!   front door, once against the warm default service (`load` — the
//!   queue never saturates, shed must be 0) and once bursting 64
//!   distinct short-deadline jobs at a starved 1-worker / 2-slot service
//!   (`overload` — admission control must shed most of the burst),
//! - pipelined submission throughput over the worker pool.
//!
//! The cold/warm/warm_canonical/pruned/coalesced rows are also written to
//! `BENCH_coordinator.json` (schema v6, nanosecond medians), together
//! with a `sharing` block (hit split, coalesced count, canonical hit
//! rate, arena pool high-water), the `service` rows above, and an `exec`
//! block (ISSUE 10: serial vs certificate-gated threaded execution of the
//! shipped loop-nest families, with the parallel-loop count so an inert
//! certificate flags), so the perf trajectory — and the sharing +
//! admission + parallel-execution machinery staying live — is tracked
//! across PRs.

use hofdla::bench_support::{bench, fmt_duration, BenchConfig, Measurement};
use hofdla::coordinator::{self, Config, Coordinator, OptimizeSpec, RankBy, Request, Response};
use hofdla::enumerate::starts;
use hofdla::exec::{execute, execute_threaded, lower, order_inputs};
use hofdla::layout::Layout;
use hofdla::typecheck::Env;
use hofdla::Error;

fn subdivided_matmul_spec(prune: bool) -> OptimizeSpec {
    OptimizeSpec::builder(
        "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
    )
    .input("A", &[64, 64])
    .input("B", &[64, 64])
    .rank_by(RankBy::CostModel)
    .subdivide_rnz(4)
    .top_k(12)
    .prune(prune)
    // The cold row measures the production configuration, verifier
    // included, so its overhead is tracked by the perf lane.
    .verify(true)
    .build()
    .expect("headline spec is valid")
}

/// The same kernel with every binder α-renamed: keys identically to
/// [`subdivided_matmul_spec`] under the canonical key, so warm service
/// traffic using this spelling exercises the canonical (not exact) hit
/// path.
fn renamed_subdivided_matmul_spec() -> OptimizeSpec {
    let mut spec = subdivided_matmul_spec(false);
    spec.source = "(map (lam (rowOfA) (map (lam (colOfB) (rnz + * rowOfA colOfB)) \
         (flip 0 (in B)))) (in A))"
        .into();
    spec
}

/// Branch-and-bound effectiveness counters for the `search` block of the
/// JSON: the advisory perf lane watches `pruned_candidates` alongside the
/// pruned-vs-cold latency ratio, so the cut going inert (a cost-model
/// regression, not a wall-clock one) still flags.
struct SearchRow {
    pruned_candidates: usize,
    exhaustive_variants: usize,
    pruned_variants: usize,
}

/// Anytime quality at a truncated node budget: does the best-first search
/// already hold the exhaustive winner, and how tight is the certified gap?
/// Tracked per-budget so `compare_bench.py` can flag a budget level that
/// used to find the winner and no longer does (a priority-order
/// regression wall-clock rows would never catch).
struct AnytimeRow {
    budget: u64,
    frac: f64,
    certified_gap: f64,
    winner_found: bool,
    variants: usize,
}

/// Cross-request sharing effectiveness for the `sharing` block of the
/// JSON: the advisory perf lane watches `canonical_hit_rate` (α-renamed
/// resubmissions answered from the cache, expected 1.0) and `coalesced`
/// (identical concurrent submissions that waited on one search) so the
/// sharing machinery going inert flags even when wall-clock rows stay
/// flat on fast hardware.
struct SharingRow {
    exact_hits: u64,
    canonical_hits: u64,
    coalesced: u64,
    canonical_hit_rate: f64,
    arena_pool_high_water: u64,
}

/// Serial vs certificate-gated threaded execution of one shipped family
/// for the `exec` block of the JSON (schema v6). `parallel_loops` is the
/// threaded run's [`hofdla::exec::ExecReport::parallel_loops`]; the
/// advisory perf lane flags the block when every row reports 0 — an inert
/// certificate (the dependence analysis demoted everything, or the
/// executor stopped consulting it) that wall-clock rows on fast machines
/// would never catch.
struct ExecRow {
    family: &'static str,
    n: usize,
    serial_ns: u128,
    parallel_ns: u128,
    speedup: f64,
    parallel_loops: u64,
}

/// One load-generator scenario for the `service` block of the JSON
/// (schema v5): the per-request latency distribution and shed behaviour
/// of the typed front door under N concurrent clients. The advisory perf
/// lane watches the `load` row's tail (p50/p99, 3× threshold like the
/// medians) and flags `shed != 0` there, and flags `shed == 0` on the
/// `overload` row — admission control going inert is a service
/// regression no wall-clock row catches.
struct ServiceRow {
    scenario: &'static str,
    clients: usize,
    offered: u64,
    completed: u64,
    shed: u64,
    shed_rate: f64,
    p50_ns: u128,
    p99_ns: u128,
}

/// Nearest-rank percentile over a sorted nanosecond sample (0 when no
/// accepted job produced a sample).
fn percentile(sorted_ns: &[u128], p: f64) -> u128 {
    match sorted_ns.len() {
        0 => 0,
        n => sorted_ns[(((n - 1) as f64) * p).round() as usize],
    }
}

/// Drive `clients` concurrent client threads against the service, each
/// submitting `per_client` jobs through the typed front door
/// ([`Coordinator::submit_optimize`]). Closed-loop clients wait for each
/// job before submitting the next (steady offered load); open-loop
/// clients burst every submission up front (overload). Latency is
/// measured submit→resolve, so queue wait is inside the number; typed
/// [`Error::Overloaded`] rejections count as shed and contribute no
/// latency sample.
fn drive_clients(
    c: &Coordinator,
    scenario: &'static str,
    clients: usize,
    per_client: usize,
    open_loop: bool,
    mk: &(dyn Fn(usize, usize) -> OptimizeSpec + Sync),
) -> ServiceRow {
    let per_thread: Vec<(Vec<u128>, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|ci| {
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(per_client);
                    let mut shed = 0u64;
                    let mut pending = Vec::new();
                    for j in 0..per_client {
                        let t = std::time::Instant::now();
                        match c.submit_optimize(mk(ci, j)) {
                            Ok(h) if open_loop => pending.push((t, h)),
                            Ok(h) => {
                                h.wait().expect("accepted job must resolve");
                                lat.push(t.elapsed().as_nanos());
                            }
                            Err(Error::Overloaded { .. }) => shed += 1,
                            Err(e) => panic!("submit failed: {e}"),
                        }
                    }
                    for (t, h) in pending {
                        h.wait().expect("accepted job must resolve");
                        lat.push(t.elapsed().as_nanos());
                    }
                    (lat, shed)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut lat: Vec<u128> = Vec::new();
    let mut shed = 0u64;
    for (l, s) in per_thread {
        lat.extend(l);
        shed += s;
    }
    lat.sort_unstable();
    let offered = (clients * per_client) as u64;
    ServiceRow {
        scenario,
        clients,
        offered,
        completed: offered - shed,
        shed,
        shed_rate: shed as f64 / offered.max(1) as f64,
        p50_ns: percentile(&lat, 0.50),
        p99_ns: percentile(&lat, 0.99),
    }
}

fn write_bench_json(
    rows: &[(&str, &Measurement)],
    jobs_per_s: f64,
    search: &SearchRow,
    anytime: &[AnytimeRow],
    sharing: &SharingRow,
    service: &[ServiceRow],
    exec_threads: usize,
    exec: &[ExecRow],
) {
    let mut s = String::from(
        "{\n  \"bench\": \"coordinator\",\n  \"schema\": 6,\n  \"workload\": \"matmul n=64 subdivide_rnz=4 (Table 2, 12 variants)\",\n  \"rows\": [\n",
    );
    for (i, (name, m)) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{name}\", \"median_ns\": {}, \"min_ns\": {}, \"runs\": {}}}{}\n",
            m.median.as_nanos(),
            m.min.as_nanos(),
            m.runs,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!(
        "  ],\n  \"search\": {{\"pruned_candidates\": {}, \"exhaustive_variants\": {}, \"pruned_variants\": {}}},\n  \"anytime\": [\n",
        search.pruned_candidates, search.exhaustive_variants, search.pruned_variants
    ));
    for (i, a) in anytime.iter().enumerate() {
        // Gaps are finite on this workload (scoring is on), so plain JSON
        // numbers are safe.
        s.push_str(&format!(
            "    {{\"budget\": {}, \"frac\": {:.2}, \"certified_gap\": {:.6}, \"winner_found\": {}, \"variants\": {}}}{}\n",
            a.budget,
            a.frac,
            a.certified_gap,
            a.winner_found,
            a.variants,
            if i + 1 < anytime.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!(
        "  ],\n  \"sharing\": {{\"exact_hits\": {}, \"canonical_hits\": {}, \"coalesced\": {}, \"canonical_hit_rate\": {:.2}, \"arena_pool_high_water\": {}}},\n  \"service\": [\n",
        sharing.exact_hits,
        sharing.canonical_hits,
        sharing.coalesced,
        sharing.canonical_hit_rate,
        sharing.arena_pool_high_water
    ));
    for (i, r) in service.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"clients\": {}, \"offered\": {}, \"completed\": {}, \"shed\": {}, \"shed_rate\": {:.2}, \"p50_ns\": {}, \"p99_ns\": {}}}{}\n",
            r.scenario,
            r.clients,
            r.offered,
            r.completed,
            r.shed,
            r.shed_rate,
            r.p50_ns,
            r.p99_ns,
            if i + 1 < service.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!(
        "  ],\n  \"exec\": {{\"threads\": {exec_threads}, \"rows\": [\n"
    ));
    for (i, r) in exec.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"family\": \"{}\", \"n\": {}, \"serial_ns\": {}, \"parallel_ns\": {}, \"speedup\": {:.3}, \"parallel_loops\": {}}}{}\n",
            r.family,
            r.n,
            r.serial_ns,
            r.parallel_ns,
            r.speedup,
            r.parallel_loops,
            if i + 1 < exec.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!(
        "  ]}},\n  \"jobs_per_s\": {jobs_per_s:.1}\n}}\n"
    ));
    match std::fs::write("BENCH_coordinator.json", &s) {
        Ok(()) => println!("wrote BENCH_coordinator.json"),
        Err(e) => eprintln!("could not write BENCH_coordinator.json: {e}"),
    }
}

fn main() {
    let cfg = BenchConfig::quick();
    let spec = subdivided_matmul_spec(false);
    let pruned_spec = subdivided_matmul_spec(true);

    // Cold path: the pipeline itself, bypassing the coordinator's LRU.
    let cold = bench("pipeline optimize 64x64 subdiv=4 (cold)", &cfg, || {
        let r = coordinator::optimize(&spec).expect("optimize");
        std::hint::black_box(r.variants_explored);
    });
    println!(
        "pipeline (cold) median latency: {}",
        fmt_duration(cold.median)
    );

    // Pruned path: cold pipeline with the in-BFS cost bound enabled.
    let pruned = bench("pipeline optimize 64x64 subdiv=4 (pruned)", &cfg, || {
        let r = coordinator::optimize(&pruned_spec).expect("optimize");
        std::hint::black_box(r.variants_explored);
    });
    println!(
        "pipeline (pruned) median latency: {} ({:.2}x of cold)",
        fmt_duration(pruned.median),
        pruned.median.as_secs_f64() / cold.median.as_secs_f64().max(f64::EPSILON)
    );

    // Branch-and-bound effectiveness on this workload: how many
    // candidates the default-slack cut rejected before lowering/scoring,
    // and how far the kept set shrank vs exhaustive mode.
    let ex = coordinator::optimize(&spec).expect("optimize");
    let search = {
        let pr = coordinator::optimize(&pruned_spec).expect("optimize");
        println!(
            "search: exhaustive kept={} pruned-mode kept={} pruned_candidates={}",
            ex.variants_explored, pr.variants_explored, pr.stats.pruned
        );
        SearchRow {
            pruned_candidates: pr.stats.pruned,
            exhaustive_variants: ex.variants_explored,
            pruned_variants: pr.variants_explored,
        }
    };

    // Anytime quality: the same workload truncated to ~25% and ~50% of the
    // full run's expansion count. Winner quality + certified gap per
    // budget level.
    let anytime: Vec<AnytimeRow> = [0.25f64, 0.5]
        .iter()
        .map(|&frac| {
            let budget = ((ex.stats.expanded as f64 * frac).ceil() as u64).max(1);
            let truncated = {
                let mut t = spec.clone();
                t.budget = budget;
                coordinator::optimize(&t).expect("optimize")
            };
            let row = AnytimeRow {
                budget,
                frac,
                certified_gap: truncated.certified_gap,
                winner_found: truncated.best == ex.best,
                variants: truncated.variants_explored,
            };
            println!(
                "anytime {:>3.0}%: budget={} gap={:.3} winner_found={} variants={}",
                frac * 100.0,
                row.budget,
                row.certified_gap,
                row.winner_found,
                row.variants
            );
            row
        })
        .collect();

    // Executor phase (ISSUE 10): serial vs certificate-gated threaded
    // execution of the shipped loop-nest families at a size where the
    // nest dominates. Both families certify their root map `Parallel`
    // (all-`+` reductions lower without temps), so the threaded run must
    // actually chunk — `parallel_loops` lands in the JSON and the
    // advisory lane flags the certificate going inert. Bit-identity to
    // the serial path is asserted on every row before timing.
    let exec_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(8);
    let exec_rows: Vec<ExecRow> = vec![
        ("matmul_naive", starts::matmul_naive_variant()),
        ("subdivided_matmul", starts::matmul_rnz_subdivided_variant(4)),
    ]
    .into_iter()
    .map(|(family, v)| {
        let n = 192usize;
        let env = Env::new()
            .with("A", Layout::row_major(&[n, n]))
            .with("B", Layout::row_major(&[n, n]));
        let prog = lower(&v.expr, &env).expect("lower family");
        let a: Vec<f64> = (0..n * n).map(|i| ((i % 13) as f64) - 6.0).collect();
        let b: Vec<f64> = (0..n * n).map(|i| ((i % 7) as f64) * 0.5 - 1.5).collect();
        let bufs = order_inputs(&prog, &[("A", &a), ("B", &b)]).expect("inputs");
        let mut serial_out = vec![0.0; prog.out_size];
        execute(&prog, &bufs, &mut serial_out).expect("serial execute");
        let mut parallel_out = vec![0.0; prog.out_size];
        let rep = execute_threaded(&prog, &bufs, &mut parallel_out, exec_threads)
            .expect("threaded execute");
        assert!(
            serial_out
                .iter()
                .zip(&parallel_out)
                .all(|(x, y)| x.to_bits() == y.to_bits()),
            "{family}: threaded output must be bit-identical to serial"
        );
        let serial = bench(&format!("exec {family} n={n} (serial)"), &cfg, || {
            let mut out = vec![0.0; prog.out_size];
            execute(&prog, &bufs, &mut out).expect("serial execute");
            std::hint::black_box(out[0]);
        });
        let parallel = bench(
            &format!("exec {family} n={n} ({exec_threads} threads)"),
            &cfg,
            || {
                let mut out = vec![0.0; prog.out_size];
                execute_threaded(&prog, &bufs, &mut out, exec_threads)
                    .expect("threaded execute");
                std::hint::black_box(out[0]);
            },
        );
        let row = ExecRow {
            family,
            n,
            serial_ns: serial.median.as_nanos(),
            parallel_ns: parallel.median.as_nanos(),
            speedup: serial.median.as_secs_f64()
                / parallel.median.as_secs_f64().max(f64::EPSILON),
            parallel_loops: rep.parallel_loops,
        };
        println!(
            "exec {family} n={n}: serial {} vs {} threads {} ({:.2}x, parallel_loops={})",
            fmt_duration(serial.median),
            exec_threads,
            fmt_duration(parallel.median),
            row.speedup,
            row.parallel_loops
        );
        row
    })
    .collect();

    let c = Coordinator::start(Config::default()).expect("start");

    // Warm path: repeated identical service traffic short-circuits in the
    // result LRU. Submitted through the typed front door
    // (`submit_optimize` → `OptimizeHandle`), the production client path.
    let warm = bench("coordinator optimize (warm LRU)", &cfg, || {
        let r = c
            .submit_optimize(spec.clone())
            .expect("submit")
            .wait()
            .expect("wait");
        std::hint::black_box(r.variants_explored);
    });
    println!(
        "service (warm) median latency: {}",
        fmt_duration(warm.median)
    );

    // Warm canonical path: α-renamed spellings of the cached kernel are
    // answered through the canonical key — no parse-identical source, no
    // fresh search (ISSUE 8 acceptance workload).
    let renamed = renamed_subdivided_matmul_spec();
    let warm_canonical = bench("coordinator optimize (warm canonical)", &cfg, || {
        let r = c
            .submit_optimize(renamed.clone())
            .expect("submit")
            .wait()
            .expect("wait");
        std::hint::black_box(r.variants_explored);
    });
    println!(
        "service (warm canonical) median latency: {}",
        fmt_duration(warm_canonical.median)
    );

    // Pipelined submission throughput (the batching path), typed handles.
    let t = std::time::Instant::now();
    let jobs = 64;
    let handles: Vec<_> = (0..jobs)
        .map(|_| c.submit_optimize(spec.clone()).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    let dt = t.elapsed();
    let jobs_per_s = jobs as f64 / dt.as_secs_f64();
    println!(
        "{} concurrent optimize jobs (subdivided matmul): {} total ({:.1} jobs/s); metrics: {}",
        jobs,
        fmt_duration(dt),
        jobs_per_s,
        c.metrics.summary()
    );

    // Coalesced burst: flush the cache, then fire 8 identical concurrent
    // submissions — single-flight runs one search and fans it out, so the
    // burst costs about one cold run, not eight.
    let coalesced_burst = bench("coordinator optimize (coalesced x8 burst)", &cfg, || {
        c.flush_opt_cache();
        let handles: Vec<_> = (0..8)
            .map(|_| c.submit_optimize(spec.clone()).unwrap())
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
    });
    println!(
        "service (coalesced x8 burst) median latency: {} ({:.2}x of cold)",
        fmt_duration(coalesced_burst.median),
        coalesced_burst.median.as_secs_f64() / cold.median.as_secs_f64().max(f64::EPSILON)
    );

    // Deterministic canonical-hit-rate phase for the sharing block: warm
    // the (freshly flushed) cache once, then send a fixed batch of
    // α-renamed resubmissions. Every one of them should be a canonical
    // hit, so the rate is 1.0 when the machinery works and 0.0 when it
    // silently stops matching.
    c.flush_opt_cache();
    c.submit_optimize(spec.clone())
        .expect("submit")
        .wait()
        .expect("warm call");
    let canonical_batch = 32u64;
    let canon_before = c
        .metrics
        .opt_cache_hits_canonical
        .load(std::sync::atomic::Ordering::Relaxed);
    for _ in 0..canonical_batch {
        c.submit_optimize(renamed.clone())
            .expect("submit")
            .wait()
            .expect("canonical call");
    }
    let canon_delta = c
        .metrics
        .opt_cache_hits_canonical
        .load(std::sync::atomic::Ordering::Relaxed)
        - canon_before;
    let sharing = SharingRow {
        exact_hits: c
            .metrics
            .opt_cache_hits_exact
            .load(std::sync::atomic::Ordering::Relaxed),
        canonical_hits: c
            .metrics
            .opt_cache_hits_canonical
            .load(std::sync::atomic::Ordering::Relaxed),
        coalesced: c
            .metrics
            .opt_coalesced
            .load(std::sync::atomic::Ordering::Relaxed),
        canonical_hit_rate: canon_delta as f64 / canonical_batch as f64,
        arena_pool_high_water: c
            .metrics
            .arena_pool_high_water
            .load(std::sync::atomic::Ordering::Relaxed),
    };
    println!(
        "sharing: exact_hits={} canonical_hits={} coalesced={} canonical_hit_rate={:.2} arena_pool_high_water={}",
        sharing.exact_hits,
        sharing.canonical_hits,
        sharing.coalesced,
        sharing.canonical_hit_rate,
        sharing.arena_pool_high_water
    );

    // Service load generator (ISSUE 9, schema v5): the typed front door
    // under N concurrent clients.
    //
    // - `load`: 8 closed-loop clients × 32 requests against the warmed
    //   default-config service — every request is a cache hit and at most
    //   8 jobs are ever queued, so nothing sheds; the row tracks the
    //   tail (p50/p99) of the service overhead under concurrency.
    // - `overload`: 8 open-loop clients burst 64 *distinct*
    //   short-deadline jobs (the headline kernel at 64 different `top_k`
    //   cut-offs — same family, so intake batching engages, but nothing
    //   coalesces or hits the cache) at a deliberately starved service
    //   (1 worker, intake queue capacity 2). Admission control must shed
    //   most of the burst with typed `Overloaded` rejections while every
    //   accepted job still resolves — its 20 ms deadline is measured
    //   from intake, so queued jobs return truncated instead of piling
    //   onto the tail.
    let clients = 8;
    let load = drive_clients(&c, "load", clients, 32, false, &|_, _| spec.clone());
    println!(
        "service load ({clients} clients x32 closed-loop, warm): p50 {} p99 {} shed {} ({:.0}%)",
        fmt_duration(std::time::Duration::from_nanos(load.p50_ns as u64)),
        fmt_duration(std::time::Duration::from_nanos(load.p99_ns as u64)),
        load.shed,
        load.shed_rate * 100.0
    );
    let overload_c = Coordinator::start(Config {
        workers: 1,
        queue_cap: 2,
        opt_batch: 4,
        ..Config::default()
    })
    .expect("start overload service");
    let overload = drive_clients(&overload_c, "overload", clients, 8, true, &|ci, j| {
        let mut s = spec.clone();
        s.top_k = ci * 8 + j + 1;
        s.deadline_ms = 20;
        s
    });
    println!(
        "service overload ({clients} clients x8 burst, 1 worker, queue_cap=2): p50 {} p99 {} \
         shed {}/{} ({:.0}%); metrics: {}",
        fmt_duration(std::time::Duration::from_nanos(overload.p50_ns as u64)),
        fmt_duration(std::time::Duration::from_nanos(overload.p99_ns as u64)),
        overload.shed,
        overload.offered,
        overload.shed_rate * 100.0,
        overload_c.metrics.summary()
    );
    drop(overload_c);
    let service = [load, overload];

    write_bench_json(
        &[
            ("cold", &cold),
            ("warm", &warm),
            ("warm_canonical", &warm_canonical),
            ("pruned", &pruned),
            ("coalesced", &coalesced_burst),
        ],
        jobs_per_s,
        &search,
        &anytime,
        &sharing,
        &service,
        exec_threads,
        &exec_rows,
    );

    if hofdla::runtime::artifact_path("matmul_xla_256").exists()
        && hofdla::runtime::pjrt_available()
    {
        let n = 256usize;
        let a = vec![1f32; n * n];
        let mk = || Request::ExecArtifact {
            name: "matmul_xla_256".into(),
            inputs: vec![(a.clone(), vec![n, n]), (a.clone(), vec![n, n])],
        };
        let m = bench("exec artifact matmul_xla_256", &cfg, || {
            let Response::Executed { output } = c.call(mk()).unwrap() else {
                panic!("wrong response type")
            };
            std::hint::black_box(output.len());
        });
        println!("artifact exec median latency: {}", fmt_duration(m.median));
    }
}
