//! Paper Figure 5: rearrangements with the reduction subdivided twice —
//! the paper's finding: all candidates at least as good as naive.
use hofdla::experiments::{self, MatmulOpts};

fn main() {
    // Default smaller than the paper's 1024: this family has many
    // variants; HOFDLA_N overrides.
    let mut opts = MatmulOpts::default();
    if std::env::var("HOFDLA_N").is_err() {
        opts.n = 384;
    }
    if opts.n % (opts.b * opts.b) != 0 {
        opts.b = 4;
    }
    let e = experiments::fig5(&opts).expect("fig5");
    print!("{}", e.render());
}
