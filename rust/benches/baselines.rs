//! Paper §4 baselines: naive (4.9 s), hand-blocked (278 ms), Eigen
//! (333/60 ms) — here naive rust, blocked rust, and the XLA/Pallas
//! artifacts through PJRT.
use hofdla::bench_support::{env_config, env_size};

fn main() {
    let n = env_size(512);
    let e = hofdla::experiments::baselines_experiment(n, &env_config()).expect("baselines");
    print!("{}", e.render());
}
