//! Paper Table 2: the twelve rearrangements of matmul with the reduction
//! subdivided (b=16).
use hofdla::experiments::{self, MatmulOpts};

fn main() {
    let opts = MatmulOpts {
        simulate: std::env::args().any(|a| a == "--sim"),
        ..Default::default()
    };
    let e = experiments::table2(&opts).expect("table2");
    print!("{}", e.render());
}
