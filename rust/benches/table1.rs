//! Paper Table 1: the six rearrangements of naive 1024x1024 matmul.
//! Default size 512 (HOFDLA_N=1024 for the paper's setting); prints the
//! paper-style sorted table plus baselines for the ratio.
use hofdla::experiments::{self, MatmulOpts};

fn main() {
    let opts = MatmulOpts {
        simulate: std::env::args().any(|a| a == "--sim"),
        ..Default::default()
    };
    let e = experiments::table1(&opts).expect("table1");
    print!("{}", e.render());
    let b = experiments::baselines_experiment(opts.n, &opts.bench).expect("baselines");
    print!("{}", b.render());
}
