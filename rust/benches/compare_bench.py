#!/usr/bin/env python3
"""Advisory comparison of a fresh BENCH_coordinator.json against the
committed baseline (BENCH_coordinator.baseline.json).

Used by the CI `bench-perf` lane. The lane is non-blocking
(continue-on-error), and the threshold is deliberately generous: shared
runners are noisy, so only gross regressions of the tracked medians
(cold/warm/warm_canonical/pruned/coalesced) should flag. Beyond the
absolute medians, the lane tracks the pruned/cold ratio
(pruned-vs-exhaustive search time), the `search` block's
`pruned_candidates` — the branch-and-bound cut going inert (pruning
nothing on the bench workload) flags even when wall-clock looks fine —
and the `sharing` block's canonical hit rate and coalesced count, so the
cross-request sharing machinery going inert flags too. The schema-v5
`service` block (load-generator rows) is guarded the same way: the
`load` row's p50/p99 tails compare against the baseline at the 3x
threshold and must not shed, while the `overload` row must shed — a
zero shed count under a 64-job burst at a 2-slot queue means admission
control went inert. The schema-v6 `exec` block (ISSUE 10: serial vs
certificate-gated threaded execution of the shipped loop-nest families)
is guarded too: every family reporting `parallel_loops == 0` means the
parallel-safety certificate went inert — the threaded path silently ran
serial — which flags even when wall-clock rows stay flat.

A second mode, `--update-baseline CURRENT.json`, schema-checks a fresh
run and writes it as `BENCH_coordinator.baseline.json` next to this
script (preserving the committed baseline's prose `note`), so refreshing
the baseline after an intended trajectory change is one command instead
of hand-editing JSON.

Exit codes: 0 = within threshold (or nothing to compare / baseline
written), 1 = at least one row regressed beyond THRESHOLD (or a
within-run signal broke), 2 = usage error. Stdlib only — the repo's
default build is dependency-free and CI should be too.
"""

import json
import os
import sys

# The bench JSON schema this script understands; `--update-baseline`
# refuses to install a baseline written by any other schema version.
EXPECTED_SCHEMA = 6

# Generous: flag only when a median is more than 3x the baseline.
THRESHOLD = 3.0

# Pruned vs cold are measured within the *same* run (far less noisy than
# cross-run baselines), and pruning should never make the search
# meaningfully slower than exhaustive — flag past modest headroom.
PRUNED_VS_COLD_THRESHOLD = 1.5

# The rows tracked across PRs (see rust/benches/README.md).
ROWS = ("cold", "warm", "warm_canonical", "pruned", "coalesced")


def rows_by_name(doc):
    return {r.get("name"): r for r in doc.get("rows", [])}


def update_baseline(current_path):
    """Schema-check a fresh run and install it as the committed baseline."""
    try:
        with open(current_path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"cannot read current results {current_path}: {e}", file=sys.stderr)
        return 2
    problems = []
    if doc.get("bench") != "coordinator":
        problems.append(f"bench is {doc.get('bench')!r}, expected 'coordinator'")
    if doc.get("schema") != EXPECTED_SCHEMA:
        problems.append(f"schema is {doc.get('schema')!r}, expected {EXPECTED_SCHEMA}")
    rows = rows_by_name(doc)
    for name in ROWS:
        if not rows.get(name, {}).get("median_ns"):
            problems.append(f"row {name!r} missing or has no median_ns")
    for block in ("search", "anytime", "sharing", "service", "exec"):
        if not doc.get(block):
            problems.append(f"block {block!r} missing or empty")
    if not doc.get("exec", {}).get("rows"):
        problems.append("exec block has no rows")
    if problems:
        for p in problems:
            print(f"refusing to write baseline: {p}", file=sys.stderr)
        return 2
    out = os.path.join(
        os.path.dirname(os.path.abspath(__file__)),
        "BENCH_coordinator.baseline.json",
    )
    # Keep the committed baseline's prose note (provenance + refresh
    # guidance) — the bench binary does not emit one.
    if "note" not in doc:
        try:
            with open(out) as f:
                note = json.load(f).get("note")
            if note is not None:
                doc = {**doc, "note": note}
        except (OSError, ValueError):
            pass
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out} (schema {EXPECTED_SCHEMA})")
    return 0


def main(argv):
    if len(argv) == 3 and argv[1] == "--update-baseline":
        return update_baseline(argv[2])
    if len(argv) != 3:
        print(
            f"usage: {argv[0]} CURRENT.json BASELINE.json\n"
            f"       {argv[0]} --update-baseline CURRENT.json",
            file=sys.stderr,
        )
        return 2
    try:
        with open(argv[1]) as f:
            current = json.load(f)
    except (OSError, ValueError) as e:
        print(f"cannot read current results {argv[1]}: {e}", file=sys.stderr)
        return 2
    try:
        with open(argv[2]) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"no baseline at {argv[2]}; nothing to compare (OK)")
        return 0
    except (OSError, ValueError) as e:
        print(f"cannot read baseline {argv[2]}: {e}", file=sys.stderr)
        return 2

    cur, base = rows_by_name(current), rows_by_name(baseline)
    regressed = []  # baseline-relative: refreshing the baseline clears these
    broken = []  # current-run-only: only a code change clears these
    for name in ROWS:
        if name not in cur or name not in base:
            print(f"{name:8} missing from current or baseline; skipping")
            continue
        c = cur[name].get("median_ns", 0)
        b = base[name].get("median_ns", 0)
        if not b or b <= 0:
            print(f"{name:8} baseline median is 0; skipping")
            continue
        ratio = c / b
        mark = "OK" if ratio <= THRESHOLD else f"REGRESSION (> {THRESHOLD}x)"
        print(f"{name:8} median {c:>13} ns  baseline {b:>13} ns  ({ratio:6.2f}x)  {mark}")
        if ratio > THRESHOLD:
            regressed.append(name)

    # Branch-and-bound tracking: pruned-vs-exhaustive search time plus the
    # cut's effectiveness counters. A pruned run meaningfully slower than
    # cold (the two are timed within the same run, so the tight
    # PRUNED_VS_COLD_THRESHOLD applies, not the cross-run 3x), or a cut
    # that stopped firing (pruned_candidates == 0), is a cost-model
    # regression even when absolute medians look fine. Advisory like the
    # rest of the lane; tolerant of pre-schema baselines.
    if "cold" in cur and "pruned" in cur and cur["cold"].get("median_ns"):
        pvc = cur["pruned"]["median_ns"] / cur["cold"]["median_ns"]
        bpvc = None
        if "cold" in base and "pruned" in base and base["cold"].get("median_ns"):
            bpvc = base["pruned"]["median_ns"] / base["cold"]["median_ns"]
        baseline_note = f"  baseline {bpvc:5.2f}x" if bpvc is not None else ""
        print(f"pruned/cold search-time ratio {pvc:5.2f}x{baseline_note}")
        if pvc > PRUNED_VS_COLD_THRESHOLD:
            print(
                f"advisory: pruned mode is > {PRUNED_VS_COLD_THRESHOLD}x the "
                "exhaustive search time — pruning has become a net loss"
            )
            broken.append("pruned/cold")
    search = current.get("search", {})
    if search:
        print(
            "search: pruned_candidates={} kept {} of {} variants".format(
                search.get("pruned_candidates", "?"),
                search.get("pruned_variants", "?"),
                search.get("exhaustive_variants", "?"),
            )
        )
        if search.get("pruned_candidates") == 0:
            print(
                "advisory: the branch-and-bound cut pruned nothing on the bench "
                "workload — the lower bound has gone inert (see "
                "costmodel::spine_lower_bound_id)"
            )
            broken.append("pruned_candidates")
        base_pruned = baseline.get("search", {}).get("pruned_candidates")
        cur_pruned = search.get("pruned_candidates")
        if (
            isinstance(base_pruned, int)
            and isinstance(cur_pruned, int)
            and 0 < cur_pruned < base_pruned
        ):
            print(
                f"advisory: the cut pruned {cur_pruned} candidates vs "
                f"{base_pruned} at the baseline — the search explores more "
                "than it used to on the same workload"
            )
            regressed.append("pruned_candidates")

    # Anytime tracking: winner quality + certified gap at truncated node
    # budgets (25% / 50% of the full run). A budget level that used to hold
    # the exhaustive winner and no longer does is a priority-order
    # regression in the best-first search that no wall-clock row catches;
    # losing the winner within the current run alone is only reported, since
    # quality at a fixed fraction is workload-dependent, not inherently
    # wrong. Tolerant of pre-anytime baselines (no "anytime" block).
    anytime = current.get("anytime", [])
    base_anytime = {a.get("frac"): a for a in baseline.get("anytime", [])}
    for row in anytime:
        frac = row.get("frac")
        gap = row.get("certified_gap")
        found = row.get("winner_found")
        b = base_anytime.get(frac)
        base_note = ""
        if b is not None:
            base_note = "  baseline gap={:.3f} winner_found={}".format(
                b.get("certified_gap", float("nan")), b.get("winner_found")
            )
        print(
            "anytime {:>3.0f}%: budget={} gap={:.3f} winner_found={}{}".format(
                (frac or 0) * 100, row.get("budget"), gap, found, base_note
            )
        )
        if b is not None and b.get("winner_found") and not found:
            print(
                f"advisory: the {frac:.0%} budget used to find the exhaustive "
                "winner and no longer does — the best-first expansion order "
                "has regressed (see enumerate::spine_lower_bound priorities)"
            )
            regressed.append(f"anytime-{frac}")

    # Cross-request sharing tracking (ISSUE 8): the canonical hit rate
    # (α-renamed resubmissions answered from the cache, expected 1.0) and
    # the single-flight coalesced count. A rate of zero, or coalescing
    # that stopped happening, means the sharing machinery went inert —
    # the service still answers correctly but re-searches identical
    # requests, which no wall-clock row on fast hardware reliably
    # catches. Within-run signals are `broken`; a rate merely below the
    # committed baseline's is `regressed`. Tolerant of pre-sharing
    # baselines (no "sharing" block).
    sharing = current.get("sharing", {})
    if sharing:
        rate = sharing.get("canonical_hit_rate")
        coalesced = sharing.get("coalesced")
        base_sharing = baseline.get("sharing", {})
        base_rate = base_sharing.get("canonical_hit_rate")
        base_note = f"  baseline {base_rate:.2f}" if base_rate is not None else ""
        print(
            "sharing: canonical_hit_rate={} coalesced={} exact_hits={} "
            "canonical_hits={} arena_pool_high_water={}{}".format(
                rate,
                coalesced,
                sharing.get("exact_hits", "?"),
                sharing.get("canonical_hits", "?"),
                sharing.get("arena_pool_high_water", "?"),
                base_note,
            )
        )
        if rate is not None and rate <= 0:
            print(
                "advisory: no α-renamed resubmission hit the result cache — "
                "canonical keying has gone inert (see "
                "OptimizeSpec::canonical_key / dsl::intern::canonical_hash)"
            )
            broken.append("canonical_hit_rate")
        elif (
            rate is not None
            and base_rate is not None
            and rate < base_rate - 1e-9
        ):
            print(
                f"advisory: canonical hit rate {rate:.2f} fell below the "
                f"baseline's {base_rate:.2f} — α-equivalent traffic is being "
                "re-searched"
            )
            regressed.append("canonical_hit_rate")
        if coalesced == 0:
            print(
                "advisory: no identical concurrent submissions coalesced on "
                "the burst workload — single-flight has gone inert (see "
                "coordinator worker loop)"
            )
            broken.append("coalesced")

    # Service front-end tracking (ISSUE 9): the load-generator rows.
    # Within-run invariants are `broken` signals — the warm `load` row
    # must not shed (admission control firing under nominal load means
    # the queue bound or the drain loop is wrong), and the starved
    # `overload` row must shed (a 64-job burst at a 2-slot queue that
    # sheds nothing means admission control went inert and tail latency
    # is unbounded again). The load row's p50/p99 tails additionally
    # compare against the committed baseline at the generous cross-run
    # threshold. Tolerant of pre-service baselines (no "service" block).
    service = {r.get("scenario"): r for r in current.get("service", [])}
    base_service = {r.get("scenario"): r for r in baseline.get("service", [])}
    for scenario, row in service.items():
        print(
            "service {}: clients={} offered={} completed={} shed={} "
            "shed_rate={} p50_ns={} p99_ns={}".format(
                scenario,
                row.get("clients", "?"),
                row.get("offered", "?"),
                row.get("completed", "?"),
                row.get("shed", "?"),
                row.get("shed_rate", "?"),
                row.get("p50_ns", "?"),
                row.get("p99_ns", "?"),
            )
        )
    if service:
        load = service.get("load")
        if load is not None and load.get("shed", 0) != 0:
            print(
                "advisory: the warm load scenario shed requests — admission "
                "control is rejecting nominal traffic (see "
                "Coordinator::submit_optimize / Config::queue_cap)"
            )
            broken.append("service-load-shed")
        overload = service.get("overload")
        if overload is not None and not overload.get("shed", 0):
            print(
                "advisory: the overload scenario shed nothing — a 64-job "
                "burst at a 2-slot intake queue must trip admission "
                "control; the typed Overloaded rejection has gone inert"
            )
            broken.append("service-overload-shed")
        base_load = base_service.get("load")
        if load is not None and base_load is not None:
            for col in ("p50_ns", "p99_ns"):
                c = load.get(col, 0)
                b = base_load.get(col, 0)
                if not b or b <= 0:
                    continue
                ratio = c / b
                mark = "OK" if ratio <= THRESHOLD else f"REGRESSION (> {THRESHOLD}x)"
                print(
                    f"service load {col:6} {c:>13} ns  baseline {b:>13} ns  "
                    f"({ratio:6.2f}x)  {mark}"
                )
                if ratio > THRESHOLD:
                    regressed.append(f"service-load-{col}")

    # Parallel-executor tracking (ISSUE 10): serial vs certificate-gated
    # threaded execution of the shipped loop-nest families. The within-run
    # signal is the certificate going inert — every family reporting
    # parallel_loops == 0 means the dependence analysis demoted all root
    # maps (or the executor stopped consulting the cert) and the threaded
    # path silently ran serial; that is `broken`, a code regression no
    # wall-clock row catches. Per-family threaded medians additionally
    # compare against the committed baseline at the generous cross-run
    # threshold. Tolerant of pre-exec baselines (no "exec" block).
    exec_block = current.get("exec", {})
    if exec_block:
        base_exec = {
            r.get("family"): r for r in baseline.get("exec", {}).get("rows", [])
        }
        total_parallel = 0
        for row in exec_block.get("rows", []):
            family = row.get("family")
            total_parallel += row.get("parallel_loops") or 0
            print(
                "exec {}: n={} serial_ns={} parallel_ns={} speedup={} "
                "parallel_loops={} (threads={})".format(
                    family,
                    row.get("n", "?"),
                    row.get("serial_ns", "?"),
                    row.get("parallel_ns", "?"),
                    row.get("speedup", "?"),
                    row.get("parallel_loops", "?"),
                    exec_block.get("threads", "?"),
                )
            )
            b = base_exec.get(family)
            c = row.get("parallel_ns", 0)
            if b and b.get("parallel_ns", 0) > 0 and c:
                ratio = c / b["parallel_ns"]
                mark = "OK" if ratio <= THRESHOLD else f"REGRESSION (> {THRESHOLD}x)"
                print(
                    f"exec {family} parallel {c:>13} ns  baseline "
                    f"{b['parallel_ns']:>13} ns  ({ratio:6.2f}x)  {mark}"
                )
                if ratio > THRESHOLD:
                    regressed.append(f"exec-{family}-parallel_ns")
        if total_parallel == 0:
            print(
                "advisory: no bench family executed a parallel loop — the "
                "parallel-safety certificate has gone inert (see "
                "verify::depend::certify and the execute_threaded gate)"
            )
            broken.append("exec-parallel-loops")

    if regressed:
        print(
            f"advisory: {', '.join(regressed)} regressed against the committed "
            "baseline. If the change is real and intended, refresh "
            "rust/benches/BENCH_coordinator.baseline.json from this run's artifact."
        )
    if broken:
        print(
            f"advisory: {', '.join(broken)} failed within this run alone — "
            "refreshing the baseline cannot clear it; look at the cost model / "
            "search pruning code."
        )
    if regressed or broken:
        return 1
    print("all tracked rows within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
