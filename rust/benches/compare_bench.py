#!/usr/bin/env python3
"""Advisory comparison of a fresh BENCH_coordinator.json against the
committed baseline (BENCH_coordinator.baseline.json).

Used by the CI `bench-perf` lane. The lane is non-blocking
(continue-on-error), and the threshold is deliberately generous: shared
runners are noisy, so only gross regressions of the cold/warm/pruned
medians should flag. Exit codes: 0 = within threshold (or nothing to
compare), 1 = at least one row regressed beyond THRESHOLD, 2 = usage
error. Stdlib only — the repo's default build is dependency-free and CI
should be too.
"""

import json
import sys

# Generous: flag only when a median is more than 3x the baseline.
THRESHOLD = 3.0

# The rows tracked across PRs (see rust/benches/README.md).
ROWS = ("cold", "warm", "pruned")


def rows_by_name(doc):
    return {r.get("name"): r for r in doc.get("rows", [])}


def main(argv):
    if len(argv) != 3:
        print(f"usage: {argv[0]} CURRENT.json BASELINE.json", file=sys.stderr)
        return 2
    try:
        with open(argv[1]) as f:
            current = json.load(f)
    except (OSError, ValueError) as e:
        print(f"cannot read current results {argv[1]}: {e}", file=sys.stderr)
        return 2
    try:
        with open(argv[2]) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"no baseline at {argv[2]}; nothing to compare (OK)")
        return 0
    except (OSError, ValueError) as e:
        print(f"cannot read baseline {argv[2]}: {e}", file=sys.stderr)
        return 2

    cur, base = rows_by_name(current), rows_by_name(baseline)
    regressed = []
    for name in ROWS:
        if name not in cur or name not in base:
            print(f"{name:8} missing from current or baseline; skipping")
            continue
        c = cur[name].get("median_ns", 0)
        b = base[name].get("median_ns", 0)
        if not b or b <= 0:
            print(f"{name:8} baseline median is 0; skipping")
            continue
        ratio = c / b
        mark = "OK" if ratio <= THRESHOLD else f"REGRESSION (> {THRESHOLD}x)"
        print(f"{name:8} median {c:>13} ns  baseline {b:>13} ns  ({ratio:6.2f}x)  {mark}")
        if ratio > THRESHOLD:
            regressed.append(name)
    if regressed:
        print(
            f"advisory: {', '.join(regressed)} exceeded {THRESHOLD}x the committed "
            "baseline. If the slowdown is real and intended, refresh "
            "rust/benches/BENCH_coordinator.baseline.json from this run's artifact."
        )
        return 1
    print("all tracked rows within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
