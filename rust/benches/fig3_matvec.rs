//! Paper Figure 3: the six rearrangements of the matrix-vector product
//! from subdividing the vector (1a-1c) or the map (2a-2c family).
use hofdla::bench_support::{env_config, env_size};

fn main() {
    let n = env_size(2048);
    let b = if n % 256 == 0 { 16 } else { 4 };
    let e = hofdla::experiments::fig3(n, b, &env_config()).expect("fig3");
    print!("{}", e.render());
}
