//! Property tests for the best-first anytime search (ISSUE 7):
//!
//! - **infinite budget ≡ exhaustive**: with no budget/deadline the
//!   best-first engine returns the exhaustive winner bit-identically at
//!   the lowered `Program` level, at shard counts 1/2/8, pruned or not,
//!   on the n=64 / b=4 acceptance workload;
//! - **gap monotonicity**: over budgets 1..=full the certified gap is
//!   monotone non-increasing, the kept sequences are nested prefixes of
//!   one discovery order, and the final (complete) run reports exactly
//!   `1.0`;
//! - **gap semantics**: the gap is always ≥ 1.0 and equals `1.0` iff the
//!   search completed; truncated runs leave an open frontier behind;
//! - **gap soundness**: on randomized seeded shapes across the
//!   subdivided/exchanged families, every truncated run's winner score is
//!   within `certified_gap ×` the family's true optimum;
//! - **deadline**: an already-expired deadline returns the start variant
//!   immediately with `deadline_hit` set, never hanging.

use hofdla::enumerate::{
    enumerate_search, starts, SearchOptions, SearchResult, Variant, DEFAULT_PRUNE_SLACK,
    MAX_SEARCH_SHARDS,
};
use hofdla::exec::lower;
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;
use hofdla::util::Rng;

/// Shard count under test — the CI matrix sets `SEARCH_SHARDS` (1, 2, 8),
/// mirroring `tests/search_props.rs`.
fn shard_count() -> usize {
    std::env::var("SEARCH_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(4)
        .min(MAX_SEARCH_SHARDS)
}

/// A is n×j, B is j×k, v has length j — the shape convention every start
/// family typechecks under (divisibility per the subdivided families).
fn env(n: usize, j: usize, k: usize) -> Env {
    Env::new()
        .with("A", Layout::row_major(&[n, j]))
        .with("B", Layout::row_major(&[j, k]))
        .with("v", Layout::row_major(&[j]))
}

/// The subdivided/exchanged families the anytime properties quantify
/// over (the naive families complete in one wave — no truncation to
/// exercise).
fn families() -> Vec<(&'static str, Variant)> {
    vec![
        ("matmul-rnz-subdiv", starts::matmul_rnz_subdivided_variant(2)),
        ("matmul-maps-subdiv", starts::matmul_maps_subdivided_variant(2)),
        (
            "matmul-rnz-twice",
            starts::matmul_rnz_twice_subdivided_variant(2, 2),
        ),
        ("matmul-all-subdiv", starts::matmul_all_subdivided_variant(2)),
        (
            "matvec-vector-subdiv",
            starts::matvec_vector_subdivided_variant(2),
        ),
    ]
}

fn scored_opts(shards: usize) -> SearchOptions {
    SearchOptions {
        limit: 4096,
        shards,
        prune_slack: None,
        score: true,
        ..SearchOptions::default()
    }
}

/// Index of the winner: first variant attaining the minimum score (the
/// pipeline's tie-breaking).
fn best_of(r: &SearchResult) -> usize {
    let (mut bi, mut bs) = (0usize, f64::INFINITY);
    for (i, &s) in r.scores.iter().enumerate() {
        if s < bs {
            bi = i;
            bs = s;
        }
    }
    bi
}

/// ISSUE 7 acceptance (theorem flavor): with an unlimited budget the
/// best-first engine *is* the exhaustive search — same winner, same
/// lowered `Program` bit for bit — at shard counts 1, 2 and 8, with the
/// branch-and-bound cut on or off, on the n=64 / b=4 workload. Every such
/// run reports `complete` with a certified gap of exactly `1.0`.
#[test]
fn infinite_budget_reproduces_exhaustive_winner_across_shards_and_pruning() {
    let env = Env::new()
        .with("A", Layout::row_major(&[64, 64]))
        .with("B", Layout::row_major(&[64, 64]));
    let ctx = Ctx::new(env.clone());
    let start = starts::matmul_rnz_subdivided_variant(4);
    let reference = enumerate_search(&start, &ctx, &scored_opts(1)).unwrap();
    assert_eq!(reference.variants.len(), 12, "Table 2");
    let rb = best_of(&reference);
    let ref_winner = &reference.variants[rb];
    let ref_prog = format!("{:?}", lower(&ref_winner.expr, &env).unwrap());
    for shards in [1usize, 2, 8] {
        for prune in [None, Some(DEFAULT_PRUNE_SLACK)] {
            let opts = SearchOptions {
                prune_slack: prune,
                ..scored_opts(shards)
            };
            let r = enumerate_search(&start, &ctx, &opts).unwrap();
            assert!(
                r.stats.complete,
                "shards={shards} prune={prune:?}: unlimited run must drain the frontier"
            );
            assert_eq!(
                r.stats.certified_gap, 1.0,
                "shards={shards} prune={prune:?}: complete runs certify exactly 1.0"
            );
            assert_eq!(r.stats.frontier_open, 0, "shards={shards} prune={prune:?}");
            let b = best_of(&r);
            assert_eq!(
                ref_winner.display_key(),
                r.variants[b].display_key(),
                "shards={shards} prune={prune:?}: winner key diverged"
            );
            assert_eq!(
                reference.scores[rb], r.scores[b],
                "shards={shards} prune={prune:?}: winner score diverged"
            );
            let prog = format!("{:?}", lower(&r.variants[b].expr, &env).unwrap());
            assert_eq!(
                ref_prog, prog,
                "shards={shards} prune={prune:?}: winner program diverged"
            );
        }
    }
}

/// Over budgets 1..=full on one family: the certified gap is monotone
/// non-increasing (expansion sets at different budgets are nested
/// prefixes of one deterministic sequence), kept-variant sequences are
/// nested prefixes too, the gap is ≥ 1.0 throughout and `1.0` exactly
/// when the run completes — which the final budget does.
#[test]
fn certified_gap_is_monotone_in_budget_and_one_exactly_at_completion() {
    let ctx = Ctx::new(env(4, 8, 4));
    let start = starts::matmul_rnz_subdivided_variant(2);
    let full = enumerate_search(&start, &ctx, &scored_opts(shard_count())).unwrap();
    assert!(full.stats.complete);
    let total = full.stats.expanded;
    assert!(total >= 4, "family too small to exercise truncation");
    let full_keys: Vec<String> = full.variants.iter().map(|v| v.display_key()).collect();
    let mut prev_gap = f64::INFINITY;
    for budget in 1..=total {
        let opts = SearchOptions {
            budget,
            ..scored_opts(shard_count())
        };
        let r = enumerate_search(&start, &ctx, &opts).unwrap();
        let gap = r.stats.certified_gap;
        assert!(gap >= 1.0, "budget={budget}: gap {gap} below 1.0");
        assert!(
            gap <= prev_gap,
            "budget={budget}: gap {gap} rose above the previous budget's {prev_gap}"
        );
        prev_gap = gap;
        assert_eq!(
            gap == 1.0,
            r.stats.complete,
            "budget={budget}: gap must be 1.0 iff the frontier drained"
        );
        assert_eq!(
            r.stats.complete,
            !r.stats.budget_hit,
            "budget={budget}: the only truncation cause here is the budget"
        );
        if !r.stats.complete {
            assert!(
                r.stats.frontier_open > 0,
                "budget={budget}: a truncated run must leave open nodes"
            );
            assert!(r.stats.min_open_bound.is_finite(), "budget={budget}");
        }
        // Nested-prefix discovery: the truncated kept sequence is a
        // prefix of the full run's.
        let keys: Vec<String> = r.variants.iter().map(|v| v.display_key()).collect();
        assert!(
            keys.len() <= full_keys.len() && keys[..] == full_keys[..keys.len()],
            "budget={budget}: kept sequence is not a prefix of the full run's"
        );
        assert_eq!(r.scores[..], full.scores[..keys.len()], "budget={budget}");
    }
    assert_eq!(prev_gap, 1.0, "the final budget covers the whole frontier");
}

/// Budget-truncated runs are deterministic across shard counts: same kept
/// sequence, bit-identical scores, bit-identical certified gap at shards
/// 1, 2 and 8 — the wave composition is shard-count-independent.
#[test]
fn truncated_runs_are_shard_count_independent() {
    let ctx = Ctx::new(env(4, 8, 4));
    let start = starts::matmul_all_subdivided_variant(2);
    for budget in [1usize, 2, 3, 5] {
        let mk = |shards: usize| SearchOptions {
            budget,
            ..scored_opts(shards)
        };
        let serial = enumerate_search(&start, &ctx, &mk(1)).unwrap();
        let serial_keys: Vec<String> =
            serial.variants.iter().map(|v| v.display_key()).collect();
        for shards in [2usize, 8] {
            let r = enumerate_search(&start, &ctx, &mk(shards)).unwrap();
            let keys: Vec<String> = r.variants.iter().map(|v| v.display_key()).collect();
            assert_eq!(serial_keys, keys, "budget={budget} shards={shards}");
            assert_eq!(serial.scores, r.scores, "budget={budget} shards={shards}");
            assert_eq!(
                serial.stats.certified_gap.to_bits(),
                r.stats.certified_gap.to_bits(),
                "budget={budget} shards={shards}: gap diverged"
            );
            assert_eq!(
                serial.stats.expanded, r.stats.expanded,
                "budget={budget} shards={shards}"
            );
        }
    }
}

/// Gap soundness on randomized seeded shapes across the subdivided
/// families: a truncated run's winner score never exceeds
/// `certified_gap ×` the family's true optimum (known from the unlimited
/// run of the same family).
#[test]
fn prop_truncated_winner_is_within_certified_gap_of_true_optimum() {
    let mut rng = Rng::new(0xa17e);
    let mut shapes = vec![(4usize, 8usize, 4usize)];
    for _ in 0..2 {
        shapes.push((2 * rng.range(1, 4), 8 * rng.range(1, 3), 2 * rng.range(1, 4)));
    }
    for (n, j, k) in shapes {
        let ctx = Ctx::new(env(n, j, k));
        for (name, start) in families() {
            let full = enumerate_search(&start, &ctx, &scored_opts(shard_count())).unwrap();
            assert!(full.stats.complete, "{name} @ {n}x{j}x{k}");
            let true_opt = full.scores[best_of(&full)];
            let total = full.stats.expanded;
            for budget in [1usize, (total / 2).max(1)] {
                let opts = SearchOptions {
                    budget,
                    ..scored_opts(shard_count())
                };
                let r = enumerate_search(&start, &ctx, &opts).unwrap();
                let winner = r.scores[best_of(&r)];
                let gap = r.stats.certified_gap;
                assert!(gap >= 1.0, "{name} @ {n}x{j}x{k} budget={budget}");
                assert!(
                    winner <= gap * true_opt,
                    "{name} @ {n}x{j}x{k} budget={budget}: winner {winner} \
                     escapes gap {gap} × optimum {true_opt}"
                );
            }
        }
    }
}

/// An already-expired deadline truncates before the first wave: the start
/// variant comes back immediately with `deadline_hit` set. With scoring
/// on the run still certifies a finite gap (the start is scored and the
/// start's floor is open); with scoring off there is nothing to certify
/// and the gap is `+∞`.
#[test]
fn expired_deadline_returns_start_with_deadline_hit() {
    let ctx = Ctx::new(env(4, 8, 4));
    let start = starts::matmul_rnz_subdivided_variant(2);
    for score in [true, false] {
        let opts = SearchOptions {
            limit: 4096,
            shards: shard_count(),
            prune_slack: None,
            score,
            deadline: Some(std::time::Instant::now()),
            ..SearchOptions::default()
        };
        let r = enumerate_search(&start, &ctx, &opts).unwrap();
        assert!(r.stats.deadline_hit, "score={score}");
        assert!(!r.stats.complete, "score={score}");
        assert_eq!(r.variants.len(), 1, "score={score}: only the start");
        assert_eq!(r.variants[0].display_key(), start.display_key());
        assert_eq!(r.stats.expanded, 0, "score={score}");
        assert!(r.stats.frontier_open > 0, "score={score}");
        if score {
            assert!(
                r.stats.certified_gap.is_finite() && r.stats.certified_gap > 1.0,
                "score={score}: gap {}",
                r.stats.certified_gap
            );
        } else {
            assert!(
                r.stats.certified_gap.is_infinite(),
                "score={score}: nothing to certify without scores"
            );
        }
    }
}
