//! Differential and mutation tests for the static access-footprint
//! verifier (ISSUE 6):
//!
//! - **trace ⊆ footprint**: for every variant of every search family, at
//!   the canonical shapes and at seeded random shapes, every access the
//!   dynamic tracer emits lies inside the statically certified
//!   [`hofdla::verify::Footprint`] — and the static per-program access
//!   *counts* equal the trace's exactly (the analysis is exact, not
//!   conservative). Runs at the CI `SEARCH_SHARDS` width (1, 2, 8) so the
//!   verified programs are the ones the sharded search actually produces.
//! - **mutations reject**: corrupting any strided `Adv`, any loop extent,
//!   or a temp size in a lowered program makes `verify` fail, with a
//!   diagnostic naming the offending space and track where applicable.
//!   A verifier that accepts everything would pass the differential suite;
//!   these prove it can actually say no.
//! - **parallel certificates** (ISSUE 10): every map loop of every shipped
//!   family certifies `Parallel` (no temps, disjoint chunks), corrupted
//!   programs never reach certification (verify rejects them outright),
//!   and a verifiable aliasing program — a map whose body declares a
//!   shared reduction temp — demotes to `Serial` with a reason naming the
//!   temp and executes serially under a threaded request, bit-identical,
//!   never racing.

use hofdla::enumerate::{enumerate_search, starts, SearchOptions, Variant, MAX_SEARCH_SHARDS};
use hofdla::exec::{count_accesses, lower, trace, Node, Program};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;
use hofdla::util::Rng;
use hofdla::verify::verify;

/// Shard count under test — the CI matrix sets `SEARCH_SHARDS` (1, 2, 8),
/// mirroring `tests/search_props.rs`.
fn shard_count() -> usize {
    std::env::var("SEARCH_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(4)
        .min(MAX_SEARCH_SHARDS)
}

/// A is n×j, B is j×k, v has length j. `j` must be divisible by 4 (the
/// twice-subdivided family blocks it by 2·2) and n, k by 2 (the map
/// subdivisions).
fn env(n: usize, j: usize, k: usize) -> Env {
    Env::new()
        .with("A", Layout::row_major(&[n, j]))
        .with("B", Layout::row_major(&[j, k]))
        .with("v", Layout::row_major(&[j]))
}

fn families() -> Vec<(&'static str, Variant)> {
    vec![
        ("matmul-naive", starts::matmul_naive_variant()),
        ("matmul-rnz-subdiv", starts::matmul_rnz_subdivided_variant(2)),
        ("matmul-maps-subdiv", starts::matmul_maps_subdivided_variant(2)),
        ("matmul-rnz-twice", starts::matmul_rnz_twice_subdivided_variant(2, 2)),
        ("matmul-all-subdiv", starts::matmul_all_subdivided_variant(2)),
        ("matvec-naive", starts::matvec_naive_variant()),
        ("matvec-vector-subdiv", starts::matvec_vector_subdivided_variant(2)),
        ("matvec-map-subdiv", starts::matvec_map_subdivided_variant(2)),
    ]
}

/// Every lowered variant of every family, at the given shape.
fn family_programs(n: usize, j: usize, k: usize) -> Vec<(String, Program)> {
    let env = env(n, j, k);
    let ctx = Ctx::new(env.clone());
    let opts = SearchOptions {
        limit: 4096,
        shards: shard_count(),
        prune_slack: None,
        score: false,
        ..SearchOptions::default()
    };
    let mut out = Vec::new();
    for (name, start) in families() {
        let r = enumerate_search(&start, &ctx, &opts).unwrap();
        for v in &r.variants {
            let key = format!("{name}/{} @ {n}x{j}x{k}", v.display_key());
            out.push((key, lower(&v.expr, &env).unwrap()));
        }
    }
    out
}

#[test]
fn prop_traced_accesses_lie_inside_static_footprint() {
    let mut rng = Rng::new(0x6fda);
    // The canonical search-props shape plus seeded random shapes with the
    // required divisibility.
    let mut shapes = vec![(4usize, 8usize, 4usize)];
    for _ in 0..2 {
        shapes.push((2 * rng.range(1, 4), 8 * rng.range(1, 3), 2 * rng.range(1, 4)));
    }
    for (n, j, k) in shapes {
        for (key, prog) in family_programs(n, j, k) {
            let fp = verify(&prog).unwrap_or_else(|e| panic!("{key}: {e}"));
            trace(&prog, &mut |a| {
                assert!(
                    fp.contains(&a),
                    "{key}: traced access {a:?} escapes the static footprint"
                );
            })
            .unwrap();
            let (reads, writes) = count_accesses(&prog).unwrap();
            assert_eq!(
                (fp.reads(), fp.writes()),
                (reads as u64, writes as u64),
                "{key}: static counts must replicate the trace exactly"
            );
        }
    }
}

/// Number of corruptible stride sites: strided advances owned by loops
/// that actually iterate (`extent > 1`, so the corruption is observable).
fn stride_sites(node: &Node) -> usize {
    match node {
        Node::MapLoop {
            extent, advances, body, ..
        }
        | Node::RedLoop {
            extent, advances, body, ..
        } => {
            let here = if *extent > 1 {
                advances.iter().filter(|a| a.stride > 0).count()
            } else {
                0
            };
            here + stride_sites(body)
        }
        Node::Leaf(_) => 0,
    }
}

/// Inflate the `i`-th stride site by a factor large enough to escape any
/// of the small test shapes. Returns false if `i` is out of range.
fn corrupt_nth_stride(node: &mut Node, mut i: usize) -> bool {
    match node {
        Node::MapLoop {
            extent, advances, body, ..
        }
        | Node::RedLoop {
            extent, advances, body, ..
        } => {
            if *extent > 1 {
                for a in advances.iter_mut().filter(|a| a.stride > 0) {
                    if i == 0 {
                        a.stride = a.stride.saturating_mul(1000);
                        return true;
                    }
                    i -= 1;
                }
            }
            corrupt_nth_stride(body, i)
        }
        Node::Leaf(_) => false,
    }
}

/// Number of corruptible extent sites: every map loop (its output span
/// changes, tripping the structural checks), and every reduction that
/// steps at least one track (the extra iteration reads past the end).
fn extent_sites(node: &Node) -> usize {
    match node {
        Node::MapLoop { body, .. } => 1 + extent_sites(body),
        Node::RedLoop { advances, body, .. } => {
            usize::from(advances.iter().any(|a| a.stride > 0)) + extent_sites(body)
        }
        Node::Leaf(_) => 0,
    }
}

fn corrupt_nth_extent(node: &mut Node, i: usize) -> bool {
    match node {
        Node::MapLoop { extent, body, .. } => {
            if i == 0 {
                *extent += 1;
                true
            } else {
                corrupt_nth_extent(body, i - 1)
            }
        }
        Node::RedLoop {
            extent, advances, body, ..
        } => {
            if advances.iter().any(|a| a.stride > 0) {
                if i == 0 {
                    *extent += 1;
                    true
                } else {
                    corrupt_nth_extent(body, i - 1)
                }
            } else {
                corrupt_nth_extent(body, i)
            }
        }
        Node::Leaf(_) => false,
    }
}

/// Exhaustive single-fault injection over every family variant: each
/// strided advance corrupted in isolation must be rejected, and the
/// diagnostic must name the space and the track the bad stride reads
/// through.
#[test]
fn mutation_every_corrupted_stride_is_rejected_naming_space_and_track() {
    let mut corrupted = 0usize;
    for (key, prog) in family_programs(4, 8, 4) {
        for i in 0..stride_sites(&prog.root) {
            let mut bad = prog.clone();
            assert!(corrupt_nth_stride(&mut bad.root, i));
            let err = verify(&bad)
                .err()
                .unwrap_or_else(|| panic!("{key}: stride site {i} corrupted, still verifies"));
            let msg = err.to_string();
            assert!(
                msg.contains("out of bounds") && msg.contains("track"),
                "{key}: site {i} diagnostic must name space and track: {msg}"
            );
            corrupted += 1;
        }
    }
    assert!(corrupted > 50, "fault injection barely ran ({corrupted} sites)");
}

/// Exhaustive single-fault injection on loop extents: growing any
/// observable extent by one must be rejected (overlapping map iterations,
/// a root/out_size mismatch, or a read past the end of an input).
#[test]
fn mutation_every_corrupted_extent_is_rejected() {
    let mut corrupted = 0usize;
    for (key, prog) in family_programs(4, 8, 4) {
        for i in 0..extent_sites(&prog.root) {
            let mut bad = prog.clone();
            assert!(corrupt_nth_extent(&mut bad.root, i));
            assert!(
                verify(&bad).is_err(),
                "{key}: extent site {i} corrupted, still verifies"
            );
            corrupted += 1;
        }
    }
    assert!(corrupted > 50, "fault injection barely ran ({corrupted} sites)");
}

/// Seeded shapes for the temp path: a reduction whose operator differs
/// from its enclosing accumulator lowers with a private temp region; its
/// declared size is part of the verified surface. Corrupting it must be
/// rejected naming the temp — and the intact program's temp traffic must
/// replicate the trace.
#[test]
fn mutation_corrupted_temp_size_is_rejected_naming_temp() {
    use hofdla::dsl::{add, input, lam1, pmax, reduce, rnz, var};
    let mut rng = Rng::new(0x7e3b);
    for _ in 0..8 {
        let (r, c) = (rng.range(2, 6), rng.range(2, 9));
        let env = Env::new().with("A", Layout::row_major(&[r, c]));
        let e = rnz(pmax(), lam1("row", reduce(add(), var("row"))), vec![input("A")]);
        let prog = lower(&e, &env).unwrap();
        assert!(!prog.temp_sizes.is_empty(), "mixed-op reduction must use a temp");

        let fp = verify(&prog).unwrap();
        let (reads, writes) = count_accesses(&prog).unwrap();
        assert_eq!((fp.reads(), fp.writes()), (reads as u64, writes as u64));
        trace(&prog, &mut |a| assert!(fp.contains(&a), "{r}x{c}: {a:?}")).unwrap();

        let mut bad = prog.clone();
        bad.temp_sizes[0] += 1;
        let msg = verify(&bad).unwrap_err().to_string();
        assert!(
            msg.contains("temp 0"),
            "{r}x{c}: diagnostic must name the temp: {msg}"
        );
    }
}

/// Parallel-safety certificates (ISSUE 10): the shipped families carry
/// only all-`+` reductions, which lower without temp regions, so the
/// dependence analysis must certify every map loop `Parallel` — one cert
/// row per map in the nest, root included.
#[test]
fn par_cert_every_family_map_loop_certifies_parallel() {
    use hofdla::verify::ParVerdict;
    for (key, prog) in family_programs(4, 8, 4) {
        let fp = verify(&prog).unwrap_or_else(|e| panic!("{key}: {e}"));
        let maps = prog.loop_kinds().iter().filter(|k| **k == "map").count();
        assert_eq!(fp.par.loops.len(), maps, "{key}: one cert row per map loop");
        assert_eq!(fp.par.serial_loops(), 0, "{key}: no temps, nothing demotes");
        if let Node::MapLoop { extent, .. } = &prog.root {
            let root = fp
                .par
                .root()
                .unwrap_or_else(|| panic!("{key}: map root must carry a root cert"));
            assert_eq!(
                root.verdict,
                ParVerdict::Parallel { chunks_disjoint: *extent },
                "{key}: root map over disjoint chunks must certify Parallel"
            );
        }
    }
}

/// Single-fault injection against the certificate. Corrupted strides and
/// extents never reach certification — `verify` rejects them outright
/// with the space/track-naming `Violation`s pinned by the mutation tests
/// above, so no cert-bearing `Footprint` exists for a racy program. The
/// reachable `Serial` verdict is the aliasing shape: a map whose body
/// declares a mixed-op reduction temp (one arena slot shared by every
/// iteration) verifies fine but demotes with a reason naming the temp —
/// and the executor fails closed, running a threaded request serially,
/// bit-identical to `execute`, never racing on the shared slot.
#[test]
fn par_cert_faults_demote_to_serial_or_reject_and_fail_closed() {
    use hofdla::dsl::{add, input, lam1, map, pmax, reduce, rnz, subdiv, var};
    use hofdla::exec::{execute, execute_threaded};
    use hofdla::verify::{ParVerdict, SerialReason};
    for (key, prog) in family_programs(4, 8, 4) {
        if stride_sites(&prog.root) > 0 {
            let mut bad = prog.clone();
            assert!(corrupt_nth_stride(&mut bad.root, 0));
            assert!(verify(&bad).is_err(), "{key}: corrupted program must not certify");
        }
        if extent_sites(&prog.root) > 0 {
            let mut bad = prog.clone();
            assert!(corrupt_nth_extent(&mut bad.root, 0));
            assert!(verify(&bad).is_err(), "{key}: corrupted program must not certify");
        }
    }
    let env = Env::new().with("A", Layout::row_major(&[3, 4]));
    let e = map(
        lam1(
            "r",
            rnz(pmax(), lam1("c", reduce(add(), var("c"))), vec![subdiv(0, 2, var("r"))]),
        ),
        input("A"),
    );
    let prog = lower(&e, &env).unwrap();
    assert_eq!(prog.temp_sizes.len(), 1, "mixed-op inner reduction must use a temp");
    let fp = verify(&prog).unwrap();
    let root = fp.par.root().expect("map root carries a cert");
    let ParVerdict::Serial { reason } = &root.verdict else {
        panic!("shared-temp map must demote, got {:?}", root.verdict);
    };
    assert!(
        matches!(reason, SerialReason::SharedTemp { temp: 0 }),
        "expected SharedTemp, got {reason:?}"
    );
    assert!(reason.to_string().contains("temp 0"), "reason must name the temp: {reason}");
    let a: Vec<f64> = (0..12).map(|i| (i as f64) - 5.5).collect();
    let mut serial = vec![0.0; prog.out_size];
    execute(&prog, &[&a], &mut serial).unwrap();
    let mut threaded = vec![0.0; prog.out_size];
    let rep = execute_threaded(&prog, &[&a], &mut threaded, 8).unwrap();
    assert!(rep.serial_fallback, "Serial verdict must force the fallback");
    assert_eq!((rep.parallel_loops, rep.threads_used), (0, 1));
    assert!(
        serial.iter().zip(&threaded).all(|(x, y)| x.to_bits() == y.to_bits()),
        "fail-closed execution must be bit-identical to serial"
    );
}

/// Seeded random single-fault sampling at random shapes — the same
/// injections as the exhaustive tests above, but at shapes the exhaustive
/// pass doesn't cover, so shape-dependent strides are also exercised.
#[test]
fn mutation_seeded_random_faults_at_random_shapes_are_rejected() {
    let mut rng = Rng::new(0xfa57);
    for _ in 0..3 {
        let (n, j, k) = (2 * rng.range(1, 4), 8 * rng.range(1, 3), 2 * rng.range(1, 4));
        let progs = family_programs(n, j, k);
        for _ in 0..24 {
            let (key, prog) = rng.pick(&progs);
            let mut bad = prog.clone();
            let ok = if rng.chance(0.5) {
                let sites = stride_sites(&bad.root);
                sites > 0 && corrupt_nth_stride(&mut bad.root, rng.below(sites))
            } else {
                let sites = extent_sites(&bad.root);
                sites > 0 && corrupt_nth_extent(&mut bad.root, rng.below(sites))
            };
            if !ok {
                continue;
            }
            assert!(
                verify(&bad).is_err(),
                "{key} @ {n}x{j}x{k}: corrupted program still verifies"
            );
        }
    }
}
