//! Property and differential tests for the hash-consed expression arena
//! (`dsl::intern`) and the memoized rewrite engine built on it (ISSUE 1):
//!
//! - random `Expr` trees round-trip through the arena unchanged, and
//!   structurally-equal trees intern to the same id;
//! - the memoized `normalize` agrees node-for-node (up to the
//!   alpha-renaming inherent in fresh-binder rules) with the unmemoized
//!   seed implementation;
//! - `enumerate_all` and the full optimize pipeline produce the same
//!   variant set and the same cost-model ranking with interning on and
//!   off.

use hofdla::coordinator::{optimize, OptimizeSpec, RankBy};
use hofdla::dsl::intern::{with_memo_disabled, ExprArena};
use hofdla::dsl::{self, Expr, Prim};
use hofdla::enumerate::{enumerate_all, starts};
use hofdla::layout::Layout;
use hofdla::rewrite::{normalize, normalize_uncached, Ctx};
use hofdla::typecheck::Env;
use hofdla::util::Rng;

/// Generate a random expression. Function positions only ever hold `Prim`
/// or `Lam` (never a variable), which keeps the fragment strongly
/// normalizing under β — the generator can safely produce β/η redexes
/// without risking divergence in `normalize`.
fn gen_expr(rng: &mut Rng, depth: usize, scope: &mut Vec<String>) -> Expr {
    if depth == 0 || rng.chance(0.25) {
        return match rng.below(4) {
            0 if !scope.is_empty() => Expr::Var(rng.pick(scope.as_slice()).clone()),
            1 => dsl::lit((rng.below(16) as f64) - 8.0),
            2 => dsl::input(&format!("in{}", rng.below(3))),
            _ => dsl::lit(rng.range_f64(-4.0, 4.0)),
        };
    }
    match rng.below(8) {
        0 => gen_lam(rng, depth, scope),
        1 => {
            // Application of a primitive.
            let p = *rng.pick(&[Prim::Add, Prim::Mul, Prim::Sub, Prim::Neg, Prim::Relu]);
            let args = (0..p.arity())
                .map(|_| gen_expr(rng, depth - 1, scope))
                .collect();
            Expr::App {
                f: Box::new(Expr::Prim(p)),
                args,
            }
        }
        2 => {
            // A β-redex: a lambda applied to matching arguments.
            let k = 1 + rng.below(2);
            let f = gen_lam_with_arity(rng, depth, scope, k);
            let args = (0..k)
                .map(|_| gen_expr(rng, depth.saturating_sub(2), scope))
                .collect();
            Expr::App {
                f: Box::new(f),
                args,
            }
        }
        3 => {
            let k = 1 + rng.below(2);
            let f = gen_lam_with_arity(rng, depth, scope, k);
            let args = (0..k)
                .map(|_| gen_expr(rng, depth - 1, scope))
                .collect();
            Expr::Nzip {
                f: Box::new(f),
                args,
            }
        }
        4 => {
            let k = 1 + rng.below(2);
            let r = Expr::Prim(*rng.pick(&[Prim::Add, Prim::Mul, Prim::Max]));
            let m = gen_lam_with_arity(rng, depth, scope, k);
            let args = (0..k)
                .map(|_| gen_expr(rng, depth - 1, scope))
                .collect();
            Expr::Rnz {
                r: Box::new(r),
                m: Box::new(m),
                args,
            }
        }
        5 => dsl::lift(if rng.chance(0.5) {
            Expr::Prim(Prim::Add)
        } else {
            gen_lam_with_arity(rng, depth, scope, 1)
        }),
        6 => dsl::subdiv(
            rng.below(2),
            1 + rng.below(4),
            gen_expr(rng, depth - 1, scope),
        ),
        _ => match rng.below(3) {
            0 => dsl::flatten(rng.below(2), gen_expr(rng, depth - 1, scope)),
            1 => dsl::flip2(rng.below(3), rng.below(3), gen_expr(rng, depth - 1, scope)),
            _ => dsl::flip(rng.below(2), gen_expr(rng, depth - 1, scope)),
        },
    }
}

fn gen_lam(rng: &mut Rng, depth: usize, scope: &mut Vec<String>) -> Expr {
    let k = 1 + rng.below(2);
    gen_lam_with_arity(rng, depth, scope, k)
}

fn gen_lam_with_arity(rng: &mut Rng, depth: usize, scope: &mut Vec<String>, k: usize) -> Expr {
    let params: Vec<String> = (0..k)
        .map(|i| format!("p{}_{}", scope.len(), i))
        .collect();
    scope.extend(params.iter().cloned());
    let body = gen_expr(rng, depth - 1, scope);
    scope.truncate(scope.len() - k);
    Expr::Lam {
        params,
        body: Box::new(body),
    }
}

#[test]
fn prop_arena_round_trip_preserves_structure() {
    let mut rng = Rng::new(0x1a7e);
    let mut arena = ExprArena::new();
    for _ in 0..300 {
        let depth = 1 + rng.below(5);
        let e = gen_expr(&mut rng, depth, &mut Vec::new());
        let id = arena.intern(&e);
        let back = arena.extract(id);
        assert_eq!(back, e, "arena round trip changed the tree");
        // Hash-consing: interning the same structure again is the same id.
        assert_eq!(arena.intern(&e.clone()), id);
    }
}

#[test]
fn prop_arena_shares_equal_subtrees() {
    let mut rng = Rng::new(0xc0de);
    for _ in 0..50 {
        let mut arena = ExprArena::new();
        let sub = gen_expr(&mut rng, 3, &mut Vec::new());
        let e = Expr::App {
            f: Box::new(Expr::Prim(Prim::Add)),
            args: vec![sub.clone(), sub.clone()],
        };
        arena.intern(&e);
        // Both copies of `sub` collapse onto one set of nodes: the arena
        // holds at most (sub nodes + the App + the Prim).
        assert!(
            arena.len() <= sub.size() + 2,
            "arena stored duplicate subtrees: {} nodes for sub of size {}",
            arena.len(),
            sub.size()
        );
    }
}

#[test]
fn prop_memoized_normalize_agrees_with_seed_implementation() {
    let mut rng = Rng::new(0xbeef);
    for i in 0..300 {
        let depth = 1 + rng.below(5);
        let e = gen_expr(&mut rng, depth, &mut Vec::new());
        let memoized = normalize(&e);
        let reference = normalize_uncached(&e);
        assert!(
            memoized.alpha_eq(&reference),
            "case {i}: memoized and seed normalize disagree\n  input: {}\n  memo:  {}\n  seed:  {}",
            dsl::pretty(&e),
            dsl::pretty(&memoized),
            dsl::pretty(&reference)
        );
    }
}

/// `with_memo_disabled` switches `normalize`/`fuse` to the unmemoized
/// seed engine; `enumerate_all`'s interned typecheck dedup is
/// behavior-neutral and runs in both arms (its output invariants — the
/// exact 6/12 variant counts — are pinned by the enumerate/pipeline unit
/// tests). So this differential isolates the memoized rewrite path.
#[test]
fn differential_enumerate_same_variants_with_and_without_rewrite_memo() {
    let env = Env::new()
        .with("A", Layout::row_major(&[4, 8]))
        .with("B", Layout::row_major(&[8, 4]));
    let ctx = Ctx::new(env);
    let start = starts::matmul_rnz_subdivided_variant(2);
    let with_intern = enumerate_all(&start, &ctx, 200).unwrap();
    let without = with_memo_disabled(|| enumerate_all(&start, &ctx, 200)).unwrap();
    assert_eq!(with_intern.len(), without.len(), "variant count diverged");
    for (a, b) in with_intern.iter().zip(&without) {
        assert_eq!(a.display_key(), b.display_key(), "variant order diverged");
        assert_eq!(a.labels, b.labels);
        assert!(
            a.expr.alpha_eq(&b.expr),
            "{}: interned and seed variants differ structurally",
            a.display_key()
        );
    }
}

#[test]
fn differential_pipeline_same_ranking_with_and_without_rewrite_memo() {
    let spec = OptimizeSpec::builder(
        "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
    )
    .input("A", &[32, 32])
    .input("B", &[32, 32])
    .rank_by(RankBy::CostModel)
    .subdivide_rnz(4)
    .top_k(12)
    .build()
    .unwrap();
    let with_intern = optimize(&spec).unwrap();
    let without = with_memo_disabled(|| optimize(&spec)).unwrap();
    assert_eq!(with_intern.variants_explored, 12, "Table 2 count");
    assert_eq!(with_intern.variants_explored, without.variants_explored);
    assert_eq!(with_intern.best, without.best);
    // Identical top-k: same keys, bit-identical scores.
    assert_eq!(with_intern.ranking, without.ranking);
}
