//! Property tests for the layout algebra (DESIGN.md §7 invariants).
//! proptest is unavailable offline; these use the crate's deterministic
//! PRNG with many random cases per property.

use hofdla::layout::{Dim, Layout, View};
use hofdla::util::{divisors, Rng};

/// Random dense row-major layout with rank 1-4 and small extents.
fn random_layout(rng: &mut Rng) -> Layout {
    let rank = rng.range(1, 5);
    let shape: Vec<usize> = (0..rank).map(|_| rng.range(1, 7)).collect();
    Layout::row_major(&shape)
}

/// Random chain of layout ops applied to a dense layout (always valid).
fn random_view_chain(rng: &mut Rng, base: &Layout, ops: usize) -> Layout {
    let mut l = base.clone();
    for _ in 0..ops {
        match rng.below(3) {
            0 => {
                let d = rng.below(l.rank());
                let divs = divisors(l.dims[d].extent);
                let b = *rng.pick(&divs);
                l = l.subdiv(d, b).unwrap();
            }
            1 => {
                if l.rank() >= 2 {
                    let d1 = rng.below(l.rank());
                    let d2 = rng.below(l.rank());
                    l = l.flip2(d1, d2).unwrap();
                }
            }
            _ => {
                // flatten only when it chains
                if l.rank() >= 2 {
                    let d = rng.below(l.rank() - 1);
                    if l.dims[d + 1].stride == l.dims[d].extent * l.dims[d].stride {
                        l = l.flatten(d).unwrap();
                    }
                }
            }
        }
    }
    l
}

#[test]
fn prop_subdiv_flatten_roundtrip() {
    let mut rng = Rng::new(101);
    for _ in 0..500 {
        let l = random_layout(&mut rng);
        let d = rng.below(l.rank());
        let divs = divisors(l.dims[d].extent);
        let b = *rng.pick(&divs);
        let round = l.subdiv(d, b).unwrap().flatten(d).unwrap();
        assert_eq!(round, l, "subdiv({d},{b}) then flatten on {l}");
    }
}

#[test]
fn prop_flip_involution() {
    let mut rng = Rng::new(102);
    for _ in 0..500 {
        let base = random_layout(&mut rng);
        let l = random_view_chain(&mut rng, &base, 3);
        if l.rank() < 2 {
            continue;
        }
        let d1 = rng.below(l.rank());
        let d2 = rng.below(l.rank());
        let twice = l.flip2(d1, d2).unwrap().flip2(d1, d2).unwrap();
        assert_eq!(twice, l);
        // commutativity in arguments
        assert_eq!(l.flip2(d1, d2).unwrap(), l.flip2(d2, d1).unwrap());
    }
}

#[test]
fn prop_layout_ops_preserve_element_set() {
    // subdiv/flip are logical reshapes: the set of flat offsets reachable
    // must not change (flatten requires chaining, so it's included via
    // random_view_chain's guard).
    let mut rng = Rng::new(103);
    for _ in 0..300 {
        let base = random_layout(&mut rng);
        let mut expect = base.offsets();
        expect.sort_unstable();
        let chained = random_view_chain(&mut rng, &base, 4);
        let mut got = chained.offsets();
        got.sort_unstable();
        assert_eq!(got, expect, "{base} vs {chained}");
    }
}

#[test]
fn prop_dense_views_stay_injective() {
    let mut rng = Rng::new(104);
    for _ in 0..300 {
        let base = random_layout(&mut rng);
        let l = random_view_chain(&mut rng, &base, 4);
        assert!(l.is_injective(), "{l}");
    }
}

#[test]
fn prop_index_outer_matches_offsets() {
    // Walking the outermost dimension and recursing must visit exactly
    // layout.offsets() in logical order.
    fn collect(v: &View, out: &mut Vec<usize>) {
        if v.layout.is_scalar() {
            out.push(v.offset);
            return;
        }
        let outer = v.layout.outer().unwrap();
        for i in 0..outer.extent {
            collect(&v.index_outer(i).unwrap(), out);
        }
    }
    let mut rng = Rng::new(105);
    for _ in 0..200 {
        let base = random_layout(&mut rng);
        let l = random_view_chain(&mut rng, &base, 3);
        let v = View::of(l.clone());
        let mut walked = Vec::new();
        collect(&v, &mut walked);
        // offsets() iterates innermost-fastest; index_outer recursion is
        // outermost-first — both enumerate the same logical order.
        let direct = l.offsets();
        let mut sorted_w = walked.clone();
        let mut sorted_d = direct.clone();
        sorted_w.sort_unstable();
        sorted_d.sort_unstable();
        assert_eq!(sorted_w, sorted_d);
        // and same cardinality as the layout's logical size
        assert_eq!(walked.len(), l.len());
    }
}

#[test]
fn prop_required_span_bounds_offsets() {
    let mut rng = Rng::new(106);
    for _ in 0..300 {
        let base = random_layout(&mut rng);
        let l = random_view_chain(&mut rng, &base, 4);
        let max = l.offsets().into_iter().max().unwrap_or(0);
        assert_eq!(l.required_span(), max + 1);
    }
}

#[test]
fn paper_subdiv_equations_hold_pointwise() {
    // The subdiv equations from §2.1, checked literally.
    let mut rng = Rng::new(107);
    for _ in 0..200 {
        let l = random_layout(&mut rng);
        let d = rng.below(l.rank());
        let divs = divisors(l.dims[d].extent);
        let b = *rng.pick(&divs);
        let s = l.subdiv(d, b).unwrap();
        for i in 0..d {
            assert_eq!(s.dims[i], l.dims[i]);
        }
        assert_eq!(s.dims[d], Dim::new(b, l.dims[d].stride));
        assert_eq!(
            s.dims[d + 1],
            Dim::new(l.dims[d].extent / b, b * l.dims[d].stride)
        );
        for i in d + 2..s.rank() {
            assert_eq!(s.dims[i], l.dims[i - 1]);
        }
    }
}
