//! Property tests: every rewrite rule preserves evaluation semantics
//! (DESIGN.md §7). Random shapes, random data, many seeds; the reference
//! evaluator is the oracle, and the fast executor must agree with it on
//! every enumerated variant.

use hofdla::dsl::*;
use hofdla::enumerate::{enumerate_all, starts};
use hofdla::eval::{eval, ArrVal, Inputs};
use hofdla::exec;
use hofdla::layout::Layout;
use hofdla::rewrite::{exchange, fusion, normalize, subdivision, Ctx};
use hofdla::typecheck::Env;
use hofdla::util::{allclose, Rng};

fn dense(rng: &mut Rng, shape: &[usize]) -> ArrVal {
    let n: usize = shape.iter().product();
    ArrVal::dense(rng.fill_vec(n), shape)
}

/// Random sizes with a divisor for blocking.
fn sizes(rng: &mut Rng) -> (usize, usize, usize, usize) {
    let b = *rng.pick(&[2usize, 3, 4]);
    let n = b * rng.range(1, 4);
    let j = b * rng.range(1, 4);
    let k = b * rng.range(1, 4);
    (n, j, k, b)
}

#[test]
fn prop_fusion_preserves_semantics() {
    let mut rng = Rng::new(201);
    for _ in 0..100 {
        let (n, j, _, _) = sizes(&mut rng);
        let mut inp = Inputs::new();
        inp.insert("A".into(), dense(&mut rng, &[n, j]));
        inp.insert("u".into(), dense(&mut rng, &[j]));
        inp.insert("v".into(), dense(&mut rng, &[j]));
        // eq 1: map (\r -> rnz + * r (zip + u v)) A — with extra map noise
        let e = map(
            lam1(
                "r",
                rnz(
                    add(),
                    mul(),
                    vec![
                        var("r"),
                        zip(
                            add(),
                            map(lam1("x", app2(mul(), var("x"), lit(2.0))), input("u")),
                            input("v"),
                        ),
                    ],
                ),
            ),
            input("A"),
        );
        let fused = fusion::fuse(&e);
        let a = eval(&e, &inp).unwrap().to_dense();
        let b = eval(&fused, &inp).unwrap().to_dense();
        assert!(allclose(&a, &b, 1e-10));
        // fused form must be executor-lowerable
        let env = Env::new()
            .with("A", Layout::row_major(&[n, j]))
            .with("u", Layout::row_major(&[j]))
            .with("v", Layout::row_major(&[j]));
        assert!(exec::lower(&fused, &env).is_ok());
    }
}

#[test]
fn prop_map_rnz_exchange_preserves_semantics_exactly() {
    // eq 42 does not reorder multiplications, only regroups additions —
    // we still allow fp tolerance for the regrouping.
    let mut rng = Rng::new(202);
    for _ in 0..100 {
        let (n, j, _, _) = sizes(&mut rng);
        let mut inp = Inputs::new();
        inp.insert("A".into(), dense(&mut rng, &[n, j]));
        inp.insert("v".into(), dense(&mut rng, &[j]));
        let env = Env::new()
            .with("A", Layout::row_major(&[n, j]))
            .with("v", Layout::row_major(&[j]));
        let ctx = Ctx::new(env);
        let e = matvec_naive(input("A"), input("v"));
        let x = normalize(&exchange::map_rnz(&e, &ctx).unwrap());
        let a = eval(&e, &inp).unwrap().to_dense();
        let b = eval(&x, &inp).unwrap().to_dense();
        assert!(allclose(&a, &b, 1e-10));
        // and back
        let back = normalize(&exchange::rnz_map(&x, &ctx).unwrap());
        let c = eval(&back, &inp).unwrap().to_dense();
        assert!(allclose(&a, &c, 1e-10));
    }
}

#[test]
fn prop_subdivision_preserves_semantics() {
    let mut rng = Rng::new(203);
    for _ in 0..100 {
        let (_, j, _, b) = sizes(&mut rng);
        let mut inp = Inputs::new();
        inp.insert("u".into(), dense(&mut rng, &[j]));
        inp.insert("v".into(), dense(&mut rng, &[j]));
        let env = Env::new()
            .with("u", Layout::row_major(&[j]))
            .with("v", Layout::row_major(&[j]));
        let ctx = Ctx::new(env);
        let e = dot(input("u"), input("v"));
        let s = subdivision::subdivide_rnz(&e, b, &ctx).unwrap();
        let a = eval(&e, &inp).unwrap().as_scalar().unwrap();
        let c = eval(&s, &inp).unwrap().as_scalar().unwrap();
        assert!((a - c).abs() < 1e-9, "{a} vs {c} (b={b}, j={j})");
    }
}

#[test]
fn prop_all_table1_variants_match_oracle_and_executor() {
    let mut rng = Rng::new(204);
    for round in 0..12 {
        let (n, j, k, _) = sizes(&mut rng);
        let env = Env::new()
            .with("A", Layout::row_major(&[n, j]))
            .with("B", Layout::row_major(&[j, k]));
        let ctx = Ctx::new(env.clone());
        let mut inp = Inputs::new();
        let a = dense(&mut rng, &[n, j]);
        let b = dense(&mut rng, &[j, k]);
        inp.insert("A".into(), a.clone());
        inp.insert("B".into(), b.clone());
        let a_flat = a.to_dense();
        let b_flat = b.to_dense();
        let variants = enumerate_all(&starts::matmul_naive_variant(), &ctx, 16).unwrap();
        assert_eq!(variants.len(), 6, "round {round}");
        for v in &variants {
            // oracle
            let oracle = eval(&v.expr, &inp).unwrap().to_dense();
            // fast executor agrees with the oracle elementwise
            let got = exec::run(&v.expr, &env, &[("A", &a_flat), ("B", &b_flat)])
                .unwrap_or_else(|e| panic!("{}: {e}", v.display_key()));
            assert!(
                allclose(&oracle, &got, 1e-9),
                "executor diverges from oracle on {}",
                v.display_key()
            );
        }
    }
}

#[test]
fn prop_table2_variants_match_oracle_and_executor() {
    let mut rng = Rng::new(205);
    for _ in 0..6 {
        let (n, j, k, b) = sizes(&mut rng);
        let env = Env::new()
            .with("A", Layout::row_major(&[n, j]))
            .with("B", Layout::row_major(&[j, k]));
        let ctx = Ctx::new(env.clone());
        let mut inp = Inputs::new();
        let a = dense(&mut rng, &[n, j]);
        let bb = dense(&mut rng, &[j, k]);
        inp.insert("A".into(), a.clone());
        inp.insert("B".into(), bb.clone());
        let a_flat = a.to_dense();
        let b_flat = bb.to_dense();
        let variants =
            enumerate_all(&starts::matmul_rnz_subdivided_variant(b), &ctx, 64).unwrap();
        assert_eq!(variants.len(), 12);
        for v in &variants {
            let oracle = eval(&v.expr, &inp).unwrap().to_dense();
            let got = exec::run(&v.expr, &env, &[("A", &a_flat), ("B", &b_flat)])
                .unwrap_or_else(|e| panic!("{}: {e}", v.display_key()));
            assert!(
                allclose(&oracle, &got, 1e-9),
                "executor diverges from oracle on {}",
                v.display_key()
            );
        }
    }
}

#[test]
fn prop_hoist_subdiv_preserves_semantics() {
    let mut rng = Rng::new(206);
    for _ in 0..60 {
        let (n, j, _, b) = sizes(&mut rng);
        let mut inp = Inputs::new();
        inp.insert("A".into(), dense(&mut rng, &[n, j]));
        inp.insert("v".into(), dense(&mut rng, &[j]));
        // map (\r -> rnz + (\u w -> dot u w) (subdiv 0 b r) (subdiv 0 b v)) A
        let e = map(
            lam1(
                "r",
                rnz(
                    add(),
                    lam2("u", "w", dot(var("u"), var("w"))),
                    vec![subdiv(0, b, var("r")), subdiv(0, b, input("v"))],
                ),
            ),
            input("A"),
        );
        let hoisted =
            hofdla::rewrite::rewrite_bottom_up(&[subdivision::hoist_subdiv()], &e);
        let x = eval(&e, &inp).unwrap().to_dense();
        let y = eval(&hoisted, &inp).unwrap().to_dense();
        assert!(allclose(&x, &y, 1e-10));
        assert!(
            hofdla::dsl::pretty(&hoisted).contains("(subdiv 0"),
            "hoist dropped the subdivision"
        );
    }
}

#[test]
fn prop_enumeration_count_is_stable_under_shapes() {
    // Table 1 = 6 and Table 2 = 12 for every valid shape.
    let mut rng = Rng::new(207);
    for _ in 0..10 {
        let (n, j, k, b) = sizes(&mut rng);
        let env = Env::new()
            .with("A", Layout::row_major(&[n, j]))
            .with("B", Layout::row_major(&[j, k]));
        let ctx = Ctx::new(env);
        assert_eq!(
            enumerate_all(&starts::matmul_naive_variant(), &ctx, 64)
                .unwrap()
                .len(),
            6
        );
        assert_eq!(
            enumerate_all(&starts::matmul_rnz_subdivided_variant(b), &ctx, 64)
                .unwrap()
                .len(),
            12
        );
    }
}
