//! Differential oracle for the certificate-gated parallel executor
//! (ISSUE 10): for every variant of every search family — enumerated at
//! the CI `SEARCH_SHARDS` width (1, 2, 8), mirroring
//! `tests/verify_props.rs` — threaded execution at 1, 2 and 8 workers is
//! bit-identical to the serial interpreter, the [`ExecReport`] agrees
//! with the program shape (the shipped families lower without temps, so
//! a map root must actually chunk at >= 2 threads and anything else must
//! fail closed), and the verifier's footprint counts and parallel
//! certificate are facts about the *program*: re-verifying after a
//! threaded run reproduces them exactly.
//!
//! [`ExecReport`]: hofdla::exec::ExecReport

use hofdla::enumerate::{enumerate_search, starts, SearchOptions, Variant, MAX_SEARCH_SHARDS};
use hofdla::exec::{execute, execute_threaded, lower, order_inputs, Node, Program};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;
use hofdla::verify::verify;

/// Shard count under test — the CI matrix sets `SEARCH_SHARDS` (1, 2, 8),
/// mirroring `tests/search_props.rs`.
fn shard_count() -> usize {
    std::env::var("SEARCH_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(4)
        .min(MAX_SEARCH_SHARDS)
}

/// A is n×j, B is j×k, v has length j (the `verify_props` conventions).
fn env(n: usize, j: usize, k: usize) -> Env {
    Env::new()
        .with("A", Layout::row_major(&[n, j]))
        .with("B", Layout::row_major(&[j, k]))
        .with("v", Layout::row_major(&[j]))
}

fn families() -> Vec<(&'static str, Variant)> {
    vec![
        ("matmul-naive", starts::matmul_naive_variant()),
        ("matmul-rnz-subdiv", starts::matmul_rnz_subdivided_variant(2)),
        ("matmul-maps-subdiv", starts::matmul_maps_subdivided_variant(2)),
        ("matmul-rnz-twice", starts::matmul_rnz_twice_subdivided_variant(2, 2)),
        ("matmul-all-subdiv", starts::matmul_all_subdivided_variant(2)),
        ("matvec-naive", starts::matvec_naive_variant()),
        ("matvec-vector-subdiv", starts::matvec_vector_subdivided_variant(2)),
        ("matvec-map-subdiv", starts::matvec_map_subdivided_variant(2)),
    ]
}

/// Every lowered variant of every family, at the given shape.
fn family_programs(n: usize, j: usize, k: usize) -> Vec<(String, Program)> {
    let env = env(n, j, k);
    let ctx = Ctx::new(env.clone());
    let opts = SearchOptions {
        limit: 4096,
        shards: shard_count(),
        prune_slack: None,
        score: false,
        ..SearchOptions::default()
    };
    let mut out = Vec::new();
    for (name, start) in families() {
        let r = enumerate_search(&start, &ctx, &opts).unwrap();
        for v in &r.variants {
            let key = format!("{name}/{} @ {n}x{j}x{k}", v.display_key());
            out.push((key, lower(&v.expr, &env).unwrap()));
        }
    }
    out
}

#[test]
fn prop_threaded_execution_is_bit_identical_across_families_and_widths() {
    let (n, j, k) = (4usize, 8usize, 4usize);
    // Deterministic mixed-sign inputs: non-constant so a misplaced or
    // doubly-written element cannot cancel out of the comparison.
    let a: Vec<f64> = (0..n * j).map(|i| ((i % 11) as f64) * 0.5 - 2.0).collect();
    let b: Vec<f64> = (0..j * k).map(|i| ((i % 7) as f64) - 3.0).collect();
    let v: Vec<f64> = (0..j).map(|i| (i as f64) * 0.25 - 1.0).collect();
    for (key, prog) in family_programs(n, j, k) {
        let fp = verify(&prog).unwrap_or_else(|e| panic!("{key}: {e}"));
        let bufs = order_inputs(&prog, &[("A", &a), ("B", &b), ("v", &v)])
            .unwrap_or_else(|e| panic!("{key}: {e}"));
        let mut serial = vec![0.0; prog.out_size];
        execute(&prog, &bufs, &mut serial).unwrap_or_else(|e| panic!("{key}: {e}"));
        for threads in [1usize, 2, 8] {
            let mut out = vec![0.0; prog.out_size];
            let rep = execute_threaded(&prog, &bufs, &mut out, threads)
                .unwrap_or_else(|e| panic!("{key} @ {threads} threads: {e}"));
            assert!(
                serial.iter().zip(&out).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{key}: {threads}-thread output diverges from serial"
            );
            // The report must agree with the program shape: these
            // families lower without temps, so every map root certifies
            // Parallel and must chunk; a reduction root must fail closed.
            let map_root =
                matches!(&prog.root, Node::MapLoop { extent, .. } if *extent >= 2);
            if threads >= 2 && map_root {
                assert_eq!(
                    rep.parallel_loops, 1,
                    "{key} @ {threads} threads: certified map root must chunk"
                );
                assert!(!rep.serial_fallback, "{key} @ {threads} threads");
                assert!(
                    (2..=threads).contains(&rep.threads_used),
                    "{key} @ {threads} threads: used {}",
                    rep.threads_used
                );
            } else if threads >= 2 {
                assert!(
                    rep.serial_fallback && rep.parallel_loops == 0,
                    "{key} @ {threads} threads: non-map root must fail closed"
                );
            } else {
                assert!(
                    !rep.serial_fallback && rep.threads_used == 1,
                    "{key}: one thread is the serial path, not a fallback"
                );
            }
        }
        // Execution mode is invisible to the static analysis: re-verifying
        // the program after the threaded runs reproduces the footprint
        // counts and the certificate bit for bit.
        let fp2 = verify(&prog).unwrap_or_else(|e| panic!("{key}: {e}"));
        assert_eq!(
            (fp.reads(), fp.writes()),
            (fp2.reads(), fp2.writes()),
            "{key}: access counts must not depend on execution mode"
        );
        assert_eq!(fp.par, fp2.par, "{key}: certificate must be deterministic");
    }
}
