//! Cross-module integration tests: coordinator service over the full
//! pipeline, PJRT runtime against interpreter numerics, and the cache
//! simulator's reproduction of the paper's orderings.

use hofdla::coordinator::{Config, Coordinator, OptimizeSpec, RankBy, Request, Response};
use hofdla::util::Rng;

fn matmul_src() -> String {
    "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))".into()
}

#[test]
fn service_optimizes_and_executes_under_concurrency() {
    let c = Coordinator::start(Config {
        workers: 3,
        max_batch: 4,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::new(42);
    // Mixed workload: optimize jobs with varying shapes + artifact execs.
    let mut opt_handles = Vec::new();
    for _ in 0..12 {
        let n = 8 * rng.range(1, 5);
        let spec = OptimizeSpec::builder(matmul_src())
            .input("A", &[n, n])
            .input("B", &[n, n])
            .rank_by(RankBy::CostModel)
            .subdivide_rnz(if rng.chance(0.5) { Some(4) } else { None })
            .top_k(12)
            .prune(rng.chance(0.5))
            .verify(rng.chance(0.5))
            .build()
            .unwrap();
        let expected = if spec.subdivide_rnz.is_some() { 12 } else { 6 };
        let pruned = spec.prune;
        opt_handles.push((n, expected, pruned, c.submit(Request::Optimize(spec)).unwrap()));
    }
    for (n, expected, pruned, h) in opt_handles {
        let Response::Optimized(r) = h.wait().unwrap() else {
            panic!()
        };
        if pruned {
            // Branch-and-bound cuts dominated rearrangements out of the
            // report; the winner survives (pinned by search_props), so
            // the report is a non-empty subset.
            assert!(
                r.variants_explored >= 1 && r.variants_explored <= expected,
                "n={n}: pruned report out of range ({} of {expected})",
                r.variants_explored
            );
        } else {
            assert_eq!(r.variants_explored, expected, "n={n}");
        }
        assert_eq!(r.input_elems, 2 * n * n);
    }
    assert_eq!(c.metrics.in_flight(), 0);
}

/// PJRT tests skip (with a reason) rather than fail on machines that never
/// ran `make artifacts` or were built without the `pjrt` feature.
fn pjrt_runtime_or_skip(artifact: &str) -> Option<hofdla::runtime::Runtime> {
    if !hofdla::runtime::artifact_path(artifact).exists() {
        eprintln!("skipping: no AOT artifact '{artifact}' (run `make artifacts` first)");
        return None;
    }
    match hofdla::runtime::Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            None
        }
    }
}

#[test]
fn interpreter_matches_pjrt_artifact_numerics() {
    let art = hofdla::runtime::artifact_path("weighted_matmul_64");
    let Some(mut rt) = pjrt_runtime_or_skip("weighted_matmul_64") else {
        return;
    };
    // Paper eq 2: C_ik = Σ_j A_ij B_jk g_j — DSL form executed by the
    // interpreter vs the fused Pallas artifact through PJRT.
    use hofdla::dsl::*;
    use hofdla::layout::Layout;
    use hofdla::typecheck::Env;
    let n = 64usize;
    let mut rng = Rng::new(5);
    let a: Vec<f64> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let b: Vec<f64> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let g: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();

    // DSL: map (\rA -> map (\cB -> rnz + (\x y w -> x*y*w) rA cB g) …) A
    let e = map(
        lam1(
            "rA",
            map(
                lam1(
                    "cB",
                    rnz(
                        add(),
                        lam3(
                            "x",
                            "y",
                            "w",
                            app2(mul(), app2(mul(), var("x"), var("y")), var("w")),
                        ),
                        vec![var("rA"), var("cB"), input("g")],
                    ),
                ),
                flip(0, input("B")),
            ),
        ),
        input("A"),
    );
    let env = Env::new()
        .with("A", Layout::row_major(&[n, n]))
        .with("B", Layout::row_major(&[n, n]))
        .with("g", Layout::row_major(&[n]));
    let ours = hofdla::exec::run(&e, &env, &[("A", &a), ("B", &b), ("g", &g)]).unwrap();

    let exe = rt.load(&art).unwrap();
    let af: Vec<f32> = a.iter().map(|&x| x as f32).collect();
    let bf: Vec<f32> = b.iter().map(|&x| x as f32).collect();
    let gf: Vec<f32> = g.iter().map(|&x| x as f32).collect();
    let theirs = rt
        .run_f32(&exe, &[(&af, &[n, n]), (&bf, &[n, n]), (&gf, &[n])])
        .unwrap();
    let max_err = ours
        .iter()
        .zip(&theirs)
        .map(|(x, y)| (x - *y as f64).abs())
        .fold(0.0f64, f64::max);
    assert!(max_err < 1e-3, "eq2 interpreter vs pallas artifact: {max_err}");
}

#[test]
fn fused_matvec_artifact_matches_dsl_fusion() {
    let art = hofdla::runtime::artifact_path("fused_matvec_64x96");
    let Some(mut rt) = pjrt_runtime_or_skip("fused_matvec_64x96") else {
        return;
    };
    use hofdla::dsl::*;
    use hofdla::layout::Layout;
    use hofdla::rewrite::fusion;
    use hofdla::typecheck::Env;
    let (m, j) = (64usize, 96);
    let mut rng = Rng::new(6);
    let a: Vec<f64> = (0..m * j).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let b: Vec<f64> = (0..m * j).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let v: Vec<f64> = (0..j).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let u: Vec<f64> = (0..j).map(|_| rng.range_f64(-1.0, 1.0)).collect();

    // Paper eq 1 as an unfused DSL pipeline; fusion collapses it.
    let e = map(
        lam1(
            "r",
            rnz(
                add(),
                mul(),
                vec![var("r"), zip(add(), input("v"), input("u"))],
            ),
        ),
        zip(lift(add()), input("A"), input("B")),
    );
    let fused = fusion::fuse(&e);
    let env = Env::new()
        .with("A", Layout::row_major(&[m, j]))
        .with("B", Layout::row_major(&[m, j]))
        .with("v", Layout::row_major(&[j]))
        .with("u", Layout::row_major(&[j]));
    let ours = hofdla::exec::run(
        &fused,
        &env,
        &[("A", &a), ("B", &b), ("v", &v), ("u", &u)],
    )
    .unwrap();

    let exe = rt.load(&art).unwrap();
    let to_f32 = |x: &[f64]| x.iter().map(|&v| v as f32).collect::<Vec<f32>>();
    let (af, bf, vf, uf) = (to_f32(&a), to_f32(&b), to_f32(&v), to_f32(&u));
    let theirs = rt
        .run_f32(
            &exe,
            &[(&af, &[m, j]), (&bf, &[m, j]), (&vf, &[j]), (&uf, &[j])],
        )
        .unwrap();
    let max_err = ours
        .iter()
        .zip(&theirs)
        .map(|(x, y)| (x - *y as f64).abs())
        .fold(0.0f64, f64::max);
    assert!(max_err < 1e-3, "eq1 fusion vs pallas artifact: {max_err}");
}

#[test]
fn cachesim_reproduces_table1_extremes_on_cpu_hierarchy() {
    use hofdla::cachesim::{simulate, HierarchyConfig};
    use hofdla::enumerate::{enumerate_all, starts};
    use hofdla::layout::Layout;
    use hofdla::rewrite::Ctx;
    use hofdla::typecheck::Env;
    let n = 128usize; // larger than L1, traceable
    let env = Env::new()
        .with("A", Layout::row_major(&[n, n]))
        .with("B", Layout::row_major(&[n, n]));
    let ctx = Ctx::new(env.clone());
    let variants = enumerate_all(&starts::matmul_naive_variant(), &ctx, 16).unwrap();
    let mut costs = std::collections::HashMap::new();
    for v in &variants {
        let prog = hofdla::exec::lower(&v.expr, &env).unwrap();
        let r = simulate(&prog, &HierarchyConfig::cpu_i5_7300hq()).unwrap();
        costs.insert(v.display_key(), r.cost_cycles());
    }
    // Paper Table 1 extremes: mapB-innermost beats the naive form, and the
    // mapA-innermost forms (column-wise B AND A) are the worst.
    assert!(costs["mapA rnz mapB"] < costs["mapA mapB rnz"]);
    assert!(costs["mapA mapB rnz"] < costs["mapB rnz mapA"]);
    assert!(costs["mapA rnz mapB"] < costs["rnz mapB mapA"]);
}

#[test]
fn fig4_and_fig6_variant_sets_verify_end_to_end() {
    use hofdla::bench_support::BenchConfig;
    use hofdla::experiments::{self, MatmulOpts};
    let opts = MatmulOpts {
        n: 32,
        b: 4,
        bench: BenchConfig {
            warmup: 0,
            runs: 1,
            max_total: std::time::Duration::from_secs(30),
        },
        measure_time: false,
        simulate: false,
    };
    let f4 = experiments::fig4(&opts).unwrap();
    assert!(f4.rows.len() >= 30, "fig4 rows: {}", f4.rows.len());
    let f6 = experiments::fig6(&opts).unwrap();
    assert!(f6.rows.len() >= 60, "fig6 rows: {}", f6.rows.len());
}
