//! Service front-end properties (ISSUE 9): admission control, deadline
//! propagation, cooperative cancellation and compatible-job batching,
//! all through the redesigned typed coordinator API
//! (`Coordinator::submit_optimize` → `OptimizeHandle`).
//!
//! - a burst past `queue_cap` sheds with typed [`Error::Overloaded`]
//!   rejections (counted in `shed`, never in `submitted`) while every
//!   accepted job still resolves;
//! - [`OptimizeHandle::cancel`] stops an *in-flight* search mid-wave:
//!   the stats report a cancelled, incomplete run — never a completed
//!   frontier — and the truncated result is never cached;
//! - a job's deadline is measured from intake, so queue wait behind a
//!   slow neighbour is charged against the anytime budget;
//! - same-family distinct jobs are checked out as one worker batch;
//! - handles resolve exactly once (`wait_timeout` lifecycle), cancel
//!   after resolution is a no-op, and dropping an unresolved handle is
//!   safe.
//!
//! Timing assumption (shared with `coordinator::tests`): the n=64
//! subdivided-matmul search runs for hundreds of milliseconds in the
//! debug profile `cargo test` uses, so a 50 ms sleep is always inside
//! the blocker's search window.

use hofdla::coordinator::{Config, Coordinator, OptimizeSpec, MAX_DEADLINE_MS};
use hofdla::Error;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn matmul_src() -> &'static str {
    "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))"
}

/// A fast job: the 6-variant n=16 family.
fn quick_spec(top_k: usize) -> OptimizeSpec {
    OptimizeSpec::builder(matmul_src())
        .input("A", &[16, 16])
        .input("B", &[16, 16])
        .top_k(top_k)
        .build()
        .unwrap()
}

/// The slow headline job: n=64, subdivided (Table 2's 12
/// rearrangements) — hundreds of milliseconds in the debug profile.
fn slow_spec() -> OptimizeSpec {
    OptimizeSpec::builder(matmul_src())
        .input("A", &[64, 64])
        .input("B", &[64, 64])
        .subdivide_rnz(4)
        .top_k(12)
        .build()
        .unwrap()
}

#[test]
fn saturated_intake_sheds_with_typed_overloaded_and_accepted_jobs_resolve() {
    let c = Coordinator::start(Config {
        workers: 1,
        queue_cap: 2,
        ..Default::default()
    })
    .unwrap();
    // Burst 16 *distinct* slow-family jobs (different top_k → different
    // canonical keys, so nothing coalesces or hits the cache) at one
    // worker with two intake slots. The short deadline keeps accepted
    // jobs from serializing 16 full searches — they truncate instead —
    // without affecting what admission control sees.
    let mut accepted = Vec::new();
    let mut shed = 0u64;
    for i in 0..16usize {
        let mut s = slow_spec();
        s.top_k = i + 1;
        s.deadline_ms = 10;
        match c.submit_optimize(s) {
            Ok(h) => accepted.push(h),
            Err(Error::Overloaded { queue_depth }) => {
                shed += 1;
                // The depth a rejection carries is the depth that caused
                // it, observed under the admission lock: exactly the cap.
                assert_eq!(queue_depth, 2, "shed must report the saturating depth");
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed > 0, "a 16-job burst at queue_cap=2 must shed");
    assert!(!accepted.is_empty(), "an empty queue must admit");
    // Every accepted job resolves (deadline-truncated is still Ok).
    let n_accepted = accepted.len() as u64;
    for h in accepted {
        h.wait().unwrap();
    }
    let m = &c.metrics;
    assert_eq!(m.shed.load(Ordering::Relaxed), shed);
    // Shed jobs never count as submitted — they were never accepted.
    assert_eq!(m.submitted.load(Ordering::Relaxed), n_accepted);
    assert_eq!(m.completed.load(Ordering::Relaxed), n_accepted);
    assert_eq!(m.in_flight(), 0);
    let high_water = m.queue_high_water.load(Ordering::Relaxed);
    assert!(
        (1..=2).contains(&high_water),
        "queue high-water {high_water} escaped the configured bound"
    );
    // The typed rejection renders a useful operator message.
    let msg = Error::Overloaded { queue_depth: 2 }.to_string();
    assert!(msg.contains("overloaded"), "unhelpful message: {msg}");
}

/// ISSUE 9 acceptance: `cancel()` stops an in-flight search — the stats
/// show a cancellation, not a completed frontier — and the truncated
/// result is never cached.
#[test]
fn cancel_stops_an_inflight_search_and_is_never_cached() {
    let c = Coordinator::start(Config {
        workers: 1,
        ..Default::default()
    })
    .unwrap();
    let h = c.submit_optimize(slow_spec()).unwrap();
    // Let the worker check the job out and get deep into the search.
    std::thread::sleep(Duration::from_millis(50));
    h.cancel();
    let r = h.wait().unwrap();
    assert!(r.stats.cancelled, "the search must observe the token");
    assert!(!r.stats.complete, "a cancelled run must not claim a completed frontier");
    assert!(!r.stats.deadline_hit, "no deadline was set");
    assert!(r.certified_gap >= 1.0, "best-so-far still certifies a gap");
    let m = &c.metrics;
    assert_eq!(m.search_cancelled.load(Ordering::Relaxed), 1);
    assert_eq!(m.cancelled_before_start.load(Ordering::Relaxed), 0);
    // The truncated report was delivered (the job completed from the
    // service's point of view)…
    assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    // …but never published: an identical resubmission misses the cache
    // and runs the full search to completion.
    let generated = m.search_generated.load(Ordering::Relaxed);
    let r2 = c.submit_optimize(slow_spec()).unwrap().wait().unwrap();
    assert_eq!(m.opt_cache_hits(), 0, "a cancelled result must never be cached");
    assert!(
        m.search_generated.load(Ordering::Relaxed) > generated,
        "the resubmission must run a fresh search"
    );
    assert!(r2.stats.complete);
    assert!(!r2.stats.cancelled);
    assert_eq!(r2.variants_explored, 12, "Table 2");
    assert_eq!(m.search_cancelled.load(Ordering::Relaxed), 1, "only the first run cancelled");
}

#[test]
fn cancelling_a_queued_job_drops_it_at_checkout() {
    let c = Coordinator::start(Config {
        workers: 1,
        ..Default::default()
    })
    .unwrap();
    let blocker = c.submit_optimize(slow_spec()).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    // Queued behind the blocker; cancelled long before a worker reaches
    // it. The checkout gate must drop it without starting (or joining)
    // a search.
    let victim = c.submit_optimize(quick_spec(6)).unwrap();
    victim.cancel();
    assert!(
        victim.wait().is_err(),
        "a job cancelled while queued resolves with an error"
    );
    let m = &c.metrics;
    assert_eq!(m.cancelled_before_start.load(Ordering::Relaxed), 1);
    assert_eq!(m.search_cancelled.load(Ordering::Relaxed), 0, "no search ever started");
    assert_eq!(m.failed.load(Ordering::Relaxed), 1);
    blocker.wait().unwrap();
    assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    assert_eq!(m.in_flight(), 0);
}

/// The explicit `shards` knob through the service: every width produces
/// the same winner and bit-identical ranking (the deterministic-merge
/// contract), with the per-shard layout reporting the requested width.
/// Each width keys differently, so all three run fresh searches.
#[test]
fn explicit_shard_widths_reproduce_the_winner_bit_identically() {
    let c = Coordinator::start(Config {
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    let mut reports = Vec::new();
    for shards in [1usize, 2, 8] {
        let mut s = slow_spec();
        s.shards = shards;
        let r = c.submit_optimize(s).unwrap().wait().unwrap();
        assert_eq!(r.stats.shards, shards, "effective shard count");
        assert_eq!(r.stats.extracted_per_shard.len(), shards);
        assert!(r.stats.complete);
        reports.push(format!("{:?} best={}", r.ranking, r.best));
    }
    assert!(
        reports.windows(2).all(|w| w[0] == w[1]),
        "shard width changed the result: {reports:?}"
    );
    assert_eq!(c.metrics.opt_cache_hits(), 0, "distinct widths key distinctly");
}

#[test]
fn queue_wait_is_charged_against_the_deadline() {
    let c = Coordinator::start(Config {
        workers: 1,
        ..Default::default()
    })
    .unwrap();
    // Occupy the only worker with a full search, then queue a
    // 1 ms-deadline job behind it. The deadline expires while the job
    // waits, so its search must truncate at the first checkpoint —
    // checkout must not restart the clock.
    let blocker = c.submit_optimize(slow_spec()).unwrap();
    let mut s = slow_spec();
    s.top_k = 1; // distinct key: must not coalesce with the blocker
    s.deadline_ms = 1;
    let h = c.submit_optimize(s).unwrap();
    let r = h.wait().unwrap();
    assert!(r.stats.deadline_hit, "queue wait must count against the deadline");
    assert!(!r.stats.complete);
    assert!(!r.stats.cancelled);
    assert!(r.variants_explored < 12, "an expired deadline must truncate the search");
    let m = &c.metrics;
    assert_eq!(m.search_deadline_hits.load(Ordering::Relaxed), 1);
    // The wait behind the blocker is visible to operators: well over the
    // job's whole deadline.
    assert!(
        m.queue_wait_max_ns.load(Ordering::Relaxed) > 1_000_000,
        "queue-wait metrics missed a job that waited out a full search"
    );
    blocker.wait().unwrap();
    assert_eq!(m.in_flight(), 0);
}

/// Compatible-job batching: distinct jobs of one kernel family queued
/// behind a blocker are checked out as a single worker batch (leader
/// plus same-family followers), visible in the batch metrics.
#[test]
fn same_family_distinct_jobs_batch_onto_one_worker_checkout() {
    let c = Coordinator::start(Config {
        workers: 1,
        opt_batch: 8,
        ..Default::default()
    })
    .unwrap();
    // The blocker is checked out alone (nothing else is queued yet).
    let blocker = c.submit_optimize(slow_spec()).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    // Three distinct jobs of the same α-invariant source family
    // (different top_k → different keys: none coalesce, none hit the
    // cache) queue while the worker is busy.
    let followers: Vec<_> = [3usize, 4, 5]
        .iter()
        .map(|&k| c.submit_optimize(quick_spec(k)).unwrap())
        .collect();
    for h in followers {
        h.wait().unwrap();
    }
    blocker.wait().unwrap();
    let m = &c.metrics;
    // Two checkouts: the lone blocker, then the three-job family batch.
    assert_eq!(m.opt_batches.load(Ordering::Relaxed), 2);
    assert_eq!(m.max_opt_batch.load(Ordering::Relaxed), 3);
    assert_eq!(m.opt_batched_jobs.load(Ordering::Relaxed), 3);
    assert_eq!(m.completed.load(Ordering::Relaxed), 4);
    assert_eq!(m.in_flight(), 0);
}

#[test]
fn handle_resolves_exactly_once_through_wait_timeout() {
    let c = Coordinator::start(Config {
        workers: 1,
        ..Default::default()
    })
    .unwrap();
    let mut h = c.submit_optimize(slow_spec()).unwrap();
    // Mid-search, a short poll reports pending and leaves the handle
    // live.
    let pending = h.wait_timeout(Duration::from_millis(1)).unwrap();
    assert!(pending.is_none(), "slow search resolved implausibly fast");
    let r = loop {
        if let Some(r) = h.wait_timeout(Duration::from_secs(60)).unwrap() {
            break r;
        }
    };
    assert!(r.stats.complete);
    // Exactly-once: the resolved handle reports an error on every later
    // poll instead of hanging or double-delivering…
    assert!(h.wait_timeout(Duration::from_millis(1)).is_err());
    // …and cancelling it now is a documented no-op: the run completed,
    // so its result was cached and a resubmission hits.
    h.cancel();
    let r2 = c.submit_optimize(slow_spec()).unwrap().wait().unwrap();
    assert_eq!(c.metrics.opt_cache_hits(), 1);
    assert_eq!(c.metrics.search_cancelled.load(Ordering::Relaxed), 0);
    assert_eq!(r.best, r2.best);
}

#[test]
fn dropping_an_unresolved_handle_is_safe_and_the_job_still_completes() {
    let c = Coordinator::start(Config {
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    drop(c.submit_optimize(quick_spec(6)).unwrap());
    // The dropped job still runs: an identical resubmission either hits
    // the cache the dropped job populated or coalesces onto its flight —
    // both resolve, and `completed` counts the dropped job too.
    let r = c.submit_optimize(quick_spec(6)).unwrap().wait().unwrap();
    assert_eq!(r.best, "map1 rnz map2");
    assert_eq!(c.metrics.completed.load(Ordering::Relaxed), 2);
    assert_eq!(c.metrics.in_flight(), 0);
}

#[test]
fn submit_validates_hand_mutated_specs_before_queueing() {
    let c = Coordinator::start(Config::default()).unwrap();
    // The builder refuses these knobs; mutation after `build()` bypasses
    // it, and `submit_optimize` re-validates before anything queues.
    let mut bad = quick_spec(6);
    bad.top_k = 0;
    assert!(c.submit_optimize(bad).is_err());
    let mut bad = quick_spec(6);
    bad.deadline_ms = MAX_DEADLINE_MS + 1;
    assert!(c.submit_optimize(bad).is_err());
    let m = &c.metrics;
    assert_eq!(m.submitted.load(Ordering::Relaxed), 0, "rejected specs must not queue");
    assert_eq!(m.shed.load(Ordering::Relaxed), 0, "a validation failure is not shed");
}
