//! Std-thread stress tests pinning the concurrent hash-sharded arena
//! (`dsl::intern::SharedArena`, ISSUE 4) to its determinism contract:
//!
//! - many threads interning overlapping expression families must agree
//!   on ids — once a tree is interned, every thread sees the same id for
//!   it, whatever the interleaving was;
//! - extraction reproduces the exact trees that went in;
//! - `enumerate_search` against the shared arena reproduces the serial
//!   variant order exactly under stressed shard counts, with zero
//!   extractions at BFS level boundaries (output-boundary extractions
//!   only, verified through the arena-backed `SearchStats` counters).

use hofdla::dsl::intern::{arena_pool_stats, ExprId, SharedArena};
use hofdla::dsl::Expr;
use hofdla::enumerate::{enumerate_search, starts, SearchOptions};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;

/// Shapes every start family typechecks under (same convention as
/// `search_props`): A is n×j, B is j×k, v has length j, with the
/// divisibility the subdivided families need.
fn ctx() -> Ctx {
    Ctx::new(
        Env::new()
            .with("A", Layout::row_major(&[4, 8]))
            .with("B", Layout::row_major(&[8, 4]))
            .with("v", Layout::row_major(&[8])),
    )
}

/// Overlapping expression families: every start variant of the seed
/// workloads. They share most of their subtrees (the naive matmul spine
/// is embedded in every subdivided form), which is exactly the overlap
/// the segments race on.
fn family_exprs() -> Vec<Expr> {
    vec![
        starts::matmul_naive_variant().expr,
        starts::matmul_rnz_subdivided_variant(2).expr,
        starts::matmul_maps_subdivided_variant(2).expr,
        starts::matmul_rnz_twice_subdivided_variant(2, 2).expr,
        starts::matmul_all_subdivided_variant(2).expr,
        starts::matvec_naive_variant().expr,
        starts::matvec_vector_subdivided_variant(2).expr,
    ]
}

/// Many threads interning the same overlapping families, each in a
/// different rotation and repeatedly, must agree on every id — the
/// id-stability contract the search's per-shard caches rest on.
#[test]
fn stress_threads_agree_on_ids_for_overlapping_families() {
    const THREADS: usize = 8;
    const ROUNDS: usize = 50;
    let arena = SharedArena::new();
    let exprs = family_exprs();
    let per_thread: Vec<Vec<ExprId>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let arena = &arena;
                let exprs = &exprs;
                s.spawn(move || {
                    let n = exprs.len();
                    let mut ids: Vec<Option<ExprId>> = vec![None; n];
                    for round in 0..ROUNDS {
                        for j in 0..n {
                            // Rotate the visit order per thread and per
                            // round so insertions genuinely interleave.
                            let i = (j + t + round) % n;
                            let id = arena.intern(&exprs[i]);
                            // Re-interning within one thread is stable.
                            if let Some(prev) = ids[i] {
                                assert_eq!(prev, id, "thread {t}: id changed on re-intern");
                            }
                            ids[i] = Some(id);
                        }
                    }
                    ids.into_iter().map(Option::unwrap).collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Cross-thread agreement: every thread resolved every family member
    // to the id the arena reports now.
    let reference: Vec<ExprId> = exprs.iter().map(|e| arena.intern(e)).collect();
    for (t, ids) in per_thread.iter().enumerate() {
        assert_eq!(ids, &reference, "thread {t} disagreed on ids");
    }
    // And the ids still mean what they meant: exact round trips.
    for (e, &id) in exprs.iter().zip(&reference) {
        assert_eq!(&arena.extract(id), e, "round trip changed the tree");
    }
}

/// Concurrent interning keeps hash-consing exact: structurally distinct
/// trees never collapse onto one id, even under contention.
#[test]
fn stress_distinct_trees_stay_distinct_under_contention() {
    let arena = SharedArena::new();
    let ids: Vec<Vec<ExprId>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let arena = &arena;
                s.spawn(move || {
                    // All threads intern the same 64 distinct literals,
                    // racing on every segment.
                    (0..64)
                        .map(|i| arena.intern(&Expr::Lit(i as f64)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for thread_ids in &ids {
        assert_eq!(thread_ids, &ids[0], "threads disagreed");
        let distinct: std::collections::HashSet<_> = thread_ids.iter().collect();
        assert_eq!(distinct.len(), 64, "distinct trees collapsed");
    }
}

/// Shard counts to stress. The CI `search-shards` matrix sets
/// `SEARCH_SHARDS` so each arm exercises exactly its width (keeping the
/// arms distinct); a local run without the variable covers the full
/// {1, 2, 8} set in one go. Clamped like the engine clamps explicit
/// requests (`SearchStats` reports the effective count, which is what
/// the padded-layout assertion below checks against).
fn stress_shard_counts() -> Vec<usize> {
    match std::env::var("SEARCH_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 1)
    {
        Some(n) => vec![n.min(hofdla::enumerate::MAX_SEARCH_SHARDS)],
        None => vec![1, 2, 8],
    }
}

/// The search against the shared arena is a pure parallelization: every
/// stressed shard count reproduces the serial variant order and
/// bit-identical scores, and the extraction counters show that nothing
/// was extracted at BFS level boundaries — exactly one output-boundary
/// extraction per kept candidate (the start is never extracted).
#[test]
fn stressed_shard_counts_reproduce_serial_order_with_boundary_only_extraction() {
    let ctx = ctx();
    let serial_opts = SearchOptions {
        limit: 4096,
        shards: 1,
        prune_slack: None,
        score: true,
        ..SearchOptions::default()
    };
    for start in [
        starts::matmul_rnz_subdivided_variant(2),
        starts::matmul_all_subdivided_variant(2),
    ] {
        let reference = enumerate_search(&start, &ctx, &serial_opts).unwrap();
        let ref_keys: Vec<String> = reference.variants.iter().map(|v| v.display_key()).collect();
        for shards in stress_shard_counts() {
            let opts = SearchOptions {
                shards,
                ..serial_opts.clone()
            };
            let got = enumerate_search(&start, &ctx, &opts).unwrap();
            let got_keys: Vec<String> = got.variants.iter().map(|v| v.display_key()).collect();
            assert_eq!(ref_keys, got_keys, "shards={shards}: order diverged");
            assert_eq!(reference.scores, got.scores, "shards={shards}: scores");
            assert_eq!(
                got.stats.extracted(),
                got.stats.kept as u64 - 1,
                "shards={shards}: extraction must be once per kept variant, \
                 at the output boundary only"
            );
            assert_eq!(
                got.stats.extracted_per_shard.len(),
                shards,
                "shards={shards}: layout must be padded to the configured count"
            );
        }
    }
}

/// Arena pooling (ISSUE 8) is invisible to search results: every search
/// checks its arena out of the process-wide pool, so by the second run
/// of any spec the arena has been reset from *some* prior search. Kept
/// sets, winners, scores and the `SearchStats` counters must be
/// bit-identical between a first (possibly pool-cold) run and a reused
/// (pool-warm) run, at every stressed shard width.
#[test]
fn pooled_arena_reproduces_fresh_search_bit_identically() {
    let ctx = ctx();
    for shards in stress_shard_counts() {
        let opts = SearchOptions {
            limit: 4096,
            shards,
            prune_slack: None,
            score: true,
            ..SearchOptions::default()
        };
        for start_fn in [
            starts::matmul_rnz_subdivided_variant
                as fn(usize) -> hofdla::enumerate::Variant,
            starts::matmul_all_subdivided_variant,
        ] {
            let cold = enumerate_search(&start_fn(2), &ctx, &opts).unwrap();
            let warm = enumerate_search(&start_fn(2), &ctx, &opts).unwrap();
            let keys = |r: &hofdla::enumerate::SearchResult| {
                r.variants.iter().map(|v| v.display_key()).collect::<Vec<_>>()
            };
            assert_eq!(keys(&cold), keys(&warm), "shards={shards}: kept set diverged");
            assert_eq!(cold.scores, warm.scores, "shards={shards}: scores diverged");
            assert_eq!(
                format!("{:?}", cold.stats),
                format!("{:?}", warm.stats),
                "shards={shards}: SearchStats diverged between pool-cold and pool-warm runs"
            );
            for (c, w) in cold.variants.iter().zip(&warm.variants) {
                assert_eq!(c.expr, w.expr, "shards={shards}: extracted tree diverged");
            }
        }
    }
    // The searches above returned their arenas; the pool is actually
    // cycling (counters are process-global and shared with concurrent
    // tests, so assert the invariant, not exact values).
    let stats = arena_pool_stats();
    assert!(
        stats.created + stats.reused >= 2,
        "searches must check arenas out of the pool: {stats:?}"
    );
    assert!(stats.high_water >= 1, "{stats:?}");
}

/// Reuse is a *reset*, not a leak: a pooled arena comes back empty, with
/// its extraction counter cleared — the search's output-boundary
/// accounting (`extracted() == kept - 1` above) would double-count
/// otherwise.
#[test]
fn reused_arena_starts_empty_with_cleared_counters() {
    // Drive the reset path directly (the pool applies it on every
    // checkout): interleaving with the global pool here would race other
    // tests for which arena comes back.
    let mut arena = SharedArena::new();
    let id = arena.intern(&family_exprs()[0]);
    let _ = arena.extract(id);
    assert!(!arena.is_empty());
    assert_eq!(arena.extractions(), 1);
    let before = arena.epoch();
    arena.reset();
    assert_eq!(arena.len(), 0);
    assert_eq!(arena.extractions(), 0);
    assert_eq!(arena.epoch(), before.wrapping_add(1));
    // And the reset arena interns from scratch, reproducing round trips.
    let id2 = arena.intern(&family_exprs()[0]);
    assert_eq!(arena.extract(id2), family_exprs()[0]);
}

/// Debug builds fail closed on ids that outlive a reset (the arena-pool
/// reuse hazard): every `ExprId` carries its arena epoch, and resolving
/// one against a later epoch panics instead of silently reading another
/// search's nodes.
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "stale ExprId")]
fn stale_id_across_pool_style_reset_fails_closed_in_debug() {
    let mut arena = SharedArena::new();
    let stale = arena.intern(&family_exprs()[0]);
    arena.reset();
    // A fresh search would now repopulate the arena; the pre-reset id
    // must not resolve against it.
    let _ = arena.extract(stale);
}
