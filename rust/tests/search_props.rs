//! Differential and property tests for the id-native, sharded,
//! cost-bounded enumeration engine (ISSUEs 2–5):
//!
//! - the id-native search (exchange rules, normalization and typechecking
//!   all running on `ExprId`s) produces exactly the variant sets, orders
//!   and labels of the seed `Box<Expr>` engine across every start family;
//! - sharded expansion is a pure parallelization: any shard count yields
//!   the serial result, bit-identical scores included;
//! - branch-and-bound pruning at the default slack actually cuts on the
//!   subdivided families (the bound is rearrangement-sensitive) yet never
//!   loses the winner: the pruned result is the exhaustive result
//!   restricted to the survivors, with the identical best variant — same
//!   key, same expression, same lowered `Program` — at every shard count.

use hofdla::coordinator::{optimize, OptimizeSpec, RankBy};
use hofdla::dsl::intern::with_memo_disabled;
use hofdla::enumerate::{
    enumerate_search, starts, SearchOptions, Variant, DEFAULT_PRUNE_SLACK, MAX_SEARCH_SHARDS,
};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;

/// Shard count under test. The CI matrix sets `SEARCH_SHARDS` (1, 2, 8)
/// so sharded==serial determinism against the shared arena is exercised
/// under real concurrency on every PR, not just at one local default.
/// Clamped like the engine clamps (`SearchStats::shards` reports the
/// effective count, which is what these tests assert against).
fn shard_count() -> usize {
    std::env::var("SEARCH_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(4)
        .min(MAX_SEARCH_SHARDS)
}

/// Shapes every start family typechecks under: A is n×j, B is j×k, v has
/// length j, with the divisibility the subdivided families (block 2,
/// twice-block 2·2) need.
fn ctx() -> Ctx {
    Ctx::new(
        Env::new()
            .with("A", Layout::row_major(&[4, 8]))
            .with("B", Layout::row_major(&[8, 4]))
            .with("v", Layout::row_major(&[8])),
    )
}

fn families() -> Vec<(&'static str, Variant)> {
    vec![
        ("matmul-naive", starts::matmul_naive_variant()),
        ("matmul-rnz-subdiv", starts::matmul_rnz_subdivided_variant(2)),
        ("matmul-maps-subdiv", starts::matmul_maps_subdivided_variant(2)),
        (
            "matmul-rnz-twice",
            starts::matmul_rnz_twice_subdivided_variant(2, 2),
        ),
        ("matmul-all-subdiv", starts::matmul_all_subdivided_variant(2)),
        ("matvec-naive", starts::matvec_naive_variant()),
        (
            "matvec-vector-subdiv",
            starts::matvec_vector_subdivided_variant(2),
        ),
    ]
}

#[test]
fn differential_id_native_search_matches_box_engine() {
    let ctx = ctx();
    let opts = SearchOptions {
        limit: 4096,
        shards: 1,
        prune_slack: None,
        score: false,
        ..SearchOptions::default()
    };
    for (name, start) in families() {
        let id_native = enumerate_search(&start, &ctx, &opts).unwrap();
        let boxed = with_memo_disabled(|| enumerate_search(&start, &ctx, &opts)).unwrap();
        assert_eq!(
            id_native.variants.len(),
            boxed.variants.len(),
            "{name}: variant count diverged"
        );
        for (a, b) in id_native.variants.iter().zip(&boxed.variants) {
            assert_eq!(
                a.display_key(),
                b.display_key(),
                "{name}: variant order diverged"
            );
            assert_eq!(a.labels, b.labels, "{name}");
            assert!(
                a.expr.alpha_eq(&b.expr),
                "{name} / {}: id-native and seed variants differ structurally",
                a.display_key()
            );
        }
    }
}

#[test]
fn sharded_search_matches_serial() {
    let ctx = ctx();
    let serial_opts = SearchOptions {
        limit: 4096,
        shards: 1,
        prune_slack: None,
        score: true,
        ..SearchOptions::default()
    };
    let sharded_opts = SearchOptions {
        shards: shard_count(),
        ..serial_opts.clone()
    };
    for (name, start) in families() {
        let serial = enumerate_search(&start, &ctx, &serial_opts).unwrap();
        let sharded = enumerate_search(&start, &ctx, &sharded_opts).unwrap();
        let serial_keys: Vec<String> = serial.variants.iter().map(|v| v.display_key()).collect();
        let sharded_keys: Vec<String> =
            sharded.variants.iter().map(|v| v.display_key()).collect();
        assert_eq!(serial_keys, sharded_keys, "{name}: order diverged");
        // Scores are computed from loop nests lowered straight from the
        // arena (`lower_id`), which are insensitive to binder naming —
        // bit-identical across shardings.
        assert_eq!(serial.scores, sharded.scores, "{name}: scores diverged");
        assert_eq!(serial.stats.kept, sharded.stats.kept, "{name}");
        assert_eq!(sharded.stats.shards, shard_count(), "{name}");
        // Stable, shard-count-padded layout: one slot per configured
        // shard no matter which shards generated kept candidates.
        assert_eq!(sharded.stats.extracted_per_shard.len(), shard_count(), "{name}");
        // Sharding is a pure parallelization of the same expansion work:
        // the total output-boundary extraction count matches serial.
        assert_eq!(
            serial.stats.extracted(),
            sharded.stats.extracted(),
            "{name}: extraction counts diverged"
        );
        // Exactly one extraction per kept candidate (the start is never
        // extracted; duplicates are deduped before extraction).
        assert_eq!(
            serial.stats.extracted(),
            serial.stats.kept as u64 - 1,
            "{name}: extraction must be once per kept variant"
        );
        assert_eq!(serial.stats.expanded, sharded.stats.expanded, "{name}");
    }
}

/// Property (ISSUE 5 tentpole): pruning at the conservative default slack
/// never loses the best-ranked variant, and the pruned result is exactly
/// the exhaustive result restricted to the surviving variants — same
/// order, bit-identical scores — on every start family. (Whether any cut
/// fires varies by family; the subdivided families do cut, pinned
/// separately below.)
#[test]
fn prop_default_pruning_preserves_winner_and_survivor_scores() {
    let ctx = ctx();
    let exhaustive_opts = SearchOptions {
        limit: 4096,
        shards: 1,
        prune_slack: None,
        score: true,
        ..SearchOptions::default()
    };
    let pruned_opts = SearchOptions {
        prune_slack: Some(DEFAULT_PRUNE_SLACK),
        ..exhaustive_opts.clone()
    };
    for (name, start) in families() {
        let exhaustive = enumerate_search(&start, &ctx, &exhaustive_opts).unwrap();
        let pruned = enumerate_search(&start, &ctx, &pruned_opts).unwrap();
        // Best = first variant attaining the minimum score (the
        // pipeline's tie-breaking).
        let best_of = |r: &hofdla::enumerate::SearchResult| {
            let (mut bi, mut bs) = (0usize, f64::INFINITY);
            for (i, &s) in r.scores.iter().enumerate() {
                if s < bs {
                    bi = i;
                    bs = s;
                }
            }
            (r.variants[bi].display_key(), r.scores[bi])
        };
        let (ek_best, es_best) = best_of(&exhaustive);
        let (pk_best, ps_best) = best_of(&pruned);
        assert_eq!(ek_best, pk_best, "{name}: pruning changed the winner");
        assert_eq!(es_best, ps_best, "{name}: winner score changed");
        // The pruned variant sequence is a subsequence of the exhaustive
        // one (cuts only remove), with bit-identical scores per survivor.
        let ek: Vec<(String, f64)> = exhaustive
            .variants
            .iter()
            .zip(&exhaustive.scores)
            .map(|(v, &s)| (v.display_key(), s))
            .collect();
        let pk: Vec<(String, f64)> = pruned
            .variants
            .iter()
            .zip(&pruned.scores)
            .map(|(v, &s)| (v.display_key(), s))
            .collect();
        let mut it = ek.iter();
        for survivor in &pk {
            assert!(
                it.any(|e| e == survivor),
                "{name}: {survivor:?} missing from (or out of order in) the exhaustive \
                 sequence {ek:?}"
            );
        }
        // Cut candidates are never extracted: extraction stays exactly
        // one per kept variant.
        assert_eq!(
            pruned.stats.extracted(),
            pruned.stats.kept as u64 - 1,
            "{name}"
        );
    }
}

/// ISSUE 5 acceptance: on the deep (depth-3-reduction chain after
/// subdivision) matmul family at the bench size — n=64, block 4, the
/// paper's Table 2 twelve rearrangements — the default-slack cut *fires*
/// (`pruned > 0`), and pruned search still returns the exhaustive winner
/// bit-identically (same labels, same expression, same lowered
/// `Program`), at every CI shard width.
#[test]
fn default_slack_cuts_deep_subdivided_family_and_keeps_winner() {
    use hofdla::exec::lower;
    let env = Env::new()
        .with("A", Layout::row_major(&[64, 64]))
        .with("B", Layout::row_major(&[64, 64]));
    let ctx = Ctx::new(env.clone());
    let start = starts::matmul_rnz_subdivided_variant(4);
    let exhaustive = enumerate_search(
        &start,
        &ctx,
        &SearchOptions {
            limit: 4096,
            shards: 1,
            prune_slack: None,
            score: true,
            ..SearchOptions::default()
        },
    )
    .unwrap();
    assert_eq!(exhaustive.variants.len(), 12, "Table 2");
    let best_of = |r: &hofdla::enumerate::SearchResult| {
        let (mut bi, mut bs) = (0usize, f64::INFINITY);
        for (i, &s) in r.scores.iter().enumerate() {
            if s < bs {
                bi = i;
                bs = s;
            }
        }
        bi
    };
    let eb = best_of(&exhaustive);
    let e_winner = &exhaustive.variants[eb];
    let e_prog = format!("{:?}", lower(&e_winner.expr, &env).unwrap());
    for shards in [1usize, 2, 8] {
        let pruned = enumerate_search(
            &start,
            &ctx,
            &SearchOptions {
                limit: 4096,
                shards,
                prune_slack: Some(DEFAULT_PRUNE_SLACK),
                score: true,
                ..SearchOptions::default()
            },
        )
        .unwrap();
        assert!(
            pruned.stats.pruned > 0,
            "shards={shards}: the rearrangement-sensitive bound must cut at slack 1.0"
        );
        assert!(
            pruned.variants.len() < exhaustive.variants.len(),
            "shards={shards}: cuts must shrink the kept set"
        );
        let pb = best_of(&pruned);
        let p_winner = &pruned.variants[pb];
        assert_eq!(
            e_winner.display_key(),
            p_winner.display_key(),
            "shards={shards}: winner diverged"
        );
        assert_eq!(exhaustive.scores[eb], pruned.scores[pb], "shards={shards}");
        assert!(
            e_winner.expr.alpha_eq(&p_winner.expr),
            "shards={shards}: winner expression diverged"
        );
        // Acceptance: the same lowered Program, bit for bit.
        let p_prog = format!("{:?}", lower(&p_winner.expr, &env).unwrap());
        assert_eq!(e_prog, p_prog, "shards={shards}: winner program diverged");
        // Cut candidates are never extracted.
        assert_eq!(pruned.stats.extracted(), pruned.stats.kept as u64 - 1);
        assert_eq!(pruned.stats.shards, shards, "effective shard count");
    }
}

/// The cut path itself works: an absurdly tight slack cuts every child of
/// the start, deterministically leaving just the start variant in the
/// result. Cut candidates still expand (reachability is what makes the
/// default slack lossless), so the search walks the whole family — but
/// extracts nothing.
#[test]
fn tight_slack_actually_prunes() {
    let ctx = ctx();
    let opts = SearchOptions {
        limit: 4096,
        shards: shard_count(),
        prune_slack: Some(1e-9),
        score: true,
        ..SearchOptions::default()
    };
    let start = starts::matmul_rnz_subdivided_variant(2);
    let r = enumerate_search(&start, &ctx, &opts).unwrap();
    assert_eq!(r.variants.len(), 1, "only the start survives");
    assert_eq!(r.variants[0].display_key(), start.display_key());
    assert!(r.stats.pruned > 0, "children must have been cut");
    // Cut candidates are rejected on the lower bound alone — before any
    // lowering, scoring, or extraction. With every child cut, no
    // `Box<Expr>` tree is ever rebuilt from a search arena.
    assert_eq!(r.stats.extracted(), 0, "cut path must not extract");
    // Cut nodes stay expansion sources: the whole 12-variant family is
    // still walked (kept set aside), so the winner could never have been
    // disconnected.
    assert_eq!(r.stats.expanded, 12, "cut nodes must still expand");
}

/// End-to-end (ISSUE 5 acceptance, service flavor): the pruned + sharded
/// pipeline cuts on the n=64 / b=4 subdivided matmul and still reports
/// the exhaustive winner with its exhaustive score.
#[test]
fn pruned_service_pipeline_matches_exhaustive() {
    let mk = |prune: bool| {
        OptimizeSpec::builder(
            "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
        )
        .input("A", &[64, 64])
        .input("B", &[64, 64])
        .rank_by(RankBy::CostModel)
        .subdivide_rnz(4)
        .top_k(12)
        .prune(prune)
        .verify(true)
        .build()
        .unwrap()
    };
    let exhaustive = optimize(&mk(false)).unwrap();
    let pruned = optimize(&mk(true)).unwrap();
    assert_eq!(exhaustive.variants_explored, 12);
    assert_eq!(exhaustive.best, pruned.best);
    // (Winner *program* bit-identity across pruning and shard counts is
    // pinned by `default_slack_cuts_deep_subdivided_family_and_keeps_winner`;
    // the pretty `best_expr` strings carry per-run gensym'd binder names
    // and are not comparable across runs.)
    assert_eq!(exhaustive.ranking[0], pruned.ranking[0]);
    assert!(pruned.stats.pruned > 0, "default-slack cut must fire");
    assert!(pruned.variants_explored < exhaustive.variants_explored);
    // Survivors keep their exhaustive scores.
    let full: std::collections::HashMap<&str, f64> = exhaustive
        .ranking
        .iter()
        .map(|(k, s)| (k.as_str(), *s))
        .collect();
    for (k, s) in &pruned.ranking {
        assert_eq!(full[k.as_str()], *s, "{k}: score changed under pruning");
    }
}
