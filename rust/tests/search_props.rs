//! Differential and property tests for the id-native, sharded,
//! cost-bounded enumeration engine (ISSUE 2):
//!
//! - the id-native search (exchange rules, normalization and typechecking
//!   all running on `ExprId`s) produces exactly the variant sets, orders
//!   and labels of the seed `Box<Expr>` engine across every start family;
//! - sharded expansion is a pure parallelization: any shard count yields
//!   the serial result, bit-identical scores included;
//! - branch-and-bound pruning under the conservative default slack never
//!   drops any variant — in particular never the best-ranked one — while
//!   an absurdly tight slack demonstrably cuts.

use hofdla::coordinator::{optimize, OptimizeSpec, RankBy};
use hofdla::dsl::intern::with_memo_disabled;
use hofdla::enumerate::{enumerate_search, starts, SearchOptions, Variant, DEFAULT_PRUNE_SLACK};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;

/// Shard count under test. The CI matrix sets `SEARCH_SHARDS` (1, 2, 8)
/// so sharded==serial determinism against the shared arena is exercised
/// under real concurrency on every PR, not just at one local default.
fn shard_count() -> usize {
    std::env::var("SEARCH_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(4)
}

/// Shapes every start family typechecks under: A is n×j, B is j×k, v has
/// length j, with the divisibility the subdivided families (block 2,
/// twice-block 2·2) need.
fn ctx() -> Ctx {
    Ctx::new(
        Env::new()
            .with("A", Layout::row_major(&[4, 8]))
            .with("B", Layout::row_major(&[8, 4]))
            .with("v", Layout::row_major(&[8])),
    )
}

fn families() -> Vec<(&'static str, Variant)> {
    vec![
        ("matmul-naive", starts::matmul_naive_variant()),
        ("matmul-rnz-subdiv", starts::matmul_rnz_subdivided_variant(2)),
        ("matmul-maps-subdiv", starts::matmul_maps_subdivided_variant(2)),
        (
            "matmul-rnz-twice",
            starts::matmul_rnz_twice_subdivided_variant(2, 2),
        ),
        ("matmul-all-subdiv", starts::matmul_all_subdivided_variant(2)),
        ("matvec-naive", starts::matvec_naive_variant()),
        (
            "matvec-vector-subdiv",
            starts::matvec_vector_subdivided_variant(2),
        ),
    ]
}

#[test]
fn differential_id_native_search_matches_box_engine() {
    let ctx = ctx();
    let opts = SearchOptions {
        limit: 4096,
        shards: 1,
        prune_slack: None,
        score: false,
    };
    for (name, start) in families() {
        let id_native = enumerate_search(&start, &ctx, &opts).unwrap();
        let boxed = with_memo_disabled(|| enumerate_search(&start, &ctx, &opts)).unwrap();
        assert_eq!(
            id_native.variants.len(),
            boxed.variants.len(),
            "{name}: variant count diverged"
        );
        for (a, b) in id_native.variants.iter().zip(&boxed.variants) {
            assert_eq!(
                a.display_key(),
                b.display_key(),
                "{name}: variant order diverged"
            );
            assert_eq!(a.labels, b.labels, "{name}");
            assert!(
                a.expr.alpha_eq(&b.expr),
                "{name} / {}: id-native and seed variants differ structurally",
                a.display_key()
            );
        }
    }
}

#[test]
fn sharded_search_matches_serial() {
    let ctx = ctx();
    let serial_opts = SearchOptions {
        limit: 4096,
        shards: 1,
        prune_slack: None,
        score: true,
    };
    let sharded_opts = SearchOptions {
        shards: shard_count(),
        ..serial_opts
    };
    for (name, start) in families() {
        let serial = enumerate_search(&start, &ctx, &serial_opts).unwrap();
        let sharded = enumerate_search(&start, &ctx, &sharded_opts).unwrap();
        let serial_keys: Vec<String> = serial.variants.iter().map(|v| v.display_key()).collect();
        let sharded_keys: Vec<String> =
            sharded.variants.iter().map(|v| v.display_key()).collect();
        assert_eq!(serial_keys, sharded_keys, "{name}: order diverged");
        // Scores are computed from loop nests lowered straight from the
        // arena (`lower_id`), which are insensitive to binder naming —
        // bit-identical across shardings.
        assert_eq!(serial.scores, sharded.scores, "{name}: scores diverged");
        assert_eq!(serial.stats.kept, sharded.stats.kept, "{name}");
        assert_eq!(sharded.stats.shards, shard_count(), "{name}");
        // Stable, shard-count-padded layout: one slot per configured
        // shard no matter which shards generated kept candidates.
        assert_eq!(sharded.stats.extracted_per_shard.len(), shard_count(), "{name}");
        // Sharding is a pure parallelization of the same expansion work:
        // the total output-boundary extraction count matches serial.
        assert_eq!(
            serial.stats.extracted(),
            sharded.stats.extracted(),
            "{name}: extraction counts diverged"
        );
        // Exactly one extraction per kept candidate (the start is never
        // extracted; duplicates are deduped before extraction).
        assert_eq!(
            serial.stats.extracted(),
            serial.stats.kept as u64 - 1,
            "{name}: extraction must be once per kept variant"
        );
        assert_eq!(serial.stats.expanded, sharded.stats.expanded, "{name}");
    }
}

/// Property (ISSUE 2 satellite): pruning under the conservative default
/// slack never drops the best-ranked variant — in fact it provably cuts
/// nothing on these workloads, so pruned and exhaustive results coincide
/// exactly.
#[test]
fn prop_default_pruning_never_drops_best_variant() {
    let ctx = ctx();
    let exhaustive_opts = SearchOptions {
        limit: 4096,
        shards: 1,
        prune_slack: None,
        score: true,
    };
    let pruned_opts = SearchOptions {
        prune_slack: Some(DEFAULT_PRUNE_SLACK),
        ..exhaustive_opts
    };
    for (name, start) in families() {
        let exhaustive = enumerate_search(&start, &ctx, &exhaustive_opts).unwrap();
        let pruned = enumerate_search(&start, &ctx, &pruned_opts).unwrap();
        // Best = first variant attaining the minimum score (the
        // pipeline's tie-breaking).
        let best_of = |r: &hofdla::enumerate::SearchResult| {
            let (mut bi, mut bs) = (0usize, f64::INFINITY);
            for (i, &s) in r.scores.iter().enumerate() {
                if s < bs {
                    bi = i;
                    bs = s;
                }
            }
            r.variants[bi].display_key()
        };
        assert_eq!(
            best_of(&exhaustive),
            best_of(&pruned),
            "{name}: pruning changed the winner"
        );
        let ek: Vec<String> = exhaustive.variants.iter().map(|v| v.display_key()).collect();
        let pk: Vec<String> = pruned.variants.iter().map(|v| v.display_key()).collect();
        assert_eq!(ek, pk, "{name}: pruning changed the variant set");
        assert_eq!(exhaustive.scores, pruned.scores, "{name}");
        assert_eq!(
            pruned.stats.pruned, 0,
            "{name}: at slack 1.0 a cut requires the candidate's lower \
             bound to exceed the best true score, which the bound's \
             soundness (lower bound ≤ true score, and best score ≥ any \
             variant's bound within a family) makes impossible"
        );
    }
}

/// The cut path itself works: an absurdly tight slack prunes every child
/// of the start, deterministically leaving just the start variant.
#[test]
fn tight_slack_actually_prunes() {
    let ctx = ctx();
    let opts = SearchOptions {
        limit: 4096,
        shards: shard_count(),
        prune_slack: Some(1e-9),
        score: true,
    };
    let start = starts::matmul_rnz_subdivided_variant(2);
    let r = enumerate_search(&start, &ctx, &opts).unwrap();
    assert_eq!(r.variants.len(), 1, "only the start survives");
    assert_eq!(r.variants[0].display_key(), start.display_key());
    assert!(r.stats.pruned > 0, "children must have been cut");
    // Cut candidates are rejected on the lower bound alone — before any
    // lowering, scoring, or extraction. With every child cut, no
    // `Box<Expr>` tree is ever rebuilt from a search arena.
    assert_eq!(r.stats.extracted(), 0, "cut path must not extract");
    assert_eq!(r.stats.expanded, 1, "only the start was expanded");
}

/// End-to-end (ISSUE 2 acceptance, service flavor): the pruned + sharded
/// pipeline and exhaustive mode agree on best variant and full ranking
/// for the n=64 / b=4 subdivided matmul.
#[test]
fn pruned_service_pipeline_matches_exhaustive() {
    let mk = |prune: bool| OptimizeSpec {
        source: "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))"
            .into(),
        inputs: vec![("A".into(), vec![64, 64]), ("B".into(), vec![64, 64])],
        rank_by: RankBy::CostModel,
        subdivide_rnz: Some(4),
        top_k: 12,
        prune,
    };
    let exhaustive = optimize(&mk(false)).unwrap();
    let pruned = optimize(&mk(true)).unwrap();
    assert_eq!(exhaustive.variants_explored, 12);
    assert_eq!(exhaustive.best, pruned.best);
    assert_eq!(exhaustive.variants_explored, pruned.variants_explored);
    assert_eq!(exhaustive.ranking, pruned.ranking);
}
