//! Canonicalization properties (ISSUE 8): the coordinator's canonical
//! cache key must identify exactly the requests the pipeline answers
//! identically, across every search family the repo ships.
//!
//! - α-renamed and whitespace/comment-permuted sources of one kernel
//!   produce the *same* [`CanonicalKey`] and (run fresh) the same
//!   optimization report — same exploration count, same ranking, same
//!   winner program;
//! - the α-invariance holds inside the engine at every CI shard width
//!   (`SEARCH_SHARDS` ∈ {1, 2, 8}): renamed binders never perturb
//!   variant order or scores;
//! - seeded *distinct* kernels never collide on the canonical hash;
//! - at the service level, an α-renamed resubmission of a completed job
//!   is a cache hit: the canonical counter increments and the search
//!   counters do not move (the ISSUE 8 acceptance criterion).

use hofdla::coordinator::{
    optimize, CanonicalKey, Config, Coordinator, OptimizeResult, OptimizeSpec, RankBy, Request,
    Response,
};
use hofdla::dsl;
use hofdla::dsl::intern::canonical_hash;
use hofdla::enumerate::{enumerate_search, SearchOptions, Variant, MAX_SEARCH_SHARDS};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;
use hofdla::util::Rng;
use std::sync::atomic::Ordering;

/// One search family: a kernel source, a hand-α-renamed twin (every
/// binder renamed, nothing else), the inputs it typechecks under, and
/// the subdivision knob that selects the family's search space.
struct Family {
    name: &'static str,
    source: &'static str,
    renamed: &'static str,
    inputs: Vec<(String, Vec<usize>)>,
    subdivide_rnz: Option<usize>,
}

/// Every search family the seed workloads exercise: plain and
/// subdivided matmul (Table 1 / Table 2), matvec, and a fused
/// single-`rnz` pipeline (the degenerate one-variant family).
fn families() -> Vec<Family> {
    vec![
        Family {
            name: "matmul",
            source:
                "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
            renamed:
                "(map (lam (rowOfA) (map (lam (colOfB) (rnz + * rowOfA colOfB)) \
                 (flip 0 (in B)))) (in A))",
            inputs: vec![("A".into(), vec![16, 16]), ("B".into(), vec![16, 16])],
            subdivide_rnz: None,
        },
        Family {
            name: "matmul-subdivided",
            source:
                "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
            renamed:
                "(map (lam (r) (map (lam (c) (rnz + * r c)) (flip 0 (in B)))) (in A))",
            inputs: vec![("A".into(), vec![16, 16]), ("B".into(), vec![16, 16])],
            subdivide_rnz: Some(4),
        },
        Family {
            name: "matvec",
            source: "(map (lam (rA) (rnz + * rA (in v))) (in A))",
            renamed: "(map (lam (row) (rnz + * row (in v))) (in A))",
            inputs: vec![("A".into(), vec![16, 16]), ("v".into(), vec![16])],
            subdivide_rnz: None,
        },
        Family {
            name: "fused-dot",
            source: "(rnz + * (map (lam (x) (app * x 2.0)) (in u)) (in v))",
            renamed: "(rnz + * (map (lam (scaled) (app * scaled 2.0)) (in u)) (in v))",
            inputs: vec![("u".into(), vec![64]), ("v".into(), vec![64])],
            subdivide_rnz: None,
        },
    ]
}

fn spec_for(f: &Family, source: &str) -> OptimizeSpec {
    OptimizeSpec::builder(source)
        .inputs(f.inputs.clone())
        .rank_by(RankBy::CostModel)
        .subdivide_rnz(f.subdivide_rnz)
        .top_k(12)
        .build()
        .unwrap()
}

/// Formatting permutations of a source that must not change its key:
/// line breaks, indentation, comments, stray leading/trailing blanks.
fn whitespace_permutations(source: &str) -> Vec<String> {
    vec![
        format!("  {source}\n"),
        source.replace(") (", ")\n  ("),
        format!("; one kernel, many spellings\n{source}"),
        format!("{}\n; trailing comment", source.replace(' ', "  ")),
        source.replace(") (", ") ; inline comment\n ("),
    ]
}

/// The comparable identity of a report. Binder names in the
/// pretty-printed winner are gensym'd per run, so the winner is compared
/// through its (name-free) lowered program instead of its source text.
fn report_identity(r: &OptimizeResult, env: &Env) -> String {
    let lowered = hofdla::exec::lower(&dsl::parse(&r.best_expr).unwrap(), env).unwrap();
    format!(
        "explored={} ranking={:?} best={} lowered={:?}",
        r.variants_explored, r.ranking, r.best, lowered
    )
}

fn env_for(f: &Family) -> Env {
    let mut env = Env::new();
    for (name, shape) in &f.inputs {
        env.inputs.insert(name.clone(), Layout::row_major(shape));
    }
    env
}

/// α-renamed and reformatted sources of every family key identically —
/// and a fresh pipeline run of each spelling produces the same report:
/// same exploration count, bit-identical ranking, same winner program.
/// This is what makes answering a canonical hit from the cache sound.
#[test]
fn alpha_and_format_variants_key_and_optimize_identically_for_every_family() {
    for f in families() {
        let base = spec_for(&f, f.source);
        let key = base.canonical_key(1).unwrap();
        let reference = optimize(&base).unwrap();
        let env = env_for(&f);
        let ref_identity = report_identity(&reference, &env);
        let mut spellings: Vec<String> = vec![f.renamed.to_string()];
        spellings.extend(whitespace_permutations(f.source));
        spellings.extend(whitespace_permutations(f.renamed));
        for (i, s) in spellings.iter().enumerate() {
            let spec = spec_for(&f, s);
            assert_eq!(
                key,
                spec.canonical_key(1).unwrap(),
                "{}: spelling {i} changed the canonical key",
                f.name
            );
            let got = optimize(&spec).unwrap();
            assert_eq!(
                ref_identity,
                report_identity(&got, &env),
                "{}: spelling {i} changed the report",
                f.name
            );
        }
    }
}

/// Shard widths to cover, mirroring `shared_arena_props`: the CI
/// `search-shards` matrix pins one width per arm via `SEARCH_SHARDS`; a
/// local run covers the full {1, 2, 8} set.
fn shard_counts() -> Vec<usize> {
    match std::env::var("SEARCH_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 1)
    {
        Some(n) => vec![n.min(MAX_SEARCH_SHARDS)],
        None => vec![1, 2, 8],
    }
}

/// Engine-level α-invariance at every CI shard width: searching an
/// α-renamed start expression yields the same variant order and
/// bit-identical scores as the original, whatever the fan-out. (Binder
/// names reach the search arena — interning is structural, λx.x ≠ λy.y —
/// so this is a real property of the search, not of parsing.)
#[test]
fn search_is_alpha_invariant_at_every_ci_shard_width() {
    let ctx = Ctx::new(
        Env::new()
            .with("A", Layout::row_major(&[4, 8]))
            .with("B", Layout::row_major(&[8, 4])),
    );
    let labels = ["map1", "map2", "rnz1"];
    let original = Variant::new(
        dsl::parse(
            "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
        )
        .unwrap(),
        &labels,
    );
    let renamed = Variant::new(
        dsl::parse(
            "(map (lam (rowOfA) (map (lam (colOfB) (rnz + * rowOfA colOfB)) \
             (flip 0 (in B)))) (in A))",
        )
        .unwrap(),
        &labels,
    );
    for shards in shard_counts() {
        let opts = SearchOptions {
            limit: 4096,
            shards,
            prune_slack: None,
            score: true,
            ..SearchOptions::default()
        };
        let a = enumerate_search(&original, &ctx, &opts).unwrap();
        let b = enumerate_search(&renamed, &ctx, &opts).unwrap();
        let keys = |r: &hofdla::enumerate::SearchResult| {
            r.variants.iter().map(|v| v.display_key()).collect::<Vec<_>>()
        };
        assert_eq!(keys(&a), keys(&b), "shards={shards}: variant order diverged");
        assert_eq!(a.scores, b.scores, "shards={shards}: scores diverged");
        assert_eq!(a.stats.kept, b.stats.kept, "shards={shards}: kept diverged");
    }
}

/// Distinct kernels must never share a canonical key. Seeded generation:
/// kernels differing only in a literal, in an input name, in spine
/// shape, or in binder *structure* (not binder names) all hash apart;
/// the only collisions are the intended α/formatting ones.
#[test]
fn seeded_distinct_kernels_never_collide_and_alpha_twins_always_do() {
    let mut rng = Rng::new(0x15_5E8);
    let mut sources: Vec<String> = Vec::new();
    // Literal-perturbed dot kernels: same shape, different constant.
    let mut lits = std::collections::HashSet::new();
    while lits.len() < 64 {
        lits.insert(rng.range(2, 100_000));
    }
    for c in &lits {
        sources.push(format!("(rnz + * (map (lam (x) (app * x {c}.0)) (in u)) (in v))"));
    }
    // Input-renamed kernels: a free name is part of the kernel identity.
    for name in ["u", "w", "p", "q"] {
        sources.push(format!("(rnz + * (in {name}) (in v))"));
    }
    // Spine-shape variants.
    sources.push("(map (lam (r) (rnz + * r (in v))) (in A))".into());
    sources.push("(map (lam (r) (map (lam (c) (rnz + * r c)) (flip 0 (in B)))) (in A))".into());
    sources.push("(map (lam (x) (app * x 2.0)) (in u))".into());
    // Binder-structure variant: λx.λy vs λ(x y) are different trees even
    // though an index-based hash numbers their variables alike.
    sources.push("(map (lam (x) (map (lam (y) (app + x y)) (in v))) (in u))".into());
    sources.push("(nzip (lam (x y) (app + x y)) (in u) (in v))".into());

    let mut seen: std::collections::HashMap<u64, String> = std::collections::HashMap::new();
    for s in &sources {
        let h = canonical_hash(&dsl::parse(s).unwrap());
        if let Some(prev) = seen.insert(h, s.clone()) {
            panic!("distinct kernels collided on canonical hash:\n  {prev}\n  {s}");
        }
    }
    // Positive control: α-twins and reformattings *must* collide.
    let a = canonical_hash(&dsl::parse("(map (lam (x) (app * x 2.0)) (in u))").unwrap());
    let b = canonical_hash(
        &dsl::parse("(map (lam (elem)\n  (app * elem 2.0)) (in u)) ; same kernel").unwrap(),
    );
    assert_eq!(a, b, "α-twins must share the canonical hash");
}

/// ISSUE 8 acceptance, pinned at the service level: after a job
/// completes, resubmitting an α-renamed spelling of it is answered from
/// the cache — the canonical hit counter increments and `search_expanded`
/// does not move.
#[test]
fn alpha_renamed_resubmission_is_a_canonical_hit_with_zero_search_delta() {
    let f = &families()[0];
    let c = Coordinator::start(Config {
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    let Response::Optimized(first) = c.call(Request::Optimize(spec_for(f, f.source))).unwrap()
    else {
        panic!("wrong response type")
    };
    let expanded = c.metrics.search_expanded.load(Ordering::Relaxed);
    let generated = c.metrics.search_generated.load(Ordering::Relaxed);
    let Response::Optimized(second) = c.call(Request::Optimize(spec_for(f, f.renamed))).unwrap()
    else {
        panic!("wrong response type")
    };
    assert_eq!(c.metrics.opt_cache_hits_canonical.load(Ordering::Relaxed), 1);
    assert_eq!(c.metrics.opt_cache_hits_exact.load(Ordering::Relaxed), 0);
    assert_eq!(c.metrics.search_expanded.load(Ordering::Relaxed), expanded);
    assert_eq!(c.metrics.search_generated.load(Ordering::Relaxed), generated);
    // The cached report is handed back as-is.
    assert_eq!(first.best, second.best);
    assert_eq!(first.best_expr, second.best_expr);
    assert_eq!(
        format!("{:?}", first.ranking),
        format!("{:?}", second.ranking)
    );
    // The sanity direction: a *different* kernel is not a hit.
    let other = &families()[2];
    c.call(Request::Optimize(spec_for(other, other.source))).unwrap();
    assert_eq!(c.metrics.opt_cache_hits(), 1);
}

/// `CanonicalKey` is plain data: the same spec keys identically across
/// independent constructions (no interior hashing state), so keys are
/// safe to build on every submission.
#[test]
fn canonical_keys_are_reproducible_values() {
    let f = &families()[1];
    let spec = spec_for(f, f.source);
    let a: CanonicalKey = spec.canonical_key(3).unwrap();
    let b: CanonicalKey = spec.canonical_key(3).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.source_hash, canonical_hash(&dsl::parse(f.source).unwrap()));
    assert_eq!(a.generation, 3);
    assert_eq!(a.subdivide_rnz, Some(4));
}
