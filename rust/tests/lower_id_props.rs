//! Differential and property tests for arena-native lowering and cost
//! estimation (ISSUE 3):
//!
//! - `lower_id(arena, intern(e))` produces *bit-identical* programs to
//!   `lower(e)` over the full enumerated variant set of every seed matmul
//!   / matvec workload family (and rejects exactly the same expressions);
//! - the lowered programs do not just look alike — they execute to
//!   identical outputs;
//! - `estimate_id` agrees with `estimate ∘ lower`;
//! - the partial-spine lower bound never exceeds the true cost-model
//!   score of any lowerable variant (the soundness property the search's
//!   branch-and-bound cut rests on).

use hofdla::costmodel::{estimate, estimate_id, spine_lower_bound_id};
use hofdla::dsl::intern::SharedArena;
use hofdla::enumerate::{enumerate_all, starts, Variant};
use hofdla::exec::{execute_named, lower, lower_id};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;

/// Shapes every start family typechecks under: A is n×j, B is j×k, v has
/// length j, with the divisibility the subdivided families (block 2,
/// twice-block 2·2) need.
fn ctx() -> Ctx {
    Ctx::new(
        Env::new()
            .with("A", Layout::row_major(&[4, 8]))
            .with("B", Layout::row_major(&[8, 4]))
            .with("v", Layout::row_major(&[8])),
    )
}

fn families() -> Vec<(&'static str, Variant)> {
    vec![
        ("matmul-naive", starts::matmul_naive_variant()),
        ("matmul-rnz-subdiv", starts::matmul_rnz_subdivided_variant(2)),
        ("matmul-maps-subdiv", starts::matmul_maps_subdivided_variant(2)),
        (
            "matmul-rnz-twice",
            starts::matmul_rnz_twice_subdivided_variant(2, 2),
        ),
        ("matmul-all-subdiv", starts::matmul_all_subdivided_variant(2)),
        ("matvec-naive", starts::matvec_naive_variant()),
        (
            "matvec-vector-subdiv",
            starts::matvec_vector_subdivided_variant(2),
        ),
    ]
}

#[test]
fn differential_lower_id_matches_lower_over_variant_sets() {
    let ctx = ctx();
    for (name, start) in families() {
        let variants = enumerate_all(&start, &ctx, 4096).unwrap();
        let arena = SharedArena::new();
        for v in &variants {
            let id = arena.intern(&v.expr);
            match (lower(&v.expr, &ctx.env), lower_id(&arena, id, &ctx.env)) {
                (Ok(pa), Ok(pb)) => {
                    // Bit-identical programs: slots, tracks, strides, temp
                    // regions, kernels — everything the Debug form shows.
                    assert_eq!(
                        format!("{pa:?}"),
                        format!("{pb:?}"),
                        "{name}/{}: lower and lower_id emitted different programs",
                        v.display_key()
                    );
                }
                (Err(_), Err(_)) => {}
                (a, b) => panic!(
                    "{name}/{}: lower/lower_id accept-reject diverged: {a:?} vs {b:?}",
                    v.display_key()
                ),
            }
        }
    }
}

#[test]
fn lower_id_programs_execute_identically() {
    let ctx = ctx();
    let mut rng = hofdla::util::Rng::new(7);
    let a = rng.fill_vec(4 * 8);
    let b = rng.fill_vec(8 * 4);
    let v = rng.fill_vec(8);
    let inputs: Vec<(&str, &[f64])> = vec![("A", &a), ("B", &b), ("v", &v)];
    for (name, start) in families() {
        let variants = enumerate_all(&start, &ctx, 4096).unwrap();
        let arena = SharedArena::new();
        for va in &variants {
            let id = arena.intern(&va.expr);
            let (Ok(pa), Ok(pb)) = (lower(&va.expr, &ctx.env), lower_id(&arena, id, &ctx.env))
            else {
                continue;
            };
            let mut oa = vec![0.0; pa.out_size];
            execute_named(&pa, &inputs, &mut oa)
                .unwrap_or_else(|e| panic!("{name}/{}: {e}", va.display_key()));
            let mut ob = vec![0.0; pb.out_size];
            execute_named(&pb, &inputs, &mut ob)
                .unwrap_or_else(|e| panic!("{name}/{}: {e}", va.display_key()));
            assert_eq!(oa, ob, "{name}/{}: outputs diverged", va.display_key());
        }
    }
}

#[test]
fn estimate_id_matches_boxed_estimate_over_variant_sets() {
    let ctx = ctx();
    for (name, start) in families() {
        let variants = enumerate_all(&start, &ctx, 4096).unwrap();
        let arena = SharedArena::new();
        for v in &variants {
            let id = arena.intern(&v.expr);
            let by_id = estimate_id(&arena, id, &ctx.env);
            let boxed = lower(&v.expr, &ctx.env).map(|p| estimate(&p));
            match (by_id, boxed) {
                (Ok(x), Ok(y)) => {
                    assert_eq!(x, y, "{name}/{}: estimates diverged", v.display_key())
                }
                (Err(_), Err(_)) => {}
                (x, y) => panic!(
                    "{name}/{}: estimate_id/estimate diverged: {x:?} vs {y:?}",
                    v.display_key()
                ),
            }
        }
    }
}

/// Property (ISSUE 3 satellite, tightened in ISSUE 5): the partial-spine
/// lower bound never exceeds the true cost — the soundness fact that
/// makes the search's branch-and-bound cut at slack 1.0 unable to drop
/// the winner.
#[test]
fn prop_spine_lower_bound_never_exceeds_true_cost() {
    let ctx = ctx();
    for (name, start) in families() {
        let variants = enumerate_all(&start, &ctx, 4096).unwrap();
        let arena = SharedArena::new();
        for v in &variants {
            let id = arena.intern(&v.expr);
            let lb = spine_lower_bound_id(&arena, id, &ctx);
            let Ok(est) = estimate_id(&arena, id, &ctx.env) else {
                // Unlowerable variants score +∞; any bound is sound.
                continue;
            };
            let score = est.score();
            assert!(
                lb <= score,
                "{name}/{}: lower bound {lb} exceeds true score {score}",
                v.display_key()
            );
            assert!(
                lb > 0.0,
                "{name}/{}: bound degenerated to zero",
                v.display_key()
            );
        }
    }
}

/// Property (ISSUE 5): the bound is sound *during candidate generation*,
/// on raw exchange output — the cross-expression invariant
/// `bound(raw) ≤ score(normalize(raw))`, which any gate consulting the
/// bound against thresholds derived from normalized candidates' true
/// scores would rest on. It holds because normalization never removes
/// (or shrinks the extent of) a spine level the raw descent charged —
/// pin it directly over every raw swap output of every enumerated
/// variant, so a future normalization rule that shrinks the spine fails
/// here, loudly, instead of silently making a generation-time cut
/// unsound.
#[test]
fn prop_raw_swap_output_bound_never_exceeds_normalized_score() {
    use hofdla::enumerate::try_swap_at_id;
    use hofdla::rewrite::{normalize_id_rules, IdRewriter};
    let ctx = ctx();
    for (name, start) in families() {
        let variants = enumerate_all(&start, &ctx, 4096).unwrap();
        let arena = SharedArena::new();
        let mut norm = IdRewriter::new(&normalize_id_rules());
        for v in &variants {
            let id = arena.intern(&v.expr);
            for d in 0..v.labels.len().saturating_sub(1) {
                let Some(raw) = try_swap_at_id(&arena, id, d, &ctx) else {
                    continue;
                };
                let raw_lb = spine_lower_bound_id(&arena, raw, &ctx);
                let nid = norm.rewrite(&arena, raw);
                let Ok(est) = estimate_id(&arena, nid, &ctx.env) else {
                    // Unlowerable candidates score +∞; any bound is sound.
                    continue;
                };
                assert!(
                    raw_lb <= est.score(),
                    "{name}/{} swap@{d}: raw-output bound {raw_lb} exceeds the \
                     normalized candidate's score {}",
                    v.display_key(),
                    est.score()
                );
            }
        }
    }
}

/// Property (ISSUE 5): soundness holds over *randomized* subdivided /
/// exchanged families, not just the docs' canonical shapes — every
/// enumerated rearrangement of every (shape, block) draw keeps
/// `spine_lower_bound_id ≤ estimate_id(..).score()`. Shapes and blocks
/// are drawn from the deterministic repo RNG with the divisibility each
/// family needs, spanning unit, small and ≥ 8 (line-sized) strides so all
/// `line_cost` regimes appear.
#[test]
fn prop_spine_lower_bound_sound_on_randomized_families() {
    let mut rng = hofdla::util::Rng::new(23);
    let mut draw = |choices: &[usize]| -> usize { choices[rng.below(choices.len())] };
    for round in 0..6 {
        let b = draw(&[2, 4]);
        let n = draw(&[4, 8, 12]);
        let j = b * 2 * draw(&[2, 4, 6]); // b1*b2 | j for the twice-subdivided family
        let k = draw(&[4, 8, 16]);
        let ctx = Ctx::new(
            Env::new()
                .with("A", Layout::row_major(&[n, j]))
                .with("B", Layout::row_major(&[j, k]))
                .with("v", Layout::row_major(&[j])),
        );
        let fams: Vec<(&str, Variant)> = vec![
            ("naive", starts::matmul_naive_variant()),
            ("rnz-subdiv", starts::matmul_rnz_subdivided_variant(b)),
            ("rnz-twice", starts::matmul_rnz_twice_subdivided_variant(b, 2)),
            ("matvec-subdiv", starts::matvec_vector_subdivided_variant(b)),
        ];
        for (name, start) in fams {
            let variants = enumerate_all(&start, &ctx, 4096).unwrap();
            assert!(!variants.is_empty(), "{name}");
            let arena = SharedArena::new();
            let mut bounds = std::collections::BTreeSet::new();
            for v in &variants {
                let id = arena.intern(&v.expr);
                let lb = spine_lower_bound_id(&arena, id, &ctx);
                bounds.insert(lb.to_bits());
                let Ok(est) = estimate_id(&arena, id, &ctx.env) else {
                    continue;
                };
                assert!(
                    lb <= est.score(),
                    "round {round} {name}/{} (n={n} j={j} k={k} b={b}): \
                     bound {lb} exceeds score {}",
                    v.display_key(),
                    est.score()
                );
                assert!(lb > 0.0, "round {round} {name}/{}", v.display_key());
            }
            // Rearrangement sensitivity: the matmul families must not
            // collapse to a single bound value (that was the inert-cut
            // bug this bound replaced). k ≥ 4 guarantees it structurally:
            // a variant reading B innermost at its column stride bounds
            // above one reading B at unit stride. (The 3-variant matvec
            // family can legitimately tie, so it is exempt.)
            if variants.len() >= 4 {
                assert!(
                    bounds.len() > 1,
                    "round {round} {name} (n={n} j={j} k={k} b={b}): \
                     bound is permutation-invariant again"
                );
            }
        }
    }
}
