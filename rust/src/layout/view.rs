//! A [`View`] pairs a [`Layout`] with a base offset into some flat buffer.
//!
//! Views are how the executor and reference evaluator address data: slicing
//! off the outermost dimension (what a HoF does when it binds its function's
//! parameter) is just an offset adjustment, and the layout operators apply
//! unchanged.

use super::Layout;
use crate::{Error, Result};

/// A strided window into a flat buffer identified externally (by slot or by
/// ownership); the view itself only stores geometry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct View {
    pub offset: usize,
    pub layout: Layout,
}

impl View {
    pub fn new(offset: usize, layout: Layout) -> Self {
        View { offset, layout }
    }

    /// Whole-buffer view with a given layout.
    pub fn of(layout: Layout) -> Self {
        View { offset: 0, layout }
    }

    /// The view of element `i` along the outermost dimension: drops that
    /// dimension and advances the offset by `i * stride`.
    pub fn index_outer(&self, i: usize) -> Result<View> {
        let outer = self
            .layout
            .outer()
            .ok_or_else(|| Error::Layout("index_outer on scalar view".into()))?;
        if i >= outer.extent {
            return Err(Error::Layout(format!(
                "index_outer: {i} out of range {}",
                outer.extent
            )));
        }
        Ok(View {
            offset: self.offset + i * outer.stride,
            layout: self.layout.peel_outer()?,
        })
    }

    /// Flat offset of a full logical index.
    pub fn offset_of(&self, idx: &[usize]) -> usize {
        self.offset + self.layout.offset_of(idx)
    }

    /// One-past-the-last flat offset this view can touch.
    pub fn span_end(&self) -> usize {
        self.offset + self.layout.required_span()
    }

    pub fn subdiv(&self, d: usize, b: usize) -> Result<View> {
        Ok(View {
            offset: self.offset,
            layout: self.layout.subdiv(d, b)?,
        })
    }

    pub fn flatten(&self, d: usize) -> Result<View> {
        Ok(View {
            offset: self.offset,
            layout: self.layout.flatten(d)?,
        })
    }

    pub fn flip2(&self, d1: usize, d2: usize) -> Result<View> {
        Ok(View {
            offset: self.offset,
            layout: self.layout.flip2(d1, d2)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::Dim;

    #[test]
    fn index_outer_walks_rows() {
        let v = View::of(Layout::row_major(&[4, 3]));
        let r2 = v.index_outer(2).unwrap();
        assert_eq!(r2.offset, 6);
        assert_eq!(r2.layout.dims, vec![Dim::new(3, 1)]);
        assert!(v.index_outer(4).is_err());
    }

    #[test]
    fn index_outer_on_flipped_walks_columns() {
        let v = View::of(Layout::row_major(&[4, 3])).flip2(0, 1).unwrap();
        let c1 = v.index_outer(1).unwrap();
        assert_eq!(c1.offset, 1);
        assert_eq!(c1.layout.dims, vec![Dim::new(4, 3)]);
    }

    #[test]
    fn nested_indexing_matches_offset_of() {
        let v = View::of(Layout::row_major(&[3, 5]));
        for i in 0..3 {
            for j in 0..5 {
                let elem = v.index_outer(i).unwrap().index_outer(j).unwrap();
                assert_eq!(elem.offset, v.offset_of(&[j, i]));
                assert!(elem.layout.is_scalar());
            }
        }
    }

    #[test]
    fn span_end() {
        let v = View::new(10, Layout::row_major(&[2, 2]));
        assert_eq!(v.span_end(), 14);
    }
}
