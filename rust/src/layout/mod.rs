//! Strided layout algebra — the paper's §2.1.
//!
//! A multi-dimensional array view is described by a list of
//! `(extent, stride)` pairs, written in the paper as
//! `a^((e_1,s_1),(e_2,s_2),…,(e_n,s_n))`. **Index 0 is the innermost
//! (fastest-varying) dimension**; higher-order functions consume the
//! *outermost* dimension, i.e. the one at the highest index. This matches
//! the paper's convention: for the 120-element example,
//! `a^((3,1),(2,3),(5,6),(4,30))` is the flat row-major 4-tensor while
//! `a^((3,1),(2,15),(5,3),(4,30))` is the same memory reinterpreted as a
//! subdivided (blocked) matrix.
//!
//! Three layout operators change the *logical* structure without moving any
//! data:
//!
//! - [`Layout::subdiv`] — split dimension `d`'s extent into blocks of `b`
//!   (paper eq. for `subdiv d b s`),
//! - [`Layout::flatten`] — merge dimensions `d` and `d+1` (inverse of
//!   `subdiv`),
//! - [`Layout::flip`] — swap two dimensions (a transpose of the logical
//!   structure; `flip` applied twice is the identity).
//!
//! Because the layouts are Naperian (a container of a fixed shape is a
//! function from its index set), these operators correspond to `curry` /
//! `uncurry` / `flip` on index functions — which is what makes the paper's
//! HoF exchange rules type-check.

mod view;

pub use view::View;

use crate::{Error, Result};

/// One logical dimension of a strided view: `extent` elements, consecutive
/// logical indices separated by `stride` elements in flat storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Dim {
    pub extent: usize,
    pub stride: usize,
}

impl Dim {
    pub fn new(extent: usize, stride: usize) -> Self {
        Dim { extent, stride }
    }
}

/// A strided multi-dimensional layout. `dims[0]` is the innermost dimension.
#[derive(Clone, Debug, PartialEq, Eq, Hash, Default)]
pub struct Layout {
    pub dims: Vec<Dim>,
}

impl Layout {
    /// Scalar layout (rank 0).
    pub fn scalar() -> Self {
        Layout { dims: Vec::new() }
    }

    /// Construct from `(extent, stride)` pairs, innermost first.
    pub fn from_pairs(pairs: &[(usize, usize)]) -> Self {
        Layout {
            dims: pairs.iter().map(|&(e, s)| Dim::new(e, s)).collect(),
        }
    }

    /// Dense row-major layout for logical shape given **outermost first**
    /// (the conventional shape order, e.g. `[rows, cols]` for a matrix).
    ///
    /// `row_major(&[n, m])` yields `dims = [(m,1),(n,m)]`: the column index
    /// is innermost.
    pub fn row_major(shape_outer_first: &[usize]) -> Self {
        let mut dims = Vec::with_capacity(shape_outer_first.len());
        let mut stride = 1;
        for &e in shape_outer_first.iter().rev() {
            dims.push(Dim::new(e, stride));
            stride *= e;
        }
        Layout { dims }
    }

    /// Dense column-major layout, shape given outermost first.
    pub fn col_major(shape_outer_first: &[usize]) -> Self {
        let mut dims: Vec<Dim> = Vec::with_capacity(shape_outer_first.len());
        let mut stride = 1;
        for &e in shape_outer_first.iter() {
            dims.push(Dim::new(e, stride));
            stride *= e;
        }
        dims.reverse();
        Layout { dims }
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// `true` for rank 0.
    pub fn is_scalar(&self) -> bool {
        self.dims.is_empty()
    }

    /// Total number of logical elements (product of extents).
    pub fn len(&self) -> usize {
        self.dims.iter().map(|d| d.extent).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The outermost dimension — the one a HoF consumes.
    pub fn outer(&self) -> Option<Dim> {
        self.dims.last().copied()
    }

    /// Layout of one element along the outermost dimension (what a HoF's
    /// function argument sees).
    pub fn peel_outer(&self) -> Result<Layout> {
        if self.dims.is_empty() {
            return Err(Error::Layout("peel_outer on scalar layout".into()));
        }
        Ok(Layout {
            dims: self.dims[..self.dims.len() - 1].to_vec(),
        })
    }

    /// The smallest flat-buffer size (in elements, relative to the view's
    /// base offset) that contains every address this layout can touch.
    pub fn required_span(&self) -> usize {
        1 + self
            .dims
            .iter()
            .map(|d| (d.extent - 1) * d.stride)
            .sum::<usize>()
    }

    /// Flat offset of a logical index (given innermost-first, one index per
    /// dimension).
    pub fn offset_of(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        idx.iter()
            .zip(&self.dims)
            .map(|(&i, d)| {
                debug_assert!(i < d.extent);
                i * d.stride
            })
            .sum()
    }

    /// `subdiv d b`: split the extent at dimension `d` into blocks of size
    /// `b`. Per the paper:
    ///
    /// ```text
    /// (e'_d,     s'_d)     = (b, s_d)           -- within-block (inner)
    /// (e'_{d+1}, s'_{d+1}) = (e_d / b, b * s_d) -- block index  (outer)
    /// ```
    ///
    /// `b` must divide `e_d`.
    pub fn subdiv(&self, d: usize, b: usize) -> Result<Layout> {
        let dim = *self
            .dims
            .get(d)
            .ok_or_else(|| Error::Layout(format!("subdiv: dim {d} out of range (rank {})", self.rank())))?;
        if b == 0 || dim.extent % b != 0 {
            return Err(Error::Layout(format!(
                "subdiv: block size {b} does not divide extent {}",
                dim.extent
            )));
        }
        let mut dims = self.dims.clone();
        dims[d] = Dim::new(b, dim.stride);
        dims.insert(d + 1, Dim::new(dim.extent / b, b * dim.stride));
        Ok(Layout { dims })
    }

    /// `flatten d`: merge dimensions `d` and `d+1` into one of extent
    /// `e_d * e_{d+1}` and stride `s_d`. It is the inverse of `subdiv`
    /// only when the strides chain (`s_{d+1} == e_d * s_d`); we enforce
    /// that, since otherwise the flattened view would address different
    /// elements than the nested one.
    pub fn flatten(&self, d: usize) -> Result<Layout> {
        if d + 1 >= self.rank() {
            return Err(Error::Layout(format!(
                "flatten: need dims {d},{} but rank is {}",
                d + 1,
                self.rank()
            )));
        }
        let inner = self.dims[d];
        let outer = self.dims[d + 1];
        if outer.stride != inner.extent * inner.stride {
            return Err(Error::Layout(format!(
                "flatten: dims {d},{} do not chain: outer stride {} != {} * {}",
                d + 1,
                outer.stride,
                inner.extent,
                inner.stride
            )));
        }
        let mut dims = self.dims.clone();
        dims[d] = Dim::new(inner.extent * outer.extent, inner.stride);
        dims.remove(d + 1);
        Ok(Layout { dims })
    }

    /// `flip d1 d2`: swap dimensions `d1` and `d2` (extent and stride
    /// together). Commutative in its arguments; an involution.
    pub fn flip2(&self, d1: usize, d2: usize) -> Result<Layout> {
        if d1 >= self.rank() || d2 >= self.rank() {
            return Err(Error::Layout(format!(
                "flip: dims {d1},{d2} out of range (rank {})",
                self.rank()
            )));
        }
        let mut dims = self.dims.clone();
        dims.swap(d1, d2);
        Ok(Layout { dims })
    }

    /// `flip d` with the paper's default second argument `d2 = d1 + 1`.
    pub fn flip(&self, d: usize) -> Result<Layout> {
        self.flip2(d, d + 1)
    }

    /// Enumerate the flat offsets of all logical elements in logical
    /// (innermost-fastest) order. For tests and the reference evaluator.
    pub fn offsets(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.len());
        let mut idx = vec![0usize; self.rank()];
        loop {
            out.push(self.offset_of(&idx));
            // increment innermost-first
            let mut d = 0;
            loop {
                if d == self.rank() {
                    return out;
                }
                idx[d] += 1;
                if idx[d] < self.dims[d].extent {
                    break;
                }
                idx[d] = 0;
                d += 1;
            }
        }
    }

    /// `true` if no two distinct logical indices map to the same flat
    /// offset (the view is a bijection onto its image).
    pub fn is_injective(&self) -> bool {
        let mut offs = self.offsets();
        let n = offs.len();
        offs.sort_unstable();
        offs.dedup();
        offs.len() == n
    }
}

impl std::fmt::Display for Layout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a^(")?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "({},{})", d.extent, d.stride)?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_matrix() {
        // n=4 rows, m=3 cols
        let l = Layout::row_major(&[4, 3]);
        assert_eq!(l.dims, vec![Dim::new(3, 1), Dim::new(4, 3)]);
        assert_eq!(l.len(), 12);
        assert_eq!(l.required_span(), 12);
        assert!(l.is_injective());
    }

    #[test]
    fn col_major_matrix() {
        let l = Layout::col_major(&[4, 3]);
        assert_eq!(l.dims, vec![Dim::new(3, 4), Dim::new(4, 1)]);
        assert!(l.is_injective());
    }

    #[test]
    fn paper_120_element_example() {
        // Paper §2.1: 120 elements; flat 4-tensor vs subdivided matrix.
        let flat = Layout::from_pairs(&[(3, 1), (2, 3), (5, 6), (4, 30)]);
        assert_eq!(flat.len(), 120);
        assert_eq!(flat.required_span(), 120);
        assert!(flat.is_injective());

        let blocked = Layout::from_pairs(&[(3, 1), (2, 15), (5, 3), (4, 30)]);
        assert_eq!(blocked.len(), 120);
        assert_eq!(blocked.required_span(), 120);
        assert!(blocked.is_injective());
    }

    #[test]
    fn subdiv_matches_paper_equations() {
        // 6x4 row-major matrix: dims [(4,1),(6,4)]
        let l = Layout::row_major(&[6, 4]);
        // split the column dimension (d=0) into blocks of 2
        let s = l.subdiv(0, 2).unwrap();
        assert_eq!(
            s.dims,
            vec![Dim::new(2, 1), Dim::new(2, 2), Dim::new(6, 4)]
        );
        assert!(s.is_injective());
        assert_eq!(s.len(), 24);
    }

    #[test]
    fn subdiv_then_flatten_is_identity() {
        let l = Layout::row_major(&[8, 6]);
        for d in 0..2 {
            for &b in &[1, 2, 3, 6] {
                if l.dims[d].extent % b != 0 {
                    continue;
                }
                let round = l.subdiv(d, b).unwrap().flatten(d).unwrap();
                assert_eq!(round, l, "subdiv({d},{b}) then flatten");
            }
        }
    }

    #[test]
    fn subdiv_requires_divisibility() {
        let l = Layout::row_major(&[6, 4]);
        assert!(l.subdiv(0, 3).is_err()); // 3 does not divide 4
        assert!(l.subdiv(1, 4).is_err()); // 4 does not divide 6
        assert!(l.subdiv(0, 0).is_err());
        assert!(l.subdiv(5, 2).is_err());
    }

    #[test]
    fn flatten_requires_chained_strides() {
        // flip first so strides no longer chain
        let l = Layout::row_major(&[4, 4]).flip(0).unwrap();
        assert!(l.flatten(0).is_err());
    }

    #[test]
    fn flip_involution_and_commutative() {
        let l = Layout::from_pairs(&[(3, 1), (5, 3), (2, 15)]);
        let f = l.flip2(0, 2).unwrap();
        assert_eq!(f.flip2(0, 2).unwrap(), l);
        assert_eq!(l.flip2(0, 2).unwrap(), l.flip2(2, 0).unwrap());
        assert_eq!(l.flip(1).unwrap(), l.flip2(1, 2).unwrap());
    }

    #[test]
    fn offsets_row_major_are_sequential() {
        let l = Layout::row_major(&[2, 3]);
        assert_eq!(l.offsets(), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn offsets_flipped_matrix_walk_columns() {
        let l = Layout::row_major(&[2, 3]).flip(0).unwrap();
        // flipped: inner dim is now the row index (stride 3)
        assert_eq!(l.offsets(), vec![0, 3, 1, 4, 2, 5]);
    }

    #[test]
    fn peel_outer_gives_element_layout() {
        let l = Layout::row_major(&[4, 3]);
        let row = l.peel_outer().unwrap();
        assert_eq!(row.dims, vec![Dim::new(3, 1)]);
        assert!(Layout::scalar().peel_outer().is_err());
    }

    #[test]
    fn display_matches_paper_notation() {
        let l = Layout::from_pairs(&[(3, 1), (4, 3)]);
        assert_eq!(l.to_string(), "a^((3,1),(4,3))");
    }
}
