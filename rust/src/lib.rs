//! # hofdla — pattern-based optimization for dense linear algebra
//!
//! A reproduction of *"Towards scalable pattern-based optimization for dense
//! linear algebra"* (Berényi, Leitereg, Lehel, 2018; DOI 10.1002/cpe.4696).
//!
//! The paper proposes describing dense multi-dimensional array computations
//! with a small, **closed** set of variadic higher-order functions (HoFs) —
//! [`nzip`](dsl::Expr::Nzip) (n-ary map/zip), [`rnz`](dsl::Expr::Rnz)
//! (reduce-of-n-ary-zip) — over strided arrays whose *logical* layout is
//! manipulated by `subdiv` / `flatten` / `flip`, and then optimizing the
//! expression purely by **structure-induced rewrites**: fusion, exchange
//! (HoF interchange paired with a layout `flip`), and subdivision identities.
//!
//! The optimize pipeline runs **arena-native end to end**: candidates are
//! generated, normalized, typechecked, lowered and cost-estimated as
//! hash-consed [`dsl::intern::ExprId`]s, and `Box<Expr>` trees are rebuilt
//! only once per kept variant at the output boundary. `ARCHITECTURE.md`
//! (repository root) walks the full request flow, the module map and the
//! differential-test invariants that hold the twin engines together.
//!
//! The crate is organised as the paper's system plus every substrate it
//! needs:
//!
//! - [`layout`] — the strided `(extent, stride)` layout algebra.
//! - [`dsl`] — the expression AST, builder combinators, pretty printer and
//!   s-expression parser.
//! - [`typecheck`] — shape/type inference over expressions.
//! - [`eval`] — slow, obviously-correct reference evaluator (the oracle for
//!   every rewrite and for the fast executor).
//! - [`rewrite`] — the rewrite engine and the paper's rule families.
//! - [`enumerate`] — HoF-spine extraction and Steinhaus–Johnson–Trotter
//!   enumeration of rearrangements: a sharded, branch-and-bound BFS
//!   running natively on interned ids.
//! - [`exec`] — lowering to a loop-nest IR (twin front ends
//!   [`exec::lower`] / [`exec::lower_id`]) and a fast strided executor
//!   (the measured artifact; stands in for the paper's generated C++14).
//! - [`cachesim`] — a set-associative multi-level cache simulator driven by
//!   the loop IR's address stream (stands in for the paper's Core i5/HD7970).
//! - [`costmodel`] — analytical locality cost model used for ranking
//!   ([`costmodel::estimate_id`]) and the paper's "early cut" pruning
//!   ([`costmodel::spine_lower_bound_id`]).
//! - [`baselines`] — naive / hand-blocked native matmul (the paper's C
//!   baselines).
//! - [`runtime`] — PJRT client wrapping the `xla` crate; loads the
//!   AOT-compiled JAX/Pallas artifacts (the paper's Eigen role).
//! - [`coordinator`] — a threaded optimization-service front end: job queue,
//!   pipeline, executable cache, batching, metrics.
//! - [`verify`] — static access-footprint verifier over the loop IR:
//!   proves bounds, initialization and map-write-disjointness per program
//!   by abstract interpretation of the affine advance chains, gating the
//!   executor's unsafe fast paths.
//! - [`bench_support`] — micro-benchmark harness, PRNG, table formatting
//!   (criterion/proptest are unavailable offline; these are self-contained).

// Every unsafe block must carry a `// SAFETY:` comment stating its
// precondition; `verify` exists to machine-check those preconditions.
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod baselines;
pub mod bench_support;
pub mod cachesim;
pub mod coordinator;
pub mod costmodel;
pub mod dsl;
pub mod enumerate;
pub mod eval;
pub mod exec;
pub mod experiments;
pub mod layout;
pub mod rewrite;
pub mod runtime;
pub mod typecheck;
pub mod util;
pub mod verify;

pub use dsl::{Expr, Prim};
pub use layout::{Dim, Layout};

/// Crate-wide error type. Implemented by hand (rather than via
/// `thiserror`) so the default build has zero dependencies and works
/// offline. `Clone` because the coordinator's single-flight path fans a
/// leader's failure out to every coalesced waiter.
#[derive(Clone, Debug)]
pub enum Error {
    Layout(String),
    Type(String),
    Parse(String),
    Lower(String),
    Eval(String),
    Rewrite(String),
    Runtime(String),
    Coordinator(String),
    /// A lowered program failed static access-footprint verification
    /// ([`verify::verify`]); the message lists every violation found.
    Verify(String),
    /// The coordinator's admission control rejected the job at intake:
    /// the optimize queue was at capacity. Carries the queue depth
    /// observed at rejection so clients can back off proportionally.
    /// Shed jobs are counted in `Metrics::shed` and never occupy a
    /// worker, a queue slot, or a reply channel.
    Overloaded { queue_depth: usize },
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Layout(m) => write!(f, "layout error: {m}"),
            Error::Type(m) => write!(f, "type error: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Lower(m) => write!(f, "lowering error: {m}"),
            Error::Eval(m) => write!(f, "eval error: {m}"),
            Error::Rewrite(m) => write!(f, "rewrite error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Verify(m) => write!(f, "verification error: {m}"),
            Error::Overloaded { queue_depth } => write!(
                f,
                "service overloaded: optimize intake queue at capacity ({queue_depth} queued)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;
