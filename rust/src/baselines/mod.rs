//! Hand-written native baselines — the paper's comparison points.
//!
//! - [`naive_matmul`] — the paper's "naive C level implementation"
//!   (4.9 s at 1024² on their i5): textbook ijk triple loop.
//! - [`blocked_matmul`] — the paper's "improved blocked version" (278 ms):
//!   three-level tiling with a contiguous inner kernel.
//! - `xla` (via [`crate::runtime`]) plays the Eigen role (333/60 ms).
//!
//! These run the same f64 workloads as the generated variants so the
//! paper's ratios (naive / best-variant / blocked) can be reproduced.

/// Naive ijk matrix multiplication: `C[n×k] = A[n×j] · B[j×k]`, row-major.
/// The exact loop order of the paper's naive C baseline.
pub fn naive_matmul(a: &[f64], b: &[f64], c: &mut [f64], n: usize, j: usize, k: usize) {
    assert_eq!(a.len(), n * j);
    assert_eq!(b.len(), j * k);
    assert_eq!(c.len(), n * k);
    for i in 0..n {
        for kk in 0..k {
            let mut acc = 0.0;
            for jj in 0..j {
                acc += a[i * j + jj] * b[jj * k + kk];
            }
            c[i * k + kk] = acc;
        }
    }
}

/// Cache-blocked matrix multiplication with block size `bs` (the paper's
/// hand-optimised baseline). Accumulates in-place over j-blocks with an
/// ikj inner order so B is read row-wise.
pub fn blocked_matmul(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    n: usize,
    j: usize,
    k: usize,
    bs: usize,
) {
    assert_eq!(a.len(), n * j);
    assert_eq!(b.len(), j * k);
    assert_eq!(c.len(), n * k);
    c.fill(0.0);
    let bs = bs.max(1);
    for i0 in (0..n).step_by(bs) {
        let i1 = (i0 + bs).min(n);
        for j0 in (0..j).step_by(bs) {
            let j1 = (j0 + bs).min(j);
            for k0 in (0..k).step_by(bs) {
                let k1 = (k0 + bs).min(k);
                for i in i0..i1 {
                    for jj in j0..j1 {
                        let aij = a[i * j + jj];
                        let brow = &b[jj * k + k0..jj * k + k1];
                        let crow = &mut c[i * k + k0..i * k + k1];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aij * bv;
                        }
                    }
                }
            }
        }
    }
}

/// Naive matrix–vector product (`u = A v`).
pub fn naive_matvec(a: &[f64], v: &[f64], u: &mut [f64], n: usize, j: usize) {
    assert_eq!(a.len(), n * j);
    assert_eq!(v.len(), j);
    assert_eq!(u.len(), n);
    for i in 0..n {
        let mut acc = 0.0;
        for jj in 0..j {
            acc += a[i * j + jj] * v[jj];
        }
        u[i] = acc;
    }
}

/// Transpose a row-major `rows×cols` matrix.
pub fn transpose(m: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    assert_eq!(m.len(), rows * cols);
    let mut out = vec![0.0; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = m[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn blocked_matches_naive() {
        let (n, j, k) = (17usize, 23, 11);
        let mut rng = Rng::new(2);
        let a = rng.fill_vec(n * j);
        let b = rng.fill_vec(j * k);
        let mut c1 = vec![0.0; n * k];
        let mut c2 = vec![0.0; n * k];
        naive_matmul(&a, &b, &mut c1, n, j, k);
        for bs in [1, 4, 7, 16, 64] {
            blocked_matmul(&a, &b, &mut c2, n, j, k, bs);
            assert!(
                crate::util::allclose(&c1, &c2, 1e-9),
                "blocked bs={bs} diverges"
            );
        }
    }

    #[test]
    fn matvec_small() {
        let a = [1., 2., 3., 4., 5., 6.];
        let v = [1., 10.];
        let mut u = [0.0; 3];
        naive_matvec(&a, &v, &mut u, 3, 2);
        assert_eq!(u, [21., 43., 65.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(4);
        let m = rng.fill_vec(12);
        let t = transpose(&m, 3, 4);
        let back = transpose(&t, 4, 3);
        assert_eq!(m, back);
        assert_eq!(t[0 * 3 + 2], m[2 * 4 + 0]);
    }
}
