//! Reference evaluator: a slow, obviously-correct interpreter for the DSL.
//!
//! Every rewrite rule and the fast loop-nest executor are validated against
//! this oracle. Arrays are immutable shared buffers with strided [`View`]s,
//! so the layout operators (`subdiv`/`flatten`/`flip`) are zero-copy here
//! too — exactly the paper's "logical structure" semantics.

use crate::dsl::Expr;
use crate::layout::{Layout, View};
use crate::{Error, Result};
use std::collections::HashMap;
use std::rc::Rc;

/// A runtime value: a scalar or a strided window over a shared buffer.
#[derive(Clone, Debug)]
pub enum Value {
    Scalar(f64),
    Arr(ArrVal),
}

/// Array value: shared flat storage plus a view describing the logical
/// structure.
#[derive(Clone, Debug)]
pub struct ArrVal {
    pub data: Rc<Vec<f64>>,
    pub view: View,
}

impl ArrVal {
    /// Dense array from data in row-major order of `shape` (outermost
    /// first).
    pub fn dense(data: Vec<f64>, shape_outer_first: &[usize]) -> Self {
        let layout = Layout::row_major(shape_outer_first);
        assert_eq!(layout.len(), data.len(), "dense: shape/data mismatch");
        ArrVal {
            data: Rc::new(data),
            view: View::of(layout),
        }
    }

    /// Read the scalar at a fully-specified logical index.
    pub fn at(&self, idx: &[usize]) -> f64 {
        self.data[self.view.offset_of(idx)]
    }

    /// Flatten to a dense `Vec` in logical (innermost-fastest) order.
    pub fn to_dense(&self) -> Vec<f64> {
        self.view
            .layout
            .offsets()
            .into_iter()
            .map(|o| self.data[self.view.offset + o])
            .collect()
    }
}

impl Value {
    pub fn as_scalar(&self) -> Result<f64> {
        match self {
            Value::Scalar(x) => Ok(*x),
            Value::Arr(a) if a.view.layout.is_scalar() => {
                Ok(a.data[a.view.offset])
            }
            _ => Err(Error::Eval("expected scalar value".into())),
        }
    }

    pub fn as_arr(&self) -> Result<&ArrVal> {
        match self {
            Value::Arr(a) => Ok(a),
            Value::Scalar(_) => Err(Error::Eval("expected array value".into())),
        }
    }

    /// Dense representation in logical order (scalar → 1 element).
    pub fn to_dense(&self) -> Vec<f64> {
        match self {
            Value::Scalar(x) => vec![*x],
            Value::Arr(a) => a.to_dense(),
        }
    }

    /// Logical extents, innermost first (empty for scalars).
    pub fn extents(&self) -> Vec<usize> {
        match self {
            Value::Scalar(_) => Vec::new(),
            Value::Arr(a) => a.view.layout.dims.iter().map(|d| d.extent).collect(),
        }
    }
}

/// Named input arrays.
pub type Inputs = HashMap<String, ArrVal>;

/// Evaluate a closed expression given its named inputs.
pub fn eval(e: &Expr, inputs: &Inputs) -> Result<Value> {
    let mut vars: HashMap<String, Value> = HashMap::new();
    go(e, inputs, &mut vars)
}

fn go(e: &Expr, inputs: &Inputs, vars: &mut HashMap<String, Value>) -> Result<Value> {
    match e {
        Expr::Var(x) => vars
            .get(x)
            .cloned()
            .ok_or_else(|| Error::Eval(format!("unbound variable '{x}'"))),
        Expr::Lit(x) => Ok(Value::Scalar(*x)),
        Expr::Input(n) => inputs
            .get(n)
            .cloned()
            .map(Value::Arr)
            .ok_or_else(|| Error::Eval(format!("missing input '{n}'"))),
        Expr::Prim(_) | Expr::Lam { .. } | Expr::Lift { .. } => Err(Error::Eval(
            "function form used as a value outside operator position".into(),
        )),
        Expr::App { f, args } => {
            let vals = args
                .iter()
                .map(|a| go(a, inputs, vars))
                .collect::<Result<Vec<_>>>()?;
            apply(f, &vals, inputs, vars)
        }
        Expr::Nzip { f, args } => {
            let vals = args
                .iter()
                .map(|a| go(a, inputs, vars))
                .collect::<Result<Vec<_>>>()?;
            nzip_values(|elems| apply(f, elems, inputs, vars), &vals, "nzip")
        }
        Expr::Rnz { r, m, args } => {
            let vals = args
                .iter()
                .map(|a| go(a, inputs, vars))
                .collect::<Result<Vec<_>>>()?;
            let extent = outer_extent(&vals, "rnz")?;
            let mut acc: Option<Value> = None;
            for i in 0..extent {
                let elems = index_all(&vals, i)?;
                let v = apply(m, &elems, inputs, vars)?;
                acc = Some(match acc {
                    None => v,
                    Some(a) => combine(r, &a, &v)?,
                });
            }
            acc.ok_or_else(|| Error::Eval("rnz over empty extent".into()))
        }
        Expr::Subdiv { d, b, arg } => {
            let v = go(arg, inputs, vars)?;
            let a = v.as_arr()?;
            Ok(Value::Arr(ArrVal {
                data: a.data.clone(),
                view: a.view.subdiv(*d, *b)?,
            }))
        }
        Expr::Flatten { d, arg } => {
            let v = go(arg, inputs, vars)?;
            let a = v.as_arr()?;
            Ok(Value::Arr(ArrVal {
                data: a.data.clone(),
                view: a.view.flatten(*d)?,
            }))
        }
        Expr::Flip { d1, d2, arg } => {
            let v = go(arg, inputs, vars)?;
            let a = v.as_arr()?;
            Ok(Value::Arr(ArrVal {
                data: a.data.clone(),
                view: a.view.flip2(*d1, *d2)?,
            }))
        }
    }
}

/// Apply a function-position expression to already-evaluated arguments.
fn apply(
    f: &Expr,
    args: &[Value],
    inputs: &Inputs,
    vars: &mut HashMap<String, Value>,
) -> Result<Value> {
    match f {
        Expr::Prim(p) => {
            if args.len() != p.arity() {
                return Err(Error::Eval(format!(
                    "primitive {} expects {} args, got {}",
                    p.name(),
                    p.arity(),
                    args.len()
                )));
            }
            let xs = args
                .iter()
                .map(Value::as_scalar)
                .collect::<Result<Vec<_>>>()?;
            Ok(Value::Scalar(p.apply(&xs)))
        }
        Expr::Lam { params, body } => {
            if params.len() != args.len() {
                return Err(Error::Eval(format!(
                    "lambda expects {} args, got {}",
                    params.len(),
                    args.len()
                )));
            }
            let mut saved = Vec::with_capacity(params.len());
            for (p, v) in params.iter().zip(args) {
                saved.push((p.clone(), vars.insert(p.clone(), v.clone())));
            }
            let r = go(body, inputs, vars);
            for (p, old) in saved.into_iter().rev() {
                match old {
                    Some(v) => {
                        vars.insert(p, v);
                    }
                    None => {
                        vars.remove(&p);
                    }
                }
            }
            r
        }
        Expr::Lift { f: inner } => {
            nzip_values(|elems| apply(inner, elems, inputs, vars), args, "lift")
        }
        other => Err(Error::Eval(format!(
            "unsupported function form: {}",
            crate::dsl::pretty(other)
        ))),
    }
}

/// Shared elementwise-over-outer-dimension loop used by `nzip` and `lift`:
/// applies `f` to each tuple of outer-indexed elements and packs the results
/// into a fresh dense array.
fn nzip_values(
    mut f: impl FnMut(&[Value]) -> Result<Value>,
    args: &[Value],
    what: &str,
) -> Result<Value> {
    let extent = outer_extent(args, what)?;
    let mut elem_extents: Option<Vec<usize>> = None;
    let mut out: Vec<f64> = Vec::new();
    for i in 0..extent {
        let elems = index_all(args, i)?;
        let v = f(&elems)?;
        match &elem_extents {
            None => elem_extents = Some(v.extents()),
            Some(prev) => {
                if *prev != v.extents() {
                    return Err(Error::Eval(format!(
                        "{what}: result shape varies across elements"
                    )));
                }
            }
        }
        out.extend(v.to_dense());
    }
    // Assemble the dense result: element dims (innermost first) + outer.
    let elem_extents = elem_extents.unwrap_or_default();
    let mut dims = Vec::with_capacity(elem_extents.len() + 1);
    let mut stride = 1;
    for &e in &elem_extents {
        dims.push(crate::layout::Dim::new(e, stride));
        stride *= e;
    }
    dims.push(crate::layout::Dim::new(extent, stride));
    Ok(Value::Arr(ArrVal {
        data: Rc::new(out),
        view: View::of(Layout { dims }),
    }))
}

/// Combine two accumulator values with a reduction operator (`Prim` or
/// `lift^k prim`).
fn combine(r: &Expr, a: &Value, b: &Value) -> Result<Value> {
    match r {
        Expr::Prim(p) => {
            if p.arity() != 2 {
                return Err(Error::Eval("reduction operator must be binary".into()));
            }
            Ok(Value::Scalar(p.apply(&[a.as_scalar()?, b.as_scalar()?])))
        }
        Expr::Lift { f } => {
            let (aa, ba) = (a.as_arr()?, b.as_arr()?);
            let ea = aa.view.layout.outer().ok_or_else(|| {
                Error::Eval("lifted reduction over scalar accumulator".into())
            })?;
            let eb = ba
                .view
                .layout
                .outer()
                .ok_or_else(|| Error::Eval("lifted reduction over scalar".into()))?;
            if ea.extent != eb.extent {
                return Err(Error::Eval(format!(
                    "lifted reduction extent mismatch: {} vs {}",
                    ea.extent, eb.extent
                )));
            }
            let mut out: Vec<f64> = Vec::new();
            let mut elem_extents: Option<Vec<usize>> = None;
            for i in 0..ea.extent {
                let va = Value::Arr(ArrVal {
                    data: aa.data.clone(),
                    view: aa.view.index_outer(i)?,
                });
                let vb = Value::Arr(ArrVal {
                    data: ba.data.clone(),
                    view: ba.view.index_outer(i)?,
                });
                let va = promote_scalar(va);
                let vb = promote_scalar(vb);
                let v = combine(f, &va, &vb)?;
                if elem_extents.is_none() {
                    elem_extents = Some(v.extents());
                }
                out.extend(v.to_dense());
            }
            let elem_extents = elem_extents.unwrap_or_default();
            let mut dims = Vec::with_capacity(elem_extents.len() + 1);
            let mut stride = 1;
            for &e in &elem_extents {
                dims.push(crate::layout::Dim::new(e, stride));
                stride *= e;
            }
            dims.push(crate::layout::Dim::new(ea.extent, stride));
            Ok(Value::Arr(ArrVal {
                data: Rc::new(out),
                view: View::of(Layout { dims }),
            }))
        }
        other => Err(Error::Eval(format!(
            "unsupported reduction operator: {}",
            crate::dsl::pretty(other)
        ))),
    }
}

/// Rank-0 array views behave as scalars under prim reduction.
fn promote_scalar(v: Value) -> Value {
    match &v {
        Value::Arr(a) if a.view.layout.is_scalar() => Value::Scalar(a.data[a.view.offset]),
        _ => v,
    }
}

fn outer_extent(args: &[Value], what: &str) -> Result<usize> {
    let mut extent = None;
    for (i, v) in args.iter().enumerate() {
        let a = v
            .as_arr()
            .map_err(|_| Error::Eval(format!("{what}: arg {i} is scalar")))?;
        let outer = a
            .view
            .layout
            .outer()
            .ok_or_else(|| Error::Eval(format!("{what}: arg {i} has rank 0")))?;
        match extent {
            None => extent = Some(outer.extent),
            Some(e) if e == outer.extent => {}
            Some(e) => {
                return Err(Error::Eval(format!(
                    "{what}: extent mismatch {e} vs {}",
                    outer.extent
                )))
            }
        }
    }
    extent.ok_or_else(|| Error::Eval(format!("{what}: no arguments")))
}

/// Index every argument at outer position `i`, yielding element values
/// (scalars where the element rank is 0).
fn index_all(args: &[Value], i: usize) -> Result<Vec<Value>> {
    args.iter()
        .map(|v| {
            let a = v.as_arr()?;
            let view = a.view.index_outer(i)?;
            if view.layout.is_scalar() {
                Ok(Value::Scalar(a.data[view.offset]))
            } else {
                Ok(Value::Arr(ArrVal {
                    data: a.data.clone(),
                    view,
                }))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;

    fn inputs2(a: (Vec<f64>, Vec<usize>), b: (Vec<f64>, Vec<usize>)) -> Inputs {
        let mut m = Inputs::new();
        m.insert("A".into(), ArrVal::dense(a.0, &a.1));
        m.insert("B".into(), ArrVal::dense(b.0, &b.1));
        m
    }

    #[test]
    fn dot_product() {
        let mut inp = Inputs::new();
        inp.insert("u".into(), ArrVal::dense(vec![1.0, 2.0, 3.0], &[3]));
        inp.insert("v".into(), ArrVal::dense(vec![4.0, 5.0, 6.0], &[3]));
        let e = dot(input("u"), input("v"));
        assert_eq!(eval(&e, &inp).unwrap().as_scalar().unwrap(), 32.0);
    }

    #[test]
    fn map_scale() {
        let mut inp = Inputs::new();
        inp.insert("v".into(), ArrVal::dense(vec![1.0, -2.0, 3.0], &[3]));
        let e = map(lam1("x", app2(mul(), var("x"), lit(2.0))), input("v"));
        assert_eq!(eval(&e, &inp).unwrap().to_dense(), vec![2.0, -4.0, 6.0]);
    }

    #[test]
    fn matvec_textbook() {
        // A = [[1,2],[3,4],[5,6]], v = [1,10] → [21, 43, 65]
        let mut inp = Inputs::new();
        inp.insert(
            "A".into(),
            ArrVal::dense(vec![1., 2., 3., 4., 5., 6.], &[3, 2]),
        );
        inp.insert("v".into(), ArrVal::dense(vec![1., 10.], &[2]));
        let e = matvec_naive(input("A"), input("v"));
        assert_eq!(eval(&e, &inp).unwrap().to_dense(), vec![21., 43., 65.]);
    }

    #[test]
    fn matvec_flipped_form_matches_eq40() {
        // rnz (lift +) (\c q -> map (\e -> e*q) c) (flip 0 A) v
        let mut inp = Inputs::new();
        inp.insert(
            "A".into(),
            ArrVal::dense(vec![1., 2., 3., 4., 5., 6.], &[3, 2]),
        );
        inp.insert("v".into(), ArrVal::dense(vec![1., 10.], &[2]));
        let e = rnz(
            lift(add()),
            lam2(
                "c",
                "q",
                map(lam1("e", app2(mul(), var("e"), var("q"))), var("c")),
            ),
            vec![flip(0, input("A")), input("v")],
        );
        assert_eq!(eval(&e, &inp).unwrap().to_dense(), vec![21., 43., 65.]);
    }

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] * [[5,6],[7,8]] = [[19,22],[43,50]]
        let inp = inputs2(
            (vec![1., 2., 3., 4.], vec![2, 2]),
            (vec![5., 6., 7., 8.], vec![2, 2]),
        );
        let e = matmul_naive(input("A"), input("B"));
        assert_eq!(
            eval(&e, &inp).unwrap().to_dense(),
            vec![19., 22., 43., 50.]
        );
    }

    #[test]
    fn dyadic_product_eq36() {
        // map (\x -> map (\y -> x*y) u) v  over v=[1,2], u=[3,4,5]
        let mut inp = Inputs::new();
        inp.insert("v".into(), ArrVal::dense(vec![1., 2.], &[2]));
        inp.insert("u".into(), ArrVal::dense(vec![3., 4., 5.], &[3]));
        let e = map(
            lam1(
                "x",
                map(lam1("y", app2(mul(), var("x"), var("y"))), input("u")),
            ),
            input("v"),
        );
        let v = eval(&e, &inp).unwrap();
        assert_eq!(v.to_dense(), vec![3., 4., 5., 6., 8., 10.]);
        assert_eq!(v.extents(), vec![3, 2]);
    }

    #[test]
    fn subdivided_map_identity_eq44() {
        let mut inp = Inputs::new();
        inp.insert(
            "v".into(),
            ArrVal::dense((0..12).map(|i| i as f64).collect(), &[12]),
        );
        let double = lam1("x", app2(mul(), var("x"), lit(2.0)));
        let plain = map(double.clone(), input("v"));
        let blocked = map(
            lam1("blk", map(double, var("blk"))),
            subdiv(0, 4, input("v")),
        );
        let a = eval(&plain, &inp).unwrap().to_dense();
        let b = eval(&blocked, &inp).unwrap().to_dense();
        assert_eq!(a, b);
    }

    #[test]
    fn reduce_with_max() {
        let mut inp = Inputs::new();
        inp.insert("v".into(), ArrVal::dense(vec![3., 9., 1., 7.], &[4]));
        let e = reduce(pmax(), input("v"));
        assert_eq!(eval(&e, &inp).unwrap().as_scalar().unwrap(), 9.0);
    }

    #[test]
    fn lifted_reduction_of_rows() {
        // Column sums of A via rnz (lift +) id rows
        let mut inp = Inputs::new();
        inp.insert(
            "A".into(),
            ArrVal::dense(vec![1., 2., 3., 4., 5., 6.], &[3, 2]),
        );
        let e = rnz(lift(add()), lam1("r", var("r")), vec![input("A")]);
        assert_eq!(eval(&e, &inp).unwrap().to_dense(), vec![9., 12.]);
    }

    #[test]
    fn errors_surface() {
        let inp = Inputs::new();
        assert!(eval(&var("x"), &inp).is_err());
        assert!(eval(&input("Q"), &inp).is_err());
        assert!(eval(&add(), &inp).is_err());
    }
}
