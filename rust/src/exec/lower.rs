//! Lowering from the DSL AST (fused normal form) to the loop-nest IR.
//!
//! Every `nzip` becomes a [`Node::MapLoop`], every `rnz` a
//! [`Node::RedLoop`]; layout operators are folded into the strides of the
//! views they wrap, and scalar bodies compile to stack bytecode
//! ([`Kernel`]). Each HoF argument position receives its own *track* (an
//! independent offset cursor), so aliased views of one buffer traverse
//! independently — offsets are derived per iteration as
//! `off[child] = off[parent] + base + i * stride`.
//!
//! # Two front ends, one machine
//!
//! Lowering has two entry points over the same machinery:
//!
//! - [`lower`] consumes the `Box<Expr>` AST — the parser/interpreter
//!   lingua franca, and the entry point for one-off lowering jobs;
//! - [`lower_id`] consumes an interned [`ExprId`] directly from a
//!   [`SharedArena`] — the search hot path, where thousands of candidates
//!   are lowered for cost estimation and rebuilding a `Box<Expr>` tree per
//!   candidate would dominate the cost of scoring it. The arena is shared
//!   across search shards, so concurrent lowering jobs read one store.
//!
//! Everything that determines the *identity* of the produced [`Program`] —
//! input-slot interning order, track allocation, temp-region layout, the
//! bound-variable table — lives in the shared `LowerState`, which both
//! front ends drive case-for-case. That is what makes
//! `lower_id(arena, id) ≡ lower(&arena.extract(id))` hold bit-for-bit
//! (pinned by the differential tests in `tests/lower_id_props.rs`).

use super::program::{Adv, Kernel, KernelOp, Node, Program, SlotId, TrackId};
use crate::dsl::intern::{ExprId, Node as ENode, SharedArena};
use crate::dsl::{Expr, Prim};
use crate::layout::Layout;
use crate::typecheck::{self, Env};
use crate::{Error, Result};
use std::collections::HashMap;

/// Lower a typechecked expression to an executable [`Program`].
pub fn lower(e: &Expr, env: &Env) -> Result<Program> {
    // Typecheck up front: lowering relies on the shape guarantees.
    typecheck::infer(e, env)?;
    let mut lw = Lowerer {
        st: LowerState::new(env),
    };
    let (root, out_size) = lw.lower_node(e, None)?;
    let prog = lw.st.into_program(root, out_size);
    // Debug/test builds verify every lowered program at the source — any
    // lowering bug surfaces as a structured rejection here rather than as
    // a bounds panic (or worse) downstream. Release keeps lowering cheap;
    // `execute` still verifies unconditionally before running.
    #[cfg(debug_assertions)]
    crate::verify::verify(&prog)?;
    Ok(prog)
}

/// Lower an interned expression to an executable [`Program`] directly from
/// the arena — the id-native twin of [`lower`], and the per-candidate
/// lowering path of the enumeration search. No `Box<Expr>` tree is ever
/// materialized: traversal, view resolution and kernel compilation all
/// read [`SharedArena`] nodes, and even diagnostics describe nodes
/// shallowly instead of extracting subtrees. Produces bit-identical
/// programs to `lower(&arena.extract(id), env)`.
pub fn lower_id(arena: &SharedArena, id: ExprId, env: &Env) -> Result<Program> {
    // Typecheck up front: lowering relies on the shape guarantees.
    typecheck::infer_id(arena, id, env)?;
    let mut lw = IdLowerer {
        arena,
        st: LowerState::new(env),
    };
    let (root, out_size) = lw.lower_node(id, None)?;
    let prog = lw.st.into_program(root, out_size);
    // Same debug/test-build verification gate as `lower` — in particular
    // every search candidate lowered on the id-native score path gets
    // verified under `cargo test`.
    #[cfg(debug_assertions)]
    crate::verify::verify(&prog)?;
    Ok(prog)
}

/// A resolved array view: which buffer, derived from which track, with what
/// residual layout.
#[derive(Clone, Debug)]
struct ViewSpec {
    slot: SlotId,
    src: Option<TrackId>,
    base: usize,
    layout: Layout,
}

#[derive(Clone, Debug)]
struct VarInfo {
    track: TrackId,
    layout: Layout,
}

/// Expression-independent lowering state and mechanics, shared by the
/// `Box<Expr>` and arena-native front ends: input-slot interning, track
/// allocation, reduction temp regions, the bound-variable table, and every
/// node-construction step that does not inspect expression syntax. Both
/// lowerers are thin syntax adapters over this machine, which is what
/// keeps their outputs identical.
struct LowerState<'a> {
    env: &'a Env,
    input_names: Vec<String>,
    input_lens: Vec<usize>,
    track_slot: Vec<SlotId>,
    temp_sizes: Vec<usize>,
    vars: HashMap<String, VarInfo>,
}

impl<'a> LowerState<'a> {
    fn new(env: &'a Env) -> Self {
        LowerState {
            env,
            input_names: Vec::new(),
            input_lens: Vec::new(),
            track_slot: Vec::new(),
            temp_sizes: Vec::new(),
            vars: HashMap::new(),
        }
    }

    fn into_program(self, root: Node, out_size: usize) -> Program {
        Program {
            root,
            input_names: self.input_names,
            track_slot: self.track_slot,
            input_lens: self.input_lens,
            out_size,
            temp_sizes: self.temp_sizes,
        }
    }

    fn slot_of(&mut self, name: &str) -> Result<(SlotId, Layout)> {
        let layout = self
            .env
            .inputs
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Lower(format!("unknown input '{name}'")))?;
        if let Some(i) = self.input_names.iter().position(|n| n == name) {
            return Ok((i, layout));
        }
        self.input_names.push(name.to_string());
        self.input_lens.push(layout.required_span());
        Ok((self.input_names.len() - 1, layout))
    }

    fn new_track(&mut self, slot: SlotId) -> TrackId {
        self.track_slot.push(slot);
        self.track_slot.len() - 1
    }

    /// Root view of a named input buffer.
    fn input_view(&mut self, name: &str) -> Result<ViewSpec> {
        let (slot, layout) = self.slot_of(name)?;
        Ok(ViewSpec {
            slot,
            src: None,
            base: 0,
            layout,
        })
    }

    /// View of a variable bound by an enclosing HoF.
    fn var_view(&self, x: &str) -> Result<ViewSpec> {
        let info = self
            .vars
            .get(x)
            .cloned()
            .ok_or_else(|| Error::Lower(format!("unbound variable '{x}'")))?;
        Ok(ViewSpec {
            slot: self.track_slot[info.track],
            src: Some(info.track),
            base: 0,
            layout: info.layout,
        })
    }

    /// Consume the outermost dimension of each argument view: create one
    /// fresh track per argument and the matching loop advances, and return
    /// the bound element views.
    fn consume_outer(&mut self, views: Vec<ViewSpec>) -> Result<(usize, Vec<Adv>, Vec<ViewSpec>)> {
        let mut extent = None;
        let mut advances = Vec::with_capacity(views.len());
        let mut elems = Vec::with_capacity(views.len());
        for v in views {
            let outer = v
                .layout
                .outer()
                .ok_or_else(|| Error::Lower("HoF over rank-0 view".into()))?;
            match extent {
                None => extent = Some(outer.extent),
                Some(e) if e == outer.extent => {}
                Some(e) => {
                    return Err(Error::Lower(format!(
                        "extent mismatch {e} vs {}",
                        outer.extent
                    )))
                }
            }
            let t = self.new_track(v.slot);
            advances.push(Adv {
                dst: t,
                src: v.src,
                base: v.base,
                stride: outer.stride,
            });
            elems.push(ViewSpec {
                slot: v.slot,
                src: Some(t),
                base: 0,
                layout: v.layout.peel_outer()?,
            });
        }
        Ok((extent.unwrap(), advances, elems))
    }

    /// Bind lambda parameters to element views (which are always
    /// track-rooted post `consume_outer`), returning the shadowed entries
    /// for [`LowerState::restore_params`].
    fn bind_params(
        &mut self,
        params: &[String],
        elems: &[ViewSpec],
    ) -> Result<Vec<(String, Option<VarInfo>)>> {
        let mut saved = Vec::new();
        for (p, v) in params.iter().zip(elems) {
            let track = match (v.src, v.base) {
                (Some(t), 0) => t,
                _ => {
                    return Err(Error::Lower(
                        "internal: element view not track-rooted".into(),
                    ))
                }
            };
            let info = VarInfo {
                track,
                layout: v.layout.clone(),
            };
            saved.push((p.clone(), self.vars.insert(p.clone(), info)));
        }
        Ok(saved)
    }

    /// Undo [`LowerState::bind_params`] (restoring any shadowed bindings).
    fn restore_params(&mut self, saved: Vec<(String, Option<VarInfo>)>) {
        for (p, old) in saved.into_iter().rev() {
            match old {
                Some(v) => {
                    self.vars.insert(p, v);
                }
                None => {
                    self.vars.remove(&p);
                }
            }
        }
    }

    /// `rnz (+) (*) u v` — the zipper is a bare primitive over scalar
    /// elements; emit the one-kernel leaf.
    fn prim_leaf(&mut self, p: Prim, elems: &[ViewSpec]) -> Result<(Node, usize)> {
        if elems.len() != p.arity() {
            return Err(Error::Lower(format!(
                "primitive {} arity {} vs {} args",
                p.name(),
                p.arity(),
                elems.len()
            )));
        }
        let mut tracks = Vec::with_capacity(elems.len());
        let mut ops = Vec::with_capacity(elems.len() + 1);
        for (i, v) in elems.iter().enumerate() {
            if !v.layout.is_scalar() {
                return Err(Error::Lower(format!(
                    "primitive {} over non-scalar element",
                    p.name()
                )));
            }
            tracks.push(v.src.expect("track-rooted"));
            ops.push(KernelOp::In(i as u8));
        }
        ops.push(KernelOp::Prim(p));
        Ok((Node::Leaf(Kernel { ops, tracks }), 1))
    }

    /// A reduction running under a different (or non-commutative) enclosing
    /// accumulator needs a private temp region; allocate it.
    fn reduction_temp(
        &mut self,
        op: Prim,
        under_op: Option<Prim>,
        body_size: usize,
    ) -> Option<usize> {
        match under_op {
            Some(o) if o == op && op.is_commutative() => None,
            None => None,
            Some(_) => {
                self.temp_sizes.push(body_size);
                Some(self.temp_sizes.len() - 1)
            }
        }
    }

    /// Lower an array-typed body (identity zipper, bare view) to a copy
    /// nest — or a scalar view to its leaf form.
    fn view_node(&mut self, v: ViewSpec) -> Result<(Node, usize)> {
        if v.layout.is_scalar() {
            let t = match (v.src, v.base) {
                (Some(t), 0) => t,
                _ => {
                    let t = self.new_track(v.slot);
                    // Constant-offset scalar view of an input: model as a
                    // 1-iteration advance-less track via base.
                    return Ok((
                        Node::MapLoop {
                            extent: 1,
                            advances: vec![Adv {
                                dst: t,
                                src: v.src,
                                base: v.base,
                                stride: 0,
                            }],
                            body_size: 1,
                            body: Box::new(Node::Leaf(Kernel {
                                ops: vec![KernelOp::In(0)],
                                tracks: vec![t],
                            })),
                        },
                        1,
                    ));
                }
            };
            return Ok((
                Node::Leaf(Kernel {
                    ops: vec![KernelOp::In(0)],
                    tracks: vec![t],
                }),
                1,
            ));
        }
        self.lower_copy(v)
    }

    /// Copy an array view to the destination: one map loop per dimension.
    fn lower_copy(&mut self, v: ViewSpec) -> Result<(Node, usize)> {
        if v.layout.is_scalar() {
            let t = v.src.expect("track-rooted");
            return Ok((
                Node::Leaf(Kernel {
                    ops: vec![KernelOp::In(0)],
                    tracks: vec![t],
                }),
                1,
            ));
        }
        let (extent, advances, mut elems) = self.consume_outer(vec![v])?;
        let elem = elems.pop().unwrap();
        let (body, body_size) = self.lower_copy(elem)?;
        Ok((
            Node::MapLoop {
                extent,
                advances,
                body_size,
                body: Box::new(body),
            },
            extent * body_size,
        ))
    }

    /// Emit the bytecode for a scalar variable read inside a leaf kernel.
    fn kernel_var(
        &mut self,
        x: &str,
        ops: &mut Vec<KernelOp>,
        tracks: &mut Vec<TrackId>,
    ) -> Result<()> {
        let info = self
            .vars
            .get(x)
            .cloned()
            .ok_or_else(|| Error::Lower(format!("unbound variable '{x}'")))?;
        if !info.layout.is_scalar() {
            return Err(Error::Lower(format!(
                "array variable '{x}' used in scalar position"
            )));
        }
        if tracks.len() >= u8::MAX as usize {
            return Err(Error::Lower("kernel has too many inputs".into()));
        }
        ops.push(KernelOp::In(tracks.len() as u8));
        tracks.push(info.track);
        Ok(())
    }
}

/// The `Box<Expr>` front end.
struct Lowerer<'a> {
    st: LowerState<'a>,
}

impl<'a> Lowerer<'a> {
    /// Resolve an expression in HoF-argument position to a strided view.
    fn resolve_view(&mut self, e: &Expr) -> Result<ViewSpec> {
        match e {
            Expr::Input(n) => self.st.input_view(n),
            Expr::Var(x) => self.st.var_view(x),
            Expr::Subdiv { d, b, arg } => {
                let v = self.resolve_view(arg)?;
                Ok(ViewSpec {
                    layout: v.layout.subdiv(*d, *b)?,
                    ..v
                })
            }
            Expr::Flatten { d, arg } => {
                let v = self.resolve_view(arg)?;
                Ok(ViewSpec {
                    layout: v.layout.flatten(*d)?,
                    ..v
                })
            }
            Expr::Flip { d1, d2, arg } => {
                let v = self.resolve_view(arg)?;
                Ok(ViewSpec {
                    layout: v.layout.flip2(*d1, *d2)?,
                    ..v
                })
            }
            other => Err(Error::Lower(format!(
                "HoF argument is not a view of an input (fuse first): {}",
                crate::dsl::pretty(other)
            ))),
        }
    }

    /// Bind a function-position expression to element views and lower its
    /// body. Handles `Lam`, bare `Prim`, and `lift^k`.
    fn bind_and_lower(
        &mut self,
        f: &Expr,
        elems: Vec<ViewSpec>,
        under_op: Option<Prim>,
    ) -> Result<(Node, usize)> {
        match f {
            Expr::Lam { params, body } => {
                if params.len() != elems.len() {
                    return Err(Error::Lower(format!(
                        "lambda arity {} vs {} args",
                        params.len(),
                        elems.len()
                    )));
                }
                let saved = self.st.bind_params(params, &elems)?;
                let r = self.lower_node(body, under_op);
                self.st.restore_params(saved);
                r
            }
            Expr::Prim(p) => self.st.prim_leaf(*p, &elems),
            Expr::Lift { f: inner } => {
                // lift g elementwise: one more map loop over the elements.
                let (extent, advances, sub_elems) = self.st.consume_outer(elems)?;
                let (body, body_size) = self.bind_and_lower(inner, sub_elems, under_op)?;
                Ok((
                    Node::MapLoop {
                        extent,
                        advances,
                        body_size,
                        body: Box::new(body),
                    },
                    extent * body_size,
                ))
            }
            other => Err(Error::Lower(format!(
                "unsupported function form: {}",
                crate::dsl::pretty(other)
            ))),
        }
    }

    fn lower_node(&mut self, e: &Expr, under_op: Option<Prim>) -> Result<(Node, usize)> {
        match e {
            Expr::Nzip { f, args } => {
                let views = args
                    .iter()
                    .map(|a| self.resolve_view(a))
                    .collect::<Result<Vec<_>>>()?;
                let (extent, advances, elems) = self.st.consume_outer(views)?;
                let (body, body_size) = self.bind_and_lower(f, elems, under_op)?;
                Ok((
                    Node::MapLoop {
                        extent,
                        advances,
                        body_size,
                        body: Box::new(body),
                    },
                    extent * body_size,
                ))
            }
            Expr::Rnz { r, m, args } => {
                let op = reducer_prim(r)?;
                let views = args
                    .iter()
                    .map(|a| self.resolve_view(a))
                    .collect::<Result<Vec<_>>>()?;
                let (extent, advances, elems) = self.st.consume_outer(views)?;
                let (body, body_size) = self.bind_and_lower(m, elems, Some(op))?;
                let temp = self.st.reduction_temp(op, under_op, body_size);
                Ok((
                    Node::RedLoop {
                        extent,
                        advances,
                        op,
                        body_size,
                        temp,
                        body: Box::new(body),
                    },
                    body_size,
                ))
            }
            // An array-typed body (identity zipper, bare view) lowers to a
            // copy nest.
            Expr::Var(_) | Expr::Input(_) | Expr::Subdiv { .. } | Expr::Flatten { .. }
            | Expr::Flip { .. } => {
                let v = self.resolve_view(e)?;
                self.st.view_node(v)
            }
            // Scalar computation leaf.
            _ => {
                let mut tracks = Vec::new();
                let mut ops = Vec::new();
                self.compile_kernel(e, &mut ops, &mut tracks)?;
                Ok((Node::Leaf(Kernel { ops, tracks }), 1))
            }
        }
    }

    /// Compile a scalar expression to stack bytecode.
    fn compile_kernel(
        &mut self,
        e: &Expr,
        ops: &mut Vec<KernelOp>,
        tracks: &mut Vec<TrackId>,
    ) -> Result<()> {
        match e {
            Expr::Lit(x) => {
                ops.push(KernelOp::Const(*x));
                Ok(())
            }
            Expr::Var(x) => self.st.kernel_var(x, ops, tracks),
            Expr::App { f, args } => match &**f {
                Expr::Prim(p) => {
                    if args.len() != p.arity() {
                        return Err(Error::Lower(format!(
                            "primitive {} arity mismatch",
                            p.name()
                        )));
                    }
                    for a in args {
                        self.compile_kernel(a, ops, tracks)?;
                    }
                    ops.push(KernelOp::Prim(*p));
                    Ok(())
                }
                Expr::Lam { .. } => Err(Error::Lower(
                    "beta-redex in scalar position (run lambda rewrites first)".into(),
                )),
                other => Err(Error::Lower(format!(
                    "unsupported scalar application head: {}",
                    crate::dsl::pretty(other)
                ))),
            },
            other => Err(Error::Lower(format!(
                "unsupported scalar expression: {}",
                crate::dsl::pretty(other)
            ))),
        }
    }
}

/// The arena-native front end: mirrors [`Lowerer`] case-for-case against
/// [`SharedArena`] nodes, driving the same [`LowerState`].
struct IdLowerer<'a> {
    arena: &'a SharedArena,
    st: LowerState<'a>,
}

impl<'a> IdLowerer<'a> {
    /// Resolve an interned expression in HoF-argument position to a
    /// strided view.
    fn resolve_view(&mut self, id: ExprId) -> Result<ViewSpec> {
        let arena = self.arena;
        match arena.get(id) {
            ENode::Input(n) => self.st.input_view(n),
            ENode::Var(x) => self.st.var_view(x),
            ENode::Subdiv { d, b, arg } => {
                let v = self.resolve_view(*arg)?;
                Ok(ViewSpec {
                    layout: v.layout.subdiv(*d, *b)?,
                    ..v
                })
            }
            ENode::Flatten { d, arg } => {
                let v = self.resolve_view(*arg)?;
                Ok(ViewSpec {
                    layout: v.layout.flatten(*d)?,
                    ..v
                })
            }
            ENode::Flip { d1, d2, arg } => {
                let v = self.resolve_view(*arg)?;
                Ok(ViewSpec {
                    layout: v.layout.flip2(*d1, *d2)?,
                    ..v
                })
            }
            other => Err(Error::Lower(format!(
                "HoF argument is not a view of an input (fuse first): {}",
                other.kind()
            ))),
        }
    }

    /// Bind an interned function-position expression to element views and
    /// lower its body. Handles `Lam`, bare `Prim`, and `lift^k`.
    fn bind_and_lower(
        &mut self,
        f: ExprId,
        elems: Vec<ViewSpec>,
        under_op: Option<Prim>,
    ) -> Result<(Node, usize)> {
        let arena = self.arena;
        match arena.get(f) {
            ENode::Lam { params, body } => {
                if params.len() != elems.len() {
                    return Err(Error::Lower(format!(
                        "lambda arity {} vs {} args",
                        params.len(),
                        elems.len()
                    )));
                }
                let saved = self.st.bind_params(params, &elems)?;
                let r = self.lower_node(*body, under_op);
                self.st.restore_params(saved);
                r
            }
            ENode::Prim(p) => self.st.prim_leaf(*p, &elems),
            ENode::Lift { f: inner } => {
                // lift g elementwise: one more map loop over the elements.
                let (extent, advances, sub_elems) = self.st.consume_outer(elems)?;
                let (body, body_size) = self.bind_and_lower(*inner, sub_elems, under_op)?;
                Ok((
                    Node::MapLoop {
                        extent,
                        advances,
                        body_size,
                        body: Box::new(body),
                    },
                    extent * body_size,
                ))
            }
            other => Err(Error::Lower(format!(
                "unsupported function form: {}",
                other.kind()
            ))),
        }
    }

    fn lower_node(&mut self, id: ExprId, under_op: Option<Prim>) -> Result<(Node, usize)> {
        let arena = self.arena;
        match arena.get(id) {
            ENode::Nzip { f, args } => {
                let views = args
                    .iter()
                    .map(|&a| self.resolve_view(a))
                    .collect::<Result<Vec<_>>>()?;
                let (extent, advances, elems) = self.st.consume_outer(views)?;
                let (body, body_size) = self.bind_and_lower(*f, elems, under_op)?;
                Ok((
                    Node::MapLoop {
                        extent,
                        advances,
                        body_size,
                        body: Box::new(body),
                    },
                    extent * body_size,
                ))
            }
            ENode::Rnz { r, m, args } => {
                let op = reducer_prim_id(arena, *r)?;
                let views = args
                    .iter()
                    .map(|&a| self.resolve_view(a))
                    .collect::<Result<Vec<_>>>()?;
                let (extent, advances, elems) = self.st.consume_outer(views)?;
                let (body, body_size) = self.bind_and_lower(*m, elems, Some(op))?;
                let temp = self.st.reduction_temp(op, under_op, body_size);
                Ok((
                    Node::RedLoop {
                        extent,
                        advances,
                        op,
                        body_size,
                        temp,
                        body: Box::new(body),
                    },
                    body_size,
                ))
            }
            // An array-typed body (identity zipper, bare view) lowers to a
            // copy nest.
            ENode::Var(_) | ENode::Input(_) | ENode::Subdiv { .. } | ENode::Flatten { .. }
            | ENode::Flip { .. } => {
                let v = self.resolve_view(id)?;
                self.st.view_node(v)
            }
            // Scalar computation leaf.
            _ => {
                let mut tracks = Vec::new();
                let mut ops = Vec::new();
                self.compile_kernel(id, &mut ops, &mut tracks)?;
                Ok((Node::Leaf(Kernel { ops, tracks }), 1))
            }
        }
    }

    /// Compile an interned scalar expression to stack bytecode.
    fn compile_kernel(
        &mut self,
        id: ExprId,
        ops: &mut Vec<KernelOp>,
        tracks: &mut Vec<TrackId>,
    ) -> Result<()> {
        let arena = self.arena;
        match arena.get(id) {
            ENode::Lit(bits) => {
                ops.push(KernelOp::Const(f64::from_bits(*bits)));
                Ok(())
            }
            ENode::Var(x) => self.st.kernel_var(x, ops, tracks),
            ENode::App { f, args } => match arena.get(*f) {
                ENode::Prim(p) => {
                    if args.len() != p.arity() {
                        return Err(Error::Lower(format!(
                            "primitive {} arity mismatch",
                            p.name()
                        )));
                    }
                    for &a in args {
                        self.compile_kernel(a, ops, tracks)?;
                    }
                    ops.push(KernelOp::Prim(*p));
                    Ok(())
                }
                ENode::Lam { .. } => Err(Error::Lower(
                    "beta-redex in scalar position (run lambda rewrites first)".into(),
                )),
                other => Err(Error::Lower(format!(
                    "unsupported scalar application head: {}",
                    other.kind()
                ))),
            },
            other => Err(Error::Lower(format!(
                "unsupported scalar expression: {}",
                other.kind()
            ))),
        }
    }
}

/// Extract the primitive from a (possibly `lift^k`-wrapped) reduction
/// operator.
fn reducer_prim(r: &Expr) -> Result<Prim> {
    let mut cur = r;
    while let Expr::Lift { f } = cur {
        cur = f;
    }
    match cur {
        Expr::Prim(p) if p.arity() == 2 && p.is_associative() => Ok(*p),
        other => Err(Error::Lower(format!(
            "unsupported reduction operator: {}",
            crate::dsl::pretty(other)
        ))),
    }
}

/// Id-native twin of [`reducer_prim`].
fn reducer_prim_id(arena: &SharedArena, r: ExprId) -> Result<Prim> {
    let mut cur = r;
    while let ENode::Lift { f } = arena.get(cur) {
        cur = *f;
    }
    match arena.get(cur) {
        ENode::Prim(p) if p.arity() == 2 && p.is_associative() => Ok(*p),
        other => Err(Error::Lower(format!(
            "unsupported reduction operator: {}",
            other.kind()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;

    #[test]
    fn lower_matvec_shape() {
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 6]))
            .with("v", Layout::row_major(&[6]));
        let e = matvec_naive(input("A"), input("v"));
        let p = lower(&e, &env).unwrap();
        assert_eq!(p.out_size, 4);
        assert_eq!(p.loop_kinds(), vec!["map", "red"]);
        assert_eq!(p.input_names, vec!["A".to_string(), "v".to_string()]);
        assert!(p.temp_sizes.is_empty());
    }

    #[test]
    fn lower_matmul_shape() {
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 6]))
            .with("B", Layout::row_major(&[6, 8]));
        let p = lower(&matmul_naive(input("A"), input("B")), &env).unwrap();
        assert_eq!(p.out_size, 32);
        assert_eq!(p.loop_kinds(), vec!["map", "map", "red"]);
    }

    #[test]
    fn lower_rejects_unfused_pipeline() {
        let env = Env::new().with("v", Layout::row_major(&[4]));
        // map f (map g v) — inner map is not a view
        let e = map(
            lam1("x", app2(mul(), var("x"), lit(2.0))),
            map(lam1("y", app2(add(), var("y"), lit(1.0))), input("v")),
        );
        assert!(lower(&e, &env).is_err());
    }

    #[test]
    fn same_op_nested_reduction_needs_no_temp() {
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 8]))
            .with("v", Layout::row_major(&[8]));
        // 1a form: subdivided dot
        let e = map(
            lam1(
                "r",
                rnz(
                    add(),
                    lam2("b", "c", dot(var("b"), var("c"))),
                    vec![subdiv(0, 2, var("r")), subdiv(0, 2, input("v"))],
                ),
            ),
            input("A"),
        );
        let p = lower(&e, &env).unwrap();
        assert!(p.temp_sizes.is_empty());
        assert_eq!(p.loop_kinds(), vec!["map", "red", "red"]);
    }

    #[test]
    fn mixed_op_nested_reduction_gets_temp() {
        let env = Env::new().with("A", Layout::row_major(&[4, 8]));
        // max over rows of (sum of row elements)
        let e = rnz(
            pmax(),
            lam1("r", reduce(add(), var("r"))),
            vec![input("A")],
        );
        let p = lower(&e, &env).unwrap();
        assert_eq!(p.temp_sizes, vec![1]);
    }

    #[test]
    fn lower_id_matches_lower_on_matmul() {
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 6]))
            .with("B", Layout::row_major(&[6, 8]));
        let e = matmul_naive(input("A"), input("B"));
        let arena = SharedArena::new();
        let id = arena.intern(&e);
        let pa = lower(&e, &env).unwrap();
        let pb = lower_id(&arena, id, &env).unwrap();
        assert_eq!(format!("{pa:?}"), format!("{pb:?}"));
    }

    #[test]
    fn lower_id_rejects_what_lower_rejects() {
        let env = Env::new().with("v", Layout::row_major(&[4]));
        let e = map(
            lam1("x", app2(mul(), var("x"), lit(2.0))),
            map(lam1("y", app2(add(), var("y"), lit(1.0))), input("v")),
        );
        let arena = SharedArena::new();
        let id = arena.intern(&e);
        assert!(lower_id(&arena, id, &env).is_err());
    }

    #[test]
    fn lower_id_allocates_temp_like_lower() {
        let env = Env::new().with("A", Layout::row_major(&[4, 8]));
        let e = rnz(
            pmax(),
            lam1("r", reduce(add(), var("r"))),
            vec![input("A")],
        );
        let arena = SharedArena::new();
        let id = arena.intern(&e);
        let p = lower_id(&arena, id, &env).unwrap();
        assert_eq!(p.temp_sizes, vec![1]);
    }
}
