//! Fast execution of DSL expressions: lowering to a loop-nest IR
//! ([`Program`]) and a strided interpreter with specialized inner loops.
//!
//! This is the measured artifact of the reproduction — it plays the role of
//! the paper's generated C++14 code (their DataView library): every HoF
//! becomes one loop whose per-iteration strides come straight from the
//! logical layout, so rearranging HoFs (and flipping layouts) changes the
//! traversal order exactly as in the paper, and the memory system does the
//! rest.
//!
//! Lowering accepts expressions in *fused normal form* (the form the
//! paper's pipeline produces before subdivision/exchange): a nest of
//! `nzip`/`rnz` whose array arguments are views of inputs (through layout
//! operators) or variables bound by enclosing HoFs, with scalar bodies at
//! the leaves.
//!
//! Lowering has two front ends over one shared machine: [`lower`] for
//! `Box<Expr>` trees (the parser/interpreter representation) and
//! [`lower_id`] for interned [`crate::dsl::intern::ExprId`]s (the search
//! hot path — candidates are lowered and cost-estimated straight from the
//! arena, never rebuilt as trees). The two are held bit-identical by the
//! differential tests in `tests/lower_id_props.rs`.
//!
//! Execution is serial by default ([`execute`]); [`execute_threaded`]
//! additionally consults the verifier's parallel-safety certificate
//! ([`crate::verify::ParCert`]) and chunks a certified root `MapLoop`
//! across a scoped thread pool, failing closed to the serial path on any
//! `Serial` verdict.

mod interp;
mod lower;
mod program;
mod trace;

pub use interp::{execute, execute_threaded, ExecReport, MAX_EXEC_THREADS};
pub use lower::{lower, lower_id};
pub use program::{Adv, Kernel, KernelOp, Node, Program, WriteMode};
pub use trace::{count_accesses, trace, Access, AccessKind};

use crate::dsl::Expr;
use crate::typecheck::Env;
use crate::Result;

/// Order input buffers to match a program's slot order.
pub fn order_inputs<'a>(
    prog: &Program,
    named_inputs: &[(&str, &'a [f64])],
) -> Result<Vec<&'a [f64]>> {
    let mut bufs: Vec<&[f64]> = Vec::with_capacity(prog.input_names.len());
    for name in &prog.input_names {
        let buf = named_inputs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, b)| *b)
            .ok_or_else(|| crate::Error::Eval(format!("missing input buffer '{name}'")))?;
        bufs.push(buf);
    }
    Ok(bufs)
}

/// Execute with inputs resolved by name (slot order varies across
/// rearrangements — a flipped variant may traverse `B` first).
pub fn execute_named(
    prog: &Program,
    named_inputs: &[(&str, &[f64])],
    out: &mut [f64],
) -> Result<()> {
    let bufs = order_inputs(prog, named_inputs)?;
    execute(prog, &bufs, out)
}

/// Convenience: lower and run in one step, resolving input buffers by name.
pub fn run(e: &Expr, env: &Env, named_inputs: &[(&str, &[f64])]) -> Result<Vec<f64>> {
    let prog = lower(e, env)?;
    let mut out = vec![0.0; prog.out_size];
    execute_named(&prog, named_inputs, &mut out)?;
    Ok(out)
}
