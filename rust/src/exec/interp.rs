//! Strided loop-nest interpreter.
//!
//! The generic path walks the [`Node`] tree, maintaining one offset cursor
//! per track. The innermost loops — the only place per-element overhead
//! matters — are specialized:
//!
//! - reduction over `a*b` (the dot-product core of every matmul variant)
//!   runs as a tight two-cursor loop with a register accumulator;
//! - elementwise loops over small kernels run with pre-gathered cursors,
//!   with a dedicated `a*b` path (the `map (*e)` core of the flipped
//!   variants).
//!
//! Because only traversal *order* differs between the paper's
//! rearrangements (identical per-element work), the interpretation overhead
//! is constant across variants and the measured differences are the memory
//! system's — which is exactly what the paper measures.

use super::program::{Adv, Kernel, KernelOp, Node, Program, WriteMode};
use crate::dsl::Prim;
use crate::{Error, Result};

/// Execute a lowered program. `inputs` must follow `prog.input_names`
/// order; `out` must have exactly `prog.out_size` elements.
///
/// Before touching any buffer the program is statically verified
/// ([`crate::verify::verify`]) and the certified footprint is checked
/// against the buffers actually provided — so release builds fail closed
/// with [`Error::Verify`] instead of trusting lowering (the unchecked fast
/// paths below rely on this gate; their `debug_assert!`s are belt and
/// braces, not the defense).
pub fn execute(prog: &Program, inputs: &[&[f64]], out: &mut [f64]) -> Result<()> {
    if inputs.len() != prog.input_names.len() {
        return Err(Error::Eval(format!(
            "expected {} inputs, got {}",
            prog.input_names.len(),
            inputs.len()
        )));
    }
    for (i, (buf, need)) in inputs.iter().zip(&prog.input_lens).enumerate() {
        if buf.len() < *need {
            return Err(Error::Eval(format!(
                "input '{}' too short: {} < {}",
                prog.input_names[i],
                buf.len(),
                need
            )));
        }
    }
    if out.len() != prog.out_size {
        return Err(Error::Eval(format!(
            "output buffer {} != {}",
            out.len(),
            prog.out_size
        )));
    }
    check_reduction_ops(&prog.root)?;
    // Static verification: prove every reachable offset in bounds (and the
    // structural invariants the fast paths assume) before running, then
    // re-check the *proven* requirement against each provided buffer. The
    // declared-length check above already implies the buffer check (verify
    // bounds reads by `input_lens`), but the precondition the unsafe code
    // needs is footprint ⊆ buffer, so that is what we assert.
    let fp = crate::verify::verify(prog)?;
    for (i, buf) in inputs.iter().enumerate() {
        let need = fp.input_required(i);
        if buf.len() < need {
            return Err(Error::Verify(format!(
                "input '{}' shorter than its verified footprint: {} < {need}",
                prog.input_names[i],
                buf.len()
            )));
        }
    }
    let mut ctx = Ctx {
        bufs: inputs,
        off: vec![0usize; prog.n_tracks()],
        track_slot: &prog.track_slot,
        temps: prog.temp_sizes.iter().map(|&s| vec![0.0; s]).collect(),
    };
    exec(&prog.root, &mut ctx, out, 0, WriteMode::Set);
    Ok(())
}

struct Ctx<'a> {
    bufs: &'a [&'a [f64]],
    off: Vec<usize>,
    track_slot: &'a [usize],
    temps: Vec<Vec<f64>>,
}

impl<'a> Ctx<'a> {
    #[inline]
    fn read(&self, track: usize) -> f64 {
        self.bufs[self.track_slot[track]][self.off[track]]
    }

    /// Initialize the child tracks of a loop; returns nothing — cursors are
    /// (re)set on entry and advanced per iteration by the loop bodies.
    #[inline]
    fn enter(&mut self, advances: &[Adv]) {
        for a in advances {
            let base = a.src.map(|s| self.off[s]).unwrap_or(0) + a.base;
            self.off[a.dst] = base;
        }
    }

    #[inline]
    fn step(&mut self, advances: &[Adv]) {
        for a in advances {
            self.off[a.dst] += a.stride;
        }
    }
}

#[inline]
fn identity(op: Prim) -> f64 {
    match op {
        Prim::Add => 0.0,
        Prim::Mul => 1.0,
        Prim::Max => f64::NEG_INFINITY,
        Prim::Min => f64::INFINITY,
        // Non-associative ops are rejected by `check_reduction_ops` before
        // execution starts; kept total so the interpreter has no panicking
        // paths.
        _ => 0.0,
    }
}

/// Reject programs whose reductions use a non-associative operator — the
/// interpreter's accumulator strategies (identity init, register
/// re-association) are only valid for associative ops, and lowering is the
/// layer meant to guarantee that. Returning an error here keeps a bad
/// `Program` from silently computing garbage (or panicking).
fn check_reduction_ops(node: &Node) -> Result<()> {
    match node {
        Node::MapLoop { body, .. } => check_reduction_ops(body),
        Node::RedLoop { op, body, .. } => {
            if !op.is_associative() {
                return Err(Error::Eval(format!(
                    "reduction operator '{}' is not associative",
                    op.name()
                )));
            }
            check_reduction_ops(body)
        }
        Node::Leaf(_) => Ok(()),
    }
}

#[inline]
fn write(dst: &mut f64, val: f64, mode: WriteMode) {
    match mode {
        WriteMode::Set => *dst = val,
        WriteMode::Acc(Prim::Add) => *dst += val,
        WriteMode::Acc(op) => *dst = op.apply(&[*dst, val]),
    }
}

fn exec(node: &Node, ctx: &mut Ctx, dst: &mut [f64], dst_off: usize, mode: WriteMode) {
    match node {
        Node::MapLoop {
            extent,
            advances,
            body_size,
            body,
        } => {
            ctx.enter(advances);
            // Innermost elementwise loop: run specialized.
            if let Node::Leaf(k) = &**body {
                map_leaf_loop(*extent, advances, k, ctx, dst, dst_off, mode);
                return;
            }
            let mut off = dst_off;
            for _ in 0..*extent {
                exec(body, ctx, dst, off, mode);
                ctx.step(advances);
                off += body_size;
            }
        }
        Node::RedLoop {
            extent,
            advances,
            op,
            body_size,
            temp,
            body,
        } => {
            match (temp, mode) {
                (Some(t), WriteMode::Acc(outer_op)) => {
                    // Private region: compute with Set semantics, then fold
                    // into dst with the enclosing operator.
                    let mut tmp = std::mem::take(&mut ctx.temps[*t]);
                    red_loop(
                        *extent, advances, *op, body, ctx, &mut tmp, 0, WriteMode::Set,
                    );
                    for (k, v) in tmp.iter().enumerate() {
                        write(&mut dst[dst_off + k], *v, WriteMode::Acc(outer_op));
                    }
                    ctx.temps[*t] = tmp;
                }
                _ => {
                    red_loop(*extent, advances, *op, body, ctx, dst, dst_off, mode);
                    let _ = body_size;
                }
            }
        }
        Node::Leaf(k) => {
            let val = eval_kernel(k, ctx);
            write(&mut dst[dst_off], val, mode);
        }
    }
}

/// Core reduction loop. Under `Set`, the destination region is initialised
/// to the operator identity and the body accumulates; under a same-op
/// enclosing accumulation the body accumulates directly (valid because the
/// operator is associative and commutative — lowering guarantees this).
fn red_loop(
    extent: usize,
    advances: &[Adv],
    op: Prim,
    body: &Node,
    ctx: &mut Ctx,
    dst: &mut [f64],
    dst_off: usize,
    mode: WriteMode,
) {
    ctx.enter(advances);
    // Specialized scalar reductions over a leaf kernel.
    if let Node::Leaf(k) = body {
        let acc = red_leaf_loop(extent, advances, k, op, ctx);
        match mode {
            WriteMode::Set => dst[dst_off] = acc,
            m @ WriteMode::Acc(_) => write(&mut dst[dst_off], acc, m),
        }
        return;
    }
    // Two-level reduction over a dot leaf (the subdivided-rnz hot path,
    // Table 2 / Figure 5): run both levels as one tight nest, skipping the
    // per-chunk dispatch.
    if let Node::RedLoop {
        extent: ei,
        advances: ai,
        op: opi,
        temp: None,
        body: bi,
        ..
    } = body
    {
        if *opi == op && op == Prim::Add {
            if let Node::Leaf(k) = &**bi {
                if k.is_mul2() {
                    let mut acc = 0.0;
                    for _ in 0..extent {
                        acc += red_leaf_loop(*ei, ai, k, op, {
                            ctx.enter(ai);
                            ctx
                        });
                        ctx.step(advances);
                    }
                    match mode {
                        WriteMode::Set => dst[dst_off] = acc,
                        m @ WriteMode::Acc(_) => write(&mut dst[dst_off], acc, m),
                    }
                    return;
                }
            }
        }
    }
    let body_size = node_out_size(body);
    if matches!(mode, WriteMode::Set) {
        dst[dst_off..dst_off + body_size].fill(identity(op));
    }
    let inner_mode = WriteMode::Acc(op);
    for _ in 0..extent {
        exec(body, ctx, dst, dst_off, inner_mode);
        ctx.step(advances);
    }
}

fn node_out_size(n: &Node) -> usize {
    match n {
        Node::MapLoop {
            extent, body_size, ..
        } => extent * body_size,
        Node::RedLoop { body_size, .. } => *body_size,
        Node::Leaf(_) => 1,
    }
}

/// Tight scalar reduction over a leaf kernel: keeps the accumulator in a
/// register and advances raw cursors.
#[inline]
fn red_leaf_loop(extent: usize, advances: &[Adv], k: &Kernel, op: Prim, ctx: &mut Ctx) -> f64 {
    // Dot-product fast path: acc op= a[i]*b[i] over two cursors.
    // Four independent accumulators break the FP-add latency chain —
    // justified by the DSL contract that reduction operators are
    // associative (the same property the paper's regrouping rules rely
    // on).
    if k.is_mul2() && op == Prim::Add {
        let (t0, t1) = (k.tracks[0], k.tracks[1]);
        let s0 = stride_of(advances, t0);
        let s1 = stride_of(advances, t1);
        let b0 = ctx.bufs[ctx.track_slot[t0]];
        let b1 = ctx.bufs[ctx.track_slot[t1]];
        let mut p0 = ctx.off[t0];
        let mut p1 = ctx.off[t1];
        debug_assert!(p0 + extent.saturating_sub(1) * s0 < b0.len());
        debug_assert!(p1 + extent.saturating_sub(1) * s1 < b1.len());
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
        let mut i = 0usize;
        // SAFETY: the cursors take exactly the offsets `entry + i*stride`,
        // i < extent, for each track — the interval the static verifier
        // bounds below the track's `input_lens` entry, and `execute`
        // re-checked the verified footprint against each provided buffer
        // before dispatching. So every `get_unchecked` offset is < len.
        unsafe {
            while i + 4 <= extent {
                a0 += b0.get_unchecked(p0) * b1.get_unchecked(p1);
                a1 += b0.get_unchecked(p0 + s0) * b1.get_unchecked(p1 + s1);
                a2 += b0.get_unchecked(p0 + 2 * s0) * b1.get_unchecked(p1 + 2 * s1);
                a3 += b0.get_unchecked(p0 + 3 * s0) * b1.get_unchecked(p1 + 3 * s1);
                p0 += 4 * s0;
                p1 += 4 * s1;
                i += 4;
            }
            while i < extent {
                a0 += b0.get_unchecked(p0) * b1.get_unchecked(p1);
                p0 += s0;
                p1 += s1;
                i += 1;
            }
        }
        // Leave cursors consistent for any sibling use.
        ctx.off[t0] = p0;
        ctx.off[t1] = p1;
        return (a0 + a2) + (a1 + a3);
    }
    let mut acc = identity(op);
    match op {
        Prim::Add => {
            for _ in 0..extent {
                acc += eval_kernel(k, ctx);
                ctx.step(advances);
            }
        }
        _ => {
            for _ in 0..extent {
                acc = op.apply(&[acc, eval_kernel(k, ctx)]);
                ctx.step(advances);
            }
        }
    }
    acc
}

/// Tight elementwise loop over a leaf kernel.
#[inline]
fn map_leaf_loop(
    extent: usize,
    advances: &[Adv],
    k: &Kernel,
    ctx: &mut Ctx,
    dst: &mut [f64],
    dst_off: usize,
    mode: WriteMode,
) {
    // a*b fast paths (the `map (*e)` core of flipped variants; one of the
    // cursors may be loop-invariant, stride 0).
    if k.is_mul2() {
        let (t0, t1) = (k.tracks[0], k.tracks[1]);
        let s0 = stride_of(advances, t0);
        let s1 = stride_of(advances, t1);
        let b0 = ctx.bufs[ctx.track_slot[t0]];
        let b1 = ctx.bufs[ctx.track_slot[t1]];
        let mut p0 = ctx.off[t0];
        let mut p1 = ctx.off[t1];
        debug_assert!(p0 + extent.saturating_sub(1) * s0 < b0.len());
        debug_assert!(p1 + extent.saturating_sub(1) * s1 < b1.len());
        match mode {
            WriteMode::Set => {
                // SAFETY: the cursors take exactly the offsets
                // `entry + i*stride`, i < extent — the interval the static
                // verifier bounds below `input_lens`, and `execute`
                // re-checked the verified footprint against each provided
                // buffer before dispatching.
                unsafe {
                    for d in &mut dst[dst_off..dst_off + extent] {
                        *d = b0.get_unchecked(p0) * b1.get_unchecked(p1);
                        p0 += s0;
                        p1 += s1;
                    }
                }
            }
            WriteMode::Acc(Prim::Add) => {
                // SAFETY: same verified-footprint argument as the Set arm
                // above; the accumulating write goes through the checked
                // `dst` slice either way.
                unsafe {
                    for d in &mut dst[dst_off..dst_off + extent] {
                        *d += b0.get_unchecked(p0) * b1.get_unchecked(p1);
                        p0 += s0;
                        p1 += s1;
                    }
                }
            }
            WriteMode::Acc(op) => {
                for d in &mut dst[dst_off..dst_off + extent] {
                    *d = op.apply(&[*d, b0[p0] * b1[p1]]);
                    p0 += s0;
                    p1 += s1;
                }
            }
        }
        ctx.off[t0] = p0;
        ctx.off[t1] = p1;
        return;
    }
    if k.is_copy() {
        let t0 = k.tracks[0];
        let s0 = stride_of(advances, t0);
        let b0 = ctx.bufs[ctx.track_slot[t0]];
        let mut p0 = ctx.off[t0];
        match mode {
            WriteMode::Set => {
                for d in &mut dst[dst_off..dst_off + extent] {
                    *d = b0[p0];
                    p0 += s0;
                }
            }
            WriteMode::Acc(Prim::Add) => {
                for d in &mut dst[dst_off..dst_off + extent] {
                    *d += b0[p0];
                    p0 += s0;
                }
            }
            WriteMode::Acc(op) => {
                for d in &mut dst[dst_off..dst_off + extent] {
                    *d = op.apply(&[*d, b0[p0]]);
                    p0 += s0;
                }
            }
        }
        ctx.off[t0] = p0;
        return;
    }
    // General bytecode loop.
    for i in 0..extent {
        let val = eval_kernel(k, ctx);
        write(&mut dst[dst_off + i], val, mode);
        ctx.step(advances);
    }
}

/// Stride with which this loop advances a given track (0 if the track is
/// owned by an enclosing loop and thus loop-invariant here).
#[inline]
fn stride_of(advances: &[Adv], track: usize) -> usize {
    advances
        .iter()
        .find(|a| a.dst == track)
        .map(|a| a.stride)
        .unwrap_or(0)
}

/// Evaluate a leaf kernel's bytecode at the current cursors.
#[inline]
fn eval_kernel(k: &Kernel, ctx: &Ctx) -> f64 {
    let mut stack = [0.0f64; 16];
    let mut sp = 0usize;
    for op in &k.ops {
        match op {
            KernelOp::In(i) => {
                stack[sp] = ctx.read(k.tracks[*i as usize]);
                sp += 1;
            }
            KernelOp::Const(c) => {
                stack[sp] = *c;
                sp += 1;
            }
            KernelOp::Prim(p) => match p.arity() {
                1 => stack[sp - 1] = p.apply(&[stack[sp - 1]]),
                _ => {
                    stack[sp - 2] = p.apply(&[stack[sp - 2], stack[sp - 1]]);
                    sp -= 1;
                }
            },
        }
    }
    debug_assert_eq!(sp, 1);
    stack[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;
    use crate::exec::{lower, run};
    use crate::layout::Layout;
    use crate::typecheck::Env;

    #[test]
    fn dot_product_exec() {
        let env = Env::new()
            .with("u", Layout::row_major(&[3]))
            .with("v", Layout::row_major(&[3]));
        let e = dot(input("u"), input("v"));
        let out = run(&e, &env, &[("u", &[1., 2., 3.]), ("v", &[4., 5., 6.])]).unwrap();
        assert_eq!(out, vec![32.0]);
    }

    #[test]
    fn matvec_exec_matches_reference() {
        let env = Env::new()
            .with("A", Layout::row_major(&[3, 2]))
            .with("v", Layout::row_major(&[2]));
        let e = matvec_naive(input("A"), input("v"));
        let out = run(
            &e,
            &env,
            &[("A", &[1., 2., 3., 4., 5., 6.]), ("v", &[1., 10.])],
        )
        .unwrap();
        assert_eq!(out, vec![21., 43., 65.]);
    }

    #[test]
    fn matvec_flipped_form_exec() {
        // eq 40: rnz (lift +) (\c q -> map (*q) c) (flip 0 A) v
        let env = Env::new()
            .with("A", Layout::row_major(&[3, 2]))
            .with("v", Layout::row_major(&[2]));
        let e = rnz(
            lift(add()),
            lam2(
                "c",
                "q",
                map(lam1("e", app2(mul(), var("e"), var("q"))), var("c")),
            ),
            vec![flip(0, input("A")), input("v")],
        );
        let out = run(
            &e,
            &env,
            &[("A", &[1., 2., 3., 4., 5., 6.]), ("v", &[1., 10.])],
        )
        .unwrap();
        assert_eq!(out, vec![21., 43., 65.]);
    }

    #[test]
    fn matmul_exec() {
        let env = Env::new()
            .with("A", Layout::row_major(&[2, 2]))
            .with("B", Layout::row_major(&[2, 2]));
        let e = matmul_naive(input("A"), input("B"));
        let out = run(
            &e,
            &env,
            &[("A", &[1., 2., 3., 4.]), ("B", &[5., 6., 7., 8.])],
        )
        .unwrap();
        assert_eq!(out, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn blocked_matvec_exec() {
        // 1a form with b=2 over an 8-vector
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 8]))
            .with("v", Layout::row_major(&[8]));
        let e = map(
            lam1(
                "r",
                rnz(
                    add(),
                    lam2("bb", "cc", dot(var("bb"), var("cc"))),
                    vec![subdiv(0, 2, var("r")), subdiv(0, 2, input("v"))],
                ),
            ),
            input("A"),
        );
        let a: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let v: Vec<f64> = (0..8).map(|i| (i + 1) as f64).collect();
        let naive = run(
            &matvec_naive(input("A"), input("v")),
            &env,
            &[("A", &a), ("v", &v)],
        )
        .unwrap();
        let blocked = run(&e, &env, &[("A", &a), ("v", &v)]).unwrap();
        assert_eq!(naive, blocked);
    }

    #[test]
    fn mixed_op_temp_reduction() {
        // max over rows of row-sums
        let env = Env::new().with("A", Layout::row_major(&[3, 4]));
        let e = rnz(
            pmax(),
            lam1("r", reduce(add(), var("r"))),
            vec![input("A")],
        );
        let a = vec![1., 2., 3., 4., -10., 0., 0., 0., 2., 2., 2., 2.];
        let out = run(&e, &env, &[("A", &a)]).unwrap();
        assert_eq!(out, vec![10.0]);
    }

    #[test]
    fn input_length_validated() {
        let env = Env::new().with("u", Layout::row_major(&[4]));
        let e = reduce(add(), input("u"));
        let prog = lower(&e, &env).unwrap();
        let short = [1.0, 2.0];
        let mut out = vec![0.0];
        assert!(execute(&prog, &[&short], &mut out).is_err());
        let mut wrong_out = vec![0.0, 0.0];
        let full = [1.0, 2.0, 3.0, 4.0];
        assert!(execute(&prog, &[&full], &mut wrong_out).is_err());
    }
}
