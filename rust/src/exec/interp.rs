//! Strided loop-nest interpreter.
//!
//! The generic path walks the [`Node`] tree, maintaining one offset cursor
//! per track. The innermost loops — the only place per-element overhead
//! matters — are specialized:
//!
//! - reduction over `a*b` (the dot-product core of every matmul variant)
//!   runs as a tight two-cursor loop with a register accumulator;
//! - elementwise loops over small kernels run with pre-gathered cursors,
//!   with a dedicated `a*b` path (the `map (*e)` core of the flipped
//!   variants).
//!
//! Because only traversal *order* differs between the paper's
//! rearrangements (identical per-element work), the interpretation overhead
//! is constant across variants and the measured differences are the memory
//! system's — which is exactly what the paper measures.
//!
//! **Certificate-gated parallel mode** ([`execute_threaded`]): the
//! verifier's dependence analysis ([`crate::verify::ParCert`]) decides
//! whether the root `MapLoop`'s iterations own disjoint destination
//! chunks. When it says `Parallel` and the caller asks for ≥ 2 threads,
//! the root loop is split into contiguous iteration ranges and run on a
//! scoped thread pool — bit-identical to serial, because each output
//! element is computed exactly once, by one thread, with the same
//! floating-point operation order (`RedLoop`s stay serial inside each
//! chunk, so reduction association never changes). On any `Serial`
//! verdict, a missing certificate, or a root that is not a map, execution
//! fails closed to the serial path — the analysis, not a flag, is the
//! authority.

use super::program::{Adv, Kernel, KernelOp, Node, Program, WriteMode};
use crate::dsl::Prim;
use crate::verify::ParVerdict;
use crate::{Error, Result};

/// Hard cap on worker threads [`execute_threaded`] will use; requests
/// beyond it are clamped (the coordinator's `exec_threads` knob rejects
/// such values at validation instead).
pub const MAX_EXEC_THREADS: usize = 64;

/// What [`execute_threaded`] actually did, for metrics plumbing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecReport {
    /// Map loops executed via the threaded path (the root chunking counts
    /// as one; nested maps run inside their chunk's thread).
    pub parallel_loops: u64,
    /// `true` when ≥ 2 threads were requested but the certificate (or the
    /// nest shape) forced the serial path.
    pub serial_fallback: bool,
    /// Worker threads actually used (1 on the serial path).
    pub threads_used: usize,
}

/// Execute a lowered program serially. `inputs` must follow
/// `prog.input_names` order; `out` must have exactly `prog.out_size`
/// elements. Equivalent to [`execute_threaded`] with one thread.
pub fn execute(prog: &Program, inputs: &[&[f64]], out: &mut [f64]) -> Result<()> {
    execute_threaded(prog, inputs, out, 1).map(|_| ())
}

/// Execute a lowered program, chunking the root `MapLoop` across up to
/// `threads` worker threads when — and only when — the verifier's
/// dependence certificate says its iterations are independent
/// ([`ParVerdict::Parallel`]). `threads <= 1` (and any `Serial` verdict,
/// missing certificate, or non-map root) runs the serial path; the output
/// is bit-identical either way. Returns what actually happened.
///
/// Before touching any buffer the program is statically verified
/// ([`crate::verify::verify`]) and the certified footprint is checked
/// against the buffers actually provided — so release builds fail closed
/// with [`Error::Verify`] instead of trusting lowering (the unchecked fast
/// paths below rely on this gate; their `debug_assert!`s are belt and
/// braces, not the defense).
pub fn execute_threaded(
    prog: &Program,
    inputs: &[&[f64]],
    out: &mut [f64],
    threads: usize,
) -> Result<ExecReport> {
    if inputs.len() != prog.input_names.len() {
        return Err(Error::Eval(format!(
            "expected {} inputs, got {}",
            prog.input_names.len(),
            inputs.len()
        )));
    }
    for (i, (buf, need)) in inputs.iter().zip(&prog.input_lens).enumerate() {
        if buf.len() < *need {
            return Err(Error::Eval(format!(
                "input '{}' too short: {} < {}",
                prog.input_names[i],
                buf.len(),
                need
            )));
        }
    }
    if out.len() != prog.out_size {
        return Err(Error::Eval(format!(
            "output buffer {} != {}",
            out.len(),
            prog.out_size
        )));
    }
    check_reduction_ops(&prog.root)?;
    // Static verification: prove every reachable offset in bounds (and the
    // structural invariants the fast paths assume) before running, then
    // re-check the *proven* requirement against each provided buffer. The
    // declared-length check above already implies the buffer check (verify
    // bounds reads by `input_lens`), but the precondition the unsafe code
    // needs is footprint ⊆ buffer, so that is what we assert.
    let fp = crate::verify::verify(prog)?;
    for (i, buf) in inputs.iter().enumerate() {
        let need = fp.input_required(i);
        if buf.len() < need {
            return Err(Error::Verify(format!(
                "input '{}' shorter than its verified footprint: {} < {need}",
                prog.input_names[i],
                buf.len()
            )));
        }
    }
    let threads = threads.clamp(1, MAX_EXEC_THREADS);
    // Certificate gate: only a root MapLoop the dependence analysis marked
    // Parallel may be chunked. Everything else — Serial verdicts, red
    // roots, single iterations — takes the serial path (fail closed).
    let plan = if threads >= 2 {
        match (&prog.root, fp.par.root()) {
            (
                Node::MapLoop {
                    extent,
                    advances,
                    body_size,
                    body,
                },
                Some(cert),
            ) if *extent >= 2 && matches!(cert.verdict, ParVerdict::Parallel { .. }) => {
                Some((*extent, advances.as_slice(), *body_size, &**body))
            }
            _ => None,
        }
    } else {
        None
    };
    let Some((extent, advances, body_size, body)) = plan else {
        let mut ctx = Ctx {
            bufs: inputs,
            off: vec![0usize; prog.n_tracks()],
            track_slot: &prog.track_slot,
            temps: prog.temp_sizes.iter().map(|&s| vec![0.0; s]).collect(),
        };
        exec(&prog.root, &mut ctx, out, 0, WriteMode::Set);
        return Ok(ExecReport {
            parallel_loops: 0,
            serial_fallback: threads >= 2,
            threads_used: 1,
        });
    };
    // Contiguous balanced iteration ranges; the output splits on the same
    // boundaries because the verified root span is extent * body_size.
    let n_threads = threads.min(extent);
    let per = extent / n_threads;
    let rem = extent % n_threads;
    let n_tracks = prog.n_tracks();
    let track_slot = &prog.track_slot;
    let temp_sizes = &prog.temp_sizes;
    let panicked = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(n_threads);
        let mut rest = out;
        let mut start = 0usize;
        for t in 0..n_threads {
            let count = per + usize::from(t < rem);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(count * body_size);
            rest = tail;
            // Each worker gets its own cursor vector and a private temp
            // arena (temps are per-iteration scratch; the certificate
            // additionally guarantees no certified-parallel body stages
            // through one, see verify::depend). Input buffers are shared
            // read-only; output chunks are disjoint by construction.
            handles.push(s.spawn(move || {
                let mut ctx = Ctx {
                    bufs: inputs,
                    off: vec![0usize; n_tracks],
                    track_slot,
                    temps: temp_sizes.iter().map(|&sz| vec![0.0; sz]).collect(),
                };
                run_root_chunk(body, advances, body_size, start, count, &mut ctx, chunk);
            }));
            start += count;
        }
        // Join every handle (no short-circuit): a panicked worker left
        // unjoined would re-panic at scope exit instead of surfacing as
        // the typed error below.
        let joins: Vec<bool> = handles.into_iter().map(|h| h.join().is_err()).collect();
        joins.into_iter().any(|p| p)
    });
    if panicked {
        return Err(Error::Eval("parallel executor worker panicked".into()));
    }
    Ok(ExecReport {
        parallel_loops: 1,
        serial_fallback: false,
        threads_used: n_threads,
    })
}

/// Run iterations `start .. start + count` of a certified-parallel root
/// map. `dst` is the output chunk whose first element corresponds to
/// iteration `start` (the root cursor advances by `body_size` per
/// iteration, so chunk-local offsets start at 0).
fn run_root_chunk(
    body: &Node,
    advances: &[Adv],
    body_size: usize,
    start: usize,
    count: usize,
    ctx: &mut Ctx,
    dst: &mut [f64],
) {
    // Reproduce `Ctx::enter` against the all-zero entry state the root
    // loop sees, then advance every cursor to iteration `start`.
    ctx.enter(advances);
    for a in advances {
        ctx.off[a.dst] += start * a.stride;
    }
    if let Node::Leaf(k) = body {
        map_leaf_loop(count, advances, k, ctx, dst, 0, WriteMode::Set);
        return;
    }
    let mut off = 0usize;
    for _ in 0..count {
        exec(body, ctx, dst, off, WriteMode::Set);
        ctx.step(advances);
        off += body_size;
    }
}

struct Ctx<'a> {
    bufs: &'a [&'a [f64]],
    off: Vec<usize>,
    track_slot: &'a [usize],
    temps: Vec<Vec<f64>>,
}

impl<'a> Ctx<'a> {
    #[inline]
    fn read(&self, track: usize) -> f64 {
        self.bufs[self.track_slot[track]][self.off[track]]
    }

    /// Initialize the child tracks of a loop; returns nothing — cursors are
    /// (re)set on entry and advanced per iteration by the loop bodies.
    #[inline]
    fn enter(&mut self, advances: &[Adv]) {
        for a in advances {
            let base = a.src.map(|s| self.off[s]).unwrap_or(0) + a.base;
            self.off[a.dst] = base;
        }
    }

    #[inline]
    fn step(&mut self, advances: &[Adv]) {
        for a in advances {
            self.off[a.dst] += a.stride;
        }
    }
}

#[inline]
fn identity(op: Prim) -> f64 {
    match op {
        Prim::Add => 0.0,
        Prim::Mul => 1.0,
        Prim::Max => f64::NEG_INFINITY,
        Prim::Min => f64::INFINITY,
        // Non-associative ops are rejected by `check_reduction_ops` before
        // execution starts; kept total so the interpreter has no panicking
        // paths.
        _ => 0.0,
    }
}

/// Reject programs whose reductions use a non-associative operator — the
/// interpreter's accumulator strategies (identity init, register
/// re-association) are only valid for associative ops, and lowering is the
/// layer meant to guarantee that. Returning an error here keeps a bad
/// `Program` from silently computing garbage (or panicking).
fn check_reduction_ops(node: &Node) -> Result<()> {
    match node {
        Node::MapLoop { body, .. } => check_reduction_ops(body),
        Node::RedLoop { op, body, .. } => {
            if !op.is_associative() {
                return Err(Error::Eval(format!(
                    "reduction operator '{}' is not associative",
                    op.name()
                )));
            }
            check_reduction_ops(body)
        }
        Node::Leaf(_) => Ok(()),
    }
}

#[inline]
fn write(dst: &mut f64, val: f64, mode: WriteMode) {
    match mode {
        WriteMode::Set => *dst = val,
        WriteMode::Acc(Prim::Add) => *dst += val,
        WriteMode::Acc(op) => *dst = op.apply(&[*dst, val]),
    }
}

fn exec(node: &Node, ctx: &mut Ctx, dst: &mut [f64], dst_off: usize, mode: WriteMode) {
    match node {
        Node::MapLoop {
            extent,
            advances,
            body_size,
            body,
        } => {
            ctx.enter(advances);
            // Innermost elementwise loop: run specialized.
            if let Node::Leaf(k) = &**body {
                map_leaf_loop(*extent, advances, k, ctx, dst, dst_off, mode);
                return;
            }
            let mut off = dst_off;
            for _ in 0..*extent {
                exec(body, ctx, dst, off, mode);
                ctx.step(advances);
                off += body_size;
            }
        }
        Node::RedLoop {
            extent,
            advances,
            op,
            body_size,
            temp,
            body,
        } => {
            match (temp, mode) {
                (Some(t), WriteMode::Acc(outer_op)) => {
                    // Private region: compute with Set semantics, then fold
                    // into dst with the enclosing operator.
                    let mut tmp = std::mem::take(&mut ctx.temps[*t]);
                    red_loop(
                        *extent, advances, *op, body, ctx, &mut tmp, 0, WriteMode::Set,
                    );
                    for (k, v) in tmp.iter().enumerate() {
                        write(&mut dst[dst_off + k], *v, WriteMode::Acc(outer_op));
                    }
                    ctx.temps[*t] = tmp;
                }
                _ => {
                    red_loop(*extent, advances, *op, body, ctx, dst, dst_off, mode);
                    let _ = body_size;
                }
            }
        }
        Node::Leaf(k) => {
            let val = eval_kernel(k, ctx);
            write(&mut dst[dst_off], val, mode);
        }
    }
}

/// Core reduction loop. Under `Set`, the destination region is initialised
/// to the operator identity and the body accumulates; under a same-op
/// enclosing accumulation the body accumulates directly (valid because the
/// operator is associative and commutative — lowering guarantees this).
fn red_loop(
    extent: usize,
    advances: &[Adv],
    op: Prim,
    body: &Node,
    ctx: &mut Ctx,
    dst: &mut [f64],
    dst_off: usize,
    mode: WriteMode,
) {
    ctx.enter(advances);
    // Specialized scalar reductions over a leaf kernel.
    if let Node::Leaf(k) = body {
        let acc = red_leaf_loop(extent, advances, k, op, ctx);
        match mode {
            WriteMode::Set => dst[dst_off] = acc,
            m @ WriteMode::Acc(_) => write(&mut dst[dst_off], acc, m),
        }
        return;
    }
    // Two-level reduction over a dot leaf (the subdivided-rnz hot path,
    // Table 2 / Figure 5): run both levels as one tight nest, skipping the
    // per-chunk dispatch.
    if let Node::RedLoop {
        extent: ei,
        advances: ai,
        op: opi,
        temp: None,
        body: bi,
        ..
    } = body
    {
        if *opi == op && op == Prim::Add {
            if let Node::Leaf(k) = &**bi {
                if k.is_mul2() {
                    let mut acc = 0.0;
                    for _ in 0..extent {
                        acc += red_leaf_loop(*ei, ai, k, op, {
                            ctx.enter(ai);
                            ctx
                        });
                        ctx.step(advances);
                    }
                    match mode {
                        WriteMode::Set => dst[dst_off] = acc,
                        m @ WriteMode::Acc(_) => write(&mut dst[dst_off], acc, m),
                    }
                    return;
                }
            }
        }
    }
    let body_size = node_out_size(body);
    if matches!(mode, WriteMode::Set) {
        dst[dst_off..dst_off + body_size].fill(identity(op));
    }
    let inner_mode = WriteMode::Acc(op);
    for _ in 0..extent {
        exec(body, ctx, dst, dst_off, inner_mode);
        ctx.step(advances);
    }
}

fn node_out_size(n: &Node) -> usize {
    match n {
        Node::MapLoop {
            extent, body_size, ..
        } => extent * body_size,
        Node::RedLoop { body_size, .. } => *body_size,
        Node::Leaf(_) => 1,
    }
}

/// Tight scalar reduction over a leaf kernel: keeps the accumulator in a
/// register and advances raw cursors.
#[inline]
fn red_leaf_loop(extent: usize, advances: &[Adv], k: &Kernel, op: Prim, ctx: &mut Ctx) -> f64 {
    // Dot-product fast path: acc op= a[i]*b[i] over two cursors.
    // Four independent accumulators break the FP-add latency chain —
    // justified by the DSL contract that reduction operators are
    // associative (the same property the paper's regrouping rules rely
    // on).
    if k.is_mul2() && op == Prim::Add {
        let (t0, t1) = (k.tracks[0], k.tracks[1]);
        let s0 = stride_of(advances, t0);
        let s1 = stride_of(advances, t1);
        let b0 = ctx.bufs[ctx.track_slot[t0]];
        let b1 = ctx.bufs[ctx.track_slot[t1]];
        let mut p0 = ctx.off[t0];
        let mut p1 = ctx.off[t1];
        debug_assert!(p0 + extent.saturating_sub(1) * s0 < b0.len());
        debug_assert!(p1 + extent.saturating_sub(1) * s1 < b1.len());
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
        let mut i = 0usize;
        // SAFETY: the cursors take exactly the offsets `entry + i*stride`,
        // i < extent, for each track — the interval the static verifier
        // bounds below the track's `input_lens` entry, and `execute`
        // re-checked the verified footprint against each provided buffer
        // before dispatching. So every `get_unchecked` offset is < len.
        unsafe {
            while i + 4 <= extent {
                a0 += b0.get_unchecked(p0) * b1.get_unchecked(p1);
                a1 += b0.get_unchecked(p0 + s0) * b1.get_unchecked(p1 + s1);
                a2 += b0.get_unchecked(p0 + 2 * s0) * b1.get_unchecked(p1 + 2 * s1);
                a3 += b0.get_unchecked(p0 + 3 * s0) * b1.get_unchecked(p1 + 3 * s1);
                p0 += 4 * s0;
                p1 += 4 * s1;
                i += 4;
            }
            while i < extent {
                a0 += b0.get_unchecked(p0) * b1.get_unchecked(p1);
                p0 += s0;
                p1 += s1;
                i += 1;
            }
        }
        // Leave cursors consistent for any sibling use.
        ctx.off[t0] = p0;
        ctx.off[t1] = p1;
        return (a0 + a2) + (a1 + a3);
    }
    let mut acc = identity(op);
    match op {
        Prim::Add => {
            for _ in 0..extent {
                acc += eval_kernel(k, ctx);
                ctx.step(advances);
            }
        }
        _ => {
            for _ in 0..extent {
                acc = op.apply(&[acc, eval_kernel(k, ctx)]);
                ctx.step(advances);
            }
        }
    }
    acc
}

/// Tight elementwise loop over a leaf kernel.
#[inline]
fn map_leaf_loop(
    extent: usize,
    advances: &[Adv],
    k: &Kernel,
    ctx: &mut Ctx,
    dst: &mut [f64],
    dst_off: usize,
    mode: WriteMode,
) {
    // a*b fast paths (the `map (*e)` core of flipped variants; one of the
    // cursors may be loop-invariant, stride 0).
    if k.is_mul2() {
        let (t0, t1) = (k.tracks[0], k.tracks[1]);
        let s0 = stride_of(advances, t0);
        let s1 = stride_of(advances, t1);
        let b0 = ctx.bufs[ctx.track_slot[t0]];
        let b1 = ctx.bufs[ctx.track_slot[t1]];
        let mut p0 = ctx.off[t0];
        let mut p1 = ctx.off[t1];
        debug_assert!(p0 + extent.saturating_sub(1) * s0 < b0.len());
        debug_assert!(p1 + extent.saturating_sub(1) * s1 < b1.len());
        match mode {
            WriteMode::Set => {
                // SAFETY: the cursors take exactly the offsets
                // `entry + i*stride`, i < extent — the interval the static
                // verifier bounds below `input_lens`, and `execute`
                // re-checked the verified footprint against each provided
                // buffer before dispatching.
                unsafe {
                    for d in &mut dst[dst_off..dst_off + extent] {
                        *d = b0.get_unchecked(p0) * b1.get_unchecked(p1);
                        p0 += s0;
                        p1 += s1;
                    }
                }
            }
            WriteMode::Acc(Prim::Add) => {
                // SAFETY: same verified-footprint argument as the Set arm
                // above; the accumulating write goes through the checked
                // `dst` slice either way.
                unsafe {
                    for d in &mut dst[dst_off..dst_off + extent] {
                        *d += b0.get_unchecked(p0) * b1.get_unchecked(p1);
                        p0 += s0;
                        p1 += s1;
                    }
                }
            }
            WriteMode::Acc(op) => {
                for d in &mut dst[dst_off..dst_off + extent] {
                    *d = op.apply(&[*d, b0[p0] * b1[p1]]);
                    p0 += s0;
                    p1 += s1;
                }
            }
        }
        ctx.off[t0] = p0;
        ctx.off[t1] = p1;
        return;
    }
    if k.is_copy() {
        let t0 = k.tracks[0];
        let s0 = stride_of(advances, t0);
        let b0 = ctx.bufs[ctx.track_slot[t0]];
        let mut p0 = ctx.off[t0];
        match mode {
            WriteMode::Set => {
                for d in &mut dst[dst_off..dst_off + extent] {
                    *d = b0[p0];
                    p0 += s0;
                }
            }
            WriteMode::Acc(Prim::Add) => {
                for d in &mut dst[dst_off..dst_off + extent] {
                    *d += b0[p0];
                    p0 += s0;
                }
            }
            WriteMode::Acc(op) => {
                for d in &mut dst[dst_off..dst_off + extent] {
                    *d = op.apply(&[*d, b0[p0]]);
                    p0 += s0;
                }
            }
        }
        ctx.off[t0] = p0;
        return;
    }
    // General bytecode loop.
    for i in 0..extent {
        let val = eval_kernel(k, ctx);
        write(&mut dst[dst_off + i], val, mode);
        ctx.step(advances);
    }
}

/// Stride with which this loop advances a given track (0 if the track is
/// owned by an enclosing loop and thus loop-invariant here).
#[inline]
fn stride_of(advances: &[Adv], track: usize) -> usize {
    advances
        .iter()
        .find(|a| a.dst == track)
        .map(|a| a.stride)
        .unwrap_or(0)
}

/// Evaluate a leaf kernel's bytecode at the current cursors.
#[inline]
fn eval_kernel(k: &Kernel, ctx: &Ctx) -> f64 {
    let mut stack = [0.0f64; 16];
    let mut sp = 0usize;
    for op in &k.ops {
        match op {
            KernelOp::In(i) => {
                stack[sp] = ctx.read(k.tracks[*i as usize]);
                sp += 1;
            }
            KernelOp::Const(c) => {
                stack[sp] = *c;
                sp += 1;
            }
            KernelOp::Prim(p) => match p.arity() {
                1 => stack[sp - 1] = p.apply(&[stack[sp - 1]]),
                _ => {
                    stack[sp - 2] = p.apply(&[stack[sp - 2], stack[sp - 1]]);
                    sp -= 1;
                }
            },
        }
    }
    debug_assert_eq!(sp, 1);
    stack[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;
    use crate::exec::{lower, run};
    use crate::layout::Layout;
    use crate::typecheck::Env;

    #[test]
    fn dot_product_exec() {
        let env = Env::new()
            .with("u", Layout::row_major(&[3]))
            .with("v", Layout::row_major(&[3]));
        let e = dot(input("u"), input("v"));
        let out = run(&e, &env, &[("u", &[1., 2., 3.]), ("v", &[4., 5., 6.])]).unwrap();
        assert_eq!(out, vec![32.0]);
    }

    #[test]
    fn matvec_exec_matches_reference() {
        let env = Env::new()
            .with("A", Layout::row_major(&[3, 2]))
            .with("v", Layout::row_major(&[2]));
        let e = matvec_naive(input("A"), input("v"));
        let out = run(
            &e,
            &env,
            &[("A", &[1., 2., 3., 4., 5., 6.]), ("v", &[1., 10.])],
        )
        .unwrap();
        assert_eq!(out, vec![21., 43., 65.]);
    }

    #[test]
    fn matvec_flipped_form_exec() {
        // eq 40: rnz (lift +) (\c q -> map (*q) c) (flip 0 A) v
        let env = Env::new()
            .with("A", Layout::row_major(&[3, 2]))
            .with("v", Layout::row_major(&[2]));
        let e = rnz(
            lift(add()),
            lam2(
                "c",
                "q",
                map(lam1("e", app2(mul(), var("e"), var("q"))), var("c")),
            ),
            vec![flip(0, input("A")), input("v")],
        );
        let out = run(
            &e,
            &env,
            &[("A", &[1., 2., 3., 4., 5., 6.]), ("v", &[1., 10.])],
        )
        .unwrap();
        assert_eq!(out, vec![21., 43., 65.]);
    }

    #[test]
    fn matmul_exec() {
        let env = Env::new()
            .with("A", Layout::row_major(&[2, 2]))
            .with("B", Layout::row_major(&[2, 2]));
        let e = matmul_naive(input("A"), input("B"));
        let out = run(
            &e,
            &env,
            &[("A", &[1., 2., 3., 4.]), ("B", &[5., 6., 7., 8.])],
        )
        .unwrap();
        assert_eq!(out, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn blocked_matvec_exec() {
        // 1a form with b=2 over an 8-vector
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 8]))
            .with("v", Layout::row_major(&[8]));
        let e = map(
            lam1(
                "r",
                rnz(
                    add(),
                    lam2("bb", "cc", dot(var("bb"), var("cc"))),
                    vec![subdiv(0, 2, var("r")), subdiv(0, 2, input("v"))],
                ),
            ),
            input("A"),
        );
        let a: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let v: Vec<f64> = (0..8).map(|i| (i + 1) as f64).collect();
        let naive = run(
            &matvec_naive(input("A"), input("v")),
            &env,
            &[("A", &a), ("v", &v)],
        )
        .unwrap();
        let blocked = run(&e, &env, &[("A", &a), ("v", &v)]).unwrap();
        assert_eq!(naive, blocked);
    }

    #[test]
    fn mixed_op_temp_reduction() {
        // max over rows of row-sums
        let env = Env::new().with("A", Layout::row_major(&[3, 4]));
        let e = rnz(
            pmax(),
            lam1("r", reduce(add(), var("r"))),
            vec![input("A")],
        );
        let a = vec![1., 2., 3., 4., -10., 0., 0., 0., 2., 2., 2., 2.];
        let out = run(&e, &env, &[("A", &a)]).unwrap();
        assert_eq!(out, vec![10.0]);
    }

    #[test]
    fn threaded_matmul_is_bit_identical_to_serial() {
        let n = 8;
        let env = Env::new()
            .with("A", Layout::row_major(&[n, n]))
            .with("B", Layout::row_major(&[n, n]));
        let prog = lower(&matmul_naive(input("A"), input("B")), &env).unwrap();
        let a: Vec<f64> = (0..n * n).map(|i| (i % 7) as f64 - 3.0).collect();
        let b: Vec<f64> = (0..n * n).map(|i| (i % 5) as f64 * 0.5).collect();
        let mut serial = vec![0.0; prog.out_size];
        execute(&prog, &[&a, &b], &mut serial).unwrap();
        for threads in [2, 3, 8, 64] {
            let mut par = vec![f64::NAN; prog.out_size];
            let rep = execute_threaded(&prog, &[&a, &b], &mut par, threads).unwrap();
            assert_eq!(rep.parallel_loops, 1);
            assert!(!rep.serial_fallback);
            assert!(rep.threads_used >= 2 && rep.threads_used <= threads.min(n));
            assert!(
                serial.iter().zip(&par).all(|(x, y)| x.to_bits() == y.to_bits()),
                "threads={threads}: parallel output differs from serial"
            );
        }
    }

    #[test]
    fn serial_verdict_fails_closed_to_serial_path() {
        // The root map stages a reduction through a shared temp, so the
        // certificate demotes it; a 4-thread request must fall back to the
        // serial path and still produce the serial answer.
        let env = Env::new().with("A", Layout::row_major(&[3, 4]));
        let e = map(
            lam1(
                "r",
                rnz(
                    pmax(),
                    lam1("c", reduce(add(), var("c"))),
                    vec![subdiv(0, 2, var("r"))],
                ),
            ),
            input("A"),
        );
        let prog = lower(&e, &env).unwrap();
        let a: Vec<f64> = (0..12).map(|i| i as f64 - 5.0).collect();
        let mut serial = vec![0.0; prog.out_size];
        execute(&prog, &[&a], &mut serial).unwrap();
        let mut par = vec![f64::NAN; prog.out_size];
        let rep = execute_threaded(&prog, &[&a], &mut par, 4).unwrap();
        assert_eq!(rep.parallel_loops, 0);
        assert!(rep.serial_fallback);
        assert_eq!(rep.threads_used, 1);
        assert_eq!(serial, par);
    }

    #[test]
    fn reduction_root_reports_serial_fallback() {
        let env = Env::new().with("u", Layout::row_major(&[4]));
        let prog = lower(&reduce(add(), input("u")), &env).unwrap();
        let u = [1.0, 2.0, 3.0, 4.0];
        let mut out = vec![0.0];
        let rep = execute_threaded(&prog, &[&u], &mut out, 2).unwrap();
        assert_eq!(out, vec![10.0]);
        assert!(rep.serial_fallback, "red root cannot be chunked");
        let rep1 = execute_threaded(&prog, &[&u], &mut out, 1).unwrap();
        assert!(!rep1.serial_fallback, "serial request is not a fallback");
    }

    #[test]
    fn input_length_validated() {
        let env = Env::new().with("u", Layout::row_major(&[4]));
        let e = reduce(add(), input("u"));
        let prog = lower(&e, &env).unwrap();
        let short = [1.0, 2.0];
        let mut out = vec![0.0];
        assert!(execute(&prog, &[&short], &mut out).is_err());
        let mut wrong_out = vec![0.0, 0.0];
        let full = [1.0, 2.0, 3.0, 4.0];
        assert!(execute(&prog, &[&full], &mut wrong_out).is_err());
    }
}
