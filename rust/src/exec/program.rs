//! The loop-nest IR produced by [`super::lower`] and consumed by
//! [`super::interp`] and [`super::trace`].

use crate::dsl::Prim;

/// Identifies one *view instance* ("track") whose flat offset the
/// interpreter maintains. Every HoF argument position gets its own track,
/// so aliased views of the same buffer advance independently.
pub type TrackId = usize;

/// External input buffer slot.
pub type SlotId = usize;

/// How a loop derives a child track's offset each iteration:
/// `off[dst] = off_at_loop_entry(src) + base + i * stride`.
#[derive(Clone, Debug)]
pub struct Adv {
    pub dst: TrackId,
    /// Parent track whose (stable, outer-loop-owned) offset is the base;
    /// `None` for direct input views (base 0).
    pub src: Option<TrackId>,
    /// Constant extra offset of the view (from slicing/base offsets).
    pub base: usize,
    /// Stride of the consumed (outermost) dimension.
    pub stride: usize,
}

/// A loop-nest node.
#[derive(Clone, Debug)]
pub enum Node {
    /// `nzip`: iterate `extent` times, advancing each argument track by its
    /// stride and the destination cursor by `body_size` elements. Because
    /// the cursor step equals the per-iteration write span (the verifier's
    /// `MapOverlap`/`MapGap` checks pin this), iterations own disjoint
    /// destination chunks — the fact the dependence analysis
    /// ([`crate::verify::ParCert`]) certifies per loop and
    /// [`super::execute_threaded`] consumes.
    MapLoop {
        extent: usize,
        advances: Vec<Adv>,
        body_size: usize,
        body: Box<Node>,
    },
    /// `rnz`: iterate `extent` times combining body results into the
    /// destination region with the associative `op`.
    RedLoop {
        extent: usize,
        advances: Vec<Adv>,
        op: Prim,
        body_size: usize,
        /// Arena slot used when this reduction runs under a *different*
        /// enclosing accumulation operator and needs a private region.
        temp: Option<usize>,
        body: Box<Node>,
    },
    /// Innermost scalar computation writing one element at the destination
    /// cursor.
    Leaf(Kernel),
}

/// Stack bytecode for scalar leaf expressions.
#[derive(Clone, Debug, PartialEq)]
pub enum KernelOp {
    /// Push the scalar at `tracks[i]`'s current offset.
    In(u8),
    /// Push a constant.
    Const(f64),
    /// Pop `arity` operands, push the primitive's result.
    Prim(Prim),
}

/// A compiled scalar leaf: bytecode over a small operand stack, reading the
/// listed tracks.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub ops: Vec<KernelOp>,
    /// Track for each `In(i)` operand.
    pub tracks: Vec<TrackId>,
}

impl Kernel {
    /// Fast-path classification: `a * b` over exactly two inputs.
    pub fn is_mul2(&self) -> bool {
        self.tracks.len() == 2
            && self.ops
                == [
                    KernelOp::In(0),
                    KernelOp::In(1),
                    KernelOp::Prim(Prim::Mul),
                ]
    }

    /// Fast-path classification: a bare copy of one input.
    pub fn is_copy(&self) -> bool {
        self.tracks.len() == 1 && self.ops == [KernelOp::In(0)]
    }

    /// Maximum operand-stack depth (for the interpreter's fixed buffer).
    pub fn max_stack(&self) -> usize {
        let mut depth = 0usize;
        let mut max = 0usize;
        for op in &self.ops {
            match op {
                KernelOp::In(_) | KernelOp::Const(_) => depth += 1,
                KernelOp::Prim(p) => depth = depth + 1 - p.arity(),
            }
            max = max.max(depth);
        }
        max
    }
}

/// How a leaf (or microkernel) writes its result element.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteMode {
    /// `dst = val`
    Set,
    /// `dst = op(dst, val)`
    Acc(Prim),
}

/// A complete lowered program.
#[derive(Clone, Debug)]
pub struct Program {
    pub root: Node,
    /// Input buffer names in slot order.
    pub input_names: Vec<String>,
    /// Buffer slot backing each track.
    pub track_slot: Vec<SlotId>,
    /// Declared length of each input buffer (for validation).
    pub input_lens: Vec<usize>,
    /// Total output elements.
    pub out_size: usize,
    /// Sizes of reduction temp regions.
    pub temp_sizes: Vec<usize>,
}

impl Program {
    pub fn n_tracks(&self) -> usize {
        self.track_slot.len()
    }

    /// Total loop-nest depth (for diagnostics).
    pub fn depth(&self) -> usize {
        fn go(n: &Node) -> usize {
            match n {
                Node::MapLoop { body, .. } | Node::RedLoop { body, .. } => 1 + go(body),
                Node::Leaf(_) => 0,
            }
        }
        go(&self.root)
    }

    /// Sequence of loop kinds from outermost in, e.g. `["map", "map",
    /// "red"]` — the paper's "HoF order from left to right is the nesting
    /// from top down".
    pub fn loop_kinds(&self) -> Vec<&'static str> {
        fn go(n: &Node, out: &mut Vec<&'static str>) {
            match n {
                Node::MapLoop { body, .. } => {
                    out.push("map");
                    go(body, out);
                }
                Node::RedLoop { body, .. } => {
                    out.push("red");
                    go(body, out);
                }
                Node::Leaf(_) => {}
            }
        }
        let mut out = Vec::new();
        go(&self.root, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_classification() {
        let mul2 = Kernel {
            ops: vec![
                KernelOp::In(0),
                KernelOp::In(1),
                KernelOp::Prim(Prim::Mul),
            ],
            tracks: vec![0, 1],
        };
        assert!(mul2.is_mul2());
        assert!(!mul2.is_copy());
        assert_eq!(mul2.max_stack(), 2);

        let copy = Kernel {
            ops: vec![KernelOp::In(0)],
            tracks: vec![3],
        };
        assert!(copy.is_copy());
        assert_eq!(copy.max_stack(), 1);
    }
}
