//! Address-trace generation from the loop IR.
//!
//! Mirrors the generic interpreter's traversal but emits the sequence of
//! memory accesses instead of computing values. The cache simulator
//! ([`crate::cachesim`]) consumes this stream to reproduce the paper's
//! hardware-dependent results on a simulated memory hierarchy.

use super::program::{Adv, Kernel, Node, Program, WriteMode};
use crate::dsl::Prim;
use crate::Result;

/// Which address space an access touches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    Read,
    Write,
}

/// One memory access: an element index within a named space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    pub kind: AccessKind,
    /// 0..n_inputs are input slots; n_inputs is the output; n_inputs+1+t are
    /// reduction temps.
    pub space: usize,
    pub offset: usize,
}

/// Walk the program, invoking `sink` for every scalar read and write in
/// execution order.
pub fn trace(prog: &Program, sink: &mut dyn FnMut(Access)) -> Result<()> {
    let mut ctx = TraceCtx {
        off: vec![0usize; prog.n_tracks()],
        track_slot: &prog.track_slot,
        n_inputs: prog.input_names.len(),
    };
    let out_space = prog.input_names.len();
    go(&prog.root, &mut ctx, out_space, 0, WriteMode::Set, sink);
    Ok(())
}

struct TraceCtx<'a> {
    off: Vec<usize>,
    track_slot: &'a [usize],
    n_inputs: usize,
}

impl<'a> TraceCtx<'a> {
    fn enter(&mut self, advances: &[Adv]) {
        for a in advances {
            self.off[a.dst] = a.src.map(|s| self.off[s]).unwrap_or(0) + a.base;
        }
    }

    fn step(&mut self, advances: &[Adv]) {
        for a in advances {
            self.off[a.dst] += a.stride;
        }
    }
}

fn emit_leaf(
    k: &Kernel,
    ctx: &TraceCtx,
    dst_space: usize,
    dst_off: usize,
    mode: WriteMode,
    sink: &mut dyn FnMut(Access),
) {
    for &t in &k.tracks {
        sink(Access {
            kind: AccessKind::Read,
            space: ctx.track_slot[t],
            offset: ctx.off[t],
        });
    }
    if matches!(mode, WriteMode::Acc(_)) {
        sink(Access {
            kind: AccessKind::Read,
            space: dst_space,
            offset: dst_off,
        });
    }
    sink(Access {
        kind: AccessKind::Write,
        space: dst_space,
        offset: dst_off,
    });
}

fn node_out_size(n: &Node) -> usize {
    match n {
        Node::MapLoop {
            extent, body_size, ..
        } => extent * body_size,
        Node::RedLoop { body_size, .. } => *body_size,
        Node::Leaf(_) => 1,
    }
}

fn go(
    node: &Node,
    ctx: &mut TraceCtx,
    dst_space: usize,
    dst_off: usize,
    mode: WriteMode,
    sink: &mut dyn FnMut(Access),
) {
    match node {
        Node::MapLoop {
            extent,
            advances,
            body_size,
            body,
        } => {
            ctx.enter(advances);
            let mut off = dst_off;
            for _ in 0..*extent {
                go(body, ctx, dst_space, off, mode, sink);
                ctx.step(advances);
                off += body_size;
            }
        }
        Node::RedLoop {
            extent,
            advances,
            op,
            body_size,
            temp,
            body,
        } => {
            let _ = op;
            match (temp, mode) {
                (Some(t), WriteMode::Acc(outer_op)) => {
                    let temp_space = ctx.n_inputs + 1 + t;
                    red_trace(*extent, advances, body, ctx, temp_space, 0, WriteMode::Set, sink);
                    for k in 0..*body_size {
                        sink(Access {
                            kind: AccessKind::Read,
                            space: temp_space,
                            offset: k,
                        });
                        sink(Access {
                            kind: AccessKind::Read,
                            space: dst_space,
                            offset: dst_off + k,
                        });
                        sink(Access {
                            kind: AccessKind::Write,
                            space: dst_space,
                            offset: dst_off + k,
                        });
                        let _ = outer_op;
                    }
                }
                _ => red_trace(*extent, advances, body, ctx, dst_space, dst_off, mode, sink),
            }
        }
        Node::Leaf(k) => emit_leaf(k, ctx, dst_space, dst_off, mode, sink),
    }
}

#[allow(clippy::too_many_arguments)]
fn red_trace(
    extent: usize,
    advances: &[Adv],
    body: &Node,
    ctx: &mut TraceCtx,
    dst_space: usize,
    dst_off: usize,
    mode: WriteMode,
    sink: &mut dyn FnMut(Access),
) {
    ctx.enter(advances);
    if matches!(mode, WriteMode::Set) {
        // identity init of the accumulator region
        for k in 0..node_out_size(body) {
            sink(Access {
                kind: AccessKind::Write,
                space: dst_space,
                offset: dst_off + k,
            });
        }
    }
    let inner = WriteMode::Acc(Prim::Add); // op identity irrelevant for addresses
    for _ in 0..extent {
        go(body, ctx, dst_space, dst_off, inner, sink);
        ctx.step(advances);
    }
}

/// Count total accesses (reads, writes) — a cheap sanity statistic.
pub fn count_accesses(prog: &Program) -> Result<(usize, usize)> {
    let mut reads = 0usize;
    let mut writes = 0usize;
    trace(prog, &mut |a| match a.kind {
        AccessKind::Read => reads += 1,
        AccessKind::Write => writes += 1,
    })?;
    Ok((reads, writes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;
    use crate::exec::lower;
    use crate::layout::Layout;
    use crate::typecheck::Env;

    #[test]
    fn dot_trace_counts() {
        let env = Env::new()
            .with("u", Layout::row_major(&[4]))
            .with("v", Layout::row_major(&[4]));
        let prog = lower(&dot(input("u"), input("v")), &env).unwrap();
        let (reads, writes) = count_accesses(&prog).unwrap();
        // 4 iterations * (2 input reads + 1 acc read) + 1 init write is not
        // modeled for leaf-scalar; generic model: init write + per-iter RMW.
        assert!(reads >= 8, "reads {reads}");
        assert!(writes >= 1, "writes {writes}");
    }

    #[test]
    fn matvec_trace_reads_every_matrix_element_once() {
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 6]))
            .with("v", Layout::row_major(&[6]));
        let prog = lower(&matvec_naive(input("A"), input("v")), &env).unwrap();
        let mut a_reads = vec![0usize; 24];
        trace(&prog, &mut |acc| {
            if acc.kind == AccessKind::Read && acc.space == 0 {
                a_reads[acc.offset] += 1;
            }
        })
        .unwrap();
        assert!(a_reads.iter().all(|&c| c == 1), "{a_reads:?}");
    }

    #[test]
    fn flipped_matvec_trace_is_column_major_on_a() {
        let env = Env::new()
            .with("A", Layout::row_major(&[3, 2]))
            .with("v", Layout::row_major(&[2]));
        let e = rnz(
            lift(add()),
            lam2(
                "c",
                "q",
                map(lam1("e", app2(mul(), var("e"), var("q"))), var("c")),
            ),
            vec![flip(0, input("A")), input("v")],
        );
        let prog = lower(&e, &env).unwrap();
        let mut a_seq = Vec::new();
        trace(&prog, &mut |acc| {
            if acc.kind == AccessKind::Read && acc.space == 0 {
                a_seq.push(acc.offset);
            }
        })
        .unwrap();
        // column-major walk of a row-major 3x2 matrix
        assert_eq!(a_seq, vec![0, 2, 4, 1, 3, 5]);
    }
}
