//! PJRT runtime: load and execute AOT-compiled artifacts from rust.
//!
//! Wraps the `xla` crate's PJRT CPU client. Artifacts are the HLO-*text*
//! modules produced by `python/compile/aot.py` (text, not serialized
//! protos — jax ≥ 0.5 emits 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns them). Compiled executables
//! are cached by artifact path, so the request path never recompiles.
//!
//! This layer plays the paper's *vendor library* role (their Eigen
//! baseline): `matmul_xla_*.hlo.txt` is XLA's own dot, and
//! `matmul_pallas_*.hlo.txt` is our tiled Pallas kernel, both invoked from
//! the rust hot path with Python long gone.
//!
//! The `xla` crate (and its PJRT shared library) is only available behind
//! the **`pjrt` cargo feature**. Without it, this module exposes the same
//! API but [`Runtime::cpu`] returns an error, so every runtime-dependent
//! path (coordinator exec jobs, artifact tests, the `run-artifact` CLI)
//! degrades to a clear "PJRT unavailable" result instead of failing to
//! build on machines without the toolchain.

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
mod backend {
    use crate::{Error, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A loaded-and-compiled artifact ready to execute.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Number of parameters the HLO entry takes (validated on execute).
        pub n_params: usize,
        pub name: String,
    }

    /// The PJRT runtime: one CPU client plus an executable cache.
    ///
    /// Not `Send`: confine to one thread (the coordinator dedicates a
    /// runtime thread and communicates via channels).
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: HashMap<PathBuf, std::rc::Rc<Executable>>,
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PJRT client: {e}")))?;
            Ok(Runtime {
                client,
                cache: HashMap::new(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact, compiling it on first use.
        pub fn load(&mut self, path: &Path) -> Result<std::rc::Rc<Executable>> {
            if let Some(e) = self.cache.get(path) {
                return Ok(e.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {}: {e}", path.display())))?;
            let n_params = count_entry_params(path)?;
            let name = path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            let exec = std::rc::Rc::new(Executable {
                exe,
                n_params,
                name,
            });
            self.cache.insert(path.to_path_buf(), exec.clone());
            Ok(exec)
        }

        /// Number of cached executables.
        pub fn cache_len(&self) -> usize {
            self.cache.len()
        }

        /// Execute with f32 inputs given as `(data, shape)` pairs; returns
        /// the flattened f32 outputs of the (1-tuple) result.
        pub fn run_f32(
            &self,
            exe: &Executable,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<f32>> {
            if exe.n_params != 0 && inputs.len() != exe.n_params {
                return Err(Error::Runtime(format!(
                    "{}: expected {} inputs, got {}",
                    exe.name,
                    exe.n_params,
                    inputs.len()
                )));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let expect: usize = shape.iter().product();
                if expect != data.len() {
                    return Err(Error::Runtime(format!(
                        "input shape {shape:?} does not match {} elements",
                        data.len()
                    )));
                }
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape: {e}")))?;
                literals.push(lit);
            }
            let result = exe
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute: {e}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch: {e}")))?;
            // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
            let out = result
                .to_tuple1()
                .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
            out.to_vec::<f32>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))
        }
    }

    /// Count the parameters of the ENTRY computation in an HLO text file.
    fn count_entry_params(path: &Path) -> Result<usize> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        // The ENTRY computation is printed as its own block; count the
        // parameter instructions between "ENTRY" and the block's closing
        // brace.
        let entry = text.find("ENTRY").unwrap_or(0);
        let block_end = text[entry..]
            .find("\n}")
            .map(|i| entry + i)
            .unwrap_or(text.len());
        Ok(text[entry..block_end]
            .lines()
            .filter(|l| l.contains("parameter("))
            .count())
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use crate::{Error, Result};
    use std::path::Path;

    /// Stand-in for the PJRT executable when the crate is built without
    /// the `pjrt` feature. Never produced: [`Runtime::cpu`] always errors.
    pub struct Executable {
        pub n_params: usize,
        pub name: String,
    }

    enum Void {}

    /// Stand-in runtime: construction always fails with a clear message,
    /// so callers take their "PJRT unavailable" paths. The struct is
    /// uninhabited, which makes the remaining methods trivially total.
    pub struct Runtime {
        void: Void,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Err(Error::Runtime(
                "PJRT runtime unavailable: crate built without the `pjrt` feature \
                 (rebuild with `cargo build --features pjrt`)"
                    .into(),
            ))
        }

        pub fn platform(&self) -> String {
            match self.void {}
        }

        pub fn load(&mut self, _path: &Path) -> Result<std::rc::Rc<Executable>> {
            match self.void {}
        }

        pub fn cache_len(&self) -> usize {
            match self.void {}
        }

        pub fn run_f32(
            &self,
            _exe: &Executable,
            _inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<f32>> {
            match self.void {}
        }
    }
}

pub use backend::{Executable, Runtime};

/// `true` when a PJRT client can be constructed in this build/environment.
/// Tests and benches use this (plus artifact existence) to skip instead of
/// fail on machines without the toolchain.
pub fn pjrt_available() -> bool {
    Runtime::cpu().is_ok()
}

/// Default artifact directory: `$HOFDLA_ARTIFACTS` or `artifacts/` relative
/// to the workspace root.
pub fn artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("HOFDLA_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Walk up from CWD looking for artifacts/ (works from target dirs too).
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Path to a named artifact.
pub fn artifact_path(name: &str) -> PathBuf {
    artifact_dir().join(format!("{name}.hlo.txt"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn have_artifacts() -> bool {
        artifact_path("matmul_xla_256").exists()
    }

    /// Skip helper: PJRT tests need both a client and AOT artifacts.
    fn runtime_or_skip(need_artifacts: bool) -> Option<Runtime> {
        if need_artifacts && !have_artifacts() {
            eprintln!("skipping: no AOT artifacts (run `make artifacts` first)");
            return None;
        }
        match Runtime::cpu() {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: PJRT runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn load_and_run_xla_matmul() {
        let Some(mut rt) = runtime_or_skip(true) else {
            return;
        };
        let exe = rt.load(&artifact_path("matmul_xla_256")).unwrap();
        assert_eq!(exe.n_params, 2);
        let n = 256usize;
        // identity * ones = ones
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b = vec![1f32; n * n];
        let out = rt.run_f32(&exe, &[(&a, &[n, n]), (&b, &[n, n])]).unwrap();
        assert_eq!(out.len(), n * n);
        assert!(out.iter().all(|&x| (x - 1.0).abs() < 1e-6));
        // cache hit on second load
        let _again = rt.load(&artifact_path("matmul_xla_256")).unwrap();
        assert_eq!(rt.cache_len(), 1);
    }

    #[test]
    fn pallas_artifact_matches_xla_artifact() {
        let Some(mut rt) = runtime_or_skip(true) else {
            return;
        };
        let xla_exe = rt.load(&artifact_path("matmul_xla_256")).unwrap();
        let pal_exe = rt.load(&artifact_path("matmul_pallas_256")).unwrap();
        let n = 256usize;
        let mut rng = crate::util::Rng::new(7);
        let a: Vec<f32> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let o1 = rt.run_f32(&xla_exe, &[(&a, &[n, n]), (&b, &[n, n])]).unwrap();
        let o2 = rt.run_f32(&pal_exe, &[(&a, &[n, n]), (&b, &[n, n])]).unwrap();
        let max = o1
            .iter()
            .zip(&o2)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(max < 1e-3, "pallas vs xla diverge: {max}");
    }

    #[test]
    fn input_validation() {
        let Some(mut rt) = runtime_or_skip(true) else {
            return;
        };
        let exe = rt.load(&artifact_path("matmul_xla_256")).unwrap();
        let a = vec![0f32; 4];
        assert!(rt.run_f32(&exe, &[(&a, &[2, 2])]).is_err()); // wrong arity
        assert!(rt.run_f32(&exe, &[(&a, &[3, 3]), (&a, &[2, 2])]).is_err());
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(mut rt) = runtime_or_skip(false) else {
            return;
        };
        assert!(rt.load(Path::new("/nonexistent/zz.hlo.txt")).is_err());
    }
}
