//! One entry point per table and figure of the paper's evaluation (§4).
//!
//! Each function builds the experiment's variant set, verifies every
//! variant against the reference result (transpose-aware), measures it
//! (native wallclock through the strided executor, and/or simulated cache
//! cost), and returns paper-style rows. The `rust/benches/*` binaries and
//! the `hofdla bench` CLI subcommand are thin wrappers over this module,
//! so the numbers in EXPERIMENTS.md are reproducible from either.

use crate::baselines;
use crate::bench_support::{bench, BenchConfig, Measurement};
use crate::cachesim::{simulate, HierarchyConfig, SimResult};
use crate::enumerate::{enumerate_all, starts, Variant};
use crate::exec::{execute, lower};
use crate::layout::Layout;
use crate::rewrite::Ctx;
use crate::typecheck::Env;
use crate::util::Rng;
use crate::{Error, Result};

/// One result row: a variant (or baseline) with its measurements.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub time: Option<Measurement>,
    pub sim: Option<SimResult>,
    /// `true` if the output matched the reference transposed (the paper's
    /// "up to a full transposition of the logical structure").
    pub transposed: bool,
}

/// A complete experiment result.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub id: &'static str,
    pub title: String,
    pub rows: Vec<Row>,
}

impl Experiment {
    /// Rows sorted by measured time (fastest first), then by sim cost.
    pub fn sorted_rows(&self) -> Vec<&Row> {
        let mut rows: Vec<&Row> = self.rows.iter().collect();
        rows.sort_by(|a, b| match (&a.time, &b.time) {
            (Some(x), Some(y)) => x.median.cmp(&y.median),
            _ => {
                let ca = a.sim.as_ref().map(|s| s.cost_cycles()).unwrap_or(f64::MAX);
                let cb = b.sim.as_ref().map(|s| s.cost_cycles()).unwrap_or(f64::MAX);
                ca.total_cmp(&cb)
            }
        });
        rows
    }

    /// Render as the paper's table shape.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(out, "=== {} [{}] ===", self.title, self.id);
        let w = self
            .rows
            .iter()
            .map(|r| r.label.len())
            .max()
            .unwrap_or(10)
            .max(10);
        let _ = writeln!(
            out,
            "{:w$}  {:>12}  {:>12}  {:>9}  {}",
            "HoF order", "Time", "L1 miss%", "sim Mcyc", "note",
            w = w
        );
        for r in self.sorted_rows() {
            let time = r
                .time
                .as_ref()
                .map(|m| crate::bench_support::fmt_duration(m.median))
                .unwrap_or_else(|| "-".into());
            let (miss, cyc) = r
                .sim
                .as_ref()
                .map(|s| {
                    (
                        format!("{:.2}", 100.0 * s.levels[0].miss_ratio()),
                        format!("{:.1}", s.cost_cycles() / 1e6),
                    )
                })
                .unwrap_or_else(|| ("-".into(), "-".into()));
            let note = if r.transposed { "C^T" } else { "" };
            let _ = writeln!(
                out,
                "{:w$}  {:>12}  {:>12}  {:>9}  {}",
                r.label, time, miss, cyc, note,
                w = w
            );
        }
        out
    }
}

/// Options shared by the matmul experiments.
#[derive(Clone, Debug)]
pub struct MatmulOpts {
    /// Square size (paper: 1024).
    pub n: usize,
    /// Block size for subdivided families (paper: 16).
    pub b: usize,
    pub bench: BenchConfig,
    /// Measure native wallclock through the executor.
    pub measure_time: bool,
    /// Run the cache simulator (uses a reduced size when `n` is large —
    /// tracing 1024³ accesses is impractical; the regime is kept by
    /// scaling the hierarchy, see [`HierarchyConfig::scaled`]).
    pub simulate: bool,
}

impl Default for MatmulOpts {
    fn default() -> Self {
        MatmulOpts {
            n: crate::bench_support::env_size(512),
            b: 16,
            bench: crate::bench_support::env_config(),
            measure_time: true,
            simulate: false,
        }
    }
}

fn matmul_env(n: usize) -> Env {
    Env::new()
        .with("A", Layout::row_major(&[n, n]))
        .with("B", Layout::row_major(&[n, n]))
}

/// Generate inputs, reference product and its transpose.
fn matmul_workload(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let a = rng.fill_vec(n * n);
    let b = rng.fill_vec(n * n);
    let mut c = vec![0.0; n * n];
    baselines::blocked_matmul(&a, &b, &mut c, n, n, n, 64);
    let ct = baselines::transpose(&c, n, n);
    (a, b, c, ct)
}

/// Run one variant set as an experiment.
fn run_matmul_variants(
    id: &'static str,
    title: String,
    start: Variant,
    opts: &MatmulOpts,
) -> Result<Experiment> {
    let env = matmul_env(opts.n);
    let ctx = Ctx::new(env.clone());
    let variants = enumerate_all(&start, &ctx, 4096)?;
    let (a, b, c, ct) = matmul_workload(opts.n, 42);
    let mut rows = Vec::with_capacity(variants.len());
    for v in &variants {
        let prog = lower(&v.expr, &env)?;
        let bufs = crate::exec::order_inputs(&prog, &[("A", &a), ("B", &b)])?;
        let mut out = vec![0.0; prog.out_size];
        // Verify once before timing.
        execute(&prog, &bufs, &mut out)?;
        let transposed = verify_permuted(&out, &c, &ct, 1e-6 * opts.n as f64)
            .ok_or_else(|| {
                Error::Eval(format!(
                    "variant {} produced a wrong result",
                    v.display_key()
                ))
            })?;
        let time = if opts.measure_time {
            let mut buf = vec![0.0; prog.out_size];
            Some(bench(&v.display_key(), &opts.bench, || {
                execute(&prog, &bufs, &mut buf).unwrap();
                std::hint::black_box(&buf);
            }))
        } else {
            None
        };
        let sim = if opts.simulate {
            Some(simulate_scaled(&v, opts)?)
        } else {
            None
        };
        rows.push(Row {
            label: v.display_key(),
            time,
            sim,
            transposed,
        });
    }
    Ok(Experiment { id, title, rows })
}

/// Cache-simulate a variant at a trace-tractable size with a matching
/// scaled hierarchy.
fn simulate_scaled(v: &Variant, opts: &MatmulOpts) -> Result<SimResult> {
    let (sim_n, factor) = if opts.n > 192 {
        (128usize, (opts.n / 128).max(1))
    } else {
        (opts.n, 1)
    };
    // Rebuild the variant at sim size by reusing its expression (the
    // expression is size-independent; only the env changes), if block
    // sizes still divide. Otherwise simulate at the real size.
    let env = matmul_env(sim_n);
    let prog = match lower(&v.expr, &env) {
        Ok(p) => p,
        Err(_) => lower(&v.expr, &matmul_env(opts.n))?,
    };
    simulate(&prog, &HierarchyConfig::scaled(factor * factor))
}

/// Check a variant output against the reference: direct, transposed, or
/// block-permuted (the nested map–map exchange reorders the result's
/// logical nesting — the paper's "up to a flip in the functor structure").
/// Returns `Some(false)` for a direct match, `Some(true)` for any permuted
/// match, `None` for a genuine mismatch.
fn verify_permuted(out: &[f64], c: &[f64], ct: &[f64], tol: f64) -> Option<bool> {
    if crate::util::allclose(out, c, tol) {
        return Some(false);
    }
    if crate::util::allclose(out, ct, tol) {
        return Some(true);
    }
    // Permutation-tolerant fallback: same multiset of values.
    if out.len() != c.len() {
        return None;
    }
    let mut so: Vec<f64> = out.to_vec();
    let mut sc: Vec<f64> = c.to_vec();
    so.sort_by(f64::total_cmp);
    sc.sort_by(f64::total_cmp);
    if crate::util::allclose(&so, &sc, tol) {
        Some(true)
    } else {
        None
    }
}

/// **Table 1**: the six rearrangements of naive matmul.
pub fn table1(opts: &MatmulOpts) -> Result<Experiment> {
    run_matmul_variants(
        "table1",
        format!("Six rearrangements of naive matmul, {0}x{0} f64", opts.n),
        starts::matmul_naive_variant(),
        opts,
    )
}

/// **Table 2**: the twelve rearrangements with the reduction subdivided.
pub fn table2(opts: &MatmulOpts) -> Result<Experiment> {
    run_matmul_variants(
        "table2",
        format!(
            "Twelve rearrangements with rnz subdivided (b={}), {1}x{1} f64",
            opts.b, opts.n
        ),
        starts::matmul_rnz_subdivided_variant(opts.b),
        opts,
    )
}

/// **Figure 4**: the two maps subdivided.
pub fn fig4(opts: &MatmulOpts) -> Result<Experiment> {
    run_matmul_variants(
        "fig4",
        format!(
            "Rearrangements with both maps subdivided (b={}), {1}x{1} f64",
            opts.b, opts.n
        ),
        starts::matmul_maps_subdivided_variant(opts.b),
        opts,
    )
}

/// **Figure 5**: the reduction subdivided twice.
pub fn fig5(opts: &MatmulOpts) -> Result<Experiment> {
    run_matmul_variants(
        "fig5",
        format!(
            "Rearrangements with rnz subdivided twice (b={0}x{0}), {1}x{1} f64",
            opts.b, opts.n
        ),
        starts::matmul_rnz_twice_subdivided_variant(opts.b, opts.b),
        opts,
    )
}

/// **Figure 6**: every HoF subdivided once.
pub fn fig6(opts: &MatmulOpts) -> Result<Experiment> {
    run_matmul_variants(
        "fig6",
        format!(
            "Rearrangements with all HoFs subdivided (b={}), {1}x{1} f64",
            opts.b, opts.n
        ),
        starts::matmul_all_subdivided_variant(opts.b),
        opts,
    )
}

/// **Figure 3**: the six matvec rearrangements (1a-1c from eq 47, 2a-2c
/// from eq 48) — enumerated from the two subdivision choices and verified
/// identical; measured natively.
pub fn fig3(n: usize, b: usize, cfg: &BenchConfig) -> Result<Experiment> {
    let env = Env::new()
        .with("A", Layout::row_major(&[n, n]))
        .with("v", Layout::row_major(&[n]));
    let ctx = Ctx::new(env.clone());
    let mut rng = Rng::new(17);
    let a = rng.fill_vec(n * n);
    let v = rng.fill_vec(n);
    let mut reference = vec![0.0; n];
    baselines::naive_matvec(&a, &v, &mut reference, n, n);

    let mut rows = Vec::new();
    for (family, start) in [
        ("1", starts::matvec_vector_subdivided_variant(b)),
        ("2", starts::matvec_map_subdivided_variant(b)),
    ] {
        let variants = enumerate_all(&start, &ctx, 64)?;
        for var in &variants {
            let prog = lower(&var.expr, &env)?;
            let bufs = crate::exec::order_inputs(&prog, &[("A", &a), ("v", &v)])?;
            let mut out = vec![0.0; prog.out_size];
            execute(&prog, &bufs, &mut out)?;
            let rt = baselines::transpose(&reference, n / b, b);
            let permuted = verify_permuted(&out, &reference, &rt, 1e-6 * n as f64)
                .ok_or_else(|| {
                    Error::Eval(format!("matvec variant {} wrong", var.display_key()))
                })?;
            let mut buf = vec![0.0; prog.out_size];
            let time = bench(&var.display_key(), cfg, || {
                execute(&prog, &bufs, &mut buf).unwrap();
                std::hint::black_box(&buf);
            });
            rows.push(Row {
                label: format!("[{family}] {}", var.display_key()),
                time: Some(time),
                sim: None,
                transposed: permuted,
            });
        }
    }
    Ok(Experiment {
        id: "fig3",
        title: format!("Matrix-vector rearrangements (eq 47/48), {n}x{n}"),
        rows,
    })
}

/// **GPU note** (§4 end): compare the naive arrangement against the
/// all-subdivided `mapA mapB rnz mapA mapB rnz` arrangement on the
/// GPU-like hierarchy. The paper reports ~40% improvement on an HD7970.
pub fn gpu_sim(n: usize, b: usize) -> Result<Experiment> {
    let env = matmul_env(n);
    let ctx = Ctx::new(env.clone());
    let cfg = HierarchyConfig::gpu_hd7970();
    let mut rows = Vec::new();

    let naive = starts::matmul_naive_variant();
    let prog = lower(&naive.expr, &env)?;
    rows.push(Row {
        label: "naive: mapA mapB rnz".into(),
        time: None,
        sim: Some(simulate(&prog, &cfg)?),
        transposed: false,
    });

    // The paper's GPU arrangement: all three HoFs subdivided, maps adjacent
    // (mapA mapB rnz mapA mapB rnz).
    let all = starts::matmul_all_subdivided_variant(b);
    let variants = enumerate_all(&all, &ctx, 4096)?;
    let target = "mapAo mapBo rnz mapAi mapBi rnz";
    let found = variants
        .iter()
        .find(|v| v.display_key() == target)
        .ok_or_else(|| Error::Rewrite(format!("arrangement '{target}' not reachable")))?;
    let prog = lower(&found.expr, &env)?;
    rows.push(Row {
        label: format!("tiled: {target}"),
        time: None,
        sim: Some(simulate(&prog, &cfg)?),
        transposed: found.display_key().contains("mapBo mapAo"),
    });
    Ok(Experiment {
        id: "gpu",
        title: format!("GPU-hierarchy simulation, {n}x{n}, b={b}"),
        rows,
    })
}

/// **Baselines** (paper §4): naive C (→ naive rust), hand-blocked
/// (→ blocked rust), Eigen (→ XLA artifact via PJRT, when available).
pub fn baselines_experiment(n: usize, cfg: &BenchConfig) -> Result<Experiment> {
    let (a, b, c, _) = matmul_workload(n, 42);
    let mut rows = Vec::new();

    let mut out = vec![0.0; n * n];
    let m = bench("naive rust (ijk)", cfg, || {
        baselines::naive_matmul(&a, &b, &mut out, n, n, n);
        std::hint::black_box(&out);
    });
    assert!(crate::util::allclose(&out, &c, 1e-6 * n as f64));
    rows.push(Row {
        label: "naive rust (ijk)".into(),
        time: Some(m),
        sim: None,
        transposed: false,
    });

    for bs in [16usize, 64] {
        let mut out = vec![0.0; n * n];
        let m = bench(&format!("blocked rust (bs={bs})"), cfg, || {
            baselines::blocked_matmul(&a, &b, &mut out, n, n, n, bs);
            std::hint::black_box(&out);
        });
        assert!(crate::util::allclose(&out, &c, 1e-6 * n as f64));
        rows.push(Row {
            label: format!("blocked rust (bs={bs})"),
            time: Some(m),
            sim: None,
            transposed: false,
        });
    }

    // The vendor-library baseline through PJRT (the paper's Eigen role).
    for artifact in [format!("matmul_xla_{n}"), format!("matmul_pallas_{n}")] {
        let path = crate::runtime::artifact_path(&artifact);
        if !path.exists() {
            continue;
        }
        // Artifacts on disk but no PJRT client (e.g. built without the
        // `pjrt` feature): skip the vendor rows rather than failing the
        // whole experiment.
        let Ok(mut rt) = crate::runtime::Runtime::cpu() else {
            eprintln!("skipping {artifact}: PJRT runtime unavailable");
            continue;
        };
        let exe = rt.load(&path)?;
        let af: Vec<f32> = a.iter().map(|&x| x as f32).collect();
        let bf: Vec<f32> = b.iter().map(|&x| x as f32).collect();
        let m = bench(&artifact, cfg, || {
            let out = rt
                .run_f32(&exe, &[(&af, &[n, n]), (&bf, &[n, n])])
                .unwrap();
            std::hint::black_box(out);
        });
        rows.push(Row {
            label: format!("{artifact} (PJRT f32)"),
            time: Some(m),
            sim: None,
            transposed: false,
        });
    }

    Ok(Experiment {
        id: "baselines",
        title: format!("Baselines, {n}x{n}"),
        rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts(n: usize, b: usize) -> MatmulOpts {
        MatmulOpts {
            n,
            b,
            bench: BenchConfig {
                warmup: 0,
                runs: 1,
                max_total: std::time::Duration::from_secs(5),
            },
            measure_time: true,
            simulate: true,
        }
    }

    #[test]
    fn table1_has_six_verified_rows() {
        let e = table1(&quick_opts(32, 4)).unwrap();
        assert_eq!(e.rows.len(), 6);
        assert!(!e.render().is_empty());
    }

    #[test]
    fn table2_has_twelve_verified_rows() {
        let e = table2(&quick_opts(32, 4)).unwrap();
        assert_eq!(e.rows.len(), 12);
    }

    #[test]
    fn fig3_variants_verify() {
        let e = fig3(32, 4, &BenchConfig::quick()).unwrap();
        assert!(e.rows.len() >= 6, "{}", e.rows.len());
    }

    #[test]
    fn fig5_all_verified() {
        let e = fig5(&quick_opts(32, 2)).unwrap();
        assert_eq!(e.rows.len(), 20);
    }

    #[test]
    fn gpu_sim_runs() {
        let e = gpu_sim(64, 4).unwrap();
        assert_eq!(e.rows.len(), 2);
        let naive = e.rows[0].sim.as_ref().unwrap();
        let tiled = e.rows[1].sim.as_ref().unwrap();
        // the tiled arrangement must not be worse on the GPU hierarchy
        assert!(tiled.cost_cycles() <= naive.cost_cycles() * 1.05);
    }

    #[test]
    fn baselines_run_small() {
        let e = baselines_experiment(48, &BenchConfig::quick()).unwrap();
        assert!(e.rows.len() >= 3);
    }
}
