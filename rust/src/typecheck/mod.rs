//! Shape/type inference for DSL expressions.
//!
//! Per the paper (§2.1), "all the dimension, shape and layout information is
//! represented at the type level"; here that means every expression is
//! assigned a [`Layout`] (rank 0 = scalar). Functions are not first-class
//! values in checked programs — they only occur in the operator positions of
//! `app` / `nzip` / `rnz` / `lift`, where they are checked structurally
//! against the argument layouts. This is exactly enough to
//!
//! - verify that HoF arguments agree on the consumed (outermost) extent,
//! - verify `subdiv` divisibility and `flip`/`flatten` well-formedness,
//! - track how rewrites change the logical layout (the paper's point that
//!   "exchanging two nested higher order functions must be done with an
//!   appropriate flip in the subdivision structure" is *checked* here),
//! - and signal mistakes in rewrite implementations (types "signal
//!   potential mistakes", §3).

use crate::dsl::intern::{ExprId, Node, SharedArena};
use crate::dsl::Expr;
use crate::layout::Layout;
use crate::{Error, Result};
use std::collections::HashMap;

/// Environment: layouts of named inputs.
#[derive(Clone, Debug, Default)]
pub struct Env {
    pub inputs: HashMap<String, Layout>,
}

impl Env {
    pub fn new() -> Self {
        Env::default()
    }

    pub fn with(mut self, name: &str, layout: Layout) -> Self {
        self.inputs.insert(name.to_string(), layout);
        self
    }
}

/// Infer the layout of `e` under `env`. Errors on any shape mismatch.
pub fn infer(e: &Expr, env: &Env) -> Result<Layout> {
    let mut vars: HashMap<String, Layout> = HashMap::new();
    go(e, env, &mut vars)
}

/// Infer with an initial variable context (used by the rewrite engine when
/// typing subexpressions under binders it has descended through).
pub fn infer_with(e: &Expr, env: &Env, vars: &HashMap<String, Layout>) -> Result<Layout> {
    let mut vars = vars.clone();
    go(e, env, &mut vars)
}

/// Infer the layout of an interned expression directly from the arena —
/// the id-native twin of [`infer`]. The search hot path uses this so no
/// `Box<Expr>` tree is ever rebuilt just to typecheck a candidate; the
/// accept/reject decisions are identical to [`infer`] by construction
/// (`go_id` mirrors `go` case for case).
pub fn infer_id(arena: &SharedArena, id: ExprId, env: &Env) -> Result<Layout> {
    let mut vars: HashMap<String, Layout> = HashMap::new();
    go_id(arena, id, env, &mut vars)
}

/// [`infer_id`] with an initial variable context (the id-native twin of
/// [`infer_with`]; used when typing subexpressions under binders the
/// caller has descended through).
pub fn infer_id_with(
    arena: &SharedArena,
    id: ExprId,
    env: &Env,
    vars: &HashMap<String, Layout>,
) -> Result<Layout> {
    let mut vars = vars.clone();
    go_id(arena, id, env, &mut vars)
}

/// [`infer_id_with`] minus the defensive clone: type `id` against a
/// caller-owned mutable binding map. Inference's own lambda binds restore
/// shadowed entries before returning (`go_id`'s bind/restore discipline),
/// so the map is unchanged on exit — callers that type many
/// subexpressions under one scope (e.g.
/// [`crate::costmodel::spine_lower_bound_id`] on the prune hot path) can
/// reuse a single map instead of cloning per query.
pub fn infer_id_scratch(
    arena: &SharedArena,
    id: ExprId,
    env: &Env,
    vars: &mut HashMap<String, Layout>,
) -> Result<Layout> {
    go_id(arena, id, env, vars)
}

fn go_id(
    arena: &SharedArena,
    id: ExprId,
    env: &Env,
    vars: &mut HashMap<String, Layout>,
) -> Result<Layout> {
    match arena.get(id) {
        Node::Var(x) => vars
            .get(x)
            .cloned()
            .ok_or_else(|| Error::Type(format!("unbound variable '{x}'"))),
        Node::Lit(_) => Ok(Layout::scalar()),
        Node::Prim(_) => Err(Error::Type(
            "primitive used as a value outside operator position".into(),
        )),
        Node::Lam { .. } => Err(Error::Type(
            "lambda used as a value outside operator position".into(),
        )),
        Node::Lift { .. } => Err(Error::Type(
            "lift used as a value outside operator position".into(),
        )),
        Node::Input(n) => env
            .inputs
            .get(n)
            .cloned()
            .ok_or_else(|| Error::Type(format!("unknown input '{n}'"))),
        Node::App { f, args } => {
            let arg_tys = args
                .iter()
                .map(|&a| go_id(arena, a, env, vars))
                .collect::<Result<Vec<_>>>()?;
            apply_id(arena, *f, &arg_tys, env, vars)
        }
        Node::Nzip { f, args } => {
            if args.is_empty() {
                return Err(Error::Type("nzip: needs at least one array".into()));
            }
            let arg_tys = args
                .iter()
                .map(|&a| go_id(arena, a, env, vars))
                .collect::<Result<Vec<_>>>()?;
            let extent = consumed_extent(&arg_tys, "nzip")?;
            let elem_tys: Vec<Layout> = arg_tys
                .iter()
                .map(|t| t.peel_outer())
                .collect::<Result<_>>()?;
            let body_ty = apply_id(arena, *f, &elem_tys, env, vars)?;
            Ok(push_outer(&body_ty, extent))
        }
        Node::Rnz { r, m, args } => {
            if args.is_empty() {
                return Err(Error::Type("rnz: needs at least one array".into()));
            }
            let arg_tys = args
                .iter()
                .map(|&a| go_id(arena, a, env, vars))
                .collect::<Result<Vec<_>>>()?;
            consumed_extent(&arg_tys, "rnz")?;
            let elem_tys: Vec<Layout> = arg_tys
                .iter()
                .map(|t| t.peel_outer())
                .collect::<Result<_>>()?;
            let body_ty = apply_id(arena, *m, &elem_tys, env, vars)?;
            check_reducer_id(arena, *r, &body_ty)?;
            Ok(body_ty)
        }
        Node::Subdiv { d, b, arg } => go_id(arena, *arg, env, vars)?.subdiv(*d, *b),
        Node::Flatten { d, arg } => go_id(arena, *arg, env, vars)?.flatten(*d),
        Node::Flip { d1, d2, arg } => go_id(arena, *arg, env, vars)?.flip2(*d1, *d2),
    }
}

/// Id-native twin of [`apply`].
fn apply_id(
    arena: &SharedArena,
    f: ExprId,
    arg_tys: &[Layout],
    env: &Env,
    vars: &mut HashMap<String, Layout>,
) -> Result<Layout> {
    match arena.get(f) {
        Node::Prim(p) => {
            if arg_tys.len() != p.arity() {
                return Err(Error::Type(format!(
                    "primitive {} expects {} args, got {}",
                    p.name(),
                    p.arity(),
                    arg_tys.len()
                )));
            }
            for (i, t) in arg_tys.iter().enumerate() {
                if !t.is_scalar() {
                    return Err(Error::Type(format!(
                        "primitive {} arg {i} must be scalar, got {t}",
                        p.name()
                    )));
                }
            }
            Ok(Layout::scalar())
        }
        Node::Lam { params, body } => {
            if params.len() != arg_tys.len() {
                return Err(Error::Type(format!(
                    "lambda expects {} args, got {}",
                    params.len(),
                    arg_tys.len()
                )));
            }
            let mut saved = Vec::with_capacity(params.len());
            for (p, t) in params.iter().zip(arg_tys) {
                saved.push((p.clone(), vars.insert(p.clone(), t.clone())));
            }
            let r = go_id(arena, *body, env, vars);
            for (p, old) in saved.into_iter().rev() {
                match old {
                    Some(t) => {
                        vars.insert(p, t);
                    }
                    None => {
                        vars.remove(&p);
                    }
                }
            }
            r
        }
        Node::Lift { f: inner } => {
            let extent = consumed_extent(arg_tys, "lift")?;
            let elem_tys: Vec<Layout> = arg_tys
                .iter()
                .map(|t| t.peel_outer())
                .collect::<Result<_>>()?;
            let body_ty = apply_id(arena, *inner, &elem_tys, env, vars)?;
            Ok(push_outer(&body_ty, extent))
        }
        // Shallow kind name, not pretty-printing: `infer_id` rejections
        // run per candidate on the search hot path and must not extract
        // a `Box<Expr>` tree — `SearchStats` documents arena extraction
        // as an output-boundary-only event.
        other => Err(Error::Type(format!(
            "unsupported function form in operator position: {}",
            other.kind()
        ))),
    }
}

/// Id-native twin of [`check_reducer`].
fn check_reducer_id(arena: &SharedArena, r: ExprId, acc_ty: &Layout) -> Result<()> {
    let mut depth = 0usize;
    let mut cur = r;
    while let Node::Lift { f } = arena.get(cur) {
        depth += 1;
        cur = *f;
    }
    match arena.get(cur) {
        Node::Prim(p) => {
            if p.arity() != 2 {
                return Err(Error::Type(format!(
                    "rnz reduction operator {} must be binary",
                    p.name()
                )));
            }
            if !p.is_associative() {
                return Err(Error::Type(format!(
                    "rnz reduction operator {} must be associative",
                    p.name()
                )));
            }
            if depth != acc_ty.rank() {
                return Err(Error::Type(format!(
                    "rnz reduction operator lift^{depth} {} does not match accumulator rank {} ({acc_ty})",
                    p.name(),
                    acc_ty.rank()
                )));
            }
            Ok(())
        }
        other => Err(Error::Type(format!(
            "unsupported rnz reduction operator: {}",
            other.kind()
        ))),
    }
}

fn go(e: &Expr, env: &Env, vars: &mut HashMap<String, Layout>) -> Result<Layout> {
    match e {
        Expr::Var(x) => vars
            .get(x)
            .cloned()
            .ok_or_else(|| Error::Type(format!("unbound variable '{x}'"))),
        Expr::Lit(_) => Ok(Layout::scalar()),
        Expr::Prim(_) => Err(Error::Type(
            "primitive used as a value outside operator position".into(),
        )),
        Expr::Lam { .. } => Err(Error::Type(
            "lambda used as a value outside operator position".into(),
        )),
        Expr::Lift { .. } => Err(Error::Type(
            "lift used as a value outside operator position".into(),
        )),
        Expr::Input(n) => env
            .inputs
            .get(n)
            .cloned()
            .ok_or_else(|| Error::Type(format!("unknown input '{n}'"))),
        Expr::App { f, args } => {
            let arg_tys = args
                .iter()
                .map(|a| go(a, env, vars))
                .collect::<Result<Vec<_>>>()?;
            apply(f, &arg_tys, env, vars)
        }
        Expr::Nzip { f, args } => {
            if args.is_empty() {
                return Err(Error::Type("nzip: needs at least one array".into()));
            }
            let arg_tys = args
                .iter()
                .map(|a| go(a, env, vars))
                .collect::<Result<Vec<_>>>()?;
            let extent = consumed_extent(&arg_tys, "nzip")?;
            let elem_tys: Vec<Layout> = arg_tys
                .iter()
                .map(|t| t.peel_outer())
                .collect::<Result<_>>()?;
            let body_ty = apply(f, &elem_tys, env, vars)?;
            Ok(push_outer(&body_ty, extent))
        }
        Expr::Rnz { r, m, args } => {
            if args.is_empty() {
                return Err(Error::Type("rnz: needs at least one array".into()));
            }
            let arg_tys = args
                .iter()
                .map(|a| go(a, env, vars))
                .collect::<Result<Vec<_>>>()?;
            consumed_extent(&arg_tys, "rnz")?;
            let elem_tys: Vec<Layout> = arg_tys
                .iter()
                .map(|t| t.peel_outer())
                .collect::<Result<_>>()?;
            let body_ty = apply(m, &elem_tys, env, vars)?;
            // The reduction operator must combine two body_ty values into one.
            check_reducer(r, &body_ty)?;
            Ok(body_ty)
        }
        Expr::Subdiv { d, b, arg } => go(arg, env, vars)?.subdiv(*d, *b),
        Expr::Flatten { d, arg } => go(arg, env, vars)?.flatten(*d),
        Expr::Flip { d1, d2, arg } => go(arg, env, vars)?.flip2(*d1, *d2),
    }
}

/// Check a function expression applied to arguments of the given layouts and
/// compute the result layout.
fn apply(
    f: &Expr,
    arg_tys: &[Layout],
    env: &Env,
    vars: &mut HashMap<String, Layout>,
) -> Result<Layout> {
    match f {
        Expr::Prim(p) => {
            if arg_tys.len() != p.arity() {
                return Err(Error::Type(format!(
                    "primitive {} expects {} args, got {}",
                    p.name(),
                    p.arity(),
                    arg_tys.len()
                )));
            }
            for (i, t) in arg_tys.iter().enumerate() {
                if !t.is_scalar() {
                    return Err(Error::Type(format!(
                        "primitive {} arg {i} must be scalar, got {t}",
                        p.name()
                    )));
                }
            }
            Ok(Layout::scalar())
        }
        Expr::Lam { params, body } => {
            if params.len() != arg_tys.len() {
                return Err(Error::Type(format!(
                    "lambda expects {} args, got {}",
                    params.len(),
                    arg_tys.len()
                )));
            }
            // Bind (shadowing), infer body, restore.
            let mut saved = Vec::with_capacity(params.len());
            for (p, t) in params.iter().zip(arg_tys) {
                saved.push((p.clone(), vars.insert(p.clone(), t.clone())));
            }
            let r = go(body, env, vars);
            for (p, old) in saved.into_iter().rev() {
                match old {
                    Some(t) => {
                        vars.insert(p, t);
                    }
                    None => {
                        vars.remove(&p);
                    }
                }
            }
            r
        }
        Expr::Lift { f: inner } => {
            // lift g applied to arrays: consumes the outer dimension of each
            // argument elementwise.
            let extent = consumed_extent(arg_tys, "lift")?;
            let elem_tys: Vec<Layout> = arg_tys
                .iter()
                .map(|t| t.peel_outer())
                .collect::<Result<_>>()?;
            let body_ty = apply(inner, &elem_tys, env, vars)?;
            Ok(push_outer(&body_ty, extent))
        }
        other => Err(Error::Type(format!(
            "unsupported function form in operator position: {}",
            crate::dsl::pretty(other)
        ))),
    }
}

/// Check that the HoF arguments all expose the same outermost extent; return
/// it.
fn consumed_extent(arg_tys: &[Layout], what: &str) -> Result<usize> {
    let mut extent = None;
    for (i, t) in arg_tys.iter().enumerate() {
        let outer = t
            .outer()
            .ok_or_else(|| Error::Type(format!("{what}: arg {i} is scalar, need rank ≥ 1")))?;
        match extent {
            None => extent = Some(outer.extent),
            Some(e) if e == outer.extent => {}
            Some(e) => {
                return Err(Error::Type(format!(
                    "{what}: outer extent mismatch: arg {i} has {}, expected {e}",
                    outer.extent
                )))
            }
        }
    }
    Ok(extent.unwrap())
}

/// The reduction operator of `rnz` must be `Prim` for scalar accumulators or
/// `lift^k prim` for rank-k array accumulators, with an associative prim
/// (paper: "assumed to be at least associative").
fn check_reducer(r: &Expr, acc_ty: &Layout) -> Result<()> {
    let mut depth = 0usize;
    let mut cur = r;
    while let Expr::Lift { f } = cur {
        depth += 1;
        cur = f;
    }
    match cur {
        Expr::Prim(p) => {
            if p.arity() != 2 {
                return Err(Error::Type(format!(
                    "rnz reduction operator {} must be binary",
                    p.name()
                )));
            }
            if !p.is_associative() {
                return Err(Error::Type(format!(
                    "rnz reduction operator {} must be associative",
                    p.name()
                )));
            }
            if depth != acc_ty.rank() {
                return Err(Error::Type(format!(
                    "rnz reduction operator lift^{depth} {} does not match accumulator rank {} ({acc_ty})",
                    p.name(),
                    acc_ty.rank()
                )));
            }
            Ok(())
        }
        other => Err(Error::Type(format!(
            "unsupported rnz reduction operator: {}",
            crate::dsl::pretty(other)
        ))),
    }
}

/// Result layout of a HoF: the element layout with a fresh dense outer
/// dimension appended (fresh results are stored densely).
fn push_outer(elem: &Layout, extent: usize) -> Layout {
    let mut dims = elem.dims.clone();
    let inner_len: usize = elem.len().max(1);
    dims.push(crate::layout::Dim::new(extent, inner_len));
    Layout { dims }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;

    fn mat_env(n: usize, m: usize) -> Env {
        Env::new()
            .with("A", Layout::row_major(&[n, m]))
            .with("v", Layout::row_major(&[m]))
    }

    #[test]
    fn matvec_types_as_vector() {
        let env = mat_env(4, 6);
        let e = matvec_naive(input("A"), input("v"));
        let t = infer(&e, &env).unwrap();
        assert_eq!(t.rank(), 1);
        assert_eq!(t.dims[0].extent, 4);
    }

    #[test]
    fn matmul_types_as_matrix() {
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 6]))
            .with("B", Layout::row_major(&[6, 8]));
        let e = matmul_naive(input("A"), input("B"));
        let t = infer(&e, &env).unwrap();
        assert_eq!(
            t.dims.iter().map(|d| d.extent).collect::<Vec<_>>(),
            vec![8, 4] // innermost first: 8 columns within each of 4 rows
        );
    }

    #[test]
    fn extent_mismatch_rejected() {
        // dot of length-4 and length-6 vectors
        let env = Env::new()
            .with("u", Layout::row_major(&[4]))
            .with("v", Layout::row_major(&[6]));
        let e = dot(input("u"), input("v"));
        assert!(infer(&e, &env).is_err());
    }

    #[test]
    fn row_of_flipped_matrix_is_column() {
        // map over flip 0 A yields columns with the row stride
        let env = mat_env(4, 6);
        let e = map(lam1("c", var("c")), flip(0, input("A")));
        let t = infer(&e, &env).unwrap();
        // 6 columns, each of 4 elements
        assert_eq!(
            t.dims.iter().map(|d| d.extent).collect::<Vec<_>>(),
            vec![4, 6]
        );
    }

    #[test]
    fn reducer_rank_must_match() {
        let env = mat_env(4, 6);
        // reduce rows of A with scalar +: accumulator is a row (rank 1) → error
        let bad = rnz(add(), lam1("r", var("r")), vec![input("A")]);
        assert!(infer(&bad, &env).is_err());
        // with lift (+) it typechecks
        let good = rnz(lift(add()), lam1("r", var("r")), vec![input("A")]);
        let t = infer(&good, &env).unwrap();
        assert_eq!(t.dims[0].extent, 6);
    }

    #[test]
    fn nonassociative_reducer_rejected() {
        let env = Env::new().with("u", Layout::row_major(&[4]));
        let bad = rnz(sub(), lam1("x", var("x")), vec![input("u")]);
        assert!(infer(&bad, &env).is_err());
    }

    #[test]
    fn subdiv_divisibility_checked_at_expr_level() {
        let env = Env::new().with("u", Layout::row_major(&[10]));
        assert!(infer(&subdiv(0, 2, input("u")), &env).is_ok());
        assert!(infer(&subdiv(0, 3, input("u")), &env).is_err());
    }

    #[test]
    fn unbound_and_unknown_errors() {
        let env = Env::new();
        assert!(infer(&var("x"), &env).is_err());
        assert!(infer(&input("Z"), &env).is_err());
        assert!(matches!(
            infer(&add(), &env),
            Err(Error::Type(_))
        ));
    }

    #[test]
    fn scalar_prims_reject_arrays() {
        let env = Env::new().with("u", Layout::row_major(&[4]));
        let e = app2(add(), input("u"), lit(1.0));
        assert!(infer(&e, &env).is_err());
    }

    #[test]
    fn infer_id_agrees_with_infer() {
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 6]))
            .with("B", Layout::row_major(&[6, 8]))
            .with("v", Layout::row_major(&[6]));
        let arena = SharedArena::new();
        for e in [
            matmul_naive(input("A"), input("B")),
            matvec_naive(input("A"), input("v")),
            subdiv(0, 2, input("v")),
            subdiv(0, 4, input("v")),                          // indivisible
            dot(input("v"), input("A")),                       // extent clash
            rnz(sub(), lam1("x", var("x")), vec![input("v")]), // non-assoc
            map(lam1("c", var("c")), flip(0, input("A"))),
        ] {
            let id = arena.intern(&e);
            match (infer(&e, &env), infer_id(&arena, id, &env)) {
                (Ok(x), Ok(y)) => assert_eq!(x, y, "{}", crate::dsl::pretty(&e)),
                (Err(_), Err(_)) => {}
                (x, y) => panic!("infer/infer_id diverge: {x:?} vs {y:?}"),
            }
        }
    }

    #[test]
    fn subdivided_dot_via_nested_rnz() {
        // 1a form for matvec: map (\r -> rnz (+) (\b c -> dot b c) r' u') A'
        let env = mat_env(4, 8);
        let e = map(
            lam1(
                "r",
                rnz(
                    add(),
                    lam2("b", "c", dot(var("b"), var("c"))),
                    vec![subdiv(0, 2, var("r")), subdiv(0, 2, input("v"))],
                ),
            ),
            input("A"),
        );
        let env = Env::new()
            .with("A", Layout::row_major(&[4, 8]))
            .with("v", Layout::row_major(&[8]));
        let t = infer(&e, &env).unwrap();
        assert_eq!(t.dims[0].extent, 4);
        let _ = env;
    }
}
