//! Product rules (paper eq 30-34): the AoS↔SoA family.
//!
//! The paper extends the HoF calculus with *products of computations*:
//!
//! ```text
//! Array dim layout (a, b) = (Array dim layout a, Array dim layout b)   (eq 30)
//! (map f x, map g y)      = map (f × g) (x, y)                         (eq 31)
//! (map f, map g) x        = map (fanOut f g) x                         (eq 32)
//! (zip f x y, zip g p q)  = zip (f × g) (x, y) (p, q)                  (eq 33)
//! (reduce f x, reduce g y)= reduce (f × g) (x, y)                      (eq 34)
//! ```
//!
//! where `f × g` is the function product (`(***)` in Haskell's
//! `Control.Arrow`) and `fanOut` duplicates one input into both
//! components. These rules fuse *independent parallel traversals* into a
//! single traversal over a structure-of-arrays view.
//!
//! The core AST deliberately has no tuple type (the executor's normal
//! form is product-free — eq 30 is precisely the license to eliminate
//! products before codegen), so this module carries its own small product
//! IR over the scalar DSL, with an evaluator to property-test the rules
//! and an `unzip` pass implementing eq 30 right-to-left.

use crate::dsl::{Expr, Prim};
use crate::eval::{eval, ArrVal, Inputs, Value};
use crate::{Error, Result};

/// A product-level computation: a tuple of ordinary DSL expressions, a
/// HoF over tupled arrays, or a fan-out of one array through several
/// functions.
#[derive(Clone, Debug, PartialEq)]
pub enum PExpr {
    /// `(e1, …, en)` — independent computations (AoS of results).
    Tuple(Vec<Expr>),
    /// `map (f1 × … × fn) (x1, …, xn)` — one traversal applying each
    /// component function to its component array (eq 31/33 RHS; `zip`s
    /// are n-ary tuples of argument lists).
    MapProd {
        fs: Vec<Expr>,
        args: Vec<Vec<Expr>>,
    },
    /// `map (fanOut f1 … fn) x` — one traversal applying every function
    /// to the same element (eq 32 RHS).
    MapFan { fs: Vec<Expr>, arg: Expr },
    /// `reduce (r1 × … × rn) (x1, …, xn)` (eq 34 RHS). Components are
    /// full `rnz`s: (reducer, zipper, args) triples share the traversal.
    RedProd {
        rs: Vec<Expr>,
        ms: Vec<Expr>,
        args: Vec<Vec<Expr>>,
    },
}

/// Evaluate a product expression to a tuple of values.
pub fn peval(p: &PExpr, inputs: &Inputs) -> Result<Vec<Value>> {
    match p {
        PExpr::Tuple(es) => es.iter().map(|e| eval(e, inputs)).collect(),
        // Semantically, the fused forms are one loop; the reference
        // evaluation decomposes them again (that is what the rules assert
        // equality against).
        PExpr::MapProd { fs, args } => fs
            .iter()
            .zip(args)
            .map(|(f, xs)| {
                eval(
                    &Expr::Nzip {
                        f: Box::new(f.clone()),
                        args: xs.clone(),
                    },
                    inputs,
                )
            })
            .collect(),
        PExpr::MapFan { fs, arg } => fs
            .iter()
            .map(|f| {
                eval(
                    &Expr::Nzip {
                        f: Box::new(f.clone()),
                        args: vec![arg.clone()],
                    },
                    inputs,
                )
            })
            .collect(),
        PExpr::RedProd { rs, ms, args } => rs
            .iter()
            .zip(ms)
            .zip(args)
            .map(|((r, m), xs)| {
                eval(
                    &Expr::Rnz {
                        r: Box::new(r.clone()),
                        m: Box::new(m.clone()),
                        args: xs.clone(),
                    },
                    inputs,
                )
            })
            .collect(),
    }
}

/// eq 31/33: `(nzip f xs, nzip g ys, …) → map (f × g × …) ((xs), (ys), …)`.
/// Requires every component to be an `nzip` and all consumed extents to
/// agree (checked at evaluation; structurally we only require the form).
pub fn pair_maps(p: &PExpr) -> Option<PExpr> {
    let PExpr::Tuple(es) = p else { return None };
    if es.len() < 2 {
        return None;
    }
    let mut fs = Vec::with_capacity(es.len());
    let mut args = Vec::with_capacity(es.len());
    for e in es {
        let Expr::Nzip { f, args: xs } = e else {
            return None;
        };
        fs.push((**f).clone());
        args.push(xs.clone());
    }
    Some(PExpr::MapProd { fs, args })
}

/// eq 32: `(map f x, map g x, …) → map (fanOut f g …) x` — all components
/// traverse the *same* array.
pub fn fan_out(p: &PExpr) -> Option<PExpr> {
    let PExpr::Tuple(es) = p else { return None };
    if es.len() < 2 {
        return None;
    }
    let mut fs = Vec::with_capacity(es.len());
    let mut shared: Option<&Expr> = None;
    for e in es {
        let Expr::Nzip { f, args } = e else {
            return None;
        };
        let [x] = args.as_slice() else { return None };
        match shared {
            None => shared = Some(x),
            Some(s) if s == x => {}
            Some(_) => return None,
        }
        fs.push((**f).clone());
    }
    Some(PExpr::MapFan {
        fs,
        arg: shared.unwrap().clone(),
    })
}

/// eq 34: `(rnz r1 m1 xs, rnz r2 m2 ys, …) → reduce (r1 × r2 × …) …`.
pub fn pair_reduces(p: &PExpr) -> Option<PExpr> {
    let PExpr::Tuple(es) = p else { return None };
    if es.len() < 2 {
        return None;
    }
    let mut rs = Vec::new();
    let mut ms = Vec::new();
    let mut args = Vec::new();
    for e in es {
        let Expr::Rnz { r, m, args: xs } = e else {
            return None;
        };
        rs.push((**r).clone());
        ms.push((**m).clone());
        args.push(xs.clone());
    }
    Some(PExpr::RedProd { rs, ms, args })
}

/// eq 30, right to left (SoA): an array-of-structs input, presented as one
/// interleaved buffer of `n`-field records, is reinterpreted as `n`
/// strided component views — `subdiv`-style layout bookkeeping with no
/// data movement. Returns one [`ArrVal`] per field.
pub fn unzip_aos(buf: &ArrVal, n_fields: usize) -> Result<Vec<ArrVal>> {
    let layout = &buf.view.layout;
    if layout.rank() != 1 {
        return Err(Error::Layout("unzip_aos: rank-1 AoS expected".into()));
    }
    let d = layout.dims[0];
    if d.extent % n_fields != 0 {
        return Err(Error::Layout(format!(
            "unzip_aos: {} elements not divisible into {n_fields} fields",
            d.extent
        )));
    }
    // (records, fields) view: field k = every n_fields-th element.
    let records = d.extent / n_fields;
    (0..n_fields)
        .map(|k| {
            Ok(ArrVal {
                data: buf.data.clone(),
                view: crate::layout::View::new(
                    buf.view.offset + k * d.stride,
                    crate::layout::Layout::from_pairs(&[(records, n_fields * d.stride)]),
                ),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;
    use crate::util::Rng;

    fn inputs() -> Inputs {
        let mut rng = Rng::new(31);
        let mut m = Inputs::new();
        m.insert("x".into(), ArrVal::dense(rng.fill_vec(8), &[8]));
        m.insert("y".into(), ArrVal::dense(rng.fill_vec(8), &[8]));
        m.insert("p".into(), ArrVal::dense(rng.fill_vec(8), &[8]));
        m.insert("q".into(), ArrVal::dense(rng.fill_vec(8), &[8]));
        m
    }

    fn assert_peval_eq(a: &PExpr, b: &PExpr, inp: &Inputs) {
        let va = peval(a, inp).unwrap();
        let vb = peval(b, inp).unwrap();
        assert_eq!(va.len(), vb.len());
        for (x, y) in va.iter().zip(&vb) {
            assert!(
                crate::util::allclose(&x.to_dense(), &y.to_dense(), 1e-12),
                "component mismatch"
            );
        }
    }

    #[test]
    fn eq31_pair_of_maps_fuses() {
        let lhs = PExpr::Tuple(vec![
            map(lam1("a", app2(mul(), var("a"), lit(2.0))), input("x")),
            map(lam1("b", app2(add(), var("b"), lit(1.0))), input("y")),
        ]);
        let rhs = pair_maps(&lhs).expect("eq 31 applies");
        assert!(matches!(rhs, PExpr::MapProd { .. }));
        assert_peval_eq(&lhs, &rhs, &inputs());
    }

    #[test]
    fn eq33_pair_of_zips_fuses() {
        let lhs = PExpr::Tuple(vec![
            zip(mul(), input("x"), input("y")),
            zip(add(), input("p"), input("q")),
        ]);
        let rhs = pair_maps(&lhs).expect("eq 33 applies");
        assert_peval_eq(&lhs, &rhs, &inputs());
    }

    #[test]
    fn eq32_fanout_requires_shared_argument() {
        let shared = PExpr::Tuple(vec![
            map(lam1("a", app2(mul(), var("a"), var("a"))), input("x")),
            map(lam1("a", app1(Expr::Prim(Prim::Neg), var("a"))), input("x")),
        ]);
        let rhs = fan_out(&shared).expect("eq 32 applies");
        assert!(matches!(rhs, PExpr::MapFan { .. }));
        assert_peval_eq(&shared, &rhs, &inputs());

        let not_shared = PExpr::Tuple(vec![
            map(lam1("a", var("a")), input("x")),
            map(lam1("a", var("a")), input("y")),
        ]);
        assert!(fan_out(&not_shared).is_none());
    }

    #[test]
    fn eq34_pair_of_reduces_fuses() {
        let lhs = PExpr::Tuple(vec![
            dot(input("x"), input("y")),
            reduce(pmax(), input("p")),
        ]);
        let rhs = pair_reduces(&lhs).expect("eq 34 applies");
        assert_peval_eq(&lhs, &rhs, &inputs());
    }

    #[test]
    fn rules_reject_mixed_forms() {
        let mixed = PExpr::Tuple(vec![
            map(lam1("a", var("a")), input("x")),
            dot(input("p"), input("q")),
        ]);
        assert!(pair_maps(&mixed).is_none());
        assert!(pair_reduces(&mixed).is_none());
    }

    #[test]
    fn eq30_unzip_aos_is_a_strided_view() {
        // interleaved (a0,b0,a1,b1,...) record buffer
        let data: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let aos = ArrVal::dense(data, &[16]);
        let fields = unzip_aos(&aos, 2).unwrap();
        assert_eq!(fields[0].to_dense(), vec![0., 2., 4., 6., 8., 10., 12., 14.]);
        assert_eq!(fields[1].to_dense(), vec![1., 3., 5., 7., 9., 11., 13., 15.]);
        // no copy: same backing buffer
        assert!(std::rc::Rc::ptr_eq(&fields[0].data, &aos.data));
        // and the SoA views compose with ordinary HoFs:
        let mut inp = Inputs::new();
        inp.insert("a".into(), fields[0].clone());
        inp.insert("b".into(), fields[1].clone());
        let s = eval(&dot(input("a"), input("b")), &inp)
            .unwrap()
            .as_scalar()
            .unwrap();
        let expect: f64 = (0..8).map(|i| (2 * i) as f64 * (2 * i + 1) as f64).sum();
        assert_eq!(s, expect);
    }

    #[test]
    fn unzip_rejects_bad_shapes() {
        let aos = ArrVal::dense(vec![1., 2., 3.], &[3]);
        assert!(unzip_aos(&aos, 2).is_err());
        let mat = ArrVal::dense(vec![0.0; 6], &[2, 3]);
        assert!(unzip_aos(&mat, 2).is_err());
    }
}
