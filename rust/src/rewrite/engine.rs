//! Generic traversal machinery: one-level child maps (the catamorphism
//! workhorse the paper implements with recursion schemes), first-match
//! application, and bottom-up fixpoint rewriting.

use crate::dsl::Expr;

/// A context-free rewrite rule: returns `Some(new)` when the pattern
/// matches at the given node.
#[derive(Clone, Copy)]
pub struct Rule {
    pub name: &'static str,
    pub apply: fn(&Expr) -> Option<Expr>,
}

impl std::fmt::Debug for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Rule({})", self.name)
    }
}

/// Rebuild a node with each direct child transformed by `f`.
pub fn map_children(e: &Expr, mut f: impl FnMut(&Expr) -> Expr) -> Expr {
    match e {
        Expr::Var(_) | Expr::Lit(_) | Expr::Prim(_) | Expr::Input(_) => e.clone(),
        Expr::Lam { params, body } => Expr::Lam {
            params: params.clone(),
            body: Box::new(f(body)),
        },
        Expr::App { f: g, args } => Expr::App {
            f: Box::new(f(g)),
            args: args.iter().map(&mut f).collect(),
        },
        Expr::Nzip { f: g, args } => Expr::Nzip {
            f: Box::new(f(g)),
            args: args.iter().map(&mut f).collect(),
        },
        Expr::Rnz { r, m, args } => Expr::Rnz {
            r: Box::new(f(r)),
            m: Box::new(f(m)),
            args: args.iter().map(&mut f).collect(),
        },
        Expr::Lift { f: g } => Expr::Lift { f: Box::new(f(g)) },
        Expr::Subdiv { d, b, arg } => Expr::Subdiv {
            d: *d,
            b: *b,
            arg: Box::new(f(arg)),
        },
        Expr::Flatten { d, arg } => Expr::Flatten {
            d: *d,
            arg: Box::new(f(arg)),
        },
        Expr::Flip { d1, d2, arg } => Expr::Flip {
            d1: *d1,
            d2: *d2,
            arg: Box::new(f(arg)),
        },
    }
}

/// Apply `rule` at the first matching node in pre-order; `None` if no node
/// matches.
pub fn rewrite_once(rule: &Rule, e: &Expr) -> Option<Expr> {
    if let Some(new) = (rule.apply)(e) {
        return Some(new);
    }
    // Try children left-to-right; rebuild on the first success.
    let mut done = false;
    let new = map_children(e, |c| {
        if done {
            return c.clone();
        }
        match rewrite_once(rule, c) {
            Some(n) => {
                done = true;
                n
            }
            None => c.clone(),
        }
    });
    if done {
        Some(new)
    } else {
        None
    }
}

/// Exhaustively apply a rule set bottom-up until fixpoint. A step budget
/// guards against non-terminating rule sets.
pub fn rewrite_bottom_up(rules: &[Rule], e: &Expr) -> Expr {
    const MAX_STEPS: usize = 100_000;
    let steps = 0usize;
    fn pass(rules: &[Rule], e: &Expr, steps: &mut usize) -> (Expr, bool) {
        let mut changed = false;
        // children first
        let mut cur = map_children(e, |c| {
            let (n, ch) = pass(rules, c, steps);
            changed |= ch;
            n
        });
        // then this node, repeatedly
        'outer: loop {
            if *steps >= MAX_STEPS {
                break;
            }
            for r in rules {
                if let Some(n) = (r.apply)(&cur) {
                    *steps += 1;
                    changed = true;
                    // The rewrite may expose new redexes in children.
                    let (n2, _) = pass(rules, &n, steps);
                    cur = n2;
                    continue 'outer;
                }
            }
            break;
        }
        (cur, changed)
    }
    let mut steps_taken = steps;
    let (out, _) = pass(rules, e, &mut steps_taken);
    out
}

/// The standard cleanup set: β-reduction, η-reduction, layout-op
/// simplification. Run after structural rewrites to keep expressions in
/// normal form.
pub fn normalize(e: &Expr) -> Expr {
    let rules = [
        super::lambda::beta(),
        super::lambda::eta(),
        super::simplify::flip_flip(),
        super::simplify::flatten_subdiv(),
        super::simplify::subdiv_trivial(),
    ];
    rewrite_bottom_up(&rules, e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;

    #[test]
    fn map_children_rebuilds() {
        let e = map(lam1("x", var("x")), input("v"));
        let out = map_children(&e, |c| c.clone());
        assert_eq!(out, e);
    }

    #[test]
    fn rewrite_once_finds_nested_match() {
        // rule: replace literal 1.0 with 2.0
        let rule = Rule {
            name: "one-to-two",
            apply: |e| match e {
                Expr::Lit(x) if *x == 1.0 => Some(Expr::Lit(2.0)),
                _ => None,
            },
        };
        let e = map(lam1("x", app2(mul(), var("x"), lit(1.0))), input("v"));
        let out = rewrite_once(&rule, &e).unwrap();
        assert_eq!(
            out,
            map(lam1("x", app2(mul(), var("x"), lit(2.0))), input("v"))
        );
        assert!(rewrite_once(&rule, &out).is_none());
    }

    #[test]
    fn bottom_up_fixpoint_terminates() {
        let rule = Rule {
            name: "dec",
            apply: |e| match e {
                Expr::Lit(x) if *x > 0.0 => Some(Expr::Lit(x - 1.0)),
                _ => None,
            },
        };
        let out = rewrite_bottom_up(&[rule], &lit(5.0));
        assert_eq!(out, lit(0.0));
    }
}
