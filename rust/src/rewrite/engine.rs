//! Generic traversal machinery: one-level child maps (the catamorphism
//! workhorse the paper implements with recursion schemes), first-match
//! application, bottom-up fixpoint rewriting, and a memoized variant built
//! on the hash-consing arena of [`crate::dsl::intern`] so shared subtrees
//! are never re-normalized.

use crate::dsl::intern::{memo_enabled, ExprArena, ExprId, SharedArena};
use crate::dsl::Expr;
use std::cell::RefCell;
use std::collections::HashMap;

/// Global rewrite-step budget: guards against non-terminating rule sets.
/// Accounted once per [`rewrite_bottom_up`] / [`MemoRewriter::rewrite`] /
/// [`IdRewriter::rewrite`] call, shared across every re-pass that call
/// performs. A memoized run that exhausts the budget drops its memo
/// tables, since partially-rewritten forms must not be remembered as
/// final.
pub const MAX_STEPS: usize = 100_000;

/// A context-free rewrite rule: returns `Some(new)` when the pattern
/// matches at the given node.
#[derive(Clone, Copy)]
pub struct Rule {
    pub name: &'static str,
    pub apply: fn(&Expr) -> Option<Expr>,
}

impl std::fmt::Debug for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Rule({})", self.name)
    }
}

/// Rebuild a node with each direct child transformed by `f`.
pub fn map_children(e: &Expr, mut f: impl FnMut(&Expr) -> Expr) -> Expr {
    match e {
        Expr::Var(_) | Expr::Lit(_) | Expr::Prim(_) | Expr::Input(_) => e.clone(),
        Expr::Lam { params, body } => Expr::Lam {
            params: params.clone(),
            body: Box::new(f(body)),
        },
        Expr::App { f: g, args } => Expr::App {
            f: Box::new(f(g)),
            args: args.iter().map(&mut f).collect(),
        },
        Expr::Nzip { f: g, args } => Expr::Nzip {
            f: Box::new(f(g)),
            args: args.iter().map(&mut f).collect(),
        },
        Expr::Rnz { r, m, args } => Expr::Rnz {
            r: Box::new(f(r)),
            m: Box::new(f(m)),
            args: args.iter().map(&mut f).collect(),
        },
        Expr::Lift { f: g } => Expr::Lift { f: Box::new(f(g)) },
        Expr::Subdiv { d, b, arg } => Expr::Subdiv {
            d: *d,
            b: *b,
            arg: Box::new(f(arg)),
        },
        Expr::Flatten { d, arg } => Expr::Flatten {
            d: *d,
            arg: Box::new(f(arg)),
        },
        Expr::Flip { d1, d2, arg } => Expr::Flip {
            d1: *d1,
            d2: *d2,
            arg: Box::new(f(arg)),
        },
    }
}

/// Replace the first child (pre-order, left-to-right) whose subtree
/// rewrites; siblings are cloned only when a rewrite actually lands.
fn rewrite_once_args(rule: &Rule, args: &[Expr]) -> Option<Vec<Expr>> {
    for (i, a) in args.iter().enumerate() {
        if let Some(na) = rewrite_once(rule, a) {
            let mut out = args.to_vec();
            out[i] = na;
            return Some(out);
        }
    }
    None
}

/// Apply `rule` at the first matching node in pre-order; `None` if no node
/// matches. Nothing is cloned or rebuilt unless a match lands, and then
/// only the spine from the root to the match (plus one clone of each
/// untouched sibling along it).
pub fn rewrite_once(rule: &Rule, e: &Expr) -> Option<Expr> {
    if let Some(new) = (rule.apply)(e) {
        return Some(new);
    }
    match e {
        Expr::Var(_) | Expr::Lit(_) | Expr::Prim(_) | Expr::Input(_) => None,
        Expr::Lam { params, body } => rewrite_once(rule, body).map(|nb| Expr::Lam {
            params: params.clone(),
            body: Box::new(nb),
        }),
        Expr::App { f, args } => {
            if let Some(nf) = rewrite_once(rule, f) {
                return Some(Expr::App {
                    f: Box::new(nf),
                    args: args.clone(),
                });
            }
            rewrite_once_args(rule, args).map(|na| Expr::App {
                f: f.clone(),
                args: na,
            })
        }
        Expr::Nzip { f, args } => {
            if let Some(nf) = rewrite_once(rule, f) {
                return Some(Expr::Nzip {
                    f: Box::new(nf),
                    args: args.clone(),
                });
            }
            rewrite_once_args(rule, args).map(|na| Expr::Nzip {
                f: f.clone(),
                args: na,
            })
        }
        Expr::Rnz { r, m, args } => {
            if let Some(nr) = rewrite_once(rule, r) {
                return Some(Expr::Rnz {
                    r: Box::new(nr),
                    m: m.clone(),
                    args: args.clone(),
                });
            }
            if let Some(nm) = rewrite_once(rule, m) {
                return Some(Expr::Rnz {
                    r: r.clone(),
                    m: Box::new(nm),
                    args: args.clone(),
                });
            }
            rewrite_once_args(rule, args).map(|na| Expr::Rnz {
                r: r.clone(),
                m: m.clone(),
                args: na,
            })
        }
        Expr::Lift { f } => rewrite_once(rule, f).map(|nf| Expr::Lift { f: Box::new(nf) }),
        Expr::Subdiv { d, b, arg } => rewrite_once(rule, arg).map(|na| Expr::Subdiv {
            d: *d,
            b: *b,
            arg: Box::new(na),
        }),
        Expr::Flatten { d, arg } => rewrite_once(rule, arg).map(|na| Expr::Flatten {
            d: *d,
            arg: Box::new(na),
        }),
        Expr::Flip { d1, d2, arg } => rewrite_once(rule, arg).map(|na| Expr::Flip {
            d1: *d1,
            d2: *d2,
            arg: Box::new(na),
        }),
    }
}

/// One bottom-up pass to a subtree fixpoint: children first, then rules at
/// this node; when a rule fires, loop — the next iteration re-passes the
/// rewritten node's children (reducing any newly exposed redexes) before
/// retrying rules at the root. Returns whether anything changed, so the
/// caller can iterate to a global fixpoint.
///
/// Iterating (rather than recursing) per fired rule keeps the recursion
/// depth bounded by the tree height, so the [`MAX_STEPS`] budget — not the
/// stack — is what stops a non-terminating rule set.
fn pass(rules: &[Rule], e: &Expr, steps: &mut usize) -> (Expr, bool) {
    let mut changed = false;
    // Children first (recursion depth = tree height).
    let mut cur = map_children(e, |c| {
        let (n, ch) = pass(rules, c, steps);
        changed |= ch;
        n
    });
    loop {
        // Rules at this node.
        let mut fired = false;
        if *steps < MAX_STEPS {
            for r in rules {
                if let Some(n) = (r.apply)(&cur) {
                    *steps += 1;
                    changed = true;
                    fired = true;
                    cur = n;
                    break;
                }
            }
        }
        if !fired {
            break;
        }
        // The fire may have exposed redexes in the new node's children;
        // re-pass them before retrying rules at the root.
        cur = map_children(&cur, |c| {
            let (n, ch) = pass(rules, c, steps);
            changed |= ch;
            n
        });
    }
    (cur, changed)
}

/// Exhaustively apply a rule set bottom-up until fixpoint. A single step
/// budget ([`MAX_STEPS`]) is accounted globally across all passes and
/// re-passes, guarding against non-terminating rule sets.
pub fn rewrite_bottom_up(rules: &[Rule], e: &Expr) -> Expr {
    let mut steps = 0usize;
    let (mut cur, mut changed) = pass(rules, e, &mut steps);
    while changed && steps < MAX_STEPS {
        let (next, ch) = pass(rules, &cur, &mut steps);
        cur = next;
        changed = ch;
    }
    cur
}

/// When a long-lived rewriter arena outgrows this many distinct nodes it
/// is dropped and rebuilt, bounding worker memory.
pub(crate) const ARENA_RESET_NODES: usize = 1 << 20;

/// A bottom-up rewriter for one fixed rule set with a memo table keyed by
/// interned [`ExprId`]: a shared subtree is normalized at most once, no
/// matter how many expressions (or repeated calls) contain it.
///
/// Equivalent to [`rewrite_bottom_up`] up to the alpha-renaming introduced
/// by rules that invent fresh binders — memoized results reuse the names
/// chosen the first time a subtree was rewritten.
pub struct MemoRewriter {
    rules: Vec<Rule>,
    arena: ExprArena,
    memo: HashMap<ExprId, ExprId>,
    steps: usize,
}

impl MemoRewriter {
    pub fn new(rules: &[Rule]) -> Self {
        MemoRewriter {
            rules: rules.to_vec(),
            arena: ExprArena::new(),
            memo: HashMap::new(),
            steps: 0,
        }
    }

    /// Distinct nodes currently interned (diagnostics / tests).
    pub fn arena_len(&self) -> usize {
        self.arena.len()
    }

    /// Memoized subtrees currently known (diagnostics / tests).
    pub fn memo_len(&self) -> usize {
        self.memo.len()
    }

    fn reset(&mut self) {
        self.arena = ExprArena::new();
        self.memo.clear();
    }

    /// Rewrite `e` to fixpoint under this rewriter's rule set, reusing
    /// memoized results for every shared subtree.
    pub fn rewrite(&mut self, e: &Expr) -> Expr {
        if self.arena.len() > ARENA_RESET_NODES {
            self.reset();
        }
        self.steps = 0;
        let id = self.arena.intern(e);
        let out = self.rewrite_id(id);
        let result = self.arena.extract(out);
        if self.steps >= MAX_STEPS {
            // Budget exhausted: partially-rewritten forms may have been
            // memoized as if final. Drop the tables so the truncation only
            // affects this call (matching the unmemoized engine).
            self.reset();
        }
        result
    }

    fn rewrite_id(&mut self, id: ExprId) -> ExprId {
        if let Some(&r) = self.memo.get(&id) {
            return r;
        }
        let mut cur = id;
        // Same strategy as `pass`: children first, rules at the node, and
        // on a fire loop back so the rewritten node's children (where new
        // redexes can appear) are reduced — memoized, so re-visiting an
        // already-normal child is an O(1) table hit. Iterating per fired
        // rule keeps recursion depth bounded by tree height.
        loop {
            if let Some(&r) = self.memo.get(&cur) {
                cur = r;
                break;
            }
            let rebuilt = self
                .arena
                .get(cur)
                .clone()
                .map_children(|c| self.rewrite_id(c));
            cur = self.arena.insert(rebuilt);
            if let Some(&r) = self.memo.get(&cur) {
                cur = r;
                break;
            }
            let expr = self.arena.extract(cur);
            let mut fired = None;
            if self.steps < MAX_STEPS {
                for r in &self.rules {
                    if let Some(n) = (r.apply)(&expr) {
                        fired = Some(n);
                        break;
                    }
                }
            }
            match fired {
                Some(n) => {
                    self.steps += 1;
                    cur = self.arena.intern(&n);
                }
                None => break,
            }
        }
        self.memo.insert(id, cur);
        self.memo.insert(cur, cur);
        cur
    }
}

/// An id-native rewrite rule: matches and rebuilds directly against
/// [`SharedArena`] nodes, so applying it allocates nothing and never
/// round-trips through `Box<Expr>`. The id-native twin of [`Rule`]; every
/// rule on the search hot path has both forms, and the differential tests
/// hold them equivalent. The arena comes in by shared reference — interning
/// is interior-mutable — so one arena can serve every search shard at once.
#[derive(Clone, Copy)]
pub struct IdRule {
    pub name: &'static str,
    pub apply: fn(&SharedArena, ExprId) -> Option<ExprId>,
}

impl std::fmt::Debug for IdRule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "IdRule({})", self.name)
    }
}

/// A memoized bottom-up rewriter for one fixed [`IdRule`] set that runs
/// *entirely* on interned ids: unlike [`MemoRewriter`] (which extracts a
/// `Box<Expr>` at every node to apply its `fn(&Expr)` rules), no tree is
/// ever rebuilt between rule applications. The caller owns the arena and
/// must pass the *same* [`SharedArena`] on every call — the memo table is
/// keyed by that arena's ids; call [`IdRewriter::clear`] when swapping
/// arenas. The memo itself stays single-threaded (each search shard owns
/// one rewriter) while all of them resolve against the one shared arena.
///
/// The strategy mirrors [`rewrite_bottom_up`] / [`MemoRewriter`] exactly
/// (children first, first-match rules at the node, re-pass children after
/// a fire, global [`MAX_STEPS`] budget), so results agree with the
/// `Box<Expr>` path up to the alpha-renaming of fresh-binder rules.
pub struct IdRewriter {
    rules: Vec<IdRule>,
    memo: HashMap<ExprId, ExprId>,
    steps: usize,
}

impl IdRewriter {
    pub fn new(rules: &[IdRule]) -> Self {
        IdRewriter {
            rules: rules.to_vec(),
            memo: HashMap::new(),
            steps: 0,
        }
    }

    /// Memoized subtrees currently known (diagnostics / tests).
    pub fn memo_len(&self) -> usize {
        self.memo.len()
    }

    /// Forget all memoized results. Must be called when the caller swaps
    /// in a different (or rebuilt) arena.
    pub fn clear(&mut self) {
        self.memo.clear();
    }

    /// Rewrite `id` to fixpoint under this rewriter's rule set within
    /// `arena`, reusing memoized results for every shared subtree.
    pub fn rewrite(&mut self, arena: &SharedArena, id: ExprId) -> ExprId {
        self.steps = 0;
        let out = self.rewrite_id(arena, id);
        if self.steps >= MAX_STEPS {
            // Budget exhausted: partially-rewritten forms may have been
            // memoized as if final. Drop the memo so the truncation only
            // affects this call (matching the unmemoized engine).
            self.memo.clear();
        }
        out
    }

    fn rewrite_id(&mut self, arena: &SharedArena, id: ExprId) -> ExprId {
        if let Some(&r) = self.memo.get(&id) {
            return r;
        }
        let mut cur = id;
        // Same strategy as `pass`/`MemoRewriter::rewrite_id`: children
        // first, rules at the node, and on a fire loop back so the
        // rewritten node's children are reduced before retrying rules at
        // the root. Recursion depth stays bounded by tree height.
        loop {
            if let Some(&r) = self.memo.get(&cur) {
                cur = r;
                break;
            }
            let rebuilt = arena
                .get(cur)
                .clone()
                .map_children(|c| self.rewrite_id(arena, c));
            cur = arena.insert(rebuilt);
            if let Some(&r) = self.memo.get(&cur) {
                cur = r;
                break;
            }
            let mut fired = None;
            if self.steps < MAX_STEPS {
                for r in &self.rules {
                    if let Some(n) = (r.apply)(arena, cur) {
                        fired = Some(n);
                        break;
                    }
                }
            }
            match fired {
                Some(n) => {
                    self.steps += 1;
                    cur = n;
                }
                None => break,
            }
        }
        self.memo.insert(id, cur);
        self.memo.insert(cur, cur);
        cur
    }
}

fn normalize_rules() -> [Rule; 5] {
    [
        super::lambda::beta(),
        super::lambda::eta(),
        super::simplify::flip_flip(),
        super::simplify::flatten_subdiv(),
        super::simplify::subdiv_trivial(),
    ]
}

/// The id-native normalize rule set — same rules, same order, as
/// [`normalize_uncached`]'s `Box<Expr>` set. Public so the enumeration
/// search can run normalization (per-shard memo, shared arena) itself.
pub fn normalize_id_rules() -> [IdRule; 5] {
    [
        super::lambda::beta_id(),
        super::lambda::eta_id(),
        super::simplify::flip_flip_id(),
        super::simplify::flatten_subdiv_id(),
        super::simplify::flip_same_dim_id(),
    ]
}

thread_local! {
    static NORMALIZE_ID: RefCell<(SharedArena, IdRewriter)> =
        RefCell::new((SharedArena::new(), IdRewriter::new(&normalize_id_rules())));
}

/// Run a thread-local `(arena, rewriter)` pair over one expression:
/// reset when the arena outgrows its budget, intern, rewrite on ids,
/// extract at the boundary. Shared by [`normalize`] and
/// [`super::fusion::fuse`]. (The arena here is a [`SharedArena`] used
/// from one thread — the id-native engine has a single arena type.)
pub(crate) fn rewrite_interned(cell: &RefCell<(SharedArena, IdRewriter)>, e: &Expr) -> Expr {
    let mut guard = cell.borrow_mut();
    let (arena, rw) = &mut *guard;
    if arena.len() > ARENA_RESET_NODES {
        *arena = SharedArena::new();
        rw.clear();
    }
    let id = arena.intern(e);
    let out = rw.rewrite(arena, id);
    arena.extract(out)
}

/// The standard cleanup set: β-reduction, η-reduction, layout-op
/// simplification. Run after structural rewrites to keep expressions in
/// normal form. Memoized per thread over the hash-consing arena and
/// executed by the id-native engine — shared subtrees (ubiquitous across
/// enumeration variants) are normalized once, and conversion to/from
/// `Box<Expr>` happens only at this function's boundary, not per node.
pub fn normalize(e: &Expr) -> Expr {
    if memo_enabled() {
        NORMALIZE_ID.with(|cell| rewrite_interned(cell, e))
    } else {
        normalize_uncached(e)
    }
}

/// The unmemoized reference implementation of [`normalize`] (the seed
/// path). Used by differential tests and when memoization is disabled via
/// [`crate::dsl::intern::with_memo_disabled`].
pub fn normalize_uncached(e: &Expr) -> Expr {
    rewrite_bottom_up(&normalize_rules(), e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;

    #[test]
    fn map_children_rebuilds() {
        let e = map(lam1("x", var("x")), input("v"));
        let out = map_children(&e, |c| c.clone());
        assert_eq!(out, e);
    }

    #[test]
    fn rewrite_once_finds_nested_match() {
        // rule: replace literal 1.0 with 2.0
        let rule = Rule {
            name: "one-to-two",
            apply: |e| match e {
                Expr::Lit(x) if *x == 1.0 => Some(Expr::Lit(2.0)),
                _ => None,
            },
        };
        let e = map(lam1("x", app2(mul(), var("x"), lit(1.0))), input("v"));
        let out = rewrite_once(&rule, &e).unwrap();
        assert_eq!(
            out,
            map(lam1("x", app2(mul(), var("x"), lit(2.0))), input("v"))
        );
        assert!(rewrite_once(&rule, &out).is_none());
    }

    #[test]
    fn rewrite_once_rewrites_only_first_match() {
        let rule = Rule {
            name: "one-to-two",
            apply: |e| match e {
                Expr::Lit(x) if *x == 1.0 => Some(Expr::Lit(2.0)),
                _ => None,
            },
        };
        // Two matching leaves: only the leftmost is rewritten per call.
        let e = app2(add(), lit(1.0), lit(1.0));
        let out = rewrite_once(&rule, &e).unwrap();
        assert_eq!(out, app2(add(), lit(2.0), lit(1.0)));
        let out2 = rewrite_once(&rule, &out).unwrap();
        assert_eq!(out2, app2(add(), lit(2.0), lit(2.0)));
        assert!(rewrite_once(&rule, &out2).is_none());
    }

    #[test]
    fn bottom_up_fixpoint_terminates() {
        let rule = Rule {
            name: "dec",
            apply: |e| match e {
                Expr::Lit(x) if *x > 0.0 => Some(Expr::Lit(x - 1.0)),
                _ => None,
            },
        };
        let out = rewrite_bottom_up(&[rule], &lit(5.0));
        assert_eq!(out, lit(0.0));
    }

    /// Regression (ISSUE 1): a rule set that only converges through the
    /// re-pass after a rule fires — `wrap` keeps introducing a `neg` node
    /// whose operand needs further rewriting, and `unwrap` strips it.
    /// An engine that dropped the re-pass (or its `changed` flag) would
    /// return an intermediate form.
    #[test]
    fn bottom_up_converges_via_re_pass() {
        let wrap = Rule {
            name: "wrap-dec",
            apply: |e| match e {
                Expr::Lit(x) if *x >= 1.0 => Some(Expr::App {
                    f: Box::new(Expr::Prim(Prim::Neg)),
                    args: vec![Expr::Lit(x - 1.0)],
                }),
                _ => None,
            },
        };
        let unwrap = Rule {
            name: "unwrap-neg",
            apply: |e| match e {
                Expr::App { f, args } if matches!(&**f, Expr::Prim(Prim::Neg)) => {
                    Some(args[0].clone())
                }
                _ => None,
            },
        };
        let out = rewrite_bottom_up(&[unwrap, wrap], &lit(3.0));
        assert_eq!(out, lit(0.0));
        // Memoized engine agrees.
        let mut memo = MemoRewriter::new(&[unwrap, wrap]);
        assert_eq!(memo.rewrite(&lit(3.0)), lit(0.0));
    }

    /// The step budget is accounted once, globally across re-passes: a
    /// long (but converging) chain completes with the correct result.
    #[test]
    fn budget_is_accounted_globally() {
        let inc = Rule {
            name: "inc-to-1000",
            apply: |e| match e {
                Expr::Lit(x) if *x < 1000.0 => Some(Expr::Lit(x + 1.0)),
                _ => None,
            },
        };
        assert_eq!(rewrite_bottom_up(&[inc], &lit(0.0)), lit(1000.0));
        let mut memo = MemoRewriter::new(&[inc]);
        assert_eq!(memo.rewrite(&lit(0.0)), lit(1000.0));
    }

    #[test]
    fn memo_rewriter_caches_across_calls() {
        let rule = Rule {
            name: "dec",
            apply: |e| match e {
                Expr::Lit(x) if *x > 0.0 => Some(Expr::Lit(x - 1.0)),
                _ => None,
            },
        };
        let mut memo = MemoRewriter::new(&[rule]);
        let e = app2(add(), lit(3.0), lit(3.0));
        assert_eq!(memo.rewrite(&e), app2(add(), lit(0.0), lit(0.0)));
        let after_first = memo.memo_len();
        // Second call over a tree sharing every subtree: pure memo hits,
        // no growth in the memo table.
        assert_eq!(memo.rewrite(&e), app2(add(), lit(0.0), lit(0.0)));
        assert_eq!(memo.memo_len(), after_first);
    }

    #[test]
    fn id_rewriter_agrees_with_memo_rewriter() {
        use crate::dsl::intern::Node;
        let dec = Rule {
            name: "dec",
            apply: |e| match e {
                Expr::Lit(x) if *x > 0.0 => Some(Expr::Lit(x - 1.0)),
                _ => None,
            },
        };
        let dec_id = IdRule {
            name: "dec",
            apply: |arena, id| {
                let &Node::Lit(bits) = arena.get(id) else {
                    return None;
                };
                let x = f64::from_bits(bits);
                if x > 0.0 {
                    Some(arena.insert(Node::Lit((x - 1.0).to_bits())))
                } else {
                    None
                }
            },
        };
        let e = app2(add(), lit(3.0), lit(3.0));
        let mut memo = MemoRewriter::new(&[dec]);
        let arena = SharedArena::new();
        let mut idr = IdRewriter::new(&[dec_id]);
        let id = arena.intern(&e);
        let out = idr.rewrite(&arena, id);
        assert_eq!(arena.extract(out), memo.rewrite(&e));
        // Second call over the same tree: pure memo hits, no growth.
        let before = idr.memo_len();
        assert_eq!(idr.rewrite(&arena, id), out);
        assert_eq!(idr.memo_len(), before);
    }

    #[test]
    fn id_normalize_rules_match_box_normalize() {
        let e = map(
            lam1("x", app1(lam1("q", var("q")), var("x"))),
            flip(0, flip(0, input("A"))),
        );
        let arena = SharedArena::new();
        let mut idr = IdRewriter::new(&normalize_id_rules());
        let id = arena.intern(&e);
        let oid = idr.rewrite(&arena, id);
        let out = arena.extract(oid);
        let reference = normalize_uncached(&e);
        assert!(
            out.alpha_eq(&reference),
            "{} vs {}",
            crate::dsl::pretty(&out),
            crate::dsl::pretty(&reference)
        );
    }

    #[test]
    fn memoized_normalize_matches_uncached() {
        // A beta/eta/layout mix; memoized and plain paths agree.
        let e = map(
            lam1("x", app1(lam1("q", var("q")), var("x"))),
            flip(0, flip(0, input("A"))),
        );
        let plain = normalize_uncached(&e);
        let memoized = normalize(&e);
        assert!(
            memoized.alpha_eq(&plain),
            "{} vs {}",
            crate::dsl::pretty(&memoized),
            crate::dsl::pretty(&plain)
        );
    }
}
