//! Layout-operator cleanups. Exchange rules insert `flip`s mechanically;
//! these rules cancel and canonicalise the resulting chains so that
//! repeated exchanges do not grow expressions without bound.

use super::engine::{IdRule, Rule};
use crate::dsl::intern::Node;
use crate::dsl::Expr;

/// `flip d1 d2 (flip d1 d2 x) → x` — flip is an involution (paper §2.1).
pub fn flip_flip() -> Rule {
    Rule {
        name: "flip-flip",
        apply: |e| {
            let Expr::Flip { d1, d2, arg } = e else {
                return None;
            };
            let Expr::Flip {
                d1: e1,
                d2: e2,
                arg: inner,
            } = &**arg
            else {
                return None;
            };
            // flip is commutative in its arguments
            let same = (d1 == e1 && d2 == e2) || (d1 == e2 && d2 == e1);
            if same {
                Some((**inner).clone())
            } else {
                None
            }
        },
    }
}

/// `flatten d (subdiv d b x) → x` — flatten is the inverse of subdiv.
pub fn flatten_subdiv() -> Rule {
    Rule {
        name: "flatten-subdiv",
        apply: |e| {
            let Expr::Flatten { d, arg } = e else {
                return None;
            };
            let Expr::Subdiv {
                d: sd,
                b: _,
                arg: inner,
            } = &**arg
            else {
                return None;
            };
            if d == sd {
                Some((**inner).clone())
            } else {
                None
            }
        },
    }
}

/// `subdiv d 1 x` has a trivial inner block; leave it (used by enumeration
/// edge cases) — but `flip d d x → x` is always removable.
pub fn subdiv_trivial() -> Rule {
    Rule {
        name: "flip-same-dim",
        apply: |e| {
            let Expr::Flip { d1, d2, arg } = e else {
                return None;
            };
            if d1 == d2 {
                Some((**arg).clone())
            } else {
                None
            }
        },
    }
}

/// Id-native twin of [`flip_flip`]: the cancelled subtree comes back as
/// the id it already had — zero allocation.
pub fn flip_flip_id() -> IdRule {
    IdRule {
        name: "flip-flip",
        apply: |arena, id| {
            let &Node::Flip { d1, d2, arg } = arena.get(id) else {
                return None;
            };
            let &Node::Flip {
                d1: e1,
                d2: e2,
                arg: inner,
            } = arena.get(arg)
            else {
                return None;
            };
            // flip is commutative in its arguments
            let same = (d1 == e1 && d2 == e2) || (d1 == e2 && d2 == e1);
            if same {
                Some(inner)
            } else {
                None
            }
        },
    }
}

/// Id-native twin of [`flatten_subdiv`].
pub fn flatten_subdiv_id() -> IdRule {
    IdRule {
        name: "flatten-subdiv",
        apply: |arena, id| {
            let &Node::Flatten { d, arg } = arena.get(id) else {
                return None;
            };
            let &Node::Subdiv {
                d: sd,
                b: _,
                arg: inner,
            } = arena.get(arg)
            else {
                return None;
            };
            if d == sd {
                Some(inner)
            } else {
                None
            }
        },
    }
}

/// Id-native twin of [`subdiv_trivial`] (`flip d d x → x`).
pub fn flip_same_dim_id() -> IdRule {
    IdRule {
        name: "flip-same-dim",
        apply: |arena, id| {
            let &Node::Flip { d1, d2, arg } = arena.get(id) else {
                return None;
            };
            if d1 == d2 {
                Some(arg)
            } else {
                None
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;
    use crate::rewrite::normalize;

    #[test]
    fn flip_cancels() {
        let e = flip(0, flip(0, input("A")));
        assert_eq!(normalize(&e), input("A"));
        let e2 = flip2(0, 2, flip2(2, 0, input("A")));
        assert_eq!(normalize(&e2), input("A"));
        // different dims do not cancel
        let e3 = flip(0, flip(1, input("A")));
        assert_eq!(normalize(&e3), e3);
    }

    #[test]
    fn flatten_cancels_subdiv() {
        let e = flatten(1, subdiv(1, 4, input("A")));
        assert_eq!(normalize(&e), input("A"));
        let e2 = flatten(0, subdiv(1, 4, input("A")));
        assert_eq!(normalize(&e2), e2);
    }

    #[test]
    fn flip_same_dim_is_identity() {
        let e = flip2(1, 1, input("A"));
        assert_eq!(normalize(&e), input("A"));
    }
}
