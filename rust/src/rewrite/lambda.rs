//! Standard lambda-calculus rules: β-reduction and η-reduction — the
//! "standard lambda calculus transformations" the paper's DataView system
//! also implements.

use super::engine::{IdRule, Rule};
use crate::dsl::intern::Node;
use crate::dsl::Expr;

/// β: `(\x1..xn -> body) a1..an  →  body[xi := ai]`.
pub fn beta() -> Rule {
    Rule {
        name: "beta",
        apply: |e| {
            let Expr::App { f, args } = e else {
                return None;
            };
            let Expr::Lam { params, body } = &**f else {
                return None;
            };
            if params.len() != args.len() {
                return None;
            }
            let mut out = (**body).clone();
            // Substitute simultaneously: rename params apart first to avoid
            // one substitution capturing another's argument.
            let fresh: Vec<String> = params
                .iter()
                .map(|p| crate::dsl::fresh_var(p))
                .collect();
            for (p, np) in params.iter().zip(&fresh) {
                out = out.subst(p, &Expr::Var(np.clone()));
            }
            for (np, a) in fresh.iter().zip(args) {
                out = out.subst(np, a);
            }
            Some(out)
        },
    }
}

/// Id-native twin of [`beta`]: β-reduction performed entirely in the
/// arena via [`crate::dsl::intern::SharedArena::subst_id`]. Same
/// simultaneous-substitution-through-fresh-renames strategy, so the two
/// engines agree up to alpha.
pub fn beta_id() -> IdRule {
    IdRule {
        name: "beta",
        apply: |arena, id| {
            let Node::App { f, args } = arena.get(id).clone() else {
                return None;
            };
            let Node::Lam { params, body } = arena.get(f).clone() else {
                return None;
            };
            if params.len() != args.len() {
                return None;
            }
            let mut out = body;
            // Substitute simultaneously: rename params apart first to avoid
            // one substitution capturing another's argument.
            let fresh: Vec<String> = params
                .iter()
                .map(|p| crate::dsl::fresh_var(p))
                .collect();
            for (p, np) in params.iter().zip(&fresh) {
                let npv = arena.insert(Node::Var(np.clone()));
                out = arena.subst_id(out, p, npv);
            }
            for (np, &a) in fresh.iter().zip(&args) {
                out = arena.subst_id(out, np, a);
            }
            Some(out)
        },
    }
}

/// η: `\x1..xn -> f x1..xn  →  f` when no `xi` is free in `f`.
pub fn eta() -> Rule {
    Rule {
        name: "eta",
        apply: |e| {
            let Expr::Lam { params, body } = e else {
                return None;
            };
            let Expr::App { f, args } = &**body else {
                return None;
            };
            if args.len() != params.len() {
                return None;
            }
            let all_vars = params
                .iter()
                .zip(args)
                .all(|(p, a)| matches!(a, Expr::Var(x) if x == p));
            if !all_vars {
                return None;
            }
            let fv = f.free_vars();
            if params.iter().any(|p| fv.contains(p)) {
                return None;
            }
            Some((**f).clone())
        },
    }
}

/// Id-native twin of [`eta`].
pub fn eta_id() -> IdRule {
    IdRule {
        name: "eta",
        apply: |arena, id| {
            let Node::Lam { params, body } = arena.get(id) else {
                return None;
            };
            let Node::App { f, args } = arena.get(*body) else {
                return None;
            };
            if args.len() != params.len() {
                return None;
            }
            let all_vars = params
                .iter()
                .zip(args)
                .all(|(p, &a)| matches!(arena.get(a), Node::Var(x) if x == p));
            if !all_vars {
                return None;
            }
            let f = *f;
            if params.iter().any(|p| arena.contains_free(f, p)) {
                return None;
            }
            Some(f)
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;

    #[test]
    fn beta_simple() {
        let e = app2(lam2("x", "y", app2(add(), var("x"), var("y"))), lit(1.0), lit(2.0));
        let out = (beta().apply)(&e).unwrap();
        assert_eq!(out, app2(add(), lit(1.0), lit(2.0)));
    }

    #[test]
    fn beta_simultaneous_no_cross_capture() {
        // (\x y -> x + y) y 3 — the arg `y` must not be captured by param y.
        let e = app2(
            lam2("x", "y", app2(add(), var("x"), var("y"))),
            var("y"),
            lit(3.0),
        );
        let out = (beta().apply)(&e).unwrap();
        assert_eq!(out, app2(add(), var("y"), lit(3.0)));
    }

    #[test]
    fn eta_reduces() {
        let e = lam1("x", app1(lam1("q", var("q")), var("x")));
        let out = (eta().apply)(&e).unwrap();
        assert_eq!(out, lam1("q", var("q")));
    }

    #[test]
    fn id_rules_match_box_rules() {
        use crate::dsl::intern::SharedArena;
        let cases = [
            app2(
                lam2("x", "y", app2(add(), var("x"), var("y"))),
                lit(1.0),
                lit(2.0),
            ),
            app2(
                lam2("x", "y", app2(add(), var("x"), var("y"))),
                var("y"),
                lit(3.0),
            ),
            lam1("x", app1(lam1("q", var("q")), var("x"))),
            lam1("x", app1(app1(var("f"), var("x")), var("x"))),
        ];
        for e in &cases {
            let arena = SharedArena::new();
            let id = arena.intern(e);
            for (r, ir) in [(beta(), beta_id()), (eta(), eta_id())] {
                let a = (r.apply)(e);
                let b = (ir.apply)(&arena, id);
                match (&a, &b) {
                    (Some(x), Some(y)) => assert!(
                        arena.extract(*y).alpha_eq(x),
                        "{}: {} vs {}",
                        r.name,
                        pretty(x),
                        pretty(&arena.extract(*y))
                    ),
                    (None, None) => {}
                    _ => panic!(
                        "box/id {} divergence on {}: {:?} vs {:?}",
                        r.name,
                        pretty(e),
                        a.is_some(),
                        b.is_some()
                    ),
                }
            }
        }
    }

    #[test]
    fn eta_respects_free_occurrence() {
        // \x -> (f x) x — not an eta redex (x free in function position)
        let e = lam1("x", app1(app1(var("f"), var("x")), var("x")));
        assert!((eta().apply)(&e).is_none());
        // \x -> f x x — arity mismatch with single param
        let e2 = lam1("x", app2(var("f"), var("x"), var("x")));
        assert!((eta().apply)(&e2).is_none());
    }
}
