//! Pipeline fusion rules (paper §3, first group).
//!
//! `nzip` is closed under arbitrary composition via the generalized
//! composition operator `ncomp` (eq 23):
//!
//! ```text
//! nzip f xs[0..i-1] (nzip g ys) xs[i+1..]  =  nzip (ncomp i f g) xs++ys  (eq 24-25)
//! rnz r f … (nzip g ys) …                  =  rnz r (ncomp i f g) …      (eq 27-28)
//! ```
//!
//! This eliminates the materialisation of every intermediate array — the
//! motivating "too many temporaries" problem of §2 (eq 1-2).

use super::engine::{IdRule, Rule};
use crate::dsl::intern::{ExprId, Node, SharedArena};
use crate::dsl::{fresh_var, Expr};

/// Build `ncomp i f g`: the function applying `g` to the `m` arguments at
/// position `i` and passing the result as `f`'s `i`-th argument (paper
/// eq. 23). `n` and `m` are the arities of `f` and `g`.
pub fn ncomp(i: usize, f: &Expr, n: usize, g: &Expr, m: usize) -> Expr {
    let a_params: Vec<String> = (0..n).map(|k| fresh_var(&format!("a{k}"))).collect();
    let b_params: Vec<String> = (0..m).map(|k| fresh_var(&format!("b{k}"))).collect();
    // f's argument list with position i replaced by (g b0..bm-1)
    let g_call = Expr::App {
        f: Box::new(g.clone()),
        args: b_params.iter().map(|b| Expr::Var(b.clone())).collect(),
    };
    let mut f_args: Vec<Expr> = a_params.iter().map(|a| Expr::Var(a.clone())).collect();
    f_args[i] = g_call;
    let body = Expr::App {
        f: Box::new(f.clone()),
        args: f_args,
    };
    // parameter order: a0..a_{i-1}, b0..b_{m-1}, a_{i+1}..a_{n-1}
    let mut params: Vec<String> = Vec::with_capacity(n - 1 + m);
    params.extend(a_params[..i].iter().cloned());
    params.extend(b_params.iter().cloned());
    params.extend(a_params[i + 1..].iter().cloned());
    Expr::Lam {
        params,
        body: Box::new(body),
    }
}

/// Arity of a function expression in operator position, if statically
/// known.
fn arity_of(f: &Expr) -> Option<usize> {
    match f {
        Expr::Lam { params, .. } => Some(params.len()),
        Expr::Prim(p) => Some(p.arity()),
        Expr::Lift { f } => arity_of(f),
        _ => None,
    }
}

/// Id-native twin of [`ncomp`], built entirely in the arena.
pub fn ncomp_id(
    arena: &SharedArena,
    i: usize,
    f: ExprId,
    n: usize,
    g: ExprId,
    m: usize,
) -> ExprId {
    let a_params: Vec<String> = (0..n).map(|k| fresh_var(&format!("a{k}"))).collect();
    let b_params: Vec<String> = (0..m).map(|k| fresh_var(&format!("b{k}"))).collect();
    let b_vars: Vec<ExprId> = b_params
        .iter()
        .map(|b| arena.insert(Node::Var(b.clone())))
        .collect();
    let g_call = arena.insert(Node::App { f: g, args: b_vars });
    let mut f_args: Vec<ExprId> = a_params
        .iter()
        .map(|a| arena.insert(Node::Var(a.clone())))
        .collect();
    f_args[i] = g_call;
    let body = arena.insert(Node::App { f, args: f_args });
    // parameter order: a0..a_{i-1}, b0..b_{m-1}, a_{i+1}..a_{n-1}
    let mut params: Vec<String> = Vec::with_capacity(n - 1 + m);
    params.extend(a_params[..i].iter().cloned());
    params.extend(b_params);
    params.extend(a_params[i + 1..].iter().cloned());
    arena.insert(Node::Lam { params, body })
}

/// Id-native twin of [`arity_of`].
fn arity_of_id(arena: &SharedArena, f: ExprId) -> Option<usize> {
    match arena.get(f) {
        Node::Lam { params, .. } => Some(params.len()),
        Node::Prim(p) => Some(p.arity()),
        Node::Lift { f } => arity_of_id(arena, *f),
        _ => None,
    }
}

/// eq 25: fuse an `nzip` appearing as an argument of another `nzip`.
pub fn nzip_nzip() -> Rule {
    Rule {
        name: "nzip-nzip-fusion",
        apply: |e| {
            let Expr::Nzip { f, args } = e else {
                return None;
            };
            let (i, (g, ys)) = args.iter().enumerate().find_map(|(i, a)| match a {
                Expr::Nzip { f, args } => Some((i, (f.as_ref(), args.as_slice()))),
                _ => None,
            })?;
            let n = args.len();
            let m = ys.len();
            // Sanity: declared arities must match the usage.
            if arity_of(f).is_some_and(|a| a != n) || arity_of(g).is_some_and(|a| a != m) {
                return None;
            }
            let fused_f = ncomp(i, f, n, g, m);
            let mut new_args = Vec::with_capacity(n - 1 + m);
            new_args.extend(args[..i].iter().cloned());
            new_args.extend(ys.iter().cloned());
            new_args.extend(args[i + 1..].iter().cloned());
            Some(Expr::Nzip {
                f: Box::new(fused_f),
                args: new_args,
            })
        },
    }
}

/// eq 27-28: fuse an `nzip` appearing as an argument of an `rnz` into the
/// reduction's zipper.
pub fn rnz_nzip() -> Rule {
    Rule {
        name: "rnz-nzip-fusion",
        apply: |e| {
            let Expr::Rnz { r, m, args } = e else {
                return None;
            };
            let (i, (g, ys)) = args.iter().enumerate().find_map(|(i, a)| match a {
                Expr::Nzip { f, args } => Some((i, (f.as_ref(), args.as_slice()))),
                _ => None,
            })?;
            let n = args.len();
            let gm = ys.len();
            if arity_of(m).is_some_and(|a| a != n) || arity_of(g).is_some_and(|a| a != gm) {
                return None;
            }
            let fused_m = ncomp(i, m, n, g, gm);
            let mut new_args = Vec::with_capacity(n - 1 + gm);
            new_args.extend(args[..i].iter().cloned());
            new_args.extend(ys.iter().cloned());
            new_args.extend(args[i + 1..].iter().cloned());
            Some(Expr::Rnz {
                r: r.clone(),
                m: Box::new(fused_m),
                args: new_args,
            })
        },
    }
}

/// `(lift f) x… = nzip f x…` — applying a lifted function *is* an
/// elementwise map (paper eq 41); normalising to `nzip` lets the fusion
/// rules see through it.
pub fn lift_app() -> Rule {
    Rule {
        name: "lift-app-to-nzip",
        apply: |e| {
            let Expr::App { f, args } = e else {
                return None;
            };
            let Expr::Lift { f: g } = &**f else {
                return None;
            };
            Some(Expr::Nzip {
                f: g.clone(),
                args: args.clone(),
            })
        },
    }
}

/// Id-native twin of [`nzip_nzip`] (eq 25).
pub fn nzip_nzip_id() -> IdRule {
    IdRule {
        name: "nzip-nzip-fusion",
        apply: |arena, id| {
            let Node::Nzip { f, args } = arena.get(id).clone() else {
                return None;
            };
            let mut found = None;
            for (i, &a) in args.iter().enumerate() {
                if let Node::Nzip { f: g, args: ys } = arena.get(a) {
                    found = Some((i, *g, ys.clone()));
                    break;
                }
            }
            let (i, g, ys) = found?;
            let n = args.len();
            let m = ys.len();
            if arity_of_id(arena, f).is_some_and(|a| a != n)
                || arity_of_id(arena, g).is_some_and(|a| a != m)
            {
                return None;
            }
            let fused_f = ncomp_id(arena, i, f, n, g, m);
            let mut new_args = Vec::with_capacity(n - 1 + m);
            new_args.extend(args[..i].iter().copied());
            new_args.extend(ys.iter().copied());
            new_args.extend(args[i + 1..].iter().copied());
            Some(arena.insert(Node::Nzip {
                f: fused_f,
                args: new_args,
            }))
        },
    }
}

/// Id-native twin of [`rnz_nzip`] (eq 27-28).
pub fn rnz_nzip_id() -> IdRule {
    IdRule {
        name: "rnz-nzip-fusion",
        apply: |arena, id| {
            let Node::Rnz { r, m, args } = arena.get(id).clone() else {
                return None;
            };
            let mut found = None;
            for (i, &a) in args.iter().enumerate() {
                if let Node::Nzip { f: g, args: ys } = arena.get(a) {
                    found = Some((i, *g, ys.clone()));
                    break;
                }
            }
            let (i, g, ys) = found?;
            let n = args.len();
            let gm = ys.len();
            if arity_of_id(arena, m).is_some_and(|a| a != n)
                || arity_of_id(arena, g).is_some_and(|a| a != gm)
            {
                return None;
            }
            let fused_m = ncomp_id(arena, i, m, n, g, gm);
            let mut new_args = Vec::with_capacity(n - 1 + gm);
            new_args.extend(args[..i].iter().copied());
            new_args.extend(ys.iter().copied());
            new_args.extend(args[i + 1..].iter().copied());
            Some(arena.insert(Node::Rnz {
                r,
                m: fused_m,
                args: new_args,
            }))
        },
    }
}

/// Id-native twin of [`lift_app`] (eq 41).
pub fn lift_app_id() -> IdRule {
    IdRule {
        name: "lift-app-to-nzip",
        apply: |arena, id| {
            let Node::App { f, args } = arena.get(id).clone() else {
                return None;
            };
            let &Node::Lift { f: g } = arena.get(f) else {
                return None;
            };
            Some(arena.insert(Node::Nzip { f: g, args }))
        },
    }
}

fn fuse_rules() -> [super::engine::Rule; 5] {
    [
        nzip_nzip(),
        rnz_nzip(),
        lift_app(),
        super::lambda::beta(),
        super::lambda::eta(),
    ]
}

/// The id-native fuse rule set — same rules, same order, as the
/// `Box<Expr>` set the seed engine uses.
pub fn fuse_id_rules() -> [IdRule; 5] {
    [
        nzip_nzip_id(),
        rnz_nzip_id(),
        lift_app_id(),
        super::lambda::beta_id(),
        super::lambda::eta_id(),
    ]
}

thread_local! {
    static FUSE_ID: std::cell::RefCell<(SharedArena, super::engine::IdRewriter)> =
        std::cell::RefCell::new((
            SharedArena::new(),
            super::engine::IdRewriter::new(&fuse_id_rules()),
        ));
}

/// The full fusion pass: fuse all pipelines, then β/η-normalize. Memoized
/// per thread over the hash-consing arena and executed by the id-native
/// engine (repeated optimize jobs on the same source fuse for free, and
/// no `Box<Expr>` tree is rebuilt between rule applications).
pub fn fuse(e: &Expr) -> Expr {
    if crate::dsl::intern::memo_enabled() {
        FUSE_ID.with(|cell| super::engine::rewrite_interned(cell, e))
    } else {
        super::engine::rewrite_bottom_up(&fuse_rules(), e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;
    use crate::eval::{eval, ArrVal, Inputs};

    fn vec_inputs() -> Inputs {
        let mut m = Inputs::new();
        m.insert("u".into(), ArrVal::dense(vec![1., 2., 3., 4.], &[4]));
        m.insert("v".into(), ArrVal::dense(vec![5., 6., 7., 8.], &[4]));
        m.insert("w".into(), ArrVal::dense(vec![0.5, 0.25, 2., 4.], &[4]));
        m
    }

    #[test]
    fn map_map_fusion_eq19() {
        // map (*2) (map (+1) u)  →  single nzip
        let inner = map(lam1("x", app2(add(), var("x"), lit(1.0))), input("u"));
        let e = map(lam1("y", app2(mul(), var("y"), lit(2.0))), inner);
        let fused = fuse(&e);
        // exactly one nzip, no nested nzip in args
        let Expr::Nzip { args, .. } = &fused else {
            panic!("expected nzip, got {}", pretty(&fused))
        };
        assert!(args.iter().all(|a| matches!(a, Expr::Input(_))));
        // semantics preserved
        let inp = vec_inputs();
        assert_eq!(
            eval(&e, &inp).unwrap().to_dense(),
            eval(&fused, &inp).unwrap().to_dense()
        );
    }

    #[test]
    fn motivating_example_eq1() {
        // w_i = Σ_j (A_ij + B_ij) (v_j + u_j) — fused matvec:
        // here the vector part: zip(+) u v zipped then reduced
        // rnz (+) (*) (zip (+) u v) w  →  rnz with 3 args, no temporaries
        let e = rnz(
            add(),
            mul(),
            vec![zip(add(), input("u"), input("v")), input("w")],
        );
        let fused = fuse(&e);
        let Expr::Rnz { args, .. } = &fused else {
            panic!("expected rnz")
        };
        assert_eq!(args.len(), 3);
        assert!(args.iter().all(|a| matches!(a, Expr::Input(_))));
        let inp = vec_inputs();
        let a = eval(&e, &inp).unwrap().as_scalar().unwrap();
        let b = eval(&fused, &inp).unwrap().as_scalar().unwrap();
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn zip_of_zips_flattens_to_variadic() {
        // zip f (zip g u v) (zip h v w) → 4-ary nzip
        let e = zip(
            add(),
            zip(mul(), input("u"), input("v")),
            zip(add(), input("v"), input("w")),
        );
        let fused = fuse(&e);
        let Expr::Nzip { args, .. } = &fused else {
            panic!("expected nzip")
        };
        assert_eq!(args.len(), 4);
        let inp = vec_inputs();
        assert_eq!(
            eval(&e, &inp).unwrap().to_dense(),
            eval(&fused, &inp).unwrap().to_dense()
        );
    }

    #[test]
    fn id_fuse_matches_box_fuse() {
        let cases = [
            map(
                lam1("y", app2(mul(), var("y"), lit(2.0))),
                map(lam1("x", app2(add(), var("x"), lit(1.0))), input("u")),
            ),
            rnz(
                add(),
                mul(),
                vec![zip(add(), input("u"), input("v")), input("w")],
            ),
            app2(lift(add()), input("u"), input("v")),
            zip(
                add(),
                zip(mul(), input("u"), input("v")),
                zip(add(), input("v"), input("w")),
            ),
        ];
        for e in &cases {
            let id_path = fuse(e); // memoized id-native engine
            let box_path = super::super::engine::rewrite_bottom_up(&fuse_rules(), e);
            assert!(
                id_path.alpha_eq(&box_path),
                "fuse divergence on {}:\n  id:  {}\n  box: {}",
                pretty(e),
                pretty(&id_path),
                pretty(&box_path)
            );
        }
    }

    #[test]
    fn fused_is_lowerable() {
        // After fusion, the executor accepts what it rejected before.
        use crate::exec::lower;
        use crate::layout::Layout;
        use crate::typecheck::Env;
        let env = Env::new().with("u", Layout::row_major(&[4]));
        let e = map(
            lam1("y", app2(mul(), var("y"), lit(2.0))),
            map(lam1("x", app2(add(), var("x"), lit(1.0))), input("u")),
        );
        assert!(lower(&e, &env).is_err());
        let fused = fuse(&e);
        let prog = lower(&fused, &env).unwrap();
        let mut out = vec![0.0; 4];
        crate::exec::execute(&prog, &[&[1., 2., 3., 4.]], &mut out).unwrap();
        assert_eq!(out, vec![4., 6., 8., 10.]);
    }
}
