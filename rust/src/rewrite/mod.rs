//! The rewrite engine and the paper's rule families (§3).
//!
//! Two groups of rules, exactly as the paper organises them:
//!
//! 1. **Pipelines** (sequential composition) — fused by generalized
//!    composition: `map f . map g = map (f . g)` (eq 19) generalized to
//!    variadic `nzip` via `ncomp` (eq 23-25), and fusion of `nzip` into
//!    `rnz` (eq 27-28). See [`fusion`].
//! 2. **Nested structures** — HoFs passed as argument functions to other
//!    HoFs are *exchanged*, relying on the Naperian-functor transposition
//!    property, always paired with a `flip` of the logical layout:
//!    map–map (eq 36-37), map–rnz (eq 42), rnz–rnz (eq 43). See
//!    [`exchange`].
//!
//! Plus the **subdivision identities** (eq 44 and the associativity-based
//! `rnz` analogue) in [`subdivision`], standard lambda-calculus rules
//! (β, η) in [`lambda`], and layout-operator cleanups in [`simplify`].
//!
//! # Two engines: `Box<Expr>` and id-native
//!
//! Every rule on the optimize hot path exists in two forms. The original
//! [`Rule`]s pattern-match on `Box<Expr>` trees and drive
//! [`rewrite_bottom_up`] — the seed engine, kept alive behind
//! [`crate::dsl::intern::with_memo_disabled`] as the reference for
//! differential tests. The [`IdRule`]s (and the context-sensitive
//! `*_id` functions in [`exchange`]/[`subdivision`]) match and build
//! directly against [`crate::dsl::intern::SharedArena`] nodes, so
//! [`IdRewriter`] and the enumeration search run natively on
//! [`crate::dsl::intern::ExprId`]s: conversion to/from `Box<Expr>`
//! happens once at the pipeline boundary, not per node per rule probe —
//! and because the shared arena interns through `&self`, every search
//! shard builds candidates into the *same* arena concurrently.
//!
//! # Memo and generation-stamp invalidation contract
//!
//! Three caches sit on top of the rewrite engine, each with its own
//! invalidation rule — keep them straight when adding caching layers:
//!
//! - **Rewrite memos** ([`MemoRewriter`], [`IdRewriter`]) are keyed by
//!   [`crate::dsl::intern::ExprId`] and therefore valid only for the
//!   arena that produced those ids: call [`IdRewriter::clear`] whenever
//!   the arena is swapped or rebuilt. Long-lived arenas are bounded by
//!   [`engine::ARENA_RESET_NODES`](engine) — outgrowing it drops arena
//!   *and* memo together. A run that exhausts the global step budget
//!   also drops its memo, since partially-rewritten forms must not be
//!   remembered as final.
//! - **Memoized results are canonical per rule set**: a rewriter instance
//!   is built for one fixed rule list; reusing it with different rules
//!   would serve stale normal forms. [`normalize`] owns a thread-local
//!   `(arena, rewriter)` pair for exactly this reason.
//! - **The coordinator's optimize-result LRU** caches whole pipeline
//!   outputs, which bake in cost-model ranking. Its keys carry a
//!   generation stamp seeded from
//!   [`crate::costmodel::COST_MODEL_VERSION`] and advanced by
//!   [`crate::coordinator::Coordinator::flush_opt_cache`]: bump the
//!   version (or flush) whenever ranking semantics change, and stale
//!   entries stop matching and age out on their own.

pub mod engine;
pub mod exchange;
pub mod fusion;
pub mod lambda;
pub mod products;
pub mod simplify;
pub mod subdivision;

pub use engine::{
    normalize, normalize_id_rules, normalize_uncached, rewrite_bottom_up, rewrite_once, IdRule,
    IdRewriter, MemoRewriter, Rule,
};

use crate::layout::Layout;
use crate::typecheck::Env;
use std::collections::HashMap;

/// Typing context carried by rules that need layout information (the
/// exchange rules must know ranks to place their `flip`s).
#[derive(Clone, Debug, Default)]
pub struct Ctx {
    pub env: Env,
    pub vars: HashMap<String, Layout>,
}

impl Ctx {
    pub fn new(env: Env) -> Self {
        Ctx {
            env,
            vars: HashMap::new(),
        }
    }

    /// Layout of a subexpression under this context.
    pub fn layout_of(&self, e: &crate::dsl::Expr) -> crate::Result<Layout> {
        crate::typecheck::infer_with(e, &self.env, &self.vars)
    }

    /// Layout of an interned subexpression under this context — the
    /// id-native twin of [`Ctx::layout_of`], used by the `*_id` exchange
    /// and subdivision rules so guards never extract a tree.
    pub fn layout_of_id(
        &self,
        arena: &crate::dsl::intern::SharedArena,
        id: crate::dsl::intern::ExprId,
    ) -> crate::Result<Layout> {
        crate::typecheck::infer_id_with(arena, id, &self.env, &self.vars)
    }

    /// Context extended with a variable binding.
    pub fn bind(&self, name: &str, layout: Layout) -> Ctx {
        let mut c = self.clone();
        c.vars.insert(name.to_string(), layout);
        c
    }
}
