//! Exchange (interchange) rules for nested HoFs — the paper's second rule
//! group and its central contribution: *exchanging two nested higher-order
//! functions must be done with an appropriate `flip` in the subdivision
//! structure* (§3).
//!
//! - [`map_map`] — eq 36-37: flip two nested independent maps (the result
//!   is transposed "up to a flip in the functor structure").
//! - [`map_rnz`] / [`rnz_map`] — eq 42 in both directions: the map/reduce
//!   interchange that turns row-dot matvec into column-axpy matvec
//!   (Figure 2), inserting `flip (rank-2)` on the consumed array and
//!   `lift`ing the reduction operator.
//! - [`rnz_rnz`] — eq 43: interchange of two same-operator reductions
//!   (requires commutativity + associativity).
//!
//! These rules are context-sensitive (they need ranks to place the flips),
//! so they take a typing [`Ctx`] rather than being plain [`super::Rule`]s.

//! Each rule also has an id-native `*_id` twin operating directly on
//! [`SharedArena`] nodes; the enumeration search uses those so candidate
//! generation never rebuilds `Box<Expr>` trees, and all shards build into
//! one concurrent arena.

use super::Ctx;
use crate::dsl::intern::{ExprId, Node, SharedArena};
use crate::dsl::{fresh_var, Expr};

/// eq 36-37. `map (\x -> map (\y -> body) U) V  =  map (\y -> map (\x ->
/// body) V) U` when `U` does not depend on `x`. The result is the "deep
/// transpose" of the original (caller must account for the transposed
/// output shape).
pub fn map_map(e: &Expr, _ctx: &Ctx) -> Option<Expr> {
    let Expr::Nzip { f, args } = e else {
        return None;
    };
    let [v_arr] = args.as_slice() else {
        return None;
    };
    let Expr::Lam { params, body } = &**f else {
        return None;
    };
    let [x] = params.as_slice() else { return None };
    let Expr::Nzip {
        f: inner_f,
        args: inner_args,
    } = &**body
    else {
        return None;
    };
    let [u_arr] = inner_args.as_slice() else {
        return None;
    };
    let Expr::Lam {
        params: inner_params,
        body: inner_body,
    } = &**inner_f
    else {
        return None;
    };
    let [y] = inner_params.as_slice() else {
        return None;
    };
    // U must not depend on x (it must be a loop-invariant array).
    if u_arr.free_vars().contains(x) {
        return None;
    }
    // Rename binders apart so V (which sits under y's binder in the result)
    // cannot capture.
    let nx = fresh_var(x.split('%').next().unwrap_or(x));
    let ny = fresh_var(y.split('%').next().unwrap_or(y));
    let new_body = inner_body
        .subst(x, &Expr::Var(nx.clone()))
        .subst(y, &Expr::Var(ny.clone()));
    Some(Expr::Nzip {
        f: Box::new(Expr::Lam {
            params: vec![ny],
            body: Box::new(Expr::Nzip {
                f: Box::new(Expr::Lam {
                    params: vec![nx],
                    body: Box::new(new_body),
                }),
                args: vec![v_arr.clone()],
            }),
        }),
        args: vec![u_arr.clone()],
    })
}

/// Id-native twin of [`map_map`]: same match conditions and guards, the
/// result is built (and maximally shared) in the arena.
pub fn map_map_id(arena: &SharedArena, id: ExprId, _ctx: &Ctx) -> Option<ExprId> {
    let Node::Nzip { f, args } = arena.get(id).clone() else {
        return None;
    };
    let [v_arr] = args.as_slice() else {
        return None;
    };
    let v_arr = *v_arr;
    let Node::Lam { params, body } = arena.get(f).clone() else {
        return None;
    };
    let [x] = params.as_slice() else { return None };
    let x = x.clone();
    let Node::Nzip {
        f: inner_f,
        args: inner_args,
    } = arena.get(body).clone()
    else {
        return None;
    };
    let [u_arr] = inner_args.as_slice() else {
        return None;
    };
    let u_arr = *u_arr;
    let Node::Lam {
        params: inner_params,
        body: inner_body,
    } = arena.get(inner_f).clone()
    else {
        return None;
    };
    let [y] = inner_params.as_slice() else {
        return None;
    };
    let y = y.clone();
    // U must not depend on x (it must be a loop-invariant array).
    if arena.contains_free(u_arr, &x) {
        return None;
    }
    // Rename binders apart so V (which sits under y's binder in the result)
    // cannot capture.
    let nx = fresh_var(x.split('%').next().unwrap_or(&x));
    let ny = fresh_var(y.split('%').next().unwrap_or(&y));
    let nxv = arena.insert(Node::Var(nx.clone()));
    let nyv = arena.insert(Node::Var(ny.clone()));
    let nb = arena.subst_id(inner_body, &x, nxv);
    let new_body = arena.subst_id(nb, &y, nyv);
    let inner_lam = arena.insert(Node::Lam {
        params: vec![nx],
        body: new_body,
    });
    let inner_nzip = arena.insert(Node::Nzip {
        f: inner_lam,
        args: vec![v_arr],
    });
    let outer_lam = arena.insert(Node::Lam {
        params: vec![ny],
        body: inner_nzip,
    });
    Some(arena.insert(Node::Nzip {
        f: outer_lam,
        args: vec![u_arr],
    }))
}

/// The *nested-dependent* variant of eq 36-37: both maps traverse the same
/// (rank ≥ 2) array, the inner one iterating the outer's binding:
///
/// ```text
/// map (\x -> map (\y -> body) x) M  =  map (\x' -> map (\y' -> body') x') (flip (rm-2) M)
/// ```
///
/// This swaps a block loop with its within-block loop (used when
/// enumerating subdivided maps, Figures 4/6). `x` must not occur in `body`
/// other than through `y`. The result is transposed at the two consumed
/// levels.
pub fn map_map_nested(e: &Expr, ctx: &Ctx) -> Option<Expr> {
    let Expr::Nzip { f, args } = e else {
        return None;
    };
    let [m_arr] = args.as_slice() else {
        return None;
    };
    let Expr::Lam { params, body } = &**f else {
        return None;
    };
    let [x] = params.as_slice() else { return None };
    let Expr::Nzip {
        f: inner_f,
        args: inner_args,
    } = &**body
    else {
        return None;
    };
    let [Expr::Var(iterated)] = inner_args.as_slice() else {
        return None;
    };
    if iterated != x {
        return None;
    }
    let Expr::Lam {
        params: inner_params,
        body: inner_body,
    } = &**inner_f
    else {
        return None;
    };
    let [y] = inner_params.as_slice() else {
        return None;
    };
    // x may not leak into the body except through y.
    if inner_body.free_vars().contains(x) {
        return None;
    }
    let rm = ctx.layout_of(m_arr).ok()?.rank();
    if rm < 2 {
        return None;
    }
    let nx = fresh_var("x");
    let ny = fresh_var("y");
    let new_body = inner_body.subst(y, &Expr::Var(ny.clone()));
    Some(Expr::Nzip {
        f: Box::new(Expr::Lam {
            params: vec![nx.clone()],
            body: Box::new(Expr::Nzip {
                f: Box::new(Expr::Lam {
                    params: vec![ny],
                    body: Box::new(new_body),
                }),
                args: vec![Expr::Var(nx)],
            }),
        }),
        args: vec![Expr::Flip {
            d1: rm - 2,
            d2: rm - 1,
            arg: Box::new(m_arr.clone()),
        }],
    })
}

/// Id-native twin of [`map_map_nested`].
pub fn map_map_nested_id(arena: &SharedArena, id: ExprId, ctx: &Ctx) -> Option<ExprId> {
    let Node::Nzip { f, args } = arena.get(id).clone() else {
        return None;
    };
    let [m_arr] = args.as_slice() else {
        return None;
    };
    let m_arr = *m_arr;
    let Node::Lam { params, body } = arena.get(f).clone() else {
        return None;
    };
    let [x] = params.as_slice() else { return None };
    let x = x.clone();
    let Node::Nzip {
        f: inner_f,
        args: inner_args,
    } = arena.get(body).clone()
    else {
        return None;
    };
    let [iterated] = inner_args.as_slice() else {
        return None;
    };
    if !matches!(arena.get(*iterated), Node::Var(v) if *v == x) {
        return None;
    }
    let Node::Lam {
        params: inner_params,
        body: inner_body,
    } = arena.get(inner_f).clone()
    else {
        return None;
    };
    let [y] = inner_params.as_slice() else {
        return None;
    };
    let y = y.clone();
    // x may not leak into the body except through y.
    if arena.contains_free(inner_body, &x) {
        return None;
    }
    let rm = ctx.layout_of_id(arena, m_arr).ok()?.rank();
    if rm < 2 {
        return None;
    }
    let nx = fresh_var("x");
    let ny = fresh_var("y");
    let nyv = arena.insert(Node::Var(ny.clone()));
    let new_body = arena.subst_id(inner_body, &y, nyv);
    let inner_lam = arena.insert(Node::Lam {
        params: vec![ny],
        body: new_body,
    });
    let nxv = arena.insert(Node::Var(nx.clone()));
    let inner_nzip = arena.insert(Node::Nzip {
        f: inner_lam,
        args: vec![nxv],
    });
    let outer_lam = arena.insert(Node::Lam {
        params: vec![nx],
        body: inner_nzip,
    });
    let flipped = arena.insert(Node::Flip {
        d1: rm - 2,
        d2: rm - 1,
        arg: m_arr,
    });
    Some(arena.insert(Node::Nzip {
        f: outer_lam,
        args: vec![flipped],
    }))
}

/// eq 42, left to right:
///
/// ```text
/// map (\a -> rnz r m … a … u…) A
///   = rnz (lift r) (\a q… -> map (\α -> m … α … q…) a) (flip (ra-2) A) u…
/// ```
///
/// `A` must have rank ≥ 2; the bound row may appear at any argument
/// position of the inner `rnz`; the remaining arguments must not depend on
/// it.
pub fn map_rnz(e: &Expr, ctx: &Ctx) -> Option<Expr> {
    let Expr::Nzip { f, args } = e else {
        return None;
    };
    let [a_arr] = args.as_slice() else {
        return None;
    };
    let Expr::Lam { params, body } = &**f else {
        return None;
    };
    let [a] = params.as_slice() else { return None };
    let Expr::Rnz {
        r,
        m,
        args: rnz_args,
    } = &**body
    else {
        return None;
    };
    // Locate the bound row among the reduction's arguments.
    let pos = rnz_args
        .iter()
        .position(|x| matches!(x, Expr::Var(v) if v == a))?;
    // All other arguments must be independent of the row.
    for (i, other) in rnz_args.iter().enumerate() {
        if i != pos && other.free_vars().contains(a) {
            return None;
        }
    }
    // Rank of A decides the flip: the map consumed dim ra-1, the reduction
    // consumes ra-2 — exchange them.
    let ra = ctx.layout_of(a_arr).ok()?.rank();
    if ra < 2 {
        return None;
    }
    let n = rnz_args.len();
    let na = fresh_var("a");
    let alpha = fresh_var("al");
    let qs: Vec<String> = (0..n - 1).map(|i| fresh_var(&format!("q{i}"))).collect();
    // m's argument list in original positions: α at pos, q's elsewhere.
    let mut m_args: Vec<Expr> = Vec::with_capacity(n);
    let mut qi = 0usize;
    for i in 0..n {
        if i == pos {
            m_args.push(Expr::Var(alpha.clone()));
        } else {
            m_args.push(Expr::Var(qs[qi].clone()));
            qi += 1;
        }
    }
    let new_m_body = Expr::Nzip {
        f: Box::new(Expr::Lam {
            params: vec![alpha],
            body: Box::new(Expr::App {
                f: m.clone(),
                args: m_args,
            }),
        }),
        args: vec![Expr::Var(na.clone())],
    };
    let mut new_params = vec![na];
    new_params.extend(qs);
    let mut new_args: Vec<Expr> = Vec::with_capacity(n);
    new_args.push(Expr::Flip {
        d1: ra - 2,
        d2: ra - 1,
        arg: Box::new(a_arr.clone()),
    });
    for (i, other) in rnz_args.iter().enumerate() {
        if i != pos {
            new_args.push(other.clone());
        }
    }
    Some(Expr::Rnz {
        r: Box::new(Expr::Lift { f: r.clone() }),
        m: Box::new(Expr::Lam {
            params: new_params,
            body: Box::new(new_m_body),
        }),
        args: new_args,
    })
}

/// Id-native twin of [`map_rnz`].
pub fn map_rnz_id(arena: &SharedArena, id: ExprId, ctx: &Ctx) -> Option<ExprId> {
    let Node::Nzip { f, args } = arena.get(id).clone() else {
        return None;
    };
    let [a_arr] = args.as_slice() else {
        return None;
    };
    let a_arr = *a_arr;
    let Node::Lam { params, body } = arena.get(f).clone() else {
        return None;
    };
    let [a] = params.as_slice() else { return None };
    let a = a.clone();
    let Node::Rnz {
        r,
        m,
        args: rnz_args,
    } = arena.get(body).clone()
    else {
        return None;
    };
    // Locate the bound row among the reduction's arguments.
    let pos = rnz_args
        .iter()
        .position(|&x| matches!(arena.get(x), Node::Var(v) if *v == a))?;
    // All other arguments must be independent of the row.
    for (i, &other) in rnz_args.iter().enumerate() {
        if i != pos && arena.contains_free(other, &a) {
            return None;
        }
    }
    // Rank of A decides the flip: the map consumed dim ra-1, the reduction
    // consumes ra-2 — exchange them.
    let ra = ctx.layout_of_id(arena, a_arr).ok()?.rank();
    if ra < 2 {
        return None;
    }
    let n = rnz_args.len();
    let na = fresh_var("a");
    let alpha = fresh_var("al");
    let qs: Vec<String> = (0..n - 1).map(|i| fresh_var(&format!("q{i}"))).collect();
    // m's argument list in original positions: α at pos, q's elsewhere.
    let mut m_args: Vec<ExprId> = Vec::with_capacity(n);
    let mut qi = 0usize;
    for i in 0..n {
        if i == pos {
            m_args.push(arena.insert(Node::Var(alpha.clone())));
        } else {
            m_args.push(arena.insert(Node::Var(qs[qi].clone())));
            qi += 1;
        }
    }
    let m_call = arena.insert(Node::App { f: m, args: m_args });
    let alpha_lam = arena.insert(Node::Lam {
        params: vec![alpha],
        body: m_call,
    });
    let nav = arena.insert(Node::Var(na.clone()));
    let new_m_body = arena.insert(Node::Nzip {
        f: alpha_lam,
        args: vec![nav],
    });
    let mut new_params = vec![na];
    new_params.extend(qs);
    let mut new_args: Vec<ExprId> = Vec::with_capacity(n);
    new_args.push(arena.insert(Node::Flip {
        d1: ra - 2,
        d2: ra - 1,
        arg: a_arr,
    }));
    for (i, &other) in rnz_args.iter().enumerate() {
        if i != pos {
            new_args.push(other);
        }
    }
    let lifted = arena.insert(Node::Lift { f: r });
    let new_m = arena.insert(Node::Lam {
        params: new_params,
        body: new_m_body,
    });
    Some(arena.insert(Node::Rnz {
        r: lifted,
        m: new_m,
        args: new_args,
    }))
}

/// eq 42, right to left: recognise the flipped form and pull the map back
/// outside.
pub fn rnz_map(e: &Expr, ctx: &Ctx) -> Option<Expr> {
    let Expr::Rnz { r, m, args } = e else {
        return None;
    };
    // Reduction operator must be a lift (the accumulator is an array).
    let Expr::Lift { f: r0 } = &**r else {
        return None;
    };
    let Expr::Lam { params, body } = &**m else {
        return None;
    };
    let Expr::Nzip {
        f: inner_f,
        args: inner_args,
    } = &**body
    else {
        return None;
    };
    let [Expr::Var(mapped)] = inner_args.as_slice() else {
        return None;
    };
    // Which parameter is the mapped one? Its position j also locates the
    // flipped array among the rnz arguments.
    let j = params.iter().position(|p| p == mapped)?;
    if args.len() != params.len() {
        return None;
    }
    let Expr::Lam {
        params: alpha_params,
        body: m_body,
    } = &**inner_f
    else {
        return None;
    };
    let [alpha] = alpha_params.as_slice() else {
        return None;
    };
    // The mapped parameter must not occur in the body beyond the map.
    if m_body.free_vars().contains(mapped) {
        return None;
    }
    let ra = ctx.layout_of(&args[j]).ok()?.rank();
    if ra < 2 {
        return None;
    }
    // Rebuild: map (\a -> rnz r0 (\.. α at j ..) [.. Var a at j ..]) (flip A)
    let na = fresh_var("a");
    let mut inner_m_params: Vec<String> = params.clone();
    inner_m_params[j] = alpha.clone();
    let mut new_rnz_args: Vec<Expr> = args.clone();
    new_rnz_args[j] = Expr::Var(na.clone());
    Some(Expr::Nzip {
        f: Box::new(Expr::Lam {
            params: vec![na],
            body: Box::new(Expr::Rnz {
                r: Box::new((**r0).clone()),
                m: Box::new(Expr::Lam {
                    params: inner_m_params,
                    body: m_body.clone(),
                }),
                args: new_rnz_args,
            }),
        }),
        args: vec![Expr::Flip {
            d1: ra - 2,
            d2: ra - 1,
            arg: Box::new(args[j].clone()),
        }],
    })
}

/// Id-native twin of [`rnz_map`].
pub fn rnz_map_id(arena: &SharedArena, id: ExprId, ctx: &Ctx) -> Option<ExprId> {
    let Node::Rnz { r, m, args } = arena.get(id).clone() else {
        return None;
    };
    // Reduction operator must be a lift (the accumulator is an array).
    let &Node::Lift { f: r0 } = arena.get(r) else {
        return None;
    };
    let Node::Lam { params, body } = arena.get(m).clone() else {
        return None;
    };
    let Node::Nzip {
        f: inner_f,
        args: inner_args,
    } = arena.get(body).clone()
    else {
        return None;
    };
    let [mapped_id] = inner_args.as_slice() else {
        return None;
    };
    let Node::Var(mapped) = arena.get(*mapped_id).clone() else {
        return None;
    };
    // Which parameter is the mapped one? Its position j also locates the
    // flipped array among the rnz arguments.
    let j = params.iter().position(|p| *p == mapped)?;
    if args.len() != params.len() {
        return None;
    }
    let Node::Lam {
        params: alpha_params,
        body: m_body,
    } = arena.get(inner_f).clone()
    else {
        return None;
    };
    let [alpha] = alpha_params.as_slice() else {
        return None;
    };
    let alpha = alpha.clone();
    // The mapped parameter must not occur in the body beyond the map.
    if arena.contains_free(m_body, &mapped) {
        return None;
    }
    let ra = ctx.layout_of_id(arena, args[j]).ok()?.rank();
    if ra < 2 {
        return None;
    }
    // Rebuild: map (\a -> rnz r0 (\.. α at j ..) [.. Var a at j ..]) (flip A)
    let na = fresh_var("a");
    let mut inner_m_params: Vec<String> = params.clone();
    inner_m_params[j] = alpha;
    let nav = arena.insert(Node::Var(na.clone()));
    let mut new_rnz_args: Vec<ExprId> = args.clone();
    new_rnz_args[j] = nav;
    let inner_m = arena.insert(Node::Lam {
        params: inner_m_params,
        body: m_body,
    });
    let inner_rnz = arena.insert(Node::Rnz {
        r: r0,
        m: inner_m,
        args: new_rnz_args,
    });
    let outer_lam = arena.insert(Node::Lam {
        params: vec![na],
        body: inner_rnz,
    });
    let flipped = arena.insert(Node::Flip {
        d1: ra - 2,
        d2: ra - 1,
        arg: args[j],
    });
    Some(arena.insert(Node::Nzip {
        f: outer_lam,
        args: vec![flipped],
    }))
}

/// eq 43: interchange two nested reductions with the same (associative and
/// commutative) operator:
///
/// ```text
/// rnz r (\a… -> rnz r m a… B…) A…
///   = rnz r (\a… b… -> rnz r (\α… -> m α… b…) a…) (flip (r-2) A)… B…
/// ```
pub fn rnz_rnz(e: &Expr, ctx: &Ctx) -> Option<Expr> {
    let Expr::Rnz { r, m, args } = e else {
        return None;
    };
    let Expr::Lam { params, body } = &**m else {
        return None;
    };
    let Expr::Rnz {
        r: r2,
        m: m2,
        args: inner_args,
    } = &**body
    else {
        return None;
    };
    // Same reduction operator (structurally), commutative base.
    if r != r2 {
        return None;
    }
    let mut base = &**r;
    while let Expr::Lift { f } = base {
        base = f;
    }
    let Expr::Prim(p) = base else { return None };
    if !p.is_commutative() || !p.is_associative() {
        return None;
    }
    // Inner args must start with exactly the outer params (in order),
    // followed by extras independent of them.
    let n = params.len();
    if inner_args.len() < n || args.len() != n {
        return None;
    }
    for (p_name, ia) in params.iter().zip(&inner_args[..n]) {
        if !matches!(ia, Expr::Var(v) if v == p_name) {
            return None;
        }
    }
    let extras = &inner_args[n..];
    for ex in extras {
        let fv = ex.free_vars();
        if params.iter().any(|p| fv.contains(p)) {
            return None;
        }
    }
    // Flip each outer array (they must all have rank ≥ 2).
    let mut flipped = Vec::with_capacity(n);
    for a in args {
        let ra = ctx.layout_of(a).ok()?.rank();
        if ra < 2 {
            return None;
        }
        flipped.push(Expr::Flip {
            d1: ra - 2,
            d2: ra - 1,
            arg: Box::new(a.clone()),
        });
    }
    let k = extras.len();
    let new_as: Vec<String> = (0..n).map(|i| fresh_var(&format!("a{i}"))).collect();
    let new_bs: Vec<String> = (0..k).map(|i| fresh_var(&format!("b{i}"))).collect();
    let alphas: Vec<String> = (0..n).map(|i| fresh_var(&format!("al{i}"))).collect();
    let mut m2_args: Vec<Expr> = alphas.iter().map(|a| Expr::Var(a.clone())).collect();
    m2_args.extend(new_bs.iter().map(|b| Expr::Var(b.clone())));
    let inner = Expr::Rnz {
        r: r.clone(),
        m: Box::new(Expr::Lam {
            params: alphas,
            body: Box::new(Expr::App {
                f: m2.clone(),
                args: m2_args,
            }),
        }),
        args: new_as.iter().map(|a| Expr::Var(a.clone())).collect(),
    };
    let mut new_params = new_as;
    new_params.extend(new_bs);
    let mut new_args = flipped;
    new_args.extend(extras.iter().cloned());
    Some(Expr::Rnz {
        r: r.clone(),
        m: Box::new(Expr::Lam {
            params: new_params,
            body: Box::new(inner),
        }),
        args: new_args,
    })
}

/// Id-native twin of [`rnz_rnz`]. Operator equality is an O(1) id
/// comparison here — structurally equal reducers always intern to the
/// same id.
pub fn rnz_rnz_id(arena: &SharedArena, id: ExprId, ctx: &Ctx) -> Option<ExprId> {
    let Node::Rnz { r, m, args } = arena.get(id).clone() else {
        return None;
    };
    let Node::Lam { params, body } = arena.get(m).clone() else {
        return None;
    };
    let Node::Rnz {
        r: r2,
        m: m2,
        args: inner_args,
    } = arena.get(body).clone()
    else {
        return None;
    };
    // Same reduction operator (structurally = same id), commutative base.
    if r != r2 {
        return None;
    }
    let mut base = r;
    while let &Node::Lift { f } = arena.get(base) {
        base = f;
    }
    let &Node::Prim(p) = arena.get(base) else {
        return None;
    };
    if !p.is_commutative() || !p.is_associative() {
        return None;
    }
    // Inner args must start with exactly the outer params (in order),
    // followed by extras independent of them.
    let n = params.len();
    if inner_args.len() < n || args.len() != n {
        return None;
    }
    for (p_name, &ia) in params.iter().zip(&inner_args[..n]) {
        if !matches!(arena.get(ia), Node::Var(v) if v == p_name) {
            return None;
        }
    }
    let extras = &inner_args[n..];
    for &ex in extras {
        if params.iter().any(|p| arena.contains_free(ex, p)) {
            return None;
        }
    }
    // Flip each outer array (they must all have rank ≥ 2).
    let mut flipped = Vec::with_capacity(n);
    for &a in &args {
        let ra = ctx.layout_of_id(arena, a).ok()?.rank();
        if ra < 2 {
            return None;
        }
        flipped.push(arena.insert(Node::Flip {
            d1: ra - 2,
            d2: ra - 1,
            arg: a,
        }));
    }
    let k = extras.len();
    let new_as: Vec<String> = (0..n).map(|i| fresh_var(&format!("a{i}"))).collect();
    let new_bs: Vec<String> = (0..k).map(|i| fresh_var(&format!("b{i}"))).collect();
    let alphas: Vec<String> = (0..n).map(|i| fresh_var(&format!("al{i}"))).collect();
    let mut m2_args: Vec<ExprId> = alphas
        .iter()
        .map(|a| arena.insert(Node::Var(a.clone())))
        .collect();
    for b in &new_bs {
        m2_args.push(arena.insert(Node::Var(b.clone())));
    }
    let m2_call = arena.insert(Node::App {
        f: m2,
        args: m2_args,
    });
    let alpha_lam = arena.insert(Node::Lam {
        params: alphas,
        body: m2_call,
    });
    let inner_rnz_args: Vec<ExprId> = new_as
        .iter()
        .map(|a| arena.insert(Node::Var(a.clone())))
        .collect();
    let inner = arena.insert(Node::Rnz {
        r,
        m: alpha_lam,
        args: inner_rnz_args,
    });
    let mut new_params = new_as;
    new_params.extend(new_bs);
    let mut new_args = flipped;
    new_args.extend(extras.iter().copied());
    let new_m = arena.insert(Node::Lam {
        params: new_params,
        body: inner,
    });
    Some(arena.insert(Node::Rnz {
        r,
        m: new_m,
        args: new_args,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;
    use crate::eval::{eval, ArrVal, Inputs};
    use crate::layout::Layout;
    use crate::rewrite::normalize;
    use crate::typecheck::Env;

    fn mv_inputs() -> (Inputs, Env) {
        let mut inp = Inputs::new();
        inp.insert(
            "A".into(),
            ArrVal::dense((0..12).map(|i| (i * i) as f64 % 7.0).collect(), &[3, 4]),
        );
        inp.insert(
            "v".into(),
            ArrVal::dense(vec![2., -1., 0.5, 3.], &[4]),
        );
        let env = Env::new()
            .with("A", Layout::row_major(&[3, 4]))
            .with("v", Layout::row_major(&[4]));
        (inp, env)
    }

    #[test]
    fn map_rnz_matches_eq42_on_matvec() {
        let (inp, env) = mv_inputs();
        let ctx = Ctx::new(env);
        let e = matvec_naive(input("A"), input("v"));
        let flipped = map_rnz(&e, &ctx).expect("rule applies");
        let flipped = normalize(&flipped);
        // Semantics preserved exactly (same multiplication order per term).
        let a = eval(&e, &inp).unwrap().to_dense();
        let b = eval(&flipped, &inp).unwrap().to_dense();
        assert_eq!(a, b);
        // And it became an rnz at the root with a lifted operator.
        assert!(matches!(&flipped, Expr::Rnz { r, .. } if matches!(&**r, Expr::Lift { .. })));
    }

    #[test]
    fn map_rnz_roundtrip_via_rnz_map() {
        let (inp, env) = mv_inputs();
        let ctx = Ctx::new(env);
        let e = matvec_naive(input("A"), input("v"));
        let there = normalize(&map_rnz(&e, &ctx).unwrap());
        let back = normalize(&rnz_map(&there, &ctx).unwrap());
        let a = eval(&e, &inp).unwrap().to_dense();
        let b = eval(&back, &inp).unwrap().to_dense();
        assert_eq!(a, b);
        // The round trip restores the map-over-rows structure.
        assert!(matches!(&back, Expr::Nzip { .. }));
    }

    #[test]
    fn map_map_transposes_dyadic_product() {
        // eq 36/37
        let mut inp = Inputs::new();
        inp.insert("v".into(), ArrVal::dense(vec![1., 2.], &[2]));
        inp.insert("u".into(), ArrVal::dense(vec![3., 4., 5.], &[3]));
        let env = Env::new()
            .with("v", Layout::row_major(&[2]))
            .with("u", Layout::row_major(&[3]));
        let ctx = Ctx::new(env);
        let e = map(
            lam1(
                "x",
                map(lam1("y", app2(mul(), var("x"), var("y"))), input("u")),
            ),
            input("v"),
        );
        let t = map_map(&e, &ctx).expect("rule applies");
        let a = eval(&e, &inp).unwrap();
        let b = eval(&t, &inp).unwrap();
        // transposed shapes
        assert_eq!(a.extents(), vec![3, 2]);
        assert_eq!(b.extents(), vec![2, 3]);
        // elementwise transpose equality
        let (aa, bb) = (a.to_dense(), b.to_dense());
        for i in 0..2 {
            for j in 0..3 {
                assert_eq!(aa[i * 3 + j], bb[j * 2 + i]);
            }
        }
    }

    #[test]
    fn map_map_requires_independence() {
        // inner array depends on x → no exchange
        let env = Env::new().with("A", Layout::row_major(&[3, 4]));
        let ctx = Ctx::new(env);
        let e = map(
            lam1("x", map(lam1("y", var("y")), var("x"))),
            input("A"),
        );
        assert!(map_map(&e, &ctx).is_none());
    }

    #[test]
    fn rnz_rnz_exchange_preserves_sum() {
        // Sum over chunked vector pair: rnz + (\u v -> dot u v) U V where
        // U, V are subdivided vectors (rank 2).
        let mut inp = Inputs::new();
        inp.insert(
            "u".into(),
            ArrVal::dense((0..8).map(|i| i as f64).collect(), &[8]),
        );
        inp.insert(
            "v".into(),
            ArrVal::dense((0..8).map(|i| (i as f64) * 0.5 + 1.0).collect(), &[8]),
        );
        let env = Env::new()
            .with("u", Layout::row_major(&[8]))
            .with("v", Layout::row_major(&[8]));
        let ctx = Ctx::new(env);
        let e = rnz(
            add(),
            lam2("bu", "bv", dot(var("bu"), var("bv"))),
            vec![subdiv(0, 2, input("u")), subdiv(0, 2, input("v"))],
        );
        let x = rnz_rnz(&e, &ctx).expect("rule applies");
        let x = normalize(&x);
        let a = eval(&e, &inp).unwrap().as_scalar().unwrap();
        let b = eval(&x, &inp).unwrap().as_scalar().unwrap();
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }

    #[test]
    fn id_exchange_rules_match_box_rules() {
        use crate::dsl::intern::{ExprId, SharedArena};
        let env = Env::new()
            .with("A", Layout::row_major(&[3, 4]))
            .with("B", Layout::row_major(&[4, 5]))
            .with("v", Layout::row_major(&[4]))
            .with("u", Layout::row_major(&[8]))
            .with("w", Layout::row_major(&[8]));
        let ctx = Ctx::new(env);
        let matvec = matvec_naive(input("A"), input("v"));
        let flipped_matvec = normalize(&map_rnz(&matvec, &ctx).unwrap());
        let cases: Vec<Expr> = vec![
            matvec.clone(),
            flipped_matvec, // rnz_map fires here
            matmul_naive(input("A"), input("B")),
            map(
                lam1(
                    "x",
                    map(lam1("y", app2(mul(), var("y"), lit(2.0))), var("x")),
                ),
                input("A"),
            ), // map_map_nested fires here
            rnz(
                add(),
                lam2("bu", "bv", dot(var("bu"), var("bv"))),
                vec![subdiv(0, 2, input("u")), subdiv(0, 2, input("w"))],
            ), // rnz_rnz fires here
            input("A"), // nothing fires
        ];
        type BoxRule = fn(&Expr, &Ctx) -> Option<Expr>;
        type IdRuleFn = fn(&SharedArena, ExprId, &Ctx) -> Option<ExprId>;
        let pairs: [(&str, BoxRule, IdRuleFn); 5] = [
            ("map_map", map_map, map_map_id),
            ("map_map_nested", map_map_nested, map_map_nested_id),
            ("map_rnz", map_rnz, map_rnz_id),
            ("rnz_map", rnz_map, rnz_map_id),
            ("rnz_rnz", rnz_rnz, rnz_rnz_id),
        ];
        for e in &cases {
            for (name, br, ir) in pairs {
                let arena = SharedArena::new();
                let id = arena.intern(e);
                let a = br(e, &ctx);
                let b = ir(&arena, id, &ctx);
                match (&a, &b) {
                    (Some(x), Some(y)) => assert!(
                        arena.extract(*y).alpha_eq(x),
                        "{name} on {}:\n  box: {}\n  id:  {}",
                        pretty(e),
                        pretty(x),
                        pretty(&arena.extract(*y))
                    ),
                    (None, None) => {}
                    _ => panic!(
                        "{name} fired differently on {}: box={} id={}",
                        pretty(e),
                        a.is_some(),
                        b.is_some()
                    ),
                }
            }
        }
    }

    #[test]
    fn rnz_rnz_requires_same_operator() {
        let env = Env::new().with("u", Layout::row_major(&[8]));
        let ctx = Ctx::new(env);
        // outer max of inner sums — must NOT exchange
        let e = rnz(
            pmax(),
            lam1("b", reduce(add(), var("b"))),
            vec![subdiv(0, 2, input("u"))],
        );
        assert!(rnz_rnz(&e, &ctx).is_none());
    }
}
