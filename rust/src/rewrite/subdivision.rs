//! Subdivision identities (paper eq 44 and the `rnz` analogue).
//!
//! Subdividing a HoF splits one loop into a loop-over-blocks of
//! loops-within-blocks, *without changing the result*: the data is
//! reinterpreted through `subdiv` on the consumed dimension and the HoF is
//! nested once. All actual computation stays in the innermost HoF — the
//! outer ones are "logical" reshapings, which is what later exchange
//! rewrites move around to create cache-friendly traversals.

use super::Ctx;
use crate::dsl::intern::{ExprId, Node, SharedArena};
use crate::dsl::{fresh_var, Expr};

/// eq 44 (n-ary): `nzip f xs = nzip (\blk… -> nzip f blk…) (subdiv c b x)…`
/// where `c` is each argument's consumed (outermost) dimension. `b` must
/// divide the common outer extent.
pub fn subdivide_nzip(e: &Expr, b: usize, ctx: &Ctx) -> Option<Expr> {
    let Expr::Nzip { f, args } = e else {
        return None;
    };
    let mut new_args = Vec::with_capacity(args.len());
    for a in args {
        let layout = ctx.layout_of(a).ok()?;
        let rank = layout.rank();
        if rank == 0 {
            return None;
        }
        let outer = layout.outer().unwrap();
        if b == 0 || outer.extent % b != 0 {
            return None;
        }
        new_args.push(Expr::Subdiv {
            d: rank - 1,
            b,
            arg: Box::new(a.clone()),
        });
    }
    let blks: Vec<String> = (0..args.len())
        .map(|i| fresh_var(&format!("blk{i}")))
        .collect();
    let inner = Expr::Nzip {
        f: f.clone(),
        args: blks.iter().map(|x| Expr::Var(x.clone())).collect(),
    };
    Some(Expr::Nzip {
        f: Box::new(Expr::Lam {
            params: blks,
            body: Box::new(inner),
        }),
        args: new_args,
    })
}

/// The `rnz` analogue of eq 44 (valid because the reduction operator is
/// associative — the paper's regrouping property):
/// `rnz r m xs = rnz r (\blk… -> rnz r m blk…) (subdiv c b x)…`.
pub fn subdivide_rnz(e: &Expr, b: usize, ctx: &Ctx) -> Option<Expr> {
    let Expr::Rnz { r, m, args } = e else {
        return None;
    };
    let mut new_args = Vec::with_capacity(args.len());
    for a in args {
        let layout = ctx.layout_of(a).ok()?;
        let rank = layout.rank();
        if rank == 0 {
            return None;
        }
        let outer = layout.outer().unwrap();
        if b == 0 || outer.extent % b != 0 {
            return None;
        }
        new_args.push(Expr::Subdiv {
            d: rank - 1,
            b,
            arg: Box::new(a.clone()),
        });
    }
    let blks: Vec<String> = (0..args.len())
        .map(|i| fresh_var(&format!("blk{i}")))
        .collect();
    let inner = Expr::Rnz {
        r: r.clone(),
        m: m.clone(),
        args: blks.iter().map(|x| Expr::Var(x.clone())).collect(),
    };
    Some(Expr::Rnz {
        r: r.clone(),
        m: Box::new(Expr::Lam {
            params: blks,
            body: Box::new(inner),
        }),
        args: new_args,
    })
}

/// Id-native twin of [`subdivide_nzip`] (eq 44): matches, checks
/// divisibility through [`Ctx::layout_of_id`], and builds the nested form
/// in the arena.
pub fn subdivide_nzip_id(
    arena: &SharedArena,
    id: ExprId,
    b: usize,
    ctx: &Ctx,
) -> Option<ExprId> {
    let Node::Nzip { f, args } = arena.get(id).clone() else {
        return None;
    };
    let mut new_args = Vec::with_capacity(args.len());
    for &a in &args {
        let layout = ctx.layout_of_id(arena, a).ok()?;
        let rank = layout.rank();
        if rank == 0 {
            return None;
        }
        let outer = layout.outer().unwrap();
        if b == 0 || outer.extent % b != 0 {
            return None;
        }
        new_args.push(arena.insert(Node::Subdiv {
            d: rank - 1,
            b,
            arg: a,
        }));
    }
    let blks: Vec<String> = (0..args.len())
        .map(|i| fresh_var(&format!("blk{i}")))
        .collect();
    let blk_vars: Vec<ExprId> = blks
        .iter()
        .map(|x| arena.insert(Node::Var(x.clone())))
        .collect();
    let inner = arena.insert(Node::Nzip { f, args: blk_vars });
    let lam = arena.insert(Node::Lam {
        params: blks,
        body: inner,
    });
    Some(arena.insert(Node::Nzip {
        f: lam,
        args: new_args,
    }))
}

/// Id-native twin of [`subdivide_rnz`].
pub fn subdivide_rnz_id(
    arena: &SharedArena,
    id: ExprId,
    b: usize,
    ctx: &Ctx,
) -> Option<ExprId> {
    let Node::Rnz { r, m, args } = arena.get(id).clone() else {
        return None;
    };
    let mut new_args = Vec::with_capacity(args.len());
    for &a in &args {
        let layout = ctx.layout_of_id(arena, a).ok()?;
        let rank = layout.rank();
        if rank == 0 {
            return None;
        }
        let outer = layout.outer().unwrap();
        if b == 0 || outer.extent % b != 0 {
            return None;
        }
        new_args.push(arena.insert(Node::Subdiv {
            d: rank - 1,
            b,
            arg: a,
        }));
    }
    let blks: Vec<String> = (0..args.len())
        .map(|i| fresh_var(&format!("blk{i}")))
        .collect();
    let blk_vars: Vec<ExprId> = blks
        .iter()
        .map(|x| arena.insert(Node::Var(x.clone())))
        .collect();
    let inner = arena.insert(Node::Rnz {
        r,
        m,
        args: blk_vars,
    });
    let lam = arena.insert(Node::Lam {
        params: blks,
        body: inner,
    });
    Some(arena.insert(Node::Rnz {
        r,
        m: lam,
        args: new_args,
    }))
}

/// Hoist a subdivision through a HoF binder to the argument (context-free
/// rule): if **every** use of a bound variable `x` in the body is
/// `subdiv d b x`, then
///
/// ```text
/// nzip (\x -> …(subdiv d b x)…) X  =  nzip (\x -> …x…) (subdiv d b X)
/// ```
///
/// (and likewise for `rnz` parameters), because subdividing a dimension
/// below the consumed one commutes with consuming it. This brings
/// `subdivide_nzip`/`subdivide_rnz` output into the input-level normal form
/// the exchange rules traverse (the paper's `A^(1a) = subdiv 0 2 A`
/// bookkeeping).
pub fn hoist_subdiv() -> crate::rewrite::Rule {
    crate::rewrite::Rule {
        name: "hoist-subdiv",
        apply: |e| {
            let (f, args, is_rnz, r) = match e {
                Expr::Nzip { f, args } => (f, args, false, None),
                Expr::Rnz { r, m, args } => (m, args, true, Some(r)),
                _ => return None,
            };
            let Expr::Lam { params, body } = &**f else {
                return None;
            };
            if params.len() != args.len() {
                return None;
            }
            for (i, p) in params.iter().enumerate() {
                if let Some((d, b)) = unique_subdiv_of_uses(body, p) {
                    let new_body = strip_subdiv(body, p, d, b);
                    let mut new_args = args.clone();
                    new_args[i] = Expr::Subdiv {
                        d,
                        b,
                        arg: Box::new(args[i].clone()),
                    };
                    let new_f = Box::new(Expr::Lam {
                        params: params.clone(),
                        body: Box::new(new_body),
                    });
                    return Some(if is_rnz {
                        Expr::Rnz {
                            r: r.unwrap().clone(),
                            m: new_f,
                            args: new_args,
                        }
                    } else {
                        Expr::Nzip {
                            f: new_f,
                            args: new_args,
                        }
                    });
                }
            }
            None
        },
    }
}

/// If every free occurrence of `x` in `e` is exactly `subdiv d b (var x)`
/// with one consistent `(d, b)`, return it.
fn unique_subdiv_of_uses(e: &Expr, x: &str) -> Option<(usize, usize)> {
    fn walk(e: &Expr, x: &str, found: &mut Option<(usize, usize)>, ok: &mut bool) {
        if !*ok {
            return;
        }
        match e {
            Expr::Subdiv { d, b, arg } if matches!(&**arg, Expr::Var(v) if v == x) => {
                match found {
                    None => *found = Some((*d, *b)),
                    Some((fd, fb)) if *fd == *d && *fb == *b => {}
                    _ => *ok = false,
                }
            }
            Expr::Var(v) if v == x => *ok = false, // bare use blocks hoisting
            Expr::Lam { params, body } => {
                if !params.iter().any(|p| p == x) {
                    walk(body, x, found, ok);
                }
            }
            _ => {
                crate::rewrite::engine::map_children(e, |c| {
                    walk(c, x, found, ok);
                    c.clone()
                });
            }
        }
    }
    let mut found = None;
    let mut ok = true;
    walk(e, x, &mut found, &mut ok);
    if ok {
        found
    } else {
        None
    }
}

/// Replace every `subdiv d b (var x)` with `var x` (shadow-aware).
fn strip_subdiv(e: &Expr, x: &str, d: usize, b: usize) -> Expr {
    match e {
        Expr::Subdiv {
            d: ed,
            b: eb,
            arg,
        } if *ed == d && *eb == b && matches!(&**arg, Expr::Var(v) if v == x) => {
            Expr::Var(x.to_string())
        }
        Expr::Lam { params, body } if params.iter().any(|p| p == x) => e.clone(),
        _ => crate::rewrite::engine::map_children(e, |c| strip_subdiv(c, x, d, b)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::*;
    use crate::eval::{eval, ArrVal, Inputs};
    use crate::layout::Layout;
    use crate::typecheck::Env;

    #[test]
    fn hoist_moves_subdiv_to_input() {
        // map (\r -> reduce + (subdiv 0 2 r)) A
        let e = map(
            lam1(
                "r",
                rnz(
                    add(),
                    lam1("c", reduce(add(), var("c"))),
                    vec![subdiv(0, 2, var("r"))],
                ),
            ),
            input("A"),
        );
        let rule = hoist_subdiv();
        let out = crate::rewrite::rewrite_bottom_up(&[rule], &e);
        // subdiv must now wrap the input, not the bound var
        let s = pretty(&out);
        assert!(
            s.contains("(subdiv 0 2 (in A))"),
            "subdiv not hoisted: {s}"
        );
        assert!(!s.contains("(subdiv 0 2 r)"), "{s}");
        // semantics preserved
        let mut inp = Inputs::new();
        inp.insert(
            "A".into(),
            ArrVal::dense((0..12).map(|i| i as f64).collect(), &[3, 4]),
        );
        assert_eq!(
            eval(&e, &inp).unwrap().to_dense(),
            eval(&out, &inp).unwrap().to_dense()
        );
    }

    #[test]
    fn hoist_blocked_by_bare_use() {
        // r used both subdivided and bare — cannot hoist
        let e = map(
            lam1(
                "r",
                zip(
                    add(),
                    flatten(0, subdiv(0, 2, var("r"))),
                    var("r"),
                ),
            ),
            input("A"),
        );
        assert!((hoist_subdiv().apply)(&e).is_none());
    }

    #[test]
    fn subdivided_map_same_dense_result() {
        let mut inp = Inputs::new();
        inp.insert(
            "v".into(),
            ArrVal::dense((0..12).map(|i| i as f64).collect(), &[12]),
        );
        let env = Env::new().with("v", Layout::row_major(&[12]));
        let ctx = Ctx::new(env);
        let e = map(lam1("x", app2(mul(), var("x"), lit(3.0))), input("v"));
        let s = subdivide_nzip(&e, 4, &ctx).unwrap();
        assert_eq!(
            eval(&e, &inp).unwrap().to_dense(),
            eval(&s, &inp).unwrap().to_dense()
        );
        // repeated subdivision also holds (paper: "or even over repeated
        // subdivisions")
        let s2 = subdivide_nzip(&s, 2, &ctx);
        // outer extent of subdivided arg is 12/4 = 3, not divisible by 2
        assert!(s2.is_none());
    }

    #[test]
    fn subdivided_rnz_same_scalar_result() {
        let mut inp = Inputs::new();
        inp.insert(
            "u".into(),
            ArrVal::dense((0..16).map(|i| (i % 5) as f64).collect(), &[16]),
        );
        inp.insert(
            "v".into(),
            ArrVal::dense((0..16).map(|i| (i % 3) as f64).collect(), &[16]),
        );
        let env = Env::new()
            .with("u", Layout::row_major(&[16]))
            .with("v", Layout::row_major(&[16]));
        let ctx = Ctx::new(env);
        let e = dot(input("u"), input("v"));
        let s = subdivide_rnz(&e, 4, &ctx).unwrap();
        let a = eval(&e, &inp).unwrap().as_scalar().unwrap();
        let b = eval(&s, &inp).unwrap().as_scalar().unwrap();
        assert!((a - b).abs() < 1e-12);
        // and the subdivided form still lowers + executes
        use crate::exec::run;
        let u: Vec<f64> = (0..16).map(|i| (i % 5) as f64).collect();
        let v: Vec<f64> = (0..16).map(|i| (i % 3) as f64).collect();
        let env2 = Env::new()
            .with("u", Layout::row_major(&[16]))
            .with("v", Layout::row_major(&[16]));
        let out = run(&s, &env2, &[("u", &u), ("v", &v)]).unwrap();
        assert!((out[0] - a).abs() < 1e-12);
    }

    #[test]
    fn id_subdivide_matches_box_subdivide() {
        use crate::dsl::intern::SharedArena;
        let env = Env::new()
            .with("u", Layout::row_major(&[16]))
            .with("v", Layout::row_major(&[16]));
        let ctx = Ctx::new(env);
        let cases = [
            (dot(input("u"), input("v")), 4usize),
            (map(lam1("x", var("x")), input("u")), 2),
            (map(lam1("x", var("x")), input("u")), 3), // indivisible
        ];
        for (e, b) in &cases {
            let arena = SharedArena::new();
            let id = arena.intern(e);
            let (bx, ix) = match e {
                Expr::Rnz { .. } => (
                    subdivide_rnz(e, *b, &ctx),
                    subdivide_rnz_id(&arena, id, *b, &ctx),
                ),
                _ => (
                    subdivide_nzip(e, *b, &ctx),
                    subdivide_nzip_id(&arena, id, *b, &ctx),
                ),
            };
            match (&bx, &ix) {
                (Some(x), Some(y)) => assert!(
                    arena.extract(*y).alpha_eq(x),
                    "b={b} on {}:\n  box: {}\n  id:  {}",
                    pretty(e),
                    pretty(x),
                    pretty(&arena.extract(*y))
                ),
                (None, None) => {}
                _ => panic!(
                    "subdivide b={b} fired differently on {}: box={} id={}",
                    pretty(e),
                    bx.is_some(),
                    ix.is_some()
                ),
            }
        }
    }

    #[test]
    fn indivisible_block_rejected() {
        let env = Env::new().with("v", Layout::row_major(&[10]));
        let ctx = Ctx::new(env);
        let e = map(lam1("x", var("x")), input("v"));
        assert!(subdivide_nzip(&e, 3, &ctx).is_none());
        assert!(subdivide_nzip(&e, 0, &ctx).is_none());
    }
}
