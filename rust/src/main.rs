//! `hofdla` — CLI for the pattern-based dense-linear-algebra optimizer.
//!
//! Subcommands:
//!
//! - `optimize <file.dsl> --input A=64x64 …` — run the full pipeline on
//!   DSL source and print the ranked rearrangements.
//! - `enumerate --family <f> --n <n> [--b <b>]` — list the rearrangements
//!   of a matmul family (naive / rnz / maps / rnz2 / all).
//! - `bench <table1|table2|fig3|fig4|fig5|fig6|gpu|baselines|all>` —
//!   regenerate a paper table/figure.
//! - `run-artifact <name> [--n <n>]` — execute an AOT artifact through
//!   PJRT.
//! - `serve --demo [--clients N] [--queue-cap N]` — start the coordinator
//!   and run a demo workload through the typed front door, including an
//!   N-client concurrent burst against a queue of the given capacity.

use hofdla::bench_support::BenchConfig;
use hofdla::coordinator::{Config, Coordinator, OptimizeSpec, RankBy};
use hofdla::enumerate::{enumerate_all, starts};
use hofdla::experiments::{self, MatmulOpts};
use hofdla::layout::Layout;
use hofdla::rewrite::Ctx;
use hofdla::typecheck::Env;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "usage:\n  hofdla optimize <file.dsl> --input NAME=DIMxDIM [--rank cost|cachesim] [--subdivide-rnz B] [--top K] [--prune] [--verify] [--budget N] [--deadline-ms MS] [--shards N] [--exec-threads N]\n  hofdla enumerate --family naive|rnz|maps|rnz2|all [--n N] [--b B]\n  hofdla bench table1|table2|fig3|fig4|fig5|fig6|gpu|baselines|all [--n N] [--b B] [--sim]\n  hofdla run-artifact <name> [--n N]\n  hofdla serve --demo [--clients N] [--queue-cap N]".to_string()
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn flag_usize(args: &[String], name: &str, default: usize) -> usize {
    flag_value(args, name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn flag_u64(args: &[String], name: &str, default: u64) -> u64 {
    flag_value(args, name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn run(args: &[String]) -> hofdla::Result<()> {
    let err = hofdla::Error::Coordinator;
    match args.first().map(|s| s.as_str()) {
        Some("optimize") => {
            let file = args
                .get(1)
                .filter(|a| !a.starts_with("--"))
                .ok_or_else(|| err(usage()))?;
            let source = std::fs::read_to_string(file)
                .map_err(|e| err(format!("read {file}: {e}")))?;
            let mut inputs = Vec::new();
            for (i, a) in args.iter().enumerate() {
                if a == "--input" {
                    let spec = args.get(i + 1).ok_or_else(|| err(usage()))?;
                    let (name, dims) = spec
                        .split_once('=')
                        .ok_or_else(|| err(format!("bad --input {spec}")))?;
                    let shape: Vec<usize> = dims
                        .split('x')
                        .map(|d| d.parse().map_err(|_| err(format!("bad dim in {spec}"))))
                        .collect::<hofdla::Result<_>>()?;
                    inputs.push((name.to_string(), shape));
                }
            }
            let rank_by = match flag_value(args, "--rank") {
                Some("cachesim") => RankBy::CacheSim,
                _ => RankBy::CostModel,
            };
            // The builder validates the knobs at build time, so a bad
            // flag value fails here with a typed error, not mid-search.
            let spec = OptimizeSpec::builder(source)
                .inputs(inputs)
                .rank_by(rank_by)
                .subdivide_rnz(
                    flag_value(args, "--subdivide-rnz").and_then(|v| v.parse::<usize>().ok()),
                )
                .top_k(flag_usize(args, "--top", 12))
                .prune(args.iter().any(|a| a == "--prune"))
                .verify(args.iter().any(|a| a == "--verify"))
                .budget(flag_u64(args, "--budget", 0))
                .deadline_ms(flag_u64(args, "--deadline-ms", 0))
                .shards(flag_usize(args, "--shards", 0))
                .exec_threads(flag_usize(args, "--exec-threads", 0))
                .build()?;
            let r = hofdla::coordinator::optimize(&spec)?;
            println!("explored {} rearrangements", r.variants_explored);
            if r.programs_verified > 0 {
                println!("winner statically verified (bounds, init, disjointness)");
            }
            if let Some(ex) = &r.exec {
                println!(
                    "exec rehearsal: cert {} parallel / {} serial loops; ran with {} thread(s){}",
                    ex.cert_parallel_loops,
                    ex.cert_serial_loops,
                    ex.threads_used,
                    if ex.serial_fallback { " (serial fallback)" } else { "" },
                );
            }
            println!("{:<28} {:>14}", "HoF order", "score");
            for (k, s) in &r.ranking {
                println!("{k:<28} {s:>14.1}");
            }
            println!("\nbest: {}\n{}", r.best, r.best_expr);
            println!(
                "search: expanded={} generated={} pruned={} type_rejects={} bound_updates={} shards={} extractions={}",
                r.stats.expanded,
                r.stats.generated,
                r.stats.pruned,
                r.stats.type_rejects,
                r.stats.bound_updates,
                r.stats.shards,
                r.stats.extracted(),
            );
            println!(
                "anytime: gap={:.3} complete={} frontier_open={}{}{}",
                r.certified_gap,
                r.stats.complete,
                r.stats.frontier_open,
                if r.stats.budget_hit { " (budget hit)" } else { "" },
                if r.stats.deadline_hit { " (deadline hit)" } else { "" },
            );
            Ok(())
        }
        Some("enumerate") => {
            let n = flag_usize(args, "--n", 64);
            let b = flag_usize(args, "--b", 4);
            let family = flag_value(args, "--family").unwrap_or("naive");
            let start = match family {
                "naive" => starts::matmul_naive_variant(),
                "rnz" => starts::matmul_rnz_subdivided_variant(b),
                "maps" => starts::matmul_maps_subdivided_variant(b),
                "rnz2" => starts::matmul_rnz_twice_subdivided_variant(b, b),
                "all" => starts::matmul_all_subdivided_variant(b),
                other => return Err(err(format!("unknown family '{other}'"))),
            };
            let env = Env::new()
                .with("A", Layout::row_major(&[n, n]))
                .with("B", Layout::row_major(&[n, n]));
            let variants = enumerate_all(&start, &Ctx::new(env), 4096)?;
            println!(
                "family={family} n={n} b={b}: {} rearrangements",
                variants.len()
            );
            for v in &variants {
                println!("  {}", v.display_key());
            }
            Ok(())
        }
        Some("bench") => {
            let which = args.get(1).map(|s| s.as_str()).unwrap_or("all");
            let mut opts = MatmulOpts {
                n: flag_usize(args, "--n", hofdla::bench_support::env_size(256)),
                b: flag_usize(args, "--b", 16),
                bench: BenchConfig::quick(),
                measure_time: true,
                simulate: args.iter().any(|a| a == "--sim"),
            };
            if opts.n % (opts.b * opts.b) != 0 {
                opts.b = 4;
            }
            let run_one = |name: &str, opts: &MatmulOpts| -> hofdla::Result<()> {
                let e = match name {
                    "table1" => experiments::table1(opts)?,
                    "table2" => experiments::table2(opts)?,
                    "fig3" => experiments::fig3(opts.n, opts.b, &opts.bench)?,
                    "fig4" => experiments::fig4(opts)?,
                    "fig5" => experiments::fig5(opts)?,
                    "fig6" => experiments::fig6(opts)?,
                    "gpu" => experiments::gpu_sim(opts.n.min(256), opts.b)?,
                    "baselines" => experiments::baselines_experiment(opts.n, &opts.bench)?,
                    other => return Err(err(format!("unknown bench '{other}'"))),
                };
                print!("{}", e.render());
                Ok(())
            };
            if which == "all" {
                for name in [
                    "table1", "table2", "fig3", "fig4", "fig5", "fig6", "gpu", "baselines",
                ] {
                    run_one(name, &opts)?;
                }
                Ok(())
            } else {
                run_one(which, &opts)
            }
        }
        Some("run-artifact") => {
            let name = args.get(1).ok_or_else(|| err(usage()))?;
            let n = flag_usize(args, "--n", 256);
            let mut rt = hofdla::runtime::Runtime::cpu()?;
            let exe = rt.load(&hofdla::runtime::artifact_path(name))?;
            println!(
                "loaded {name} on {} ({} params)",
                rt.platform(),
                exe.n_params
            );
            if exe.n_params == 2 {
                let a = vec![1f32; n * n];
                let out = rt.run_f32(&exe, &[(&a, &[n, n]), (&a, &[n, n])])?;
                println!(
                    "output[0..4] = {:?} (len {})",
                    &out[..4.min(out.len())],
                    out.len()
                );
            } else {
                println!("(no demo input convention for {} params)", exe.n_params);
            }
            Ok(())
        }
        Some("serve") => {
            let clients = flag_usize(args, "--clients", 8);
            let queue_cap = flag_usize(args, "--queue-cap", 256);
            let c = Coordinator::start(Config {
                queue_cap,
                ..Config::default()
            })?;
            println!("coordinator started (queue_cap={queue_cap}): demo workload");
            let spec = OptimizeSpec::builder(
                "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))",
            )
            .input("A", &[128, 128])
            .input("B", &[128, 128])
            .rank_by(RankBy::CacheSim)
            .subdivide_rnz(16)
            .verify(true)
            .exec_threads(2)
            .build()?;
            let r = c.submit_optimize(spec.clone())?.wait()?;
            println!(
                "explored {} rearrangements; best = {} (gap {:.3})",
                r.variants_explored, r.best, r.certified_gap
            );
            // Parallel-safety flavor: the winner's dependence certificate
            // splits its map loops into parallel/serial, and the rehearsal
            // ran it through the certificate-gated threaded executor.
            if let Some(ex) = &r.exec {
                println!(
                    "parallel certificate: {} parallel / {} serial map loop(s); \
                     rehearsed with {} thread(s){}",
                    ex.cert_parallel_loops,
                    ex.cert_serial_loops,
                    ex.threads_used,
                    if ex.serial_fallback { " (serial fallback)" } else { "" },
                );
            }
            // Cross-request sharing flavor: the same kernel resubmitted
            // with every binder α-renamed is answered from the result
            // cache through the canonical key — no fresh search (watch
            // opt_cache_hits_canonical tick in the metrics line, with
            // search_expanded unchanged).
            let mut renamed = spec.clone();
            renamed.source = "(map (lam (rowOfA) (map (lam (colOfB) (rnz + * rowOfA colOfB)) \
                 (flip 0 (in B)))) (in A))"
                .into();
            let rn = c.submit_optimize(renamed)?.wait()?;
            println!(
                "α-renamed resubmission: best = {} (canonical cache hit: {})",
                rn.best,
                rn.best == r.best
            );
            // Anytime flavor: the same job under a 4-expansion budget still
            // returns a winner, now with a certified optimality gap.
            let mut budgeted = spec.clone();
            budgeted.budget = 4;
            let b = c.submit_optimize(budgeted)?.wait()?;
            println!(
                "budgeted (4 expansions): best = {} gap={:.3} complete={}",
                b.best, b.certified_gap, b.stats.complete
            );
            // Admission-control flavor: --clients concurrent submissions
            // of the (now cached) kernel through the typed front door.
            // With the default --queue-cap nothing sheds; rerun with e.g.
            // `--clients 32 --queue-cap 1` to watch typed Overloaded
            // rejections and the shed counter move instead.
            let mut shed = 0usize;
            let mut handles = Vec::new();
            for _ in 0..clients {
                match c.submit_optimize(spec.clone()) {
                    Ok(h) => handles.push(h),
                    Err(hofdla::Error::Overloaded { queue_depth }) => {
                        shed += 1;
                        println!("  shed: intake queue at capacity ({queue_depth} queued)");
                    }
                    Err(e) => return Err(e),
                }
            }
            for h in handles {
                h.wait()?;
            }
            println!(
                "{} concurrent clients: {} answered, {} shed",
                clients,
                clients - shed,
                shed
            );
            println!("metrics: {}", c.metrics.summary());
            Ok(())
        }
        _ => {
            println!("{}", usage());
            Ok(())
        }
    }
}
