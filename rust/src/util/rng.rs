//! Deterministic xoshiro256++ PRNG.
//!
//! The `rand` crate is not available in this offline environment, so tests,
//! property checks and workload generators use this small, well-known
//! generator (Blackman & Vigna). Seeded via SplitMix64 as recommended.

/// xoshiro256++ pseudo-random number generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded with SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next_sm(), next_sm(), next_sm(), next_sm()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Rejection-free modulo bias is negligible for our test sizes, but
        // use Lemire's method anyway for correctness.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range: empty range");
        lo + self.below(hi - lo)
    }

    /// Random boolean with probability `p` of true.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fill a vector with uniform values in [-1, 1).
    pub fn fill_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.range_f64(-1.0, 1.0)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            let x = r.below(5);
            assert!(x < 5);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }
}
