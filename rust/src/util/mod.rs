//! Small self-contained utilities: a deterministic PRNG (no `rand` crate is
//! available offline) and assorted helpers shared across modules.

mod lru;
mod rng;

pub use lru::Lru;
pub use rng::Rng;

/// Greatest common divisor.
pub fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// All divisors of `n` in ascending order.
pub fn divisors(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            out.push(i);
            if i != n / i {
                out.push(n / i);
            }
        }
        i += 1;
    }
    out.sort_unstable();
    out
}

/// Max relative/absolute difference between two equally-sized slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "max_abs_diff: length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// `true` if slices agree within `tol` absolutely.
pub fn allclose(a: &[f64], b: &[f64], tol: f64) -> bool {
    a.len() == b.len() && max_abs_diff(a, b) <= tol
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_basic() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(7, 13), 1);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(gcd(5, 0), 5);
    }

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(16), vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn allclose_basic() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-13], 1e-12));
        assert!(!allclose(&[1.0], &[1.1], 1e-12));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-12));
    }
}
