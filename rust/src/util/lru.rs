//! A small least-recently-used cache (no external crates are available
//! offline). Eviction scans for the oldest entry, which is O(capacity) —
//! fine for the coordinator's result cache (capacity ≲ a few hundred);
//! swap in a linked structure if a hot path ever needs more.

use std::collections::HashMap;
use std::hash::Hash;

/// Bounded map evicting the least-recently-touched entry on overflow.
#[derive(Debug)]
pub struct Lru<K, V> {
    cap: usize,
    tick: u64,
    map: HashMap<K, (V, u64)>,
}

impl<K: Eq + Hash + Clone, V: Clone> Lru<K, V> {
    /// Create a cache holding at most `cap` entries (minimum 1).
    pub fn new(cap: usize) -> Self {
        Lru {
            cap: cap.max(1),
            tick: 0,
            map: HashMap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up and refresh an entry.
    pub fn get(&mut self, k: &K) -> Option<V> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(k).map(|(v, t)| {
            *t = tick;
            v.clone()
        })
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used one
    /// if the cache is full.
    pub fn put(&mut self, k: K, v: V) {
        self.tick += 1;
        if !self.map.contains_key(&k) && self.map.len() >= self.cap {
            if let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        let tick = self.tick;
        self.map.insert(k, (v, tick));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_and_put_round_trip() {
        let mut c: Lru<String, u32> = Lru::new(4);
        assert!(c.is_empty());
        assert_eq!(c.get(&"a".into()), None);
        c.put("a".into(), 1);
        assert_eq!(c.get(&"a".into()), Some(1));
        c.put("a".into(), 2);
        assert_eq!(c.get(&"a".into()), Some(2));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: Lru<u32, u32> = Lru::new(2);
        c.put(1, 10);
        c.put(2, 20);
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(&1), Some(10));
        c.put(3, 30);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&2), None, "LRU entry should have been evicted");
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&3), Some(30));
    }

    #[test]
    fn capacity_has_a_floor_of_one() {
        let mut c: Lru<u32, u32> = Lru::new(0);
        c.put(1, 10);
        assert_eq!(c.get(&1), Some(10));
        c.put(2, 20);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&2), Some(20));
    }
}
