//! Steinhaus–Johnson–Trotter permutation enumeration (paper refs [16][17]).
//!
//! Generates all permutations of `0..n` such that consecutive permutations
//! differ by one adjacent transposition — exactly the moves the exchange
//! rules can realise on the HoF spine. The BFS in [`super::enumerate_all`]
//! is the robust path (it skips inapplicable swaps); SJT is exposed for the
//! cases where every adjacent swap is known to apply, and as the reference
//! for the enumeration tests.

/// All permutations of `0..n` in SJT order; each differs from its
/// predecessor by one adjacent swap. `n = 0` yields one empty permutation.
pub fn sjt_permutations(n: usize) -> Vec<Vec<usize>> {
    let mut perm: Vec<usize> = (0..n).collect();
    // direction: -1 = looking left, +1 = looking right
    let mut dir: Vec<isize> = vec![-1; n];
    let mut out = vec![perm.clone()];
    loop {
        // find the largest mobile element
        let mut mobile: Option<usize> = None; // index into perm
        for i in 0..n {
            let j = (i as isize + dir[perm[i]]) as i64;
            if j < 0 || j >= n as i64 {
                continue;
            }
            let j = j as usize;
            if perm[j] < perm[i]
                && mobile.map(|mi| perm[i] > perm[mi]).unwrap_or(true)
            {
                mobile = Some(i);
            }
        }
        let Some(i) = mobile else { break };
        let v = perm[i];
        let j = (i as isize + dir[v]) as usize;
        perm.swap(i, j);
        // reverse direction of all elements larger than v
        for d in v + 1..n {
            dir[d] = -dir[d];
        }
        out.push(perm.clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn counts_are_factorials() {
        assert_eq!(sjt_permutations(0).len(), 1);
        assert_eq!(sjt_permutations(1).len(), 1);
        assert_eq!(sjt_permutations(2).len(), 2);
        assert_eq!(sjt_permutations(3).len(), 6);
        assert_eq!(sjt_permutations(4).len(), 24);
        assert_eq!(sjt_permutations(5).len(), 120);
    }

    #[test]
    fn all_distinct_and_valid() {
        let perms = sjt_permutations(4);
        let set: HashSet<&Vec<usize>> = perms.iter().collect();
        assert_eq!(set.len(), 24);
        for p in &perms {
            let mut q = p.clone();
            q.sort_unstable();
            assert_eq!(q, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn consecutive_differ_by_adjacent_swap() {
        let perms = sjt_permutations(5);
        for w in perms.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            let diffs: Vec<usize> = (0..5).filter(|&i| a[i] != b[i]).collect();
            assert_eq!(diffs.len(), 2, "{a:?} -> {b:?}");
            assert_eq!(diffs[1], diffs[0] + 1, "swap not adjacent");
            assert_eq!(a[diffs[0]], b[diffs[1]]);
            assert_eq!(a[diffs[1]], b[diffs[0]]);
        }
    }

    #[test]
    fn starts_with_identity() {
        assert_eq!(sjt_permutations(3)[0], vec![0, 1, 2]);
    }
}
