//! Enumeration of HoF rearrangements (paper §4).
//!
//! The nesting of HoFs in a (fused, subdivided) expression forms a list —
//! the *spine*. Adjacent spine levels can be swapped by the exchange rules
//! of [`crate::rewrite::exchange`], each swap pairing with a `flip` of the
//! logical layout. Enumerating all permutations by adjacent transpositions
//! is exactly the Steinhaus–Johnson–Trotter scheme the paper cites; here we
//! additionally keep the search robust by breadth-first exploring the swap
//! graph and deduplicating on the paper's display form (the two/three
//! `rnz`s of a subdivided reduction are "not differentiated", so 4 HoFs
//! with two rnzs yield the paper's 12 cases, not 24).
//!
//! # The search engine (ISSUE 2–4)
//!
//! [`enumerate_search`] runs the BFS natively on
//! [`ExprId`]s: candidate generation ([`try_swap_at_id`]), normalization
//! (an [`IdRewriter`] over the id-native rule set) and typechecking
//! ([`crate::typecheck::infer_id`]) all happen inside one concurrent
//! [`SharedArena`] shared by every worker shard, so `Box<Expr>` trees are
//! rebuilt only once per *kept* candidate at the output boundary — never
//! per node per rule probe, and never at a BFS level boundary.
//!
//! - **Sharding** — each BFS level's frontier is split round-robin across
//!   worker shards. All shards build candidates into the *same*
//!   hash-sharded arena (ISSUE 4), so frontier variants cross shard and
//!   level boundaries as plain ids: a parent expanded this level was
//!   interned exactly once, when it was first kept, no matter which shard
//!   keeps expanding its descendants. Each shard still owns its
//!   *caches* — normalize memo, typecheck/score/bound maps — all keyed by
//!   the shared arena's (thread-stable) ids. Every expansion is tagged
//!   `(shard, seq)` and the deterministic merge orders candidates by
//!   frontier tag, parents in frontier order and children in swap-depth
//!   order, so the result order is identical to the serial queue BFS no
//!   matter how many shards run or how they were scheduled.
//! - **Scoring** — with [`SearchOptions::score`] set (implied by
//!   pruning), candidates are lowered and cost-estimated *in the arena*
//!   via [`crate::costmodel::estimate_id`]; the per-candidate path
//!   allocates no `Box<Expr>` tree (ISSUE 3 — extraction happens once per
//!   *kept* candidate at the output boundary, and [`SearchStats`] reports
//!   the per-shard extraction counts so that stays observable).
//! - **Pruning (branch-and-bound)** — with
//!   [`SearchOptions::prune_slack`] set, each candidate's
//!   [`crate::costmodel::spine_lower_bound_id`] — a provable lower bound
//!   on its true score, computed from the spine without lowering — is
//!   compared against `slack × best-known-score` (an atomic shared across
//!   shards). A candidate whose bound exceeds the threshold is cut
//!   before it is kept: never lowered, never scored, never extracted,
//!   excluded from the result set. Cut candidates *do* remain expansion
//!   sources — the swap graph stays connected, so reachability (and with
//!   it the winner) is preserved by construction, not by luck: since the
//!   bound never exceeds the true score, the eventual winner always
//!   satisfies `bound ≤ score ≤ best-known` and can never be cut at the
//!   default slack ([`DEFAULT_PRUNE_SLACK`] = 1.0). The bound only
//!   tightens at level boundaries, so pruning decisions stay
//!   deterministic under any shard count. (Its partial descent also
//!   makes it sound on raw, mid-rewrite exchange output —
//!   `tests/lower_id_props.rs` pins `bound(raw) ≤ score(normalize(raw))`
//!   — which is what would let a future engine gate generation itself;
//!   this engine consults it post-normalization only, where the read is
//!   memoized per candidate.)
//! - **Dedup** — candidates are deduplicated on an integer label-token
//!   key (the collapsed spine permutation), not on formatted
//!   `display_key()` strings; display strings are produced only at the
//!   output boundary. (Dedup *cannot* key on raw `ExprId`s: fresh-binder
//!   rules make alpha-variants of the same permutation intern to
//!   different ids, which would break the paper's 6/12 counts — the
//!   per-shard typecheck cache is what keys on `ExprId`.)
//!
//! The seed `Box<Expr>` expansion path is kept alive behind
//! [`crate::dsl::intern::with_memo_disabled`] and the differential tests
//! hold both engines to identical variant sets and orders.

mod sjt;
pub mod starts;

pub use sjt::sjt_permutations;

use crate::costmodel::{estimate_id, spine_lower_bound_id};
use crate::dsl::intern::{memo_enabled, ExprId, Node, SharedArena};
use crate::dsl::Expr;
use crate::rewrite::{exchange, normalize, normalize_id_rules, Ctx, IdRewriter};
use crate::typecheck::Env;
use crate::{Error, Result};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};

/// One rearrangement of the computation: the expression plus the spine
/// labels from outermost to innermost (`["mapA", "rnz", "mapB"]` reads as
/// the paper's table rows).
#[derive(Clone, Debug)]
pub struct Variant {
    pub expr: Expr,
    pub labels: Vec<String>,
}

impl Variant {
    pub fn new(expr: Expr, labels: &[&str]) -> Self {
        Variant {
            expr,
            labels: labels.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// The paper's display form: collapsed labels joined by spaces
    /// (`rnz*` labels are not differentiated).
    pub fn display_key(&self) -> String {
        self.labels
            .iter()
            .map(|l| collapse(l))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Collapse a label to its display form: all `rnz…` labels are the same.
pub fn collapse(label: &str) -> &str {
    if label.starts_with("rnz") {
        "rnz"
    } else {
        label
    }
}

/// The spine: the chain of HoF kinds from the root inward, descending
/// through operator lambdas.
pub fn spine_kinds(e: &Expr) -> Vec<&'static str> {
    let mut out = Vec::new();
    let mut cur = e;
    loop {
        match cur {
            Expr::Nzip { f, .. } => {
                out.push("map");
                match &**f {
                    Expr::Lam { body, .. } => cur = body,
                    _ => break,
                }
            }
            Expr::Rnz { m, .. } => {
                out.push("red");
                match &**m {
                    Expr::Lam { body, .. } => cur = body,
                    _ => break,
                }
            }
            _ => break,
        }
    }
    out
}

/// Try to swap spine levels `depth` and `depth+1` by applying an exchange
/// rule at that node. Returns the normalized full expression on success.
pub fn try_swap_at(e: &Expr, depth: usize, ctx: &Ctx) -> Option<Expr> {
    fn rec(e: &Expr, depth: usize, ctx: &Ctx) -> Option<Expr> {
        if depth == 0 {
            return exchange::map_map(e, ctx)
                .or_else(|| exchange::map_map_nested(e, ctx))
                .or_else(|| exchange::map_rnz(e, ctx))
                .or_else(|| exchange::rnz_map(e, ctx))
                .or_else(|| exchange::rnz_rnz(e, ctx));
        }
        match e {
            Expr::Nzip { f, args } => {
                let Expr::Lam { params, body } = &**f else {
                    return None;
                };
                if params.len() != args.len() {
                    return None;
                }
                let mut ctx2 = ctx.clone();
                for (p, a) in params.iter().zip(args) {
                    let elem = ctx.layout_of(a).ok()?.peel_outer().ok()?;
                    ctx2.vars.insert(p.clone(), elem);
                }
                let new_body = rec(body, depth - 1, &ctx2)?;
                Some(Expr::Nzip {
                    f: Box::new(Expr::Lam {
                        params: params.clone(),
                        body: Box::new(new_body),
                    }),
                    args: args.clone(),
                })
            }
            Expr::Rnz { r, m, args } => {
                let Expr::Lam { params, body } = &**m else {
                    return None;
                };
                if params.len() != args.len() {
                    return None;
                }
                let mut ctx2 = ctx.clone();
                for (p, a) in params.iter().zip(args) {
                    let elem = ctx.layout_of(a).ok()?.peel_outer().ok()?;
                    ctx2.vars.insert(p.clone(), elem);
                }
                let new_body = rec(body, depth - 1, &ctx2)?;
                Some(Expr::Rnz {
                    r: r.clone(),
                    m: Box::new(Expr::Lam {
                        params: params.clone(),
                        body: Box::new(new_body),
                    }),
                    args: args.clone(),
                })
            }
            _ => None,
        }
    }
    rec(e, depth, ctx).map(|x| normalize(&x))
}

/// Id-native twin of [`try_swap_at`]: descend the interned spine to
/// `depth` (binding parameter layouts as it goes) and apply an exchange
/// rule there. Unlike [`try_swap_at`] the result is **not** normalized —
/// the caller runs its own [`IdRewriter`] over the same arena so the
/// normalize memo is shared across every candidate of the search. The
/// arena comes in by shared reference: all search shards generate
/// candidates into one [`SharedArena`] concurrently.
pub fn try_swap_at_id(
    arena: &SharedArena,
    id: ExprId,
    depth: usize,
    ctx: &Ctx,
) -> Option<ExprId> {
    if depth == 0 {
        if let Some(r) = exchange::map_map_id(arena, id, ctx) {
            return Some(r);
        }
        if let Some(r) = exchange::map_map_nested_id(arena, id, ctx) {
            return Some(r);
        }
        if let Some(r) = exchange::map_rnz_id(arena, id, ctx) {
            return Some(r);
        }
        if let Some(r) = exchange::rnz_map_id(arena, id, ctx) {
            return Some(r);
        }
        return exchange::rnz_rnz_id(arena, id, ctx);
    }
    match arena.get(id).clone() {
        Node::Nzip { f, args } => {
            let Node::Lam { params, body } = arena.get(f).clone() else {
                return None;
            };
            if params.len() != args.len() {
                return None;
            }
            let mut ctx2 = ctx.clone();
            for (p, &a) in params.iter().zip(&args) {
                let elem = ctx.layout_of_id(arena, a).ok()?.peel_outer().ok()?;
                ctx2.vars.insert(p.clone(), elem);
            }
            let new_body = try_swap_at_id(arena, body, depth - 1, &ctx2)?;
            let lam = arena.insert(Node::Lam {
                params,
                body: new_body,
            });
            Some(arena.insert(Node::Nzip { f: lam, args }))
        }
        Node::Rnz { r, m, args } => {
            let Node::Lam { params, body } = arena.get(m).clone() else {
                return None;
            };
            if params.len() != args.len() {
                return None;
            }
            let mut ctx2 = ctx.clone();
            for (p, &a) in params.iter().zip(&args) {
                let elem = ctx.layout_of_id(arena, a).ok()?.peel_outer().ok()?;
                ctx2.vars.insert(p.clone(), elem);
            }
            let new_body = try_swap_at_id(arena, body, depth - 1, &ctx2)?;
            let lam = arena.insert(Node::Lam {
                params,
                body: new_body,
            });
            Some(arena.insert(Node::Rnz { r, m: lam, args }))
        }
        _ => None,
    }
}

/// Default branch-and-bound slack for [`SearchOptions::prune_slack`].
///
/// The cut compares [`crate::costmodel::spine_lower_bound_id`] — a
/// *provable lower bound* on a candidate's true cost-model score, never
/// exceeding it (pinned by `tests/lower_id_props.rs`) — against
/// `slack × best-known-score`. At slack `1.0` a cut candidate therefore
/// provably scores worse than a variant already in hand, so the winner
/// can never be cut, on *any* workload — unlike the earlier heuristic
/// (PR 2) that compared full scores and needed a ~64× cushion derived
/// from the cost-model constants and a ≤ ~20-track assumption.
///
/// Since the bound gained rearrangement-sensitive per-track input-traffic
/// terms (`COST_MODEL_VERSION` 2), this default cut *actually fires*:
/// within one family the bound varies with the permutation, and dominated
/// rearrangements — e.g. ones forced to stream a matrix at a large stride
/// — bound strictly above the family's best score. On the subdivided
/// matmul families, roughly the worse half of the variant set is cut
/// before being lowered, scored, or extracted (`pruned > 0` is pinned by
/// `tests/search_props.rs`, as is winner identity with exhaustive mode).
/// Cut candidates still expand, so pruned mode walks the same swap graph
/// and the winner is preserved by construction; what it saves is the
/// per-candidate lower + estimate + output-boundary extraction.
pub const DEFAULT_PRUNE_SLACK: f64 = 1.0;

/// Hard cap on shard fan-out, for the auto path *and* explicit
/// [`SearchOptions::shards`] requests alike: several coordinator workers
/// may each be searching at once, and an unbounded per-job fan-out would
/// oversubscribe the machine workers-fold (same rationale as the ranking
/// fan-out cap in the pipeline). The cap equals the widest arm of CI's
/// `SEARCH_SHARDS` ∈ {1, 2, 8} differential matrix, so every CI width
/// runs at its nominal fan-out; [`SearchStats::shards`] always reports
/// the *effective* (post-clamp) count.
pub const MAX_SEARCH_SHARDS: usize = 8;

/// Knobs for [`enumerate_search`].
#[derive(Clone, Copy, Debug)]
pub struct SearchOptions {
    /// Stop once this many candidates have been *discovered* (kept +
    /// bound-cut). Exhaustive mode discovers exactly what it keeps, so
    /// this is the classic kept-variant cap there; under pruning it also
    /// caps the expansion work itself (cut candidates stay expansion
    /// sources, so a kept-only cap would let a heavily-cut search walk
    /// arbitrarily far past it). Pruned and exhaustive searches share one
    /// discovery sequence, so a binding limit truncates both at the same
    /// prefix and winner parity is preserved.
    pub limit: usize,
    /// Worker shards for frontier expansion: `1` = serial, `0` = auto
    /// (one per available core). Both the auto path and explicit counts
    /// are clamped to [`MAX_SEARCH_SHARDS`]; [`SearchStats::shards`]
    /// reports the effective count.
    pub shards: usize,
    /// Branch-and-bound slack: a candidate whose partial-spine lower
    /// bound ([`crate::costmodel::spine_lower_bound_id`]) exceeds
    /// `slack × best-known-score` is cut *before* it is lowered, scored,
    /// or extracted, and excluded from the result set. Cut candidates are
    /// still expanded (the swap graph stays connected), so — the bound
    /// never exceeding the true score — [`DEFAULT_PRUNE_SLACK`] (= 1.0)
    /// never loses the eventual winner. `None` keeps the search
    /// exhaustive.
    pub prune_slack: Option<f64>,
    /// Score candidates with the analytic cost model during the BFS and
    /// return the scores (implied by `prune_slack`; the pipeline reuses
    /// them as the ranking, skipping a second scoring pass).
    pub score: bool,
}

impl Default for SearchOptions {
    fn default() -> Self {
        SearchOptions {
            limit: 4096,
            shards: 0,
            prune_slack: None,
            score: false,
        }
    }
}

/// Aggregate counters from one [`enumerate_search`] run. Surfaced through
/// [`crate::coordinator::Metrics`] on production traffic so pruning
/// effectiveness (and the no-extraction invariant of the score path) is
/// observable, not just asserted in tests.
#[derive(Clone, Debug, Default)]
pub struct SearchStats {
    /// Frontier parents expanded (BFS nodes whose swaps were tried).
    /// Includes bound-cut nodes: they leave the result set but stay
    /// expansion sources, so the swap graph — and with it the winner —
    /// stays reachable under pruning.
    pub expanded: usize,
    /// Successful exchange applications (pre-dedup).
    pub generated: usize,
    /// Variants kept in the result set.
    pub kept: usize,
    /// Candidates cut by the lower-bound branch-and-bound (counted
    /// per generated instance, pre-dedup; each was rejected before being
    /// lowered, scored, or extracted).
    pub pruned: usize,
    /// Candidates dropped because they no longer typechecked.
    pub type_rejects: usize,
    /// Times the shared best-known score tightened during the merge step.
    pub bound_updates: usize,
    /// Worker shards used (the effective count after clamping to
    /// [`MAX_SEARCH_SHARDS`]).
    pub shards: usize,
    /// Output-boundary `Box<Expr>` extractions attributed to the shard
    /// that *generated* each kept candidate. The layout is stable and
    /// shard-count-independent in the sense coordinator `Metrics` merges
    /// need: always exactly `shards` entries (padded with zeros for
    /// shards that happened to generate no kept candidate), regardless of
    /// runtime scheduling. On the id-native path the total is exactly the
    /// output-boundary extraction of *kept* candidates (`kept - 1`: the
    /// start is never extracted, duplicates are deduped before
    /// extraction) and equals the shared arena's
    /// [`SharedArena::extractions`] counter — the per-candidate
    /// score/lower path never extracts, and nothing is extracted at BFS
    /// level boundaries.
    pub extracted_per_shard: Vec<u64>,
}

impl SearchStats {
    /// Total `Box<Expr>` extractions across all shards.
    pub fn extracted(&self) -> u64 {
        self.extracted_per_shard.iter().sum()
    }
}

/// Everything [`enumerate_search`] produces.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub variants: Vec<Variant>,
    /// Cost-model score per variant (same order as `variants`; empty when
    /// scoring was off).
    pub scores: Vec<f64>,
    pub stats: SearchStats,
}

/// The shared best-known score: an `f64` min over an atomic word, the
/// bound every shard consults when pruning.
struct AtomicScore(AtomicU64);

impl AtomicScore {
    fn new(v: f64) -> Self {
        AtomicScore(AtomicU64::new(v.to_bits()))
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Lower the bound to `v` if `v` is smaller; returns whether the
    /// bound actually tightened.
    fn fetch_min(&self, v: f64) -> bool {
        let mut cur = self.0.load(Ordering::Relaxed);
        while v < f64::from_bits(cur) {
            match self.0.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
        false
    }
}

/// Collapse a label sequence to its integer token key — the dedup key of
/// the BFS (two permutations collide exactly when their `display_key()`s
/// would be equal, but no `String` is ever formatted here).
fn label_key(labels: &[String], tokens: &mut Vec<String>) -> Vec<u8> {
    labels
        .iter()
        .map(|l| {
            let c = collapse(l);
            match tokens.iter().position(|t| t == c) {
                Some(i) => i as u8,
                None => {
                    tokens.push(c.to_string());
                    (tokens.len() - 1) as u8
                }
            }
        })
        .collect()
}

/// Analytic cost-model score of one interned candidate (the paper's
/// early-cut metric): lower the loop nest and estimate *in the arena*
/// ([`crate::costmodel::estimate_id`] — no `Box<Expr>` is ever rebuilt to
/// score a candidate), then collapse to the scalar score. Candidates that
/// do not lower score `+∞`; they are kept (ranked last) rather than
/// failing the job, as on the seed path — and since `+∞` can never become
/// the shared bound, they are also never the reason something else gets
/// cut.
fn score_expr_id(arena: &SharedArena, id: ExprId, env: &Env) -> f64 {
    match estimate_id(arena, id, env) {
        Ok(est) => est.score(),
        Err(_) => f64::INFINITY,
    }
}

/// One surviving child candidate, still unextracted: the id-native path
/// carries only the interned id (in the search's shared arena) and the
/// merge step rebuilds a `Box<Expr>` *only* for children that survive
/// dedup *and* the bound cut — so duplicates reached along several swap
/// paths, and cut candidates, never cost a tree. The seed `Box<Expr>`
/// engine already owns the tree and passes it through.
struct Child {
    labels: Vec<String>,
    /// `Some` on the seed engine path; `None` means "extract `nid` from
    /// the shared arena iff kept".
    expr: Option<Expr>,
    nid: ExprId,
    /// Cut by the branch-and-bound: excluded from the result set (never
    /// lowered, scored, or extracted) but still enqueued as an expansion
    /// source.
    cut: bool,
}

/// One BFS frontier entry. Distinct from the kept [`Variant`] set: cut
/// candidates live only here (as plain ids — no tree is ever built for
/// them), while kept candidates appear in both — by *index*, so neither
/// their labels nor (on the seed path) their trees are ever cloned.
struct FrontierNode {
    /// Cut nodes own their labels; kept nodes leave this empty (no
    /// allocation) and read them — like the seed path reads trees — from
    /// the result set via [`ExprSrc::Kept`].
    labels: Vec<String>,
    id: ExprId,
    src: ExprSrc,
}

/// Where a [`FrontierNode`]'s labels and (seed-path) tree live.
enum ExprSrc {
    /// Cut candidate on the id-native path: labels inline, no tree.
    None,
    /// Kept candidate (either engine): labels — and, for the seed
    /// engine, the tree — live at this index of the result set, moved
    /// there once and never cloned.
    Kept(usize),
    /// Cut candidate on the seed path: the tree is not in the result
    /// set, so the frontier owns it (it was already materialized by the
    /// swap — no clone).
    Owned(Expr),
}

/// What one shard returns for one expanded parent: surviving children in
/// swap-depth order plus the counters the merge step aggregates. The
/// `(shard, seq)` pair is the merge tag — together with the BFS level
/// (implicit in which merge round processes the expansion) it restores
/// the serial discovery order deterministically, whatever the thread
/// scheduling was.
#[derive(Default)]
struct Expansion {
    children: Vec<(Child, Option<f64>)>,
    generated: usize,
    pruned: usize,
    type_rejects: usize,
    /// Which shard generated the children (extraction attribution).
    shard: usize,
    /// The parent's index in this level's frontier (merge order).
    seq: usize,
}

/// One search worker: a memoized id-native normalizer and `ExprId`-keyed
/// typecheck/score/bound caches, all resolving against the search's one
/// [`SharedArena`]. Shards persist across BFS levels so every cache warms
/// up over the whole search — and because the arena is shared, a parent
/// kept by *any* shard reaches the next level as a plain id, with no
/// extract/re-intern at the level boundary.
struct Shard {
    norm: IdRewriter,
    checked: HashMap<ExprId, bool>,
    /// Cost-model score per interned candidate — scoring is structural,
    /// so a variant reached along several swap paths is lowered and
    /// estimated once, not once per path.
    scored: HashMap<ExprId, f64>,
    /// Partial-spine lower bound per interned candidate — like `scored`,
    /// a candidate reached along several swap paths pays the spine walk
    /// once.
    bounded: HashMap<ExprId, f64>,
}

impl Shard {
    fn new() -> Self {
        Shard {
            norm: IdRewriter::new(&normalize_id_rules()),
            checked: HashMap::new(),
            scored: HashMap::new(),
            bounded: HashMap::new(),
        }
    }

    /// Expand one frontier node: try every adjacent swap, normalize,
    /// typecheck, bound, score. Children come back in swap-depth order so
    /// the merge step can reproduce the serial BFS order exactly.
    ///
    /// On the id-native path the parent arrives as `node.id` — the id it
    /// was interned under when first discovered — so no per-level
    /// re-intern of the parent tree happens anywhere (the cost ISSUE 4
    /// removes). The seed `Box<Expr>` path still swaps on the owned tree;
    /// it interns each child once so the typecheck/score caches work
    /// identically.
    ///
    /// With pruning on, each candidate's lower bound is consulted once,
    /// on the normalized id, before any scoring work. A bound exceeding
    /// `slack × best` cuts the candidate — it is returned with
    /// [`Child::cut`] set and is never lowered, scored, or extracted.
    /// (The bound's partial descent also makes it meaningful on the raw,
    /// unnormalized exchange output — `tests/lower_id_props.rs` pins
    /// `bound(raw) ≤ score(normalize(raw))` — but consulting it there
    /// buys nothing on this path: the raw read never exceeds the refined
    /// one, cannot be memoized across swap paths, and normalization runs
    /// regardless because cut candidates re-enter the frontier as
    /// normalized ids.) The shared bound only moves at level boundaries,
    /// so the read is the same in every shard — pruning is deterministic
    /// under any shard count — and since the bound never exceeds the
    /// candidate's true score, the default slack (1.0) can never cut the
    /// eventual winner.
    #[allow(clippy::too_many_arguments)]
    fn expand(
        &mut self,
        arena: &SharedArena,
        node: &FrontierNode,
        out: &[Variant],
        n: usize,
        ctx: &Ctx,
        id_native: bool,
        scoring: bool,
        slack: Option<f64>,
        bound: &AtomicScore,
    ) -> Expansion {
        let mut exp = Expansion::default();
        let threshold = slack.map(|sl| sl * bound.get());
        // Kept parents read their labels (and, on the seed engine, their
        // tree) from the kept set by index; cut parents carry them
        // inline. The id-native path swaps on `node.id` and never reads
        // `pexpr`.
        let (labels, pexpr): (&[String], Option<&Expr>) = match &node.src {
            ExprSrc::None => (&node.labels, None),
            ExprSrc::Kept(i) => {
                let v = &out[*i];
                (&v.labels, Some(&v.expr))
            }
            ExprSrc::Owned(e) => (&node.labels, Some(e)),
        };
        for d in 0..n.saturating_sub(1) {
            // The id-native engine is the production path; the seed
            // `Box<Expr>` path stays reachable via `with_memo_disabled`
            // for differential testing. The flag is sampled once on the
            // search's calling thread (`memo_enabled` is thread-local and
            // would read `true` inside freshly spawned shard threads).
            let (nid, extracted) = if id_native {
                let Some(swapped) = try_swap_at_id(arena, node.id, d, ctx) else {
                    continue;
                };
                (self.norm.rewrite(arena, swapped), None)
            } else {
                let Some(new_expr) = pexpr.and_then(|pe| try_swap_at(pe, d, ctx)) else {
                    continue;
                };
                (arena.intern(&new_expr), Some(new_expr))
            };
            exp.generated += 1;
            // Defensive: drop rewrites that no longer typecheck — paying
            // for inference once per distinct interned tree. This gate
            // also covers cut candidates: they re-enter the frontier, and
            // an ill-typed expansion source could reach rearrangements
            // the exhaustive search never would.
            let ok = match self.checked.get(&nid) {
                Some(&ok) => ok,
                None => {
                    let ok = crate::typecheck::infer_id(arena, nid, &ctx.env).is_ok();
                    self.checked.insert(nid, ok);
                    ok
                }
            };
            if !ok {
                exp.type_rejects += 1;
                continue;
            }
            // The bound gate, before any scoring work (cached — a
            // candidate reached along several swap paths pays the spine
            // walk once).
            let cut = match threshold {
                Some(t) => {
                    let lb = match self.bounded.get(&nid) {
                        Some(&lb) => lb,
                        None => {
                            let lb = spine_lower_bound_id(arena, nid, ctx);
                            self.bounded.insert(nid, lb);
                            lb
                        }
                    };
                    lb > t
                }
                None => false,
            };
            if cut {
                exp.pruned += 1;
            }
            // Score in the arena — a variant reached along several swap
            // paths is lowered and estimated once, not once per path, and
            // never as a `Box<Expr>` tree. Cut candidates are never
            // scored: skipping this lower + estimate (and the output
            // extraction) is what the cut buys.
            let score = if scoring && !cut {
                Some(match self.scored.get(&nid) {
                    Some(&s) => s,
                    None => {
                        let s = score_expr_id(arena, nid, &ctx.env);
                        self.scored.insert(nid, s);
                        s
                    }
                })
            } else {
                None
            };
            // No extraction here: the merge step rebuilds a tree only for
            // children that survive dedup and the cut (the output
            // boundary).
            let mut labels = labels.to_vec();
            labels.swap(d, d + 1);
            exp.children.push((
                Child {
                    labels,
                    expr: extracted,
                    nid,
                    cut,
                },
                score,
            ));
        }
        exp
    }
}

/// Expand a whole frontier level across the shard pool, returning one
/// [`Expansion`] per parent **in frontier order**: parents are dealt
/// round-robin, every expansion is tagged `(shard, seq)` by the worker
/// that produced it, and the merge sorts on the `seq` tag — so the output
/// order is independent of thread scheduling. All shards expand against
/// the one shared arena; parents arrive as plain ids.
#[allow(clippy::too_many_arguments)]
fn parallel_expand(
    shards: &mut [Shard],
    arena: &SharedArena,
    frontier: &[FrontierNode],
    out: &[Variant],
    n: usize,
    ctx: &Ctx,
    scoring: bool,
    slack: Option<f64>,
    bound: &AtomicScore,
) -> Result<Vec<Expansion>> {
    let nshards = shards.len();
    let mut all: Vec<Expansion> = Vec::with_capacity(frontier.len());
    let mut panicked = false;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (k, shard) in shards.iter_mut().enumerate() {
            let parents: Vec<(usize, &FrontierNode)> = frontier
                .iter()
                .enumerate()
                .filter(|(i, _)| i % nshards == k)
                .collect();
            if parents.is_empty() {
                continue;
            }
            handles.push(s.spawn(move || {
                parents
                    .into_iter()
                    .map(|(i, nd)| {
                        let mut exp =
                            shard.expand(arena, nd, out, n, ctx, true, scoring, slack, bound);
                        exp.shard = k;
                        exp.seq = i;
                        exp
                    })
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            match h.join() {
                Ok(mut rs) => all.append(&mut rs),
                Err(_) => panicked = true,
            }
        }
    });
    if panicked {
        return Err(Error::Rewrite("search shard panicked".into()));
    }
    // Deterministic merge: order by the frontier tag, exactly the serial
    // parent order.
    all.sort_by_key(|e| e.seq);
    debug_assert_eq!(all.len(), frontier.len(), "every parent expanded once");
    Ok(all)
}

/// Breadth-first enumeration of rearrangements reachable by adjacent
/// exchanges, sharded across a worker pool and (optionally) pruned by a
/// shared cost bound. Every returned variant typechecks under `ctx.env`;
/// the result order is the serial BFS discovery order regardless of shard
/// count or pruning settings.
pub fn enumerate_search(
    start: &Variant,
    ctx: &Ctx,
    opts: &SearchOptions,
) -> Result<SearchResult> {
    let n = start.labels.len();
    if spine_kinds(&start.expr).len() != n {
        return Err(Error::Rewrite(format!(
            "label count {} does not match spine length {}",
            n,
            spine_kinds(&start.expr).len()
        )));
    }
    crate::typecheck::infer(&start.expr, &ctx.env)?;
    let scoring = opts.score || opts.prune_slack.is_some();
    // Sampled once here: `memo_enabled` is thread-local, so shard threads
    // cannot consult it themselves. The seed engine also stays serial —
    // it exists to reproduce seed behavior exactly.
    let id_native = memo_enabled();
    // Both the auto path and explicit requests are clamped to
    // MAX_SEARCH_SHARDS: an explicit `shards: t` used to spawn `t`
    // threads unbounded, silently oversubscribing the machine when
    // several coordinator workers searched at once. `SearchStats::shards`
    // reports this effective count.
    let threads = if !id_native {
        1
    } else {
        match opts.shards {
            0 => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            t => t,
        }
        .min(MAX_SEARCH_SHARDS)
        .max(1)
    };
    let mut shards: Vec<Shard> = (0..threads).map(|_| Shard::new()).collect();
    // One concurrent hash-sharded arena for the whole search (ISSUE 4):
    // every shard generates, normalizes, typechecks and scores against
    // it, and frontier variants cross shard and level boundaries as plain
    // ids — the per-level extract/re-intern of the per-shard-arena design
    // is gone.
    let arena = SharedArena::new();
    let start_id = arena.intern(&start.expr);
    // The start variant is scored through the same arena-native path as
    // every candidate (and warms shard 0's score cache).
    let start_score = if scoring {
        let s = score_expr_id(&arena, start_id, &ctx.env);
        shards[0].scored.insert(start_id, s);
        Some(s)
    } else {
        None
    };

    let mut tokens: Vec<String> = Vec::new();
    let mut seen: HashSet<Vec<u8>> = HashSet::new();
    seen.insert(label_key(&start.labels, &mut tokens));
    let mut out: Vec<Variant> = vec![start.clone()];
    // The BFS frontier, separate from the kept set since the cut started
    // firing: every deduplicated, typechecked candidate — kept or cut —
    // becomes an expansion source (cut nodes cross levels as plain ids
    // and never grow a tree), so pruning can never disconnect the swap
    // graph from the eventual winner. A discovered candidate is interned
    // exactly once in its whole life; the next level reads it back from
    // here.
    let mut frontier: Vec<FrontierNode> = vec![FrontierNode {
        labels: Vec::new(),
        id: start_id,
        src: ExprSrc::Kept(0),
    }];
    let mut scores: Vec<f64> = Vec::new();
    if let Some(s) = start_score {
        scores.push(s);
    }
    let bound = AtomicScore::new(start_score.unwrap_or(f64::INFINITY));
    let mut stats = SearchStats {
        shards: threads,
        ..Default::default()
    };
    // Stable, padded layout (one slot per configured shard) so the
    // coordinator's Metrics merge never depends on which shards happened
    // to generate kept candidates.
    let mut extracted_per_shard = vec![0u64; threads];
    let mut level = 0..1usize;

    // The limit caps *discovered* candidates (`frontier` — in exhaustive
    // mode identical to the kept set), so pruned searches cannot walk
    // arbitrarily far past it through cut expansion sources.
    while !level.is_empty() && frontier.len() < opts.limit {
        stats.expanded += level.len();
        let expansions: Vec<Expansion> = {
            let nodes = &frontier[level.clone()];
            let kept: &[Variant] = &out;
            if threads > 1 && nodes.len() > 1 {
                parallel_expand(
                    &mut shards,
                    &arena,
                    nodes,
                    kept,
                    n,
                    ctx,
                    scoring,
                    opts.prune_slack,
                    &bound,
                )?
            } else {
                nodes
                    .iter()
                    .map(|nd| {
                        shards[0].expand(
                            &arena,
                            nd,
                            kept,
                            n,
                            ctx,
                            id_native,
                            scoring,
                            opts.prune_slack,
                            &bound,
                        )
                    })
                    .collect()
            }
        };
        // Deterministic merge: parents in frontier (seq-tag) order,
        // children in swap-depth order — exactly the serial queue BFS
        // sequence.
        let level_start = frontier.len();
        for exp in expansions {
            // Count the whole level's work even past the limit — the
            // shards already did it; only *keeping* stops (mirroring the
            // serial per-pop limit check for the kept set).
            stats.generated += exp.generated;
            stats.pruned += exp.pruned;
            stats.type_rejects += exp.type_rejects;
            if frontier.len() >= opts.limit {
                continue;
            }
            for (child, s) in exp.children {
                if let Some(s) = s {
                    if bound.fetch_min(s) {
                        stats.bound_updates += 1;
                    }
                }
                let key = label_key(&child.labels, &mut tokens);
                if seen.insert(key) {
                    if child.cut {
                        // Cut candidates stay expansion sources but leave
                        // the result set — and never cost a tree: the
                        // seed path keeps the tree the swap already
                        // built, the id-native path carries just the id.
                        let src = match child.expr {
                            Some(e) => ExprSrc::Owned(e),
                            None => ExprSrc::None,
                        };
                        frontier.push(FrontierNode {
                            labels: child.labels,
                            id: child.nid,
                            src,
                        });
                        continue;
                    }
                    // Output boundary: the one extract per *kept*
                    // candidate — duplicates and cut candidates never
                    // rebuild a tree, and level boundaries never extract.
                    // Kept labels and trees are moved into `out` and the
                    // frontier refers back by index, so nothing is cloned
                    // and the id-native path pays exactly the one
                    // extraction.
                    let expr = match child.expr {
                        Some(e) => e,
                        None => {
                            extracted_per_shard[exp.shard] += 1;
                            arena.extract(child.nid)
                        }
                    };
                    frontier.push(FrontierNode {
                        labels: Vec::new(),
                        id: child.nid,
                        src: ExprSrc::Kept(out.len()),
                    });
                    out.push(Variant {
                        expr,
                        labels: child.labels,
                    });
                    if let Some(s) = s {
                        scores.push(s);
                    }
                }
            }
        }
        level = level_start..frontier.len();
    }
    stats.kept = out.len();
    debug_assert_eq!(
        extracted_per_shard.iter().sum::<u64>(),
        if id_native { arena.extractions() } else { 0 },
        "output-boundary extraction must be the arena's only extraction"
    );
    stats.extracted_per_shard = extracted_per_shard;
    Ok(SearchResult {
        variants: out,
        scores,
        stats,
    })
}

/// Breadth-first enumeration of all rearrangements reachable by adjacent
/// exchanges, deduplicated on the display form. Every returned variant
/// typechecks under `ctx.env`. Serial and exhaustive — the compatibility
/// entry point; the pipeline calls [`enumerate_search`] for the sharded,
/// cost-bounded engine.
pub fn enumerate_all(start: &Variant, ctx: &Ctx, limit: usize) -> Result<Vec<Variant>> {
    let opts = SearchOptions {
        limit,
        shards: 1,
        prune_slack: None,
        score: false,
    };
    Ok(enumerate_search(start, ctx, &opts)?.variants)
}

/// Compare a variant's executed output against reference candidates (the
/// reference result and, for transposing rearrangements, its transpose).
/// Returns the index of the matching candidate.
pub fn verify_against(
    got: &[f64],
    candidates: &[Vec<f64>],
    tol: f64,
) -> Option<usize> {
    candidates
        .iter()
        .position(|c| crate::util::allclose(got, c, tol))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::Layout;
    use crate::typecheck::Env;

    fn matmul_env(n: usize, j: usize, k: usize) -> Env {
        Env::new()
            .with("A", Layout::row_major(&[n, j]))
            .with("B", Layout::row_major(&[j, k]))
    }

    #[test]
    fn spine_of_naive_matmul() {
        let e = crate::dsl::matmul_naive(crate::dsl::input("A"), crate::dsl::input("B"));
        assert_eq!(spine_kinds(&e), vec!["map", "map", "red"]);
    }

    #[test]
    fn naive_matmul_has_six_rearrangements() {
        // Paper Table 1: 3 distinct HoFs → 6 permutations.
        let env = matmul_env(4, 6, 8);
        let ctx = Ctx::new(env);
        let start = starts::matmul_naive_variant();
        let variants = enumerate_all(&start, &ctx, 100).unwrap();
        assert_eq!(variants.len(), 6, "{:?}",
            variants.iter().map(|v| v.display_key()).collect::<Vec<_>>());
        // all 6 label orders present
        let keys: std::collections::HashSet<String> =
            variants.iter().map(|v| v.display_key()).collect();
        for perm in [
            "mapA mapB rnz",
            "mapA rnz mapB",
            "rnz mapA mapB",
            "mapB mapA rnz",
            "mapB rnz mapA",
            "rnz mapB mapA",
        ] {
            assert!(keys.contains(perm), "missing {perm}; got {keys:?}");
        }
    }

    #[test]
    fn all_rearrangements_compute_matmul_or_its_transpose() {
        use crate::exec::run;
        use crate::util::Rng;
        let (n, j, k) = (4usize, 6, 8);
        let env = matmul_env(n, j, k);
        let ctx = Ctx::new(env.clone());
        let mut rng = Rng::new(11);
        let a = rng.fill_vec(n * j);
        let b = rng.fill_vec(j * k);
        // reference C and C^T
        let mut c = vec![0.0; n * k];
        for i in 0..n {
            for jj in 0..j {
                for kk in 0..k {
                    c[i * k + kk] += a[i * j + jj] * b[jj * k + kk];
                }
            }
        }
        let mut ct = vec![0.0; n * k];
        for i in 0..n {
            for kk in 0..k {
                ct[kk * n + i] = c[i * k + kk];
            }
        }
        let start = starts::matmul_naive_variant();
        let variants = enumerate_all(&start, &ctx, 100).unwrap();
        assert_eq!(variants.len(), 6);
        for v in &variants {
            let out = run(&v.expr, &env, &[("A", &a), ("B", &b)])
                .unwrap_or_else(|e| panic!("{}: {e}", v.display_key()));
            let hit = verify_against(&out, &[c.clone(), ct.clone()], 1e-9);
            assert!(hit.is_some(), "variant {} wrong result", v.display_key());
        }
    }

    #[test]
    fn subdivided_rnz_has_twelve_rearrangements() {
        // Paper Table 2: 4 HoFs, two indistinguishable rnzs → 12 cases.
        let env = matmul_env(4, 8, 4);
        let ctx = Ctx::new(env.clone());
        let start = starts::matmul_rnz_subdivided_variant(2);
        let variants = enumerate_all(&start, &ctx, 200).unwrap();
        assert_eq!(
            variants.len(),
            12,
            "{:?}",
            variants.iter().map(|v| v.display_key()).collect::<Vec<_>>()
        );
    }
}
