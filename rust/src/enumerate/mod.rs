//! Enumeration of HoF rearrangements (paper §4).
//!
//! The nesting of HoFs in a (fused, subdivided) expression forms a list —
//! the *spine*. Adjacent spine levels can be swapped by the exchange rules
//! of [`crate::rewrite::exchange`], each swap pairing with a `flip` of the
//! logical layout. Enumerating all permutations by adjacent transpositions
//! is exactly the Steinhaus–Johnson–Trotter scheme the paper cites; here we
//! additionally keep the search robust by breadth-first exploring the swap
//! graph and deduplicating on the paper's display form (the two/three
//! `rnz`s of a subdivided reduction are "not differentiated", so 4 HoFs
//! with two rnzs yield the paper's 12 cases, not 24).
//!
//! # The search engine (ISSUE 2–7)
//!
//! [`enumerate_search`] explores the swap graph **best-first**, natively
//! on [`ExprId`]s: candidate generation ([`try_swap_at_id`]),
//! normalization (an [`IdRewriter`] over the id-native rule set) and
//! typechecking ([`crate::typecheck::infer_id`]) all happen inside one
//! concurrent [`SharedArena`] shared by every worker shard, so
//! `Box<Expr>` trees are rebuilt only once per *kept* candidate at the
//! output boundary — never per node per rule probe, and never at a wave
//! boundary.
//!
//! - **Best-first waves (ISSUE 7)** — open nodes live in a priority
//!   frontier ordered by `(bound_bits, seq)`: the total-order bits of the
//!   memoized [`crate::costmodel::spine_lower_bound_id`] first, discovery
//!   sequence as the deterministic tie-break. Each iteration pops the
//!   [`EXPANSION_WAVE`] cheapest nodes (fewer if the heap or the node
//!   budget runs short) and expands them as one wave. The wave size is a
//!   constant — *not* the shard count — and the shared best-known score
//!   moves only in the serial merge between waves, so wave composition,
//!   expansion thresholds, dedup, and output order are all
//!   shard-count-independent. Expanding cheapest-bound-first tightens the
//!   branch-and-bound cut fastest and is what makes truncated runs
//!   meaningful: the open frontier's bounds certify how far the
//!   best-so-far can be from the true optimum.
//! - **Anytime (ISSUE 7)** — [`SearchOptions::budget`] caps expanded
//!   nodes (waves shrink to land on it exactly, so the expansion sets of
//!   two budgets are nested prefixes) and [`SearchOptions::deadline`]
//!   cancels in-flight shard work cooperatively through the same shared
//!   atomic the branch-and-bound consults ([`SearchBound`]); a cancelled
//!   wave is discarded whole and its nodes return to the frontier. An
//!   external [`SearchOptions::cancel`] token (ISSUE 9) rides the same
//!   mechanism, so a service caller's `cancel()` stops a running search
//!   mid-wave exactly like a deadline — attributed to
//!   [`SearchStats::cancelled`] rather than `deadline_hit`.
//!   Truncated or not, the run reports a **certified optimality gap**
//!   ([`SearchStats::certified_gap`]): `best_score` divided by the
//!   minimum [`crate::costmodel::spine_reachable_floor_id`] over the open
//!   frontier — the *rearrangement-invariant* floor, which bounds every
//!   family member still reachable through the connected swap graph
//!   (the sensitive expansion bound deliberately does not). Gap `1.0`
//!   means the frontier drained: the winner is exhaustively optimal.
//! - **Sharding** — each wave is split round-robin across worker shards.
//!   All shards build candidates into the *same* hash-sharded arena
//!   (ISSUE 4), so frontier variants cross shard and wave boundaries as
//!   plain ids: a parent expanded this wave was interned exactly once,
//!   when it was first discovered, no matter which shard keeps expanding
//!   its descendants. Each shard still owns its *caches* — normalize
//!   memo, typecheck/score/bound/floor maps — all keyed by the shared
//!   arena's (thread-stable) ids. Every expansion is tagged
//!   `(shard, seq)` and the deterministic merge orders candidates by
//!   wave tag, parents in wave order and children in swap-depth order,
//!   so the result order is identical to the serial best-first walk no
//!   matter how many shards run or how they were scheduled.
//! - **Scoring** — with [`SearchOptions::score`] set (implied by
//!   pruning), candidates are lowered and cost-estimated *in the arena*
//!   via [`crate::costmodel::estimate_id`]; the per-candidate path
//!   allocates no `Box<Expr>` tree (ISSUE 3 — extraction happens once per
//!   *kept* candidate at the output boundary, and [`SearchStats`] reports
//!   the per-shard extraction counts so that stays observable).
//! - **Pruning (branch-and-bound)** — with
//!   [`SearchOptions::prune_slack`] set, each candidate's
//!   [`crate::costmodel::spine_lower_bound_id`] — a provable lower bound
//!   on its true score, computed from the spine without lowering — is
//!   compared against `slack × best-known-score` (an atomic shared across
//!   shards). A candidate whose bound exceeds the threshold is cut
//!   before it is kept: never lowered, never scored, never extracted,
//!   excluded from the result set. The merge step *rechecks* survivors
//!   against the freshest bound (scores merged earlier in the same wave
//!   may have tightened it), so best-first ordering strictly increases
//!   cut counts over the old level-synchronous walk. Cut candidates *do*
//!   remain expansion sources — the swap graph stays connected, so
//!   reachability (and with it the winner) is preserved by construction,
//!   not by luck: since the bound never exceeds the true score, the
//!   eventual winner always satisfies `bound ≤ score ≤ best-known` and
//!   can never be cut at the default slack ([`DEFAULT_PRUNE_SLACK`] =
//!   1.0). The bound only tightens at wave boundaries (expansion) and
//!   between merged children (recheck), both serial and
//!   shard-count-independent, so pruning decisions stay deterministic
//!   under any shard count. (The bound's partial descent also makes it
//!   sound on raw, mid-rewrite exchange output —
//!   `tests/lower_id_props.rs` pins `bound(raw) ≤ score(normalize(raw))`
//!   — which is what would let a future engine gate generation itself;
//!   this engine consults it post-normalization only, where the read is
//!   memoized per candidate.)
//! - **Dedup** — candidates are deduplicated on an integer label-token
//!   key (the collapsed spine permutation), not on formatted
//!   `display_key()` strings; display strings are produced only at the
//!   output boundary. (Dedup *cannot* key on raw `ExprId`s: fresh-binder
//!   rules make alpha-variants of the same permutation intern to
//!   different ids, which would break the paper's 6/12 counts — the
//!   per-shard typecheck cache is what keys on `ExprId`.)
//!
//! Exhaustive, pruned, and budget-truncated runs all share **one**
//! discovery sequence (priorities are structural bounds, computed whether
//! or not the cut is armed), so a pruned result is a subsequence of the
//! exhaustive one and a truncated result is a prefix-expansion of a
//! larger budget's — the properties `tests/search_props.rs` and
//! `tests/anytime_props.rs` pin.
//!
//! The seed `Box<Expr>` expansion path is kept alive behind
//! [`crate::dsl::intern::with_memo_disabled`] and the differential tests
//! hold both engines to identical variant sets and orders.

mod sjt;
pub mod starts;

pub use sjt::sjt_permutations;

use crate::costmodel::{estimate_id, spine_lower_bound_id, spine_reachable_floor_id};
use crate::dsl::intern::{memo_enabled, ExprId, Node, SharedArena};
use crate::dsl::Expr;
use crate::rewrite::{exchange, normalize, normalize_id_rules, Ctx, IdRewriter};
use crate::typecheck::Env;
use crate::{Error, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// One rearrangement of the computation: the expression plus the spine
/// labels from outermost to innermost (`["mapA", "rnz", "mapB"]` reads as
/// the paper's table rows).
#[derive(Clone, Debug)]
pub struct Variant {
    pub expr: Expr,
    pub labels: Vec<String>,
}

impl Variant {
    pub fn new(expr: Expr, labels: &[&str]) -> Self {
        Variant {
            expr,
            labels: labels.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// The paper's display form: collapsed labels joined by spaces
    /// (`rnz*` labels are not differentiated).
    pub fn display_key(&self) -> String {
        self.labels
            .iter()
            .map(|l| collapse(l))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Collapse a label to its display form: all `rnz…` labels are the same.
pub fn collapse(label: &str) -> &str {
    if label.starts_with("rnz") {
        "rnz"
    } else {
        label
    }
}

/// The spine: the chain of HoF kinds from the root inward, descending
/// through operator lambdas.
pub fn spine_kinds(e: &Expr) -> Vec<&'static str> {
    let mut out = Vec::new();
    let mut cur = e;
    loop {
        match cur {
            Expr::Nzip { f, .. } => {
                out.push("map");
                match &**f {
                    Expr::Lam { body, .. } => cur = body,
                    _ => break,
                }
            }
            Expr::Rnz { m, .. } => {
                out.push("red");
                match &**m {
                    Expr::Lam { body, .. } => cur = body,
                    _ => break,
                }
            }
            _ => break,
        }
    }
    out
}

/// Try to swap spine levels `depth` and `depth+1` by applying an exchange
/// rule at that node. Returns the normalized full expression on success.
pub fn try_swap_at(e: &Expr, depth: usize, ctx: &Ctx) -> Option<Expr> {
    fn rec(e: &Expr, depth: usize, ctx: &Ctx) -> Option<Expr> {
        if depth == 0 {
            return exchange::map_map(e, ctx)
                .or_else(|| exchange::map_map_nested(e, ctx))
                .or_else(|| exchange::map_rnz(e, ctx))
                .or_else(|| exchange::rnz_map(e, ctx))
                .or_else(|| exchange::rnz_rnz(e, ctx));
        }
        match e {
            Expr::Nzip { f, args } => {
                let Expr::Lam { params, body } = &**f else {
                    return None;
                };
                if params.len() != args.len() {
                    return None;
                }
                let mut ctx2 = ctx.clone();
                for (p, a) in params.iter().zip(args) {
                    let elem = ctx.layout_of(a).ok()?.peel_outer().ok()?;
                    ctx2.vars.insert(p.clone(), elem);
                }
                let new_body = rec(body, depth - 1, &ctx2)?;
                Some(Expr::Nzip {
                    f: Box::new(Expr::Lam {
                        params: params.clone(),
                        body: Box::new(new_body),
                    }),
                    args: args.clone(),
                })
            }
            Expr::Rnz { r, m, args } => {
                let Expr::Lam { params, body } = &**m else {
                    return None;
                };
                if params.len() != args.len() {
                    return None;
                }
                let mut ctx2 = ctx.clone();
                for (p, a) in params.iter().zip(args) {
                    let elem = ctx.layout_of(a).ok()?.peel_outer().ok()?;
                    ctx2.vars.insert(p.clone(), elem);
                }
                let new_body = rec(body, depth - 1, &ctx2)?;
                Some(Expr::Rnz {
                    r: r.clone(),
                    m: Box::new(Expr::Lam {
                        params: params.clone(),
                        body: Box::new(new_body),
                    }),
                    args: args.clone(),
                })
            }
            _ => None,
        }
    }
    rec(e, depth, ctx).map(|x| normalize(&x))
}

/// Id-native twin of [`try_swap_at`]: descend the interned spine to
/// `depth` (binding parameter layouts as it goes) and apply an exchange
/// rule there. Unlike [`try_swap_at`] the result is **not** normalized —
/// the caller runs its own [`IdRewriter`] over the same arena so the
/// normalize memo is shared across every candidate of the search. The
/// arena comes in by shared reference: all search shards generate
/// candidates into one [`SharedArena`] concurrently.
pub fn try_swap_at_id(
    arena: &SharedArena,
    id: ExprId,
    depth: usize,
    ctx: &Ctx,
) -> Option<ExprId> {
    if depth == 0 {
        if let Some(r) = exchange::map_map_id(arena, id, ctx) {
            return Some(r);
        }
        if let Some(r) = exchange::map_map_nested_id(arena, id, ctx) {
            return Some(r);
        }
        if let Some(r) = exchange::map_rnz_id(arena, id, ctx) {
            return Some(r);
        }
        if let Some(r) = exchange::rnz_map_id(arena, id, ctx) {
            return Some(r);
        }
        return exchange::rnz_rnz_id(arena, id, ctx);
    }
    match arena.get(id).clone() {
        Node::Nzip { f, args } => {
            let Node::Lam { params, body } = arena.get(f).clone() else {
                return None;
            };
            if params.len() != args.len() {
                return None;
            }
            let mut ctx2 = ctx.clone();
            for (p, &a) in params.iter().zip(&args) {
                let elem = ctx.layout_of_id(arena, a).ok()?.peel_outer().ok()?;
                ctx2.vars.insert(p.clone(), elem);
            }
            let new_body = try_swap_at_id(arena, body, depth - 1, &ctx2)?;
            let lam = arena.insert(Node::Lam {
                params,
                body: new_body,
            });
            Some(arena.insert(Node::Nzip { f: lam, args }))
        }
        Node::Rnz { r, m, args } => {
            let Node::Lam { params, body } = arena.get(m).clone() else {
                return None;
            };
            if params.len() != args.len() {
                return None;
            }
            let mut ctx2 = ctx.clone();
            for (p, &a) in params.iter().zip(&args) {
                let elem = ctx.layout_of_id(arena, a).ok()?.peel_outer().ok()?;
                ctx2.vars.insert(p.clone(), elem);
            }
            let new_body = try_swap_at_id(arena, body, depth - 1, &ctx2)?;
            let lam = arena.insert(Node::Lam {
                params,
                body: new_body,
            });
            Some(arena.insert(Node::Rnz { r, m: lam, args }))
        }
        _ => None,
    }
}

/// Default branch-and-bound slack for [`SearchOptions::prune_slack`].
///
/// The cut compares [`crate::costmodel::spine_lower_bound_id`] — a
/// *provable lower bound* on a candidate's true cost-model score, never
/// exceeding it (pinned by `tests/lower_id_props.rs`) — against
/// `slack × best-known-score`. At slack `1.0` a cut candidate therefore
/// provably scores worse than a variant already in hand, so the winner
/// can never be cut, on *any* workload — unlike the earlier heuristic
/// (PR 2) that compared full scores and needed a ~64× cushion derived
/// from the cost-model constants and a ≤ ~20-track assumption.
///
/// Since the bound gained rearrangement-sensitive per-track input-traffic
/// terms (`COST_MODEL_VERSION` 2), this default cut *actually fires*:
/// within one family the bound varies with the permutation, and dominated
/// rearrangements — e.g. ones forced to stream a matrix at a large stride
/// — bound strictly above the family's best score. On the subdivided
/// matmul families, roughly the worse half of the variant set is cut
/// before being lowered, scored, or extracted (`pruned > 0` is pinned by
/// `tests/search_props.rs`, as is winner identity with exhaustive mode).
/// Cut candidates still expand, so pruned mode walks the same swap graph
/// and the winner is preserved by construction; what it saves is the
/// per-candidate lower + estimate + output-boundary extraction.
pub const DEFAULT_PRUNE_SLACK: f64 = 1.0;

/// Hard cap on shard fan-out, for the auto path *and* explicit
/// [`SearchOptions::shards`] requests alike: several coordinator workers
/// may each be searching at once, and an unbounded per-job fan-out would
/// oversubscribe the machine workers-fold (same rationale as the ranking
/// fan-out cap in the pipeline). The cap equals the widest arm of CI's
/// `SEARCH_SHARDS` ∈ {1, 2, 8} differential matrix, so every CI width
/// runs at its nominal fan-out; [`SearchStats::shards`] always reports
/// the *effective* (post-clamp) count.
pub const MAX_SEARCH_SHARDS: usize = 8;

/// How many frontier nodes one best-first wave expands (fewer when the
/// heap or the remaining [`SearchOptions::budget`] runs short). The value
/// is [`MAX_SEARCH_SHARDS`] so every CI shard width runs at full fan-out —
/// but it is deliberately a **constant, not the shard count**: wave
/// composition (and with it every expansion threshold, dedup decision,
/// and the output order) must be identical at `shards` 1, 2, and 8 for
/// the deterministic-merge contract to survive best-first ordering.
pub const EXPANSION_WAVE: usize = MAX_SEARCH_SHARDS;

/// External cooperative-cancellation handle for an in-flight search
/// (ISSUE 9): a shared sticky flag the caller flips from *outside* the
/// search — typically another thread holding the service handle
/// ([`crate::coordinator::OptimizeHandle::cancel`]) while a worker is
/// mid-search. The search consults it through the same [`SearchBound`]
/// polling the branch-and-bound already does, so a cancellation stops
/// in-flight shard work mid-wave exactly like a deadline expiry: the
/// partial wave is discarded whole, its nodes return to the open
/// frontier, and the run reports best-so-far with a sound certified gap
/// and [`SearchStats::cancelled`] set.
///
/// Clones share the flag. Cancellation is idempotent and sticky —
/// flipping it after the search finished is a harmless no-op, and a token
/// cancelled *before* the search starts truncates it at wave zero (only
/// the start variant is returned).
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, uncancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cooperative cancellation. Sticky and idempotent.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Knobs for [`enumerate_search`].
///
/// # The four caps, and how they compose
///
/// - [`limit`](Self::limit) caps **discovered** candidates (kept +
///   bound-cut) — the result-set/memory cap.
/// - [`budget`](Self::budget) caps **expanded** frontier nodes — the work
///   cap of the anytime search (`0` = unlimited).
/// - [`deadline`](Self::deadline) caps **wall-clock time**, cancelling
///   in-flight shard work cooperatively.
/// - [`cancel`](Self::cancel) is the caller-driven cap: an external
///   [`CancelToken`] stops the search the same cooperative way a deadline
///   does, whenever another thread flips it.
///
/// Whichever binds first truncates the search; any truncation is reported
/// uniformly through [`SearchStats::complete`] (false) and a certified
/// gap > 1.0, so callers never need to know *which* cap fired to trust
/// the result.
#[derive(Clone, Debug)]
pub struct SearchOptions {
    /// Stop once this many candidates have been *discovered* (kept +
    /// bound-cut). Exhaustive mode discovers exactly what it keeps, so
    /// this is the classic kept-variant cap there; under pruning it also
    /// caps the expansion work itself (cut candidates stay expansion
    /// sources, so a kept-only cap would let a heavily-cut search walk
    /// arbitrarily far past it). Pruned and exhaustive searches share one
    /// discovery sequence, so a binding limit truncates both at the same
    /// prefix and winner parity is preserved. Contrast with [`budget`]:
    /// `limit` bounds how many candidates the search may *hold*, `budget`
    /// bounds how many it may *expand*.
    ///
    /// [`budget`]: Self::budget
    pub limit: usize,
    /// Worker shards for frontier expansion: `1` = serial, `0` = auto
    /// (one per available core). Both the auto path and explicit counts
    /// are clamped to [`MAX_SEARCH_SHARDS`]; [`SearchStats::shards`]
    /// reports the effective count.
    pub shards: usize,
    /// Branch-and-bound slack: a candidate whose partial-spine lower
    /// bound ([`crate::costmodel::spine_lower_bound_id`]) exceeds
    /// `slack × best-known-score` is cut *before* it is lowered, scored,
    /// or extracted, and excluded from the result set. Cut candidates are
    /// still expanded (the swap graph stays connected), so — the bound
    /// never exceeding the true score — [`DEFAULT_PRUNE_SLACK`] (= 1.0)
    /// never loses the eventual winner. `None` keeps the search
    /// exhaustive.
    pub prune_slack: Option<f64>,
    /// Score candidates with the analytic cost model during the search
    /// and return the scores (implied by `prune_slack`; the pipeline
    /// reuses them as the ranking, skipping a second scoring pass).
    pub score: bool,
    /// Anytime node budget: stop after this many frontier expansions
    /// (`0` = unlimited). Enforced exactly — the final wave shrinks to
    /// land on it — so the expansion sets of two budgets are nested
    /// prefixes of one deterministic sequence, which is what makes the
    /// certified gap monotone non-increasing in the budget
    /// (`tests/anytime_props.rs`). With an unlimited budget (and no
    /// deadline or binding [`limit`](Self::limit)) the frontier drains
    /// and the result is bit-identical to the exhaustive search.
    pub budget: usize,
    /// Wall-clock deadline. Checked between waves and cooperatively
    /// inside shard expansion (through the shared [`SearchBound`]
    /// cancellation flag, so a deadline *cancels* in-flight shard work
    /// rather than waiting it out). A cancelled wave is discarded whole
    /// and its nodes return to the open frontier, keeping the certified
    /// gap sound. `None` = no deadline.
    pub deadline: Option<Instant>,
    /// External cooperative cancellation ([`CancelToken`]): checked
    /// between waves and — through the shared [`SearchBound`] flag —
    /// mid-wave inside shard expansion, so flipping the token from
    /// another thread stops a running search without waiting the wave
    /// out. A cancelled wave is discarded whole and its nodes return to
    /// the open frontier (identical to a deadline trip), keeping the
    /// certified gap sound; the run reports [`SearchStats::cancelled`]
    /// instead of `deadline_hit`. `None` = not cancellable.
    pub cancel: Option<CancelToken>,
}

impl Default for SearchOptions {
    fn default() -> Self {
        SearchOptions {
            limit: 4096,
            shards: 0,
            prune_slack: None,
            score: false,
            budget: 0,
            deadline: None,
            cancel: None,
        }
    }
}

/// Aggregate counters from one [`enumerate_search`] run. Surfaced through
/// [`crate::coordinator::Metrics`] on production traffic so pruning
/// effectiveness (and the no-extraction invariant of the score path) is
/// observable, not just asserted in tests.
#[derive(Clone, Debug, Default)]
pub struct SearchStats {
    /// Frontier parents expanded (BFS nodes whose swaps were tried).
    /// Includes bound-cut nodes: they leave the result set but stay
    /// expansion sources, so the swap graph — and with it the winner —
    /// stays reachable under pruning.
    pub expanded: usize,
    /// Successful exchange applications (pre-dedup).
    pub generated: usize,
    /// Variants kept in the result set.
    pub kept: usize,
    /// Candidates cut by the lower-bound branch-and-bound (counted
    /// per generated instance, pre-dedup; each was rejected before being
    /// lowered, scored, or extracted).
    pub pruned: usize,
    /// Candidates dropped because they no longer typechecked.
    pub type_rejects: usize,
    /// Times the shared best-known score tightened during the merge step.
    pub bound_updates: usize,
    /// Worker shards used (the effective count after clamping to
    /// [`MAX_SEARCH_SHARDS`]).
    pub shards: usize,
    /// Output-boundary `Box<Expr>` extractions attributed to the shard
    /// that *generated* each kept candidate. The layout is stable and
    /// shard-count-independent in the sense coordinator `Metrics` merges
    /// need: always exactly `shards` entries (padded with zeros for
    /// shards that happened to generate no kept candidate), regardless of
    /// runtime scheduling. On the id-native path the total is exactly the
    /// output-boundary extraction of *kept* candidates (`kept - 1`: the
    /// start is never extracted, duplicates are deduped before
    /// extraction) and equals the shared arena's
    /// [`SharedArena::extractions`] counter — the per-candidate
    /// score/lower path never extracts, and nothing is extracted at wave
    /// boundaries.
    pub extracted_per_shard: Vec<u64>,
    /// Certified optimality gap: `best_score / min_open_floor`, where the
    /// denominator is the minimum rearrangement-invariant floor
    /// ([`crate::costmodel::spine_reachable_floor_id`]) over everything
    /// still unexplored. Always ≥ 1.0; exactly `1.0` iff the frontier
    /// drained ([`complete`](Self::complete)) — the winner is then
    /// exhaustively optimal. `+∞` when the run was truncated without
    /// scoring enabled (no best-known score exists to certify). Under
    /// pruning the certificate additionally assumes
    /// [`SearchOptions::prune_slack`] ≥ 1.0 — a sub-1.0 slack
    /// deliberately discards candidates that provably score *better* than
    /// the best in hand, which no frontier bound can account for.
    pub certified_gap: f64,
    /// The gap denominator: minimum invariant floor over the open
    /// frontier (falling back to the family floor when a binding
    /// [`SearchOptions::limit`] dropped children the heap no longer
    /// tracks). `+∞` when the search completed — nothing is open.
    pub min_open_bound: f64,
    /// Open (discovered but unexpanded) frontier nodes left behind by a
    /// truncated run; `0` when the search completed.
    pub frontier_open: usize,
    /// The frontier drained with nothing dropped: the result set is
    /// exhaustive (up to pruning, which preserves the winner) and the
    /// certified gap is exactly `1.0`.
    pub complete: bool,
    /// The node budget stopped expansion before the frontier drained.
    pub budget_hit: bool,
    /// The deadline stopped expansion (between waves, or by cancelling an
    /// in-flight wave) before the frontier drained.
    pub deadline_hit: bool,
    /// An external [`CancelToken`] ([`SearchOptions::cancel`]) stopped
    /// expansion before the frontier drained — the caller-driven
    /// counterpart of [`deadline_hit`](Self::deadline_hit). The run still
    /// returns its best-so-far prefix with a sound certified gap; it is
    /// never [`complete`](Self::complete).
    pub cancelled: bool,
}

impl SearchStats {
    /// Total `Box<Expr>` extractions across all shards.
    pub fn extracted(&self) -> u64 {
        self.extracted_per_shard.iter().sum()
    }
}

/// Everything [`enumerate_search`] produces.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub variants: Vec<Variant>,
    /// Cost-model score per variant (same order as `variants`; empty when
    /// scoring was off).
    pub scores: Vec<f64>,
    pub stats: SearchStats,
}

/// The shared search state every shard consults: the best-known score (an
/// `f64` min over an atomic word — the branch-and-bound threshold) plus
/// the cooperative cancellation flag the anytime deadline rides on, plus
/// an optional *external* [`CancelToken`] flipped by the caller (service
/// cancellation, ISSUE 9). One structure on purpose: a shard that is
/// already polling the bound costs nothing extra to also notice either
/// kind of cancellation, which is how a deadline — or a user's
/// `cancel()` — *cancels* in-flight expansion work instead of waiting
/// for the wave to finish. The internal flag and the external token stay
/// distinct so the driver can attribute the stop to
/// [`SearchStats::deadline_hit`] vs [`SearchStats::cancelled`].
pub struct SearchBound {
    best: AtomicU64,
    cancelled: AtomicBool,
    external: Option<CancelToken>,
}

impl SearchBound {
    fn new(v: f64, external: Option<CancelToken>) -> Self {
        SearchBound {
            best: AtomicU64::new(v.to_bits()),
            cancelled: AtomicBool::new(false),
            external,
        }
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.best.load(Ordering::Relaxed))
    }

    /// Lower the bound to `v` if `v` is smaller; returns whether the
    /// bound actually tightened.
    fn fetch_min(&self, v: f64) -> bool {
        let mut cur = self.best.load(Ordering::Relaxed);
        while v < f64::from_bits(cur) {
            match self.best.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
        false
    }

    /// Request cooperative cancellation of the current wave (deadline
    /// expiry). Sticky for the rest of the search — the driver breaks out
    /// of the wave loop as soon as the wave is discarded.
    fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// The *internal* (deadline-driven) flag alone — the driver uses this
    /// to attribute a mid-wave stop to the deadline vs the external token.
    fn deadline_tripped(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Whether the external [`CancelToken`] (if any) was flipped.
    fn externally_cancelled(&self) -> bool {
        self.external.as_ref().is_some_and(CancelToken::is_cancelled)
    }

    /// Either cancellation source: the shards' mid-expansion poll. One
    /// load in the common (no external token) case.
    fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed) || self.externally_cancelled()
    }
}

/// Map a non-NaN `f64` to a `u64` whose unsigned order matches the float
/// order — the priority-heap key for a node's lower bound. Bounds are
/// finite and non-negative in practice, but the transform is total-order
/// correct for any sign so a surprising bound can never corrupt the heap.
fn order_bits(x: f64) -> u64 {
    let b = x.to_bits();
    if b & (1 << 63) != 0 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// Collapse a label sequence to its integer token key — the dedup key of
/// the BFS (two permutations collide exactly when their `display_key()`s
/// would be equal, but no `String` is ever formatted here).
fn label_key(labels: &[String], tokens: &mut Vec<String>) -> Vec<u8> {
    labels
        .iter()
        .map(|l| {
            let c = collapse(l);
            match tokens.iter().position(|t| t == c) {
                Some(i) => i as u8,
                None => {
                    tokens.push(c.to_string());
                    (tokens.len() - 1) as u8
                }
            }
        })
        .collect()
}

/// Analytic cost-model score of one interned candidate (the paper's
/// early-cut metric): lower the loop nest and estimate *in the arena*
/// ([`crate::costmodel::estimate_id`] — no `Box<Expr>` is ever rebuilt to
/// score a candidate), then collapse to the scalar score. Candidates that
/// do not lower score `+∞`; they are kept (ranked last) rather than
/// failing the job, as on the seed path — and since `+∞` can never become
/// the shared bound, they are also never the reason something else gets
/// cut.
fn score_expr_id(arena: &SharedArena, id: ExprId, env: &Env) -> f64 {
    match estimate_id(arena, id, env) {
        Ok(est) => est.score(),
        Err(_) => f64::INFINITY,
    }
}

/// One surviving child candidate, still unextracted: the id-native path
/// carries only the interned id (in the search's shared arena) and the
/// merge step rebuilds a `Box<Expr>` *only* for children that survive
/// dedup *and* the bound cut — so duplicates reached along several swap
/// paths, and cut candidates, never cost a tree. The seed `Box<Expr>`
/// engine already owns the tree and passes it through.
struct Child {
    labels: Vec<String>,
    /// `Some` on the seed engine path; `None` means "extract `nid` from
    /// the shared arena iff kept".
    expr: Option<Expr>,
    nid: ExprId,
    /// Cut by the branch-and-bound: excluded from the result set (never
    /// lowered, scored, or extracted) but still enqueued as an expansion
    /// source.
    cut: bool,
    /// Rearrangement-sensitive lower bound — the child's expansion
    /// priority, and what the merge step rechecks against the freshest
    /// best-known score.
    bound: f64,
    /// Rearrangement-invariant floor — the child's contribution to the
    /// certified-gap denominator while it stays unexpanded.
    floor: f64,
}

/// One BFS frontier entry. Distinct from the kept [`Variant`] set: cut
/// candidates live only here (as plain ids — no tree is ever built for
/// them), while kept candidates appear in both — by *index*, so neither
/// their labels nor (on the seed path) their trees are ever cloned.
struct FrontierNode {
    /// Cut nodes own their labels; kept nodes leave this empty (no
    /// allocation) and read them — like the seed path reads trees — from
    /// the result set via [`ExprSrc::Kept`].
    labels: Vec<String>,
    id: ExprId,
    src: ExprSrc,
    /// Rearrangement-invariant floor, kept on the node so a truncated run
    /// can take the minimum over whatever is still open in the heap.
    floor: f64,
}

/// Where a [`FrontierNode`]'s labels and (seed-path) tree live.
enum ExprSrc {
    /// Cut candidate on the id-native path: labels inline, no tree.
    None,
    /// Kept candidate (either engine): labels — and, for the seed
    /// engine, the tree — live at this index of the result set, moved
    /// there once and never cloned.
    Kept(usize),
    /// Cut candidate on the seed path: the tree is not in the result
    /// set, so the frontier owns it (it was already materialized by the
    /// swap — no clone).
    Owned(Expr),
}

/// What one shard returns for one expanded parent: surviving children in
/// swap-depth order plus the counters the merge step aggregates. The
/// `(shard, seq)` pair is the merge tag — together with the BFS level
/// (implicit in which merge round processes the expansion) it restores
/// the serial discovery order deterministically, whatever the thread
/// scheduling was.
#[derive(Default)]
struct Expansion {
    children: Vec<(Child, Option<f64>)>,
    generated: usize,
    pruned: usize,
    type_rejects: usize,
    /// Which shard generated the children (extraction attribution).
    shard: usize,
    /// The parent's index in this level's frontier (merge order).
    seq: usize,
}

/// One search worker: a memoized id-native normalizer and `ExprId`-keyed
/// typecheck/score/bound caches, all resolving against the search's one
/// [`SharedArena`]. Shards persist across BFS levels so every cache warms
/// up over the whole search — and because the arena is shared, a parent
/// kept by *any* shard reaches the next level as a plain id, with no
/// extract/re-intern at the level boundary.
struct Shard {
    norm: IdRewriter,
    checked: HashMap<ExprId, bool>,
    /// Cost-model score per interned candidate — scoring is structural,
    /// so a variant reached along several swap paths is lowered and
    /// estimated once, not once per path.
    scored: HashMap<ExprId, f64>,
    /// Partial-spine lower bound per interned candidate — like `scored`,
    /// a candidate reached along several swap paths pays the spine walk
    /// once.
    bounded: HashMap<ExprId, f64>,
    /// Rearrangement-invariant floor per interned candidate (the
    /// certified-gap denominator), memoized like `bounded`.
    floored: HashMap<ExprId, f64>,
}

impl Shard {
    fn new() -> Self {
        Shard {
            norm: IdRewriter::new(&normalize_id_rules()),
            checked: HashMap::new(),
            scored: HashMap::new(),
            bounded: HashMap::new(),
            floored: HashMap::new(),
        }
    }

    /// Expand one frontier node: try every adjacent swap, normalize,
    /// typecheck, bound, score. Children come back in swap-depth order so
    /// the merge step can reproduce the serial BFS order exactly.
    ///
    /// On the id-native path the parent arrives as `node.id` — the id it
    /// was interned under when first discovered — so no per-level
    /// re-intern of the parent tree happens anywhere (the cost ISSUE 4
    /// removes). The seed `Box<Expr>` path still swaps on the owned tree;
    /// it interns each child once so the typecheck/score caches work
    /// identically.
    ///
    /// Every candidate's lower bound (and invariant floor) is computed
    /// once, on the normalized id, before any scoring work — the bound is
    /// the child's best-first priority, so it is needed whether or not
    /// the cut is armed (which is also what keeps exhaustive, pruned, and
    /// truncated runs on one discovery sequence). With pruning on, a
    /// bound exceeding `slack × best` cuts the candidate — it is returned
    /// with [`Child::cut`] set and is never lowered, scored, or
    /// extracted. (The bound's partial descent also makes it meaningful
    /// on the raw, unnormalized exchange output —
    /// `tests/lower_id_props.rs` pins `bound(raw) ≤
    /// score(normalize(raw))` — but consulting it there buys nothing on
    /// this path: the raw read never exceeds the refined one, cannot be
    /// memoized across swap paths, and normalization runs regardless
    /// because cut candidates re-enter the frontier as normalized ids.)
    /// The shared bound only moves in the serial merge between waves, so
    /// the read is the same in every shard — pruning is deterministic
    /// under any shard count — and since the bound never exceeds the
    /// candidate's true score, the default slack (1.0) can never cut the
    /// eventual winner.
    ///
    /// A `deadline` in the past trips the shared cancellation flag; a
    /// cancelled expansion bails out between swap depths. The driver
    /// discards the whole wave in that case, so partial expansions never
    /// leak into the result.
    #[allow(clippy::too_many_arguments)]
    fn expand(
        &mut self,
        arena: &SharedArena,
        node: &FrontierNode,
        out: &[Variant],
        n: usize,
        ctx: &Ctx,
        id_native: bool,
        scoring: bool,
        slack: Option<f64>,
        deadline: Option<Instant>,
        bound: &SearchBound,
    ) -> Expansion {
        let mut exp = Expansion::default();
        if let Some(d) = deadline {
            if Instant::now() >= d {
                bound.cancel();
            }
        }
        let threshold = slack.map(|sl| sl * bound.get());
        // Kept parents read their labels (and, on the seed engine, their
        // tree) from the kept set by index; cut parents carry them
        // inline. The id-native path swaps on `node.id` and never reads
        // `pexpr`.
        let (labels, pexpr): (&[String], Option<&Expr>) = match &node.src {
            ExprSrc::None => (&node.labels, None),
            ExprSrc::Kept(i) => {
                let v = &out[*i];
                (&v.labels, Some(&v.expr))
            }
            ExprSrc::Owned(e) => (&node.labels, Some(e)),
        };
        for d in 0..n.saturating_sub(1) {
            // Cooperative cancellation point: a deadline hit by any shard
            // (or by the driver) stops the remaining swap depths — the
            // wave is being discarded anyway.
            if bound.is_cancelled() {
                break;
            }
            // The id-native engine is the production path; the seed
            // `Box<Expr>` path stays reachable via `with_memo_disabled`
            // for differential testing. The flag is sampled once on the
            // search's calling thread (`memo_enabled` is thread-local and
            // would read `true` inside freshly spawned shard threads).
            let (nid, extracted) = if id_native {
                let Some(swapped) = try_swap_at_id(arena, node.id, d, ctx) else {
                    continue;
                };
                (self.norm.rewrite(arena, swapped), None)
            } else {
                let Some(new_expr) = pexpr.and_then(|pe| try_swap_at(pe, d, ctx)) else {
                    continue;
                };
                (arena.intern(&new_expr), Some(new_expr))
            };
            exp.generated += 1;
            // Defensive: drop rewrites that no longer typecheck — paying
            // for inference once per distinct interned tree. This gate
            // also covers cut candidates: they re-enter the frontier, and
            // an ill-typed expansion source could reach rearrangements
            // the exhaustive search never would.
            let ok = match self.checked.get(&nid) {
                Some(&ok) => ok,
                None => {
                    let ok = crate::typecheck::infer_id(arena, nid, &ctx.env).is_ok();
                    self.checked.insert(nid, ok);
                    ok
                }
            };
            if !ok {
                exp.type_rejects += 1;
                continue;
            }
            // The lower bound is the child's best-first priority, so it
            // is computed unconditionally (cached — a candidate reached
            // along several swap paths pays the spine walk once); with
            // pruning armed it doubles as the cut gate, before any
            // scoring work. The invariant floor rides the same cache
            // discipline for the gap denominator.
            let lb = match self.bounded.get(&nid) {
                Some(&lb) => lb,
                None => {
                    let lb = spine_lower_bound_id(arena, nid, ctx);
                    self.bounded.insert(nid, lb);
                    lb
                }
            };
            let floor = match self.floored.get(&nid) {
                Some(&fl) => fl,
                None => {
                    let fl = spine_reachable_floor_id(arena, nid, ctx);
                    self.floored.insert(nid, fl);
                    fl
                }
            };
            let cut = match threshold {
                Some(t) => lb > t,
                None => false,
            };
            if cut {
                exp.pruned += 1;
            }
            // Score in the arena — a variant reached along several swap
            // paths is lowered and estimated once, not once per path, and
            // never as a `Box<Expr>` tree. Cut candidates are never
            // scored: skipping this lower + estimate (and the output
            // extraction) is what the cut buys.
            let score = if scoring && !cut {
                Some(match self.scored.get(&nid) {
                    Some(&s) => s,
                    None => {
                        let s = score_expr_id(arena, nid, &ctx.env);
                        self.scored.insert(nid, s);
                        s
                    }
                })
            } else {
                None
            };
            // No extraction here: the merge step rebuilds a tree only for
            // children that survive dedup and the cut (the output
            // boundary).
            let mut labels = labels.to_vec();
            labels.swap(d, d + 1);
            exp.children.push((
                Child {
                    labels,
                    expr: extracted,
                    nid,
                    cut,
                    bound: lb,
                    floor,
                },
                score,
            ));
        }
        exp
    }
}

/// Expand one best-first wave across the shard pool, returning one
/// [`Expansion`] per parent **in wave order**: parents are dealt
/// round-robin, every expansion is tagged `(shard, seq)` by the worker
/// that produced it, and the merge sorts on the `seq` tag — so the output
/// order is independent of thread scheduling (and, the wave having been
/// composed shard-count-independently, of the shard count too). All
/// shards expand against the one shared arena; parents arrive as plain
/// ids.
#[allow(clippy::too_many_arguments)]
fn parallel_expand(
    shards: &mut [Shard],
    arena: &SharedArena,
    wave: &[&FrontierNode],
    out: &[Variant],
    n: usize,
    ctx: &Ctx,
    scoring: bool,
    slack: Option<f64>,
    deadline: Option<Instant>,
    bound: &SearchBound,
) -> Result<Vec<Expansion>> {
    let nshards = shards.len();
    let mut all: Vec<Expansion> = Vec::with_capacity(wave.len());
    let mut panicked = false;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (k, shard) in shards.iter_mut().enumerate() {
            let parents: Vec<(usize, &FrontierNode)> = wave
                .iter()
                .copied()
                .enumerate()
                .filter(|(i, _)| i % nshards == k)
                .collect();
            if parents.is_empty() {
                continue;
            }
            handles.push(s.spawn(move || {
                parents
                    .into_iter()
                    .map(|(i, nd)| {
                        let mut exp = shard
                            .expand(arena, nd, out, n, ctx, true, scoring, slack, deadline, bound);
                        exp.shard = k;
                        exp.seq = i;
                        exp
                    })
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            match h.join() {
                Ok(mut rs) => all.append(&mut rs),
                Err(_) => panicked = true,
            }
        }
    });
    if panicked {
        return Err(Error::Rewrite("search shard panicked".into()));
    }
    // Deterministic merge: order by the wave tag, exactly the serial
    // parent order.
    all.sort_by_key(|e| e.seq);
    debug_assert_eq!(all.len(), wave.len(), "every parent expanded once");
    Ok(all)
}

/// Best-first, anytime enumeration of rearrangements reachable by
/// adjacent exchanges, sharded across a worker pool and (optionally)
/// pruned by a shared cost bound. Expansion is ordered by the memoized
/// rearrangement-sensitive lower bound (deterministic tie-break on
/// discovery sequence) in constant-size waves, so the result order is the
/// serial best-first discovery order regardless of shard count, pruning,
/// budget, or deadline settings. Every returned variant typechecks under
/// `ctx.env`. With no binding budget/deadline/limit the frontier drains
/// and the result is exhaustive ([`SearchStats::complete`], certified gap
/// exactly `1.0`); a truncated run returns the best-so-far prefix plus a
/// sound gap certificate (see [`SearchStats::certified_gap`]).
pub fn enumerate_search(
    start: &Variant,
    ctx: &Ctx,
    opts: &SearchOptions,
) -> Result<SearchResult> {
    let n = start.labels.len();
    if spine_kinds(&start.expr).len() != n {
        return Err(Error::Rewrite(format!(
            "label count {} does not match spine length {}",
            n,
            spine_kinds(&start.expr).len()
        )));
    }
    crate::typecheck::infer(&start.expr, &ctx.env)?;
    let scoring = opts.score || opts.prune_slack.is_some();
    // Sampled once here: `memo_enabled` is thread-local, so shard threads
    // cannot consult it themselves. The seed engine also stays serial —
    // it exists to reproduce seed behavior exactly.
    let id_native = memo_enabled();
    // Both the auto path and explicit requests are clamped to
    // MAX_SEARCH_SHARDS: an explicit `shards: t` used to spawn `t`
    // threads unbounded, silently oversubscribing the machine when
    // several coordinator workers searched at once. `SearchStats::shards`
    // reports this effective count.
    let threads = if !id_native {
        1
    } else {
        match opts.shards {
            0 => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            t => t,
        }
        .min(MAX_SEARCH_SHARDS)
        .max(1)
    };
    let mut shards: Vec<Shard> = (0..threads).map(|_| Shard::new()).collect();
    // One concurrent hash-sharded arena for the whole search (ISSUE 4):
    // every shard generates, normalizes, typechecks and scores against
    // it, and frontier variants cross shard and level boundaries as plain
    // ids — the per-level extract/re-intern of the per-shard-arena design
    // is gone. The arena is checked out of the process-wide pool
    // (ISSUE 8) and returned — segments cleared, allocations retained —
    // when the search drops it; ids never outlive the search, which the
    // pool's debug-mode epoch stamps fail closed.
    let arena = crate::dsl::intern::arena_acquire();
    let start_id = arena.intern(&start.expr);
    // The start variant is scored through the same arena-native path as
    // every candidate (and warms shard 0's score cache).
    let start_score = if scoring {
        let s = score_expr_id(&arena, start_id, &ctx.env);
        shards[0].scored.insert(start_id, s);
        Some(s)
    } else {
        None
    };

    let mut tokens: Vec<String> = Vec::new();
    let mut seen: HashSet<Vec<u8>> = HashSet::new();
    seen.insert(label_key(&start.labels, &mut tokens));
    let mut out: Vec<Variant> = vec![start.clone()];
    // Every discovered candidate — kept or cut — becomes a frontier node
    // and an expansion source (cut nodes cross waves as plain ids and
    // never grow a tree), so pruning can never disconnect the swap graph
    // from the eventual winner. A discovered candidate is interned
    // exactly once in its whole life; later waves read it back from here.
    let start_bound = spine_lower_bound_id(&arena, start_id, ctx);
    shards[0].bounded.insert(start_id, start_bound);
    let start_floor = spine_reachable_floor_id(&arena, start_id, ctx);
    shards[0].floored.insert(start_id, start_floor);
    let mut nodes: Vec<FrontierNode> = vec![FrontierNode {
        labels: Vec::new(),
        id: start_id,
        src: ExprSrc::Kept(0),
        floor: start_floor,
    }];
    // The best-first priority frontier: `(bound_bits, seq)` min-heap.
    // Discovery sequence (== index into `nodes`) breaks bound ties, so
    // pop order is a deterministic function of the discovery sequence —
    // which is itself deterministic, waves being merged serially in wave
    // order.
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    heap.push(Reverse((order_bits(start_bound), 0)));
    // Running min over every invariant floor ever seen — the gap
    // denominator of last resort when a binding `limit` dropped children
    // the heap no longer tracks. (The floor is family-invariant, so any
    // one member's floor bounds every reachable member.)
    let mut global_floor = start_floor;
    let mut dropped = false;
    let mut scores: Vec<f64> = Vec::new();
    if let Some(s) = start_score {
        scores.push(s);
    }
    let shared = SearchBound::new(start_score.unwrap_or(f64::INFINITY), opts.cancel.clone());
    let mut stats = SearchStats {
        shards: threads,
        ..Default::default()
    };
    // Stable, padded layout (one slot per configured shard) so the
    // coordinator's Metrics merge never depends on which shards happened
    // to generate kept candidates.
    let mut extracted_per_shard = vec![0u64; threads];
    let budget = if opts.budget == 0 {
        usize::MAX
    } else {
        opts.budget
    };

    loop {
        if heap.is_empty() {
            break;
        }
        // The limit caps *discovered* candidates (`nodes` — in exhaustive
        // mode identical to the kept set), so pruned searches cannot walk
        // arbitrarily far past it through cut expansion sources.
        if nodes.len() >= opts.limit {
            break;
        }
        if stats.expanded >= budget {
            stats.budget_hit = true;
            break;
        }
        if opts.deadline.is_some_and(|d| Instant::now() >= d) {
            stats.deadline_hit = true;
            break;
        }
        // External cancellation (service `cancel()`): same between-wave
        // checkpoint as the deadline, attributed separately.
        if shared.externally_cancelled() {
            stats.cancelled = true;
            break;
        }
        // Pop one wave of the cheapest open nodes. The wave shrinks to
        // land on the budget exactly, so expansion sets at different
        // budgets are nested prefixes of one deterministic sequence.
        let take = EXPANSION_WAVE
            .min(budget - stats.expanded)
            .min(heap.len());
        let mut wave: Vec<(u64, usize)> = Vec::with_capacity(take);
        for _ in 0..take {
            let Reverse(k) = heap.pop().expect("heap len checked");
            wave.push(k);
        }
        let expansions: Vec<Expansion> = {
            let wave_nodes: Vec<&FrontierNode> =
                wave.iter().map(|&(_, i)| &nodes[i]).collect();
            let kept: &[Variant] = &out;
            if threads > 1 && wave_nodes.len() > 1 {
                parallel_expand(
                    &mut shards,
                    &arena,
                    &wave_nodes,
                    kept,
                    n,
                    ctx,
                    scoring,
                    opts.prune_slack,
                    opts.deadline,
                    &shared,
                )?
            } else {
                wave_nodes
                    .iter()
                    .enumerate()
                    .map(|(i, nd)| {
                        let mut exp = shards[0].expand(
                            &arena,
                            nd,
                            kept,
                            n,
                            ctx,
                            id_native,
                            scoring,
                            opts.prune_slack,
                            opts.deadline,
                            &shared,
                        );
                        exp.seq = i;
                        exp
                    })
                    .collect()
            }
        };
        if shared.is_cancelled() {
            // The deadline or an external cancellation tripped mid-wave:
            // discard the partial expansions entirely and return the wave
            // to the open frontier, so the gap certificate still covers
            // everything the truncated run did not explore. Attribute the
            // stop to its source(s) — the internal flag is only ever set
            // by deadline expiry, the external token only by the caller
            // (both can fire within one wave).
            for (bits, i) in wave {
                heap.push(Reverse((bits, i)));
            }
            if shared.deadline_tripped() {
                stats.deadline_hit = true;
            }
            if shared.externally_cancelled() {
                stats.cancelled = true;
            }
            break;
        }
        stats.expanded += wave.len();
        // Deterministic merge: parents in wave (seq-tag) order, children
        // in swap-depth order — exactly the serial best-first sequence.
        for exp in expansions {
            // Count the whole wave's work even past the limit — the
            // shards already did it; only *keeping* stops.
            stats.generated += exp.generated;
            stats.pruned += exp.pruned;
            stats.type_rejects += exp.type_rejects;
            for (child, s) in exp.children {
                let key = label_key(&child.labels, &mut tokens);
                if !seen.insert(key) {
                    continue;
                }
                global_floor = global_floor.min(child.floor);
                if nodes.len() >= opts.limit {
                    // Discovered but dropped: the heap will not track it,
                    // so the end-of-search gap must fall back to the
                    // family floor.
                    dropped = true;
                    continue;
                }
                // Merge-time cut recheck: scores merged earlier in this
                // very wave may have tightened the shared bound past what
                // the expansion threshold saw. Serial and in merge order,
                // so still deterministic and shard-count-independent —
                // and still winner-safe at slack 1.0 (`bound ≤ score ≤
                // best-known` keeps holding however fresh `best-known`
                // is).
                let mut cut = child.cut;
                let mut s = s;
                if !cut {
                    if let Some(sl) = opts.prune_slack {
                        if child.bound > sl * shared.get() {
                            cut = true;
                            s = None;
                            stats.pruned += 1;
                        }
                    }
                }
                // The shared best only absorbs *kept* scores (after dedup
                // and the recheck), so the gap numerator is always the
                // score of a variant actually in the result set — a
                // duplicate's score is a memoized repeat (no-op here), and
                // a cut child's score provably exceeds the bound anyway at
                // slack ≥ 1.0.
                if let Some(sv) = s {
                    if shared.fetch_min(sv) {
                        stats.bound_updates += 1;
                    }
                }
                let idx = nodes.len();
                if cut {
                    // Cut candidates stay expansion sources but leave
                    // the result set — and never cost a tree: the seed
                    // path keeps the tree the swap already built, the
                    // id-native path carries just the id.
                    let src = match child.expr {
                        Some(e) => ExprSrc::Owned(e),
                        None => ExprSrc::None,
                    };
                    nodes.push(FrontierNode {
                        labels: child.labels,
                        id: child.nid,
                        src,
                        floor: child.floor,
                    });
                } else {
                    // Output boundary: the one extract per *kept*
                    // candidate — duplicates and cut candidates never
                    // rebuild a tree, and wave boundaries never extract.
                    // Kept labels and trees are moved into `out` and the
                    // frontier refers back by index, so nothing is
                    // cloned and the id-native path pays exactly the one
                    // extraction.
                    let expr = match child.expr {
                        Some(e) => e,
                        None => {
                            extracted_per_shard[exp.shard] += 1;
                            arena.extract(child.nid)
                        }
                    };
                    nodes.push(FrontierNode {
                        labels: Vec::new(),
                        id: child.nid,
                        src: ExprSrc::Kept(out.len()),
                        floor: child.floor,
                    });
                    out.push(Variant {
                        expr,
                        labels: child.labels,
                    });
                    if let Some(s) = s {
                        scores.push(s);
                    }
                }
                heap.push(Reverse((order_bits(child.bound), idx)));
            }
        }
    }
    stats.kept = out.len();
    stats.frontier_open = heap.len();
    stats.complete = heap.is_empty()
        && !dropped
        && !stats.budget_hit
        && !stats.deadline_hit
        && !stats.cancelled;
    // The certified gap: best-known score over the tightest invariant
    // floor still open. Sound because the floor is rearrangement-
    // invariant — it bounds not just each open node but every family
    // member reachable through it (the swap graph is connected), i.e.
    // everything a longer run could still discover.
    let min_open = if heap.is_empty() {
        // Nothing open in the heap; if the run is still incomplete a
        // binding `limit` dropped children, covered by the family floor.
        global_floor
    } else {
        heap.iter()
            .map(|&Reverse((_, i))| nodes[i].floor)
            .fold(f64::INFINITY, f64::min)
    };
    stats.min_open_bound = if stats.complete { f64::INFINITY } else { min_open };
    let best = shared.get();
    stats.certified_gap = if stats.complete {
        1.0
    } else if best.is_finite() && min_open.is_finite() && min_open > 0.0 {
        // Clamped to strictly-above-1.0: even if the truncated winner
        // already beats every open floor, only a drained frontier reports
        // exactly 1.0 — "gap == 1.0 iff complete" is the caller-facing
        // contract, and rounding up is always sound for an upper bound.
        (best / min_open).max(1.0 + f64::EPSILON)
    } else {
        // No finite best (scoring off) or no usable floor: nothing to
        // certify.
        f64::INFINITY
    };
    debug_assert_eq!(
        extracted_per_shard.iter().sum::<u64>(),
        if id_native { arena.extractions() } else { 0 },
        "output-boundary extraction must be the arena's only extraction"
    );
    stats.extracted_per_shard = extracted_per_shard;
    Ok(SearchResult {
        variants: out,
        scores,
        stats,
    })
}

/// Exhaustive enumeration of all rearrangements reachable by adjacent
/// exchanges, deduplicated on the display form (best-first discovery
/// order, like everything built on [`enumerate_search`]). Every returned
/// variant typechecks under `ctx.env`. Serial and unbudgeted — the
/// compatibility entry point; the pipeline calls [`enumerate_search`] for
/// the sharded, cost-bounded, anytime engine.
pub fn enumerate_all(start: &Variant, ctx: &Ctx, limit: usize) -> Result<Vec<Variant>> {
    let opts = SearchOptions {
        limit,
        shards: 1,
        prune_slack: None,
        score: false,
        ..SearchOptions::default()
    };
    Ok(enumerate_search(start, ctx, &opts)?.variants)
}

/// Compare a variant's executed output against reference candidates (the
/// reference result and, for transposing rearrangements, its transpose).
/// Returns the index of the matching candidate.
pub fn verify_against(
    got: &[f64],
    candidates: &[Vec<f64>],
    tol: f64,
) -> Option<usize> {
    candidates
        .iter()
        .position(|c| crate::util::allclose(got, c, tol))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::Layout;
    use crate::typecheck::Env;

    fn matmul_env(n: usize, j: usize, k: usize) -> Env {
        Env::new()
            .with("A", Layout::row_major(&[n, j]))
            .with("B", Layout::row_major(&[j, k]))
    }

    #[test]
    fn spine_of_naive_matmul() {
        let e = crate::dsl::matmul_naive(crate::dsl::input("A"), crate::dsl::input("B"));
        assert_eq!(spine_kinds(&e), vec!["map", "map", "red"]);
    }

    #[test]
    fn naive_matmul_has_six_rearrangements() {
        // Paper Table 1: 3 distinct HoFs → 6 permutations.
        let env = matmul_env(4, 6, 8);
        let ctx = Ctx::new(env);
        let start = starts::matmul_naive_variant();
        let variants = enumerate_all(&start, &ctx, 100).unwrap();
        assert_eq!(variants.len(), 6, "{:?}",
            variants.iter().map(|v| v.display_key()).collect::<Vec<_>>());
        // all 6 label orders present
        let keys: std::collections::HashSet<String> =
            variants.iter().map(|v| v.display_key()).collect();
        for perm in [
            "mapA mapB rnz",
            "mapA rnz mapB",
            "rnz mapA mapB",
            "mapB mapA rnz",
            "mapB rnz mapA",
            "rnz mapB mapA",
        ] {
            assert!(keys.contains(perm), "missing {perm}; got {keys:?}");
        }
    }

    #[test]
    fn all_rearrangements_compute_matmul_or_its_transpose() {
        use crate::exec::run;
        use crate::util::Rng;
        let (n, j, k) = (4usize, 6, 8);
        let env = matmul_env(n, j, k);
        let ctx = Ctx::new(env.clone());
        let mut rng = Rng::new(11);
        let a = rng.fill_vec(n * j);
        let b = rng.fill_vec(j * k);
        // reference C and C^T
        let mut c = vec![0.0; n * k];
        for i in 0..n {
            for jj in 0..j {
                for kk in 0..k {
                    c[i * k + kk] += a[i * j + jj] * b[jj * k + kk];
                }
            }
        }
        let mut ct = vec![0.0; n * k];
        for i in 0..n {
            for kk in 0..k {
                ct[kk * n + i] = c[i * k + kk];
            }
        }
        let start = starts::matmul_naive_variant();
        let variants = enumerate_all(&start, &ctx, 100).unwrap();
        assert_eq!(variants.len(), 6);
        for v in &variants {
            let out = run(&v.expr, &env, &[("A", &a), ("B", &b)])
                .unwrap_or_else(|e| panic!("{}: {e}", v.display_key()));
            let hit = verify_against(&out, &[c.clone(), ct.clone()], 1e-9);
            assert!(hit.is_some(), "variant {} wrong result", v.display_key());
        }
    }

    #[test]
    fn subdivided_rnz_has_twelve_rearrangements() {
        // Paper Table 2: 4 HoFs, two indistinguishable rnzs → 12 cases.
        let env = matmul_env(4, 8, 4);
        let ctx = Ctx::new(env.clone());
        let start = starts::matmul_rnz_subdivided_variant(2);
        let variants = enumerate_all(&start, &ctx, 200).unwrap();
        assert_eq!(
            variants.len(),
            12,
            "{:?}",
            variants.iter().map(|v| v.display_key()).collect::<Vec<_>>()
        );
    }
}
