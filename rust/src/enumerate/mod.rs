//! Enumeration of HoF rearrangements (paper §4).
//!
//! The nesting of HoFs in a (fused, subdivided) expression forms a list —
//! the *spine*. Adjacent spine levels can be swapped by the exchange rules
//! of [`crate::rewrite::exchange`], each swap pairing with a `flip` of the
//! logical layout. Enumerating all permutations by adjacent transpositions
//! is exactly the Steinhaus–Johnson–Trotter scheme the paper cites; here we
//! additionally keep the search robust by breadth-first exploring the swap
//! graph and deduplicating on the paper's display form (the two/three
//! `rnz`s of a subdivided reduction are "not differentiated", so 4 HoFs
//! with two rnzs yield the paper's 12 cases, not 24).

mod sjt;
pub mod starts;

pub use sjt::sjt_permutations;

use crate::dsl::intern::{ExprArena, ExprId};
use crate::dsl::Expr;
use crate::rewrite::{exchange, normalize, Ctx};
use crate::{Error, Result};
use std::collections::{HashMap, VecDeque};

/// One rearrangement of the computation: the expression plus the spine
/// labels from outermost to innermost (`["mapA", "rnz", "mapB"]` reads as
/// the paper's table rows).
#[derive(Clone, Debug)]
pub struct Variant {
    pub expr: Expr,
    pub labels: Vec<String>,
}

impl Variant {
    pub fn new(expr: Expr, labels: &[&str]) -> Self {
        Variant {
            expr,
            labels: labels.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// The paper's display form: collapsed labels joined by spaces
    /// (`rnz*` labels are not differentiated).
    pub fn display_key(&self) -> String {
        self.labels
            .iter()
            .map(|l| collapse(l))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Collapse a label to its display form: all `rnz…` labels are the same.
pub fn collapse(label: &str) -> &str {
    if label.starts_with("rnz") {
        "rnz"
    } else {
        label
    }
}

/// The spine: the chain of HoF kinds from the root inward, descending
/// through operator lambdas.
pub fn spine_kinds(e: &Expr) -> Vec<&'static str> {
    let mut out = Vec::new();
    let mut cur = e;
    loop {
        match cur {
            Expr::Nzip { f, .. } => {
                out.push("map");
                match &**f {
                    Expr::Lam { body, .. } => cur = body,
                    _ => break,
                }
            }
            Expr::Rnz { m, .. } => {
                out.push("red");
                match &**m {
                    Expr::Lam { body, .. } => cur = body,
                    _ => break,
                }
            }
            _ => break,
        }
    }
    out
}

/// Try to swap spine levels `depth` and `depth+1` by applying an exchange
/// rule at that node. Returns the normalized full expression on success.
pub fn try_swap_at(e: &Expr, depth: usize, ctx: &Ctx) -> Option<Expr> {
    fn rec(e: &Expr, depth: usize, ctx: &Ctx) -> Option<Expr> {
        if depth == 0 {
            return exchange::map_map(e, ctx)
                .or_else(|| exchange::map_map_nested(e, ctx))
                .or_else(|| exchange::map_rnz(e, ctx))
                .or_else(|| exchange::rnz_map(e, ctx))
                .or_else(|| exchange::rnz_rnz(e, ctx));
        }
        match e {
            Expr::Nzip { f, args } => {
                let Expr::Lam { params, body } = &**f else {
                    return None;
                };
                if params.len() != args.len() {
                    return None;
                }
                let mut ctx2 = ctx.clone();
                for (p, a) in params.iter().zip(args) {
                    let elem = ctx.layout_of(a).ok()?.peel_outer().ok()?;
                    ctx2.vars.insert(p.clone(), elem);
                }
                let new_body = rec(body, depth - 1, &ctx2)?;
                Some(Expr::Nzip {
                    f: Box::new(Expr::Lam {
                        params: params.clone(),
                        body: Box::new(new_body),
                    }),
                    args: args.clone(),
                })
            }
            Expr::Rnz { r, m, args } => {
                let Expr::Lam { params, body } = &**m else {
                    return None;
                };
                if params.len() != args.len() {
                    return None;
                }
                let mut ctx2 = ctx.clone();
                for (p, a) in params.iter().zip(args) {
                    let elem = ctx.layout_of(a).ok()?.peel_outer().ok()?;
                    ctx2.vars.insert(p.clone(), elem);
                }
                let new_body = rec(body, depth - 1, &ctx2)?;
                Some(Expr::Rnz {
                    r: r.clone(),
                    m: Box::new(Expr::Lam {
                        params: params.clone(),
                        body: Box::new(new_body),
                    }),
                    args: args.clone(),
                })
            }
            _ => None,
        }
    }
    rec(e, depth, ctx).map(|x| normalize(&x))
}

/// Breadth-first enumeration of all rearrangements reachable by adjacent
/// exchanges, deduplicated on the display key. Every returned variant
/// typechecks under `ctx.env`.
pub fn enumerate_all(start: &Variant, ctx: &Ctx, limit: usize) -> Result<Vec<Variant>> {
    let n = start.labels.len();
    if spine_kinds(&start.expr).len() != n {
        return Err(Error::Rewrite(format!(
            "label count {} does not match spine length {}",
            n,
            spine_kinds(&start.expr).len()
        )));
    }
    crate::typecheck::infer(&start.expr, &ctx.env)?;
    // Hash-consing arena for the BFS: interning a candidate gives O(1)
    // structural identity, so a tree reached along several swap paths is
    // typechecked once instead of once per path.
    let mut arena = ExprArena::new();
    let mut checked: HashMap<ExprId, bool> = HashMap::new();
    let start_id = arena.intern(&start.expr);
    checked.insert(start_id, true);
    let mut seen: HashMap<String, usize> = HashMap::new();
    let mut out: Vec<Variant> = Vec::new();
    let mut queue: VecDeque<Variant> = VecDeque::new();
    seen.insert(start.display_key(), 0);
    out.push(start.clone());
    queue.push_back(start.clone());
    while let Some(v) = queue.pop_front() {
        if out.len() >= limit {
            break;
        }
        for d in 0..n.saturating_sub(1) {
            if let Some(new_expr) = try_swap_at(&v.expr, d, ctx) {
                // Defensive: drop rewrites that no longer typecheck —
                // paying for inference once per distinct interned tree.
                let id = arena.intern(&new_expr);
                let ok = *checked
                    .entry(id)
                    .or_insert_with(|| crate::typecheck::infer(&new_expr, &ctx.env).is_ok());
                if !ok {
                    continue;
                }
                let mut labels = v.labels.clone();
                labels.swap(d, d + 1);
                let nv = Variant {
                    expr: new_expr,
                    labels,
                };
                let key = nv.display_key();
                if !seen.contains_key(&key) {
                    seen.insert(key, out.len());
                    out.push(nv.clone());
                    queue.push_back(nv);
                }
            }
        }
    }
    Ok(out)
}

/// Compare a variant's executed output against reference candidates (the
/// reference result and, for transposing rearrangements, its transpose).
/// Returns the index of the matching candidate.
pub fn verify_against(
    got: &[f64],
    candidates: &[Vec<f64>],
    tol: f64,
) -> Option<usize> {
    candidates
        .iter()
        .position(|c| crate::util::allclose(got, c, tol))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::Layout;
    use crate::typecheck::Env;

    fn matmul_env(n: usize, j: usize, k: usize) -> Env {
        Env::new()
            .with("A", Layout::row_major(&[n, j]))
            .with("B", Layout::row_major(&[j, k]))
    }

    #[test]
    fn spine_of_naive_matmul() {
        let e = crate::dsl::matmul_naive(crate::dsl::input("A"), crate::dsl::input("B"));
        assert_eq!(spine_kinds(&e), vec!["map", "map", "red"]);
    }

    #[test]
    fn naive_matmul_has_six_rearrangements() {
        // Paper Table 1: 3 distinct HoFs → 6 permutations.
        let env = matmul_env(4, 6, 8);
        let ctx = Ctx::new(env);
        let start = starts::matmul_naive_variant();
        let variants = enumerate_all(&start, &ctx, 100).unwrap();
        assert_eq!(variants.len(), 6, "{:?}",
            variants.iter().map(|v| v.display_key()).collect::<Vec<_>>());
        // all 6 label orders present
        let keys: std::collections::HashSet<String> =
            variants.iter().map(|v| v.display_key()).collect();
        for perm in [
            "mapA mapB rnz",
            "mapA rnz mapB",
            "rnz mapA mapB",
            "mapB mapA rnz",
            "mapB rnz mapA",
            "rnz mapB mapA",
        ] {
            assert!(keys.contains(perm), "missing {perm}; got {keys:?}");
        }
    }

    #[test]
    fn all_rearrangements_compute_matmul_or_its_transpose() {
        use crate::exec::run;
        use crate::util::Rng;
        let (n, j, k) = (4usize, 6, 8);
        let env = matmul_env(n, j, k);
        let ctx = Ctx::new(env.clone());
        let mut rng = Rng::new(11);
        let a = rng.fill_vec(n * j);
        let b = rng.fill_vec(j * k);
        // reference C and C^T
        let mut c = vec![0.0; n * k];
        for i in 0..n {
            for jj in 0..j {
                for kk in 0..k {
                    c[i * k + kk] += a[i * j + jj] * b[jj * k + kk];
                }
            }
        }
        let mut ct = vec![0.0; n * k];
        for i in 0..n {
            for kk in 0..k {
                ct[kk * n + i] = c[i * k + kk];
            }
        }
        let start = starts::matmul_naive_variant();
        let variants = enumerate_all(&start, &ctx, 100).unwrap();
        assert_eq!(variants.len(), 6);
        for v in &variants {
            let out = run(&v.expr, &env, &[("A", &a), ("B", &b)])
                .unwrap_or_else(|e| panic!("{}: {e}", v.display_key()));
            let hit = verify_against(&out, &[c.clone(), ct.clone()], 1e-9);
            assert!(hit.is_some(), "variant {} wrong result", v.display_key());
        }
    }

    #[test]
    fn subdivided_rnz_has_twelve_rearrangements() {
        // Paper Table 2: 4 HoFs, two indistinguishable rnzs → 12 cases.
        let env = matmul_env(4, 8, 4);
        let ctx = Ctx::new(env.clone());
        let start = starts::matmul_rnz_subdivided_variant(2);
        let variants = enumerate_all(&start, &ctx, 200).unwrap();
        assert_eq!(
            variants.len(),
            12,
            "{:?}",
            variants.iter().map(|v| v.display_key()).collect::<Vec<_>>()
        );
    }
}
