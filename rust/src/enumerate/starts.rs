//! Canonical starting expressions for the paper's experiments (§4).
//!
//! Subdivisions are expressed at the *input* level (the paper's
//! `A^(1a) = subdiv 0 2 A` bookkeeping): every HoF argument in the nest is
//! then a bare variable or input view, which is the normal form the
//! exchange rules traverse.
//!
//! Inputs are always named `A` (n×j, row-major) and `B` (j×k, row-major);
//! `C_ik = Σ_j A_ij · B_jk`. Columns of `B` are made explicit with
//! `flip 0 B`, exactly as the paper's eq 51 assumes.

use super::Variant;
use crate::dsl::*;

/// Paper eq 51: `map (\rA -> map (\cB -> rnz (+) (*) rA cB) (flip 0 B)) A`.
/// Spine: `mapA mapB rnz` — Table 1's first row.
pub fn matmul_naive_variant() -> Variant {
    Variant::new(
        matmul_naive(input("A"), input("B")),
        &["mapA", "mapB", "rnz"],
    )
}

/// Table 2 start: the reduction subdivided with block size `b`.
///
/// `A2 = subdiv 0 b A` (rows chunked), `B2 = subdiv 0 b (flip 0 B)`
/// (columns chunked); the dot product becomes a reduction over chunk dot
/// products. Spine: `mapA mapB rnzO rnzI`.
pub fn matmul_rnz_subdivided_variant(b: usize) -> Variant {
    let a2 = subdiv(0, b, input("A"));
    let b2 = subdiv(0, b, flip(0, input("B")));
    let e = map(
        lam1(
            "rA",
            map(
                lam1(
                    "cB",
                    rnz(
                        add(),
                        lam2("u", "v", dot(var("u"), var("v"))),
                        vec![var("rA"), var("cB")],
                    ),
                ),
                b2,
            ),
        ),
        a2,
    );
    Variant::new(e, &["mapA", "mapB", "rnzO", "rnzI"])
}

/// Figure 4 start: the two maps subdivided with block size `b` (in their
/// outermost direction — rows of A and columns of B are grouped).
/// Spine: `mapAo mapAi mapBo mapBi rnz`.
pub fn matmul_maps_subdivided_variant(b: usize) -> Variant {
    // A: [(j,1),(n,j)] — subdiv the row-index dim (1)
    let a2 = subdiv(1, b, input("A"));
    // flip 0 B: [(j,k),(k,1)] — subdiv the column-index dim (1)
    let b2 = subdiv(1, b, flip(0, input("B")));
    let e = map(
        lam1(
            "RA",
            map(
                lam1(
                    "rA",
                    map(
                        lam1(
                            "CB",
                            map(
                                lam1("cB", dot(var("rA"), var("cB"))),
                                var("CB"),
                            ),
                        ),
                        b2.clone(),
                    ),
                ),
                var("RA"),
            ),
        ),
        a2,
    );
    Variant::new(e, &["mapAo", "mapAi", "mapBo", "mapBi", "rnz"])
}

/// Figure 5 start: the reduction subdivided twice (`b1` outer chunks of
/// `b2`-element inner chunks). Spine: `mapA mapB rnzO rnzM rnzI`.
pub fn matmul_rnz_twice_subdivided_variant(b1: usize, b2: usize) -> Variant {
    // j dimension: (b2,1),(b1,b2),(j/(b1 b2), b1 b2)
    let a2 = subdiv(1, b1, subdiv(0, b2, input("A")));
    let b2e = subdiv(1, b1, subdiv(0, b2, flip(0, input("B"))));
    let e = map(
        lam1(
            "rA",
            map(
                lam1(
                    "cB",
                    rnz(
                        add(),
                        lam2(
                            "u",
                            "v",
                            rnz(
                                add(),
                                lam2("p", "q", dot(var("p"), var("q"))),
                                vec![var("u"), var("v")],
                            ),
                        ),
                        vec![var("rA"), var("cB")],
                    ),
                ),
                b2e,
            ),
        ),
        a2,
    );
    Variant::new(e, &["mapA", "mapB", "rnzO", "rnzM", "rnzI"])
}

/// Figure 6 start: every HoF subdivided once with block size `b`.
/// Spine: `mapAo mapAi mapBo mapBi rnzO rnzI`.
pub fn matmul_all_subdivided_variant(b: usize) -> Variant {
    // A: subdiv rows (dim 1) and row contents (dim 0)
    let a2 = subdiv(0, b, subdiv(1, b, input("A")));
    // flip 0 B: subdiv columns (dim 1) and column contents (dim 0)
    let b2 = subdiv(0, b, subdiv(1, b, flip(0, input("B"))));
    let e = map(
        lam1(
            "RA",
            map(
                lam1(
                    "rA",
                    map(
                        lam1(
                            "CB",
                            map(
                                lam1(
                                    "cB",
                                    rnz(
                                        add(),
                                        lam2("u", "v", dot(var("u"), var("v"))),
                                        vec![var("rA"), var("cB")],
                                    ),
                                ),
                                var("CB"),
                            ),
                        ),
                        b2.clone(),
                    ),
                ),
                var("RA"),
            ),
        ),
        a2,
    );
    Variant::new(
        e,
        &["mapAo", "mapAi", "mapBo", "mapBi", "rnzO", "rnzI"],
    )
}

/// Figure 3 starts: the matrix–vector product (`A`: n×j, `v`: j).
/// Cases 1a-1c subdivide the vector (eq 47); 2a-2c subdivide the map side
/// (eq 48).
pub fn matvec_naive_variant() -> Variant {
    Variant::new(
        matvec_naive(input("A"), input("v")),
        &["mapA", "rnz"],
    )
}

/// eq 47 (the 1a form): rows and vector chunked with block size `b`.
/// Spine: `mapA rnzO rnzI`.
pub fn matvec_vector_subdivided_variant(b: usize) -> Variant {
    let a2 = subdiv(0, b, input("A"));
    let v2 = subdiv(0, b, input("v"));
    let e = map(
        lam1(
            "r",
            rnz(
                add(),
                lam2("u", "w", dot(var("u"), var("w"))),
                vec![var("r"), v2],
            ),
        ),
        a2,
    );
    Variant::new(e, &["mapA", "rnzO", "rnzI"])
}

/// eq 48/49 (the 2a-side family): subdividing the map over rows instead.
/// Spine: `mapAo mapAi rnz`.
pub fn matvec_map_subdivided_variant(b: usize) -> Variant {
    let a2 = subdiv(1, b, input("A"));
    let e = map(
        lam1(
            "R",
            map(lam1("r", dot(var("r"), input("v"))), var("R")),
        ),
        a2,
    );
    Variant::new(e, &["mapAo", "mapAi", "rnz"])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::run;
    use crate::layout::Layout;
    use crate::typecheck::Env;
    use crate::util::Rng;

    fn env(n: usize, j: usize, k: usize) -> Env {
        Env::new()
            .with("A", Layout::row_major(&[n, j]))
            .with("B", Layout::row_major(&[j, k]))
            .with("v", Layout::row_major(&[j]))
    }

    fn reference_matmul(a: &[f64], b: &[f64], n: usize, j: usize, k: usize) -> Vec<f64> {
        let mut c = vec![0.0; n * k];
        for i in 0..n {
            for jj in 0..j {
                for kk in 0..k {
                    c[i * k + kk] += a[i * j + jj] * b[jj * k + kk];
                }
            }
        }
        c
    }

    #[test]
    fn all_starts_compute_the_same_matmul() {
        let (n, j, k) = (4usize, 8, 4);
        let env = env(n, j, k);
        let mut rng = Rng::new(5);
        let a = rng.fill_vec(n * j);
        let b = rng.fill_vec(j * k);
        let c = reference_matmul(&a, &b, n, j, k);
        for (name, v) in [
            ("naive", matmul_naive_variant()),
            ("rnz-subdiv", matmul_rnz_subdivided_variant(2)),
            ("maps-subdiv", matmul_maps_subdivided_variant(2)),
            ("rnz-twice", matmul_rnz_twice_subdivided_variant(2, 2)),
            ("all-subdiv", matmul_all_subdivided_variant(2)),
        ] {
            let out = run(&v.expr, &env, &[("A", &a), ("B", &b)])
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(
                crate::util::allclose(&out, &c, 1e-9),
                "{name} produced wrong result"
            );
            assert_eq!(
                super::super::spine_kinds(&v.expr).len(),
                v.labels.len(),
                "{name} labels mismatch spine"
            );
        }
    }

    #[test]
    fn matvec_starts_agree() {
        let (n, j) = (6usize, 8);
        let env = env(n, j, 1);
        let mut rng = Rng::new(9);
        let a = rng.fill_vec(n * j);
        let v = rng.fill_vec(j);
        let reference = run(
            &matvec_naive_variant().expr,
            &env,
            &[("A", &a), ("v", &v)],
        )
        .unwrap();
        for (name, var) in [
            ("1a", matvec_vector_subdivided_variant(2)),
            ("2-family", matvec_map_subdivided_variant(2)),
        ] {
            let out = run(&var.expr, &env, &[("A", &a), ("v", &v)]).unwrap();
            assert!(
                crate::util::allclose(&out, &reference, 1e-9),
                "{name} wrong"
            );
        }
    }
}
