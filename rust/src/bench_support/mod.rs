//! Self-contained micro-benchmark harness and table formatting.
//!
//! criterion is not available in this offline environment; this module
//! provides the warmup + repeated-measurement + median protocol the
//! benches use, plus helpers to print the paper-style tables.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Median wall time of the measured runs.
    pub median: Duration,
    /// Minimum observed (best-case) time.
    pub min: Duration,
    /// Number of measured runs.
    pub runs: usize,
}

impl Measurement {
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Benchmark protocol configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: usize,
    pub runs: usize,
    /// Cap on total measured time; stops early once exceeded (variants in
    /// the matmul tables differ by ~100×, so slow ones take fewer runs).
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: 1,
            runs: 5,
            max_total: Duration::from_secs(20),
        }
    }
}

impl BenchConfig {
    /// Quick config for CI-style runs.
    pub fn quick() -> Self {
        BenchConfig {
            warmup: 1,
            runs: 3,
            max_total: Duration::from_secs(8),
        }
    }
}

/// Time a closure under the protocol. The closure must perform the full
/// unit of work per call; use [`std::hint::black_box`] inside as needed.
pub fn bench(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup {
        f();
    }
    let mut times = Vec::with_capacity(cfg.runs);
    let start_all = Instant::now();
    for _ in 0..cfg.runs {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
        if start_all.elapsed() > cfg.max_total {
            break;
        }
    }
    times.sort();
    Measurement {
        name: name.to_string(),
        median: times[times.len() / 2],
        min: times[0],
        runs: times.len(),
    }
}

/// Format a duration like the paper's tables (seconds with 2-3 significant
/// digits, or milliseconds under a second).
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.0} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Print a paper-style two-column table sorted ascending by time.
pub fn print_table(title: &str, rows: &mut Vec<(String, Duration)>) {
    println!("\n=== {title} ===");
    rows.sort_by_key(|(_, d)| *d);
    let w = rows.iter().map(|(n, _)| n.len()).max().unwrap_or(8).max(8);
    println!("{:w$}  {:>10}", "HoF order", "Time", w = w);
    for (name, d) in rows.iter() {
        println!("{:w$}  {:>10}", name, fmt_duration(*d), w = w);
    }
}

/// Read a benchmark problem size from the environment (`HOFDLA_N`),
/// defaulting as given. The paper uses 1024; benches default smaller so the
/// full suite stays tractable, and `HOFDLA_N=1024` reproduces the paper's
/// setting.
pub fn env_size(default: usize) -> usize {
    std::env::var("HOFDLA_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Read the bench protocol from the environment (`HOFDLA_QUICK=1`).
pub fn env_config() -> BenchConfig {
    if std::env::var("HOFDLA_QUICK").is_ok() {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("spin", &BenchConfig::quick(), || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(x);
        });
        assert!(m.min <= m.median);
        assert!(m.runs >= 1);
        assert!(m.median.as_nanos() > 0);
    }

    #[test]
    fn fmt_durations() {
        assert_eq!(fmt_duration(Duration::from_secs(5)), "5.00 s");
        assert_eq!(fmt_duration(Duration::from_millis(186)), "186 ms");
        assert!(fmt_duration(Duration::from_micros(3)).contains("µs"));
    }

    #[test]
    fn env_size_default() {
        std::env::remove_var("HOFDLA_N");
        assert_eq!(env_size(512), 512);
    }
}
