//! The certified result of a successful verification: per-space access
//! intervals and exact access counts.

use super::depend::ParCert;
use crate::exec::{Access, AccessKind};

/// A closed interval `[lo, hi]` of element offsets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    pub lo: usize,
    pub hi: usize,
}

impl Interval {
    pub fn point(x: usize) -> Self {
        Interval { lo: x, hi: x }
    }

    pub fn contains(&self, x: usize) -> bool {
        self.lo <= x && x <= self.hi
    }

    /// Smallest interval covering both.
    pub fn hull(self, other: Interval) -> Interval {
        Interval {
            lo: self.lo.min(other.lo),
            hi: self.hi.max(other.hi),
        }
    }

    /// Widen the upper end by `extra` (saturating — the checker flags any
    /// offset arithmetic that would overflow via its bounds checks).
    pub(super) fn widen_hi(self, extra: usize) -> Interval {
        Interval {
            lo: self.lo,
            hi: self.hi.saturating_add(extra),
        }
    }
}

/// Access summary for one address space (an input slot, the output, or a
/// reduction temp).
#[derive(Clone, Debug, Default)]
pub struct SpaceUse {
    /// Hull of all read offsets (`None` if the space is never read).
    pub read: Option<Interval>,
    /// Hull of all written offsets (`None` if the space is never written).
    pub write: Option<Interval>,
    /// Exact number of scalar reads, matching [`crate::exec::trace`]'s
    /// emission (saturating on astronomically large programs).
    pub reads: u64,
    /// Exact number of scalar writes, matching the trace.
    pub writes: u64,
}

impl SpaceUse {
    pub(super) fn record(&mut self, kind: AccessKind, iv: Interval, count: u64) {
        let (slot, n) = match kind {
            AccessKind::Read => (&mut self.read, &mut self.reads),
            AccessKind::Write => (&mut self.write, &mut self.writes),
        };
        *slot = Some(slot.map_or(iv, |old| old.hull(iv)));
        *n = n.saturating_add(count);
    }
}

/// The statically-computed access footprint of a verified
/// [`crate::exec::Program`].
///
/// Space numbering matches [`crate::exec::Access::space`]: `0..n_inputs`
/// are input slots, `n_inputs` is the output, `n_inputs + 1 + t` is
/// reduction temp `t`. The intervals are exact hulls of the offsets the
/// interpreter will touch (loop strides are non-negative, so the extremes
/// are actually reached); the counts replicate the dynamic trace exactly,
/// which the differential tests in `tests/verify_props.rs` pin.
#[derive(Clone, Debug)]
pub struct Footprint {
    pub spaces: Vec<SpaceUse>,
    pub n_inputs: usize,
    /// Number of leaf-kernel evaluations — the program's scalar-op count,
    /// cross-checked against [`crate::costmodel::CostEstimate::flops`].
    pub leaf_evals: u64,
    /// Parallel-safety certificate: a dependence verdict for every
    /// `MapLoop` in the nest (see [`super::depend`]). The executor's
    /// threaded mode is gated on this — a `Serial` verdict or a missing
    /// root entry falls back to the serial path.
    pub par: ParCert,
}

impl Footprint {
    /// Does a traced access fall inside the certified footprint?
    pub fn contains(&self, a: &Access) -> bool {
        let Some(use_) = self.spaces.get(a.space) else {
            return false;
        };
        let iv = match a.kind {
            AccessKind::Read => use_.read,
            AccessKind::Write => use_.write,
        };
        iv.is_some_and(|iv| iv.contains(a.offset))
    }

    /// Minimum buffer length input `slot` provably needs (0 if never read).
    pub fn input_required(&self, slot: usize) -> usize {
        self.spaces
            .get(slot)
            .filter(|_| slot < self.n_inputs)
            .and_then(|u| u.read)
            .map_or(0, |iv| iv.hi + 1)
    }

    /// Access summary of the output space.
    pub fn output(&self) -> &SpaceUse {
        &self.spaces[self.n_inputs]
    }

    /// Total scalar reads across all spaces.
    pub fn reads(&self) -> u64 {
        self.spaces.iter().fold(0u64, |s, u| s.saturating_add(u.reads))
    }

    /// Total scalar writes across all spaces.
    pub fn writes(&self) -> u64 {
        self.spaces.iter().fold(0u64, |s, u| s.saturating_add(u.writes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_algebra() {
        let a = Interval::point(3);
        assert!(a.contains(3) && !a.contains(2));
        let h = a.hull(Interval { lo: 5, hi: 9 });
        assert_eq!(h, Interval { lo: 3, hi: 9 });
        assert_eq!(a.widen_hi(4), Interval { lo: 3, hi: 7 });
    }

    #[test]
    fn footprint_contains_and_required() {
        let mut out = SpaceUse::default();
        out.record(AccessKind::Write, Interval { lo: 0, hi: 7 }, 8);
        let mut a = SpaceUse::default();
        a.record(AccessKind::Read, Interval { lo: 0, hi: 31 }, 32);
        let fp = Footprint {
            spaces: vec![a, out],
            n_inputs: 1,
            leaf_evals: 32,
            par: ParCert::default(),
        };
        assert!(fp.contains(&Access {
            kind: AccessKind::Read,
            space: 0,
            offset: 31,
        }));
        assert!(!fp.contains(&Access {
            kind: AccessKind::Read,
            space: 0,
            offset: 32,
        }));
        assert!(!fp.contains(&Access {
            kind: AccessKind::Write,
            space: 0,
            offset: 0,
        }));
        assert_eq!(fp.input_required(0), 32);
        assert_eq!(fp.input_required(1), 0, "output is not an input");
        assert_eq!(fp.reads(), 32);
        assert_eq!(fp.writes(), 8);
    }
}
