//! The abstract interpreter behind [`super::verify`]: walks the loop nest
//! once, propagating per-track offset intervals through the affine `Adv`
//! chains, and collects [`Violation`]s instead of touching memory.
//!
//! The analysis is *exact*, not conservative: every `Adv` advances its
//! track by a non-negative `base + i * stride`, each track is entered by
//! exactly one loop on any root-to-leaf path, and loop bodies are single
//! nodes — so the interval `[entry.lo + base, entry.hi + base +
//! (extent-1)*stride]` is precisely the set extremes of offsets the
//! interpreter's cursor takes at read time, and the extremes are reached.
//! Offset arithmetic saturates; a saturated bound fails the corresponding
//! bounds check, so overflow rejects instead of wrapping.

use super::footprint::{Footprint, Interval, SpaceUse};
use crate::dsl::Prim;
use crate::exec::{AccessKind, Adv, Kernel, KernelOp, Node, Program, WriteMode};

/// The interpreter evaluates leaf kernels on a fixed 16-slot operand
/// stack; the verifier proves every kernel stays within it.
pub const MAX_KERNEL_STACK: usize = 16;

/// One reason a program failed verification. `Display` names the offending
/// space (input name, output, or temp index) and track where applicable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// A kernel read through `track` can reach `max_offset`, but the
    /// backing space only has `len` elements.
    ReadBounds {
        space: String,
        track: usize,
        max_offset: usize,
        len: usize,
    },
    /// A write can reach `max_offset` outside the destination space.
    WriteBounds {
        space: String,
        max_offset: usize,
        len: usize,
    },
    /// A `MapLoop` body writes more elements than the loop advances the
    /// destination cursor by — distinct iterations would overlap.
    MapOverlap {
        at: String,
        body_span: usize,
        body_size: usize,
    },
    /// A `MapLoop` body writes fewer elements than `body_size` — the loop
    /// would leave gaps of uninitialized output.
    MapGap {
        at: String,
        body_span: usize,
        body_size: usize,
    },
    /// A `RedLoop`'s declared `body_size` disagrees with the region its
    /// body actually writes (the identity fill and the accumulation would
    /// cover different elements).
    RedSizeMismatch {
        at: String,
        body_span: usize,
        body_size: usize,
    },
    /// A reduction temp region's size disagrees with the body span the
    /// fill/fold traverse.
    TempSizeMismatch {
        temp: usize,
        need: usize,
        have: usize,
    },
    /// A reduction without a private temp runs under a different (or
    /// non-commutative) enclosing accumulator: its partial results would
    /// be combined into elements initialized for the *outer* operator,
    /// i.e. combined before being properly set.
    AccWithoutTemp { at: String, op: Prim, outer: Prim },
    /// Structural defect (bad track/slot/temp index, zero extent,
    /// malformed kernel bytecode, size-table mismatch, …).
    Malformed(String),
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::ReadBounds {
                space,
                track,
                max_offset,
                len,
            } => write!(
                f,
                "read out of bounds: track {track} into {space} reaches offset {max_offset} (len {len})"
            ),
            Violation::WriteBounds {
                space,
                max_offset,
                len,
            } => write!(
                f,
                "write out of bounds: {space} written at offset {max_offset} (len {len})"
            ),
            Violation::MapOverlap {
                at,
                body_span,
                body_size,
            } => write!(
                f,
                "map iterations overlap at {at}: body writes {body_span} elements but advances by {body_size}"
            ),
            Violation::MapGap {
                at,
                body_span,
                body_size,
            } => write!(
                f,
                "map leaves uninitialized gaps at {at}: body writes {body_span} elements but advances by {body_size}"
            ),
            Violation::RedSizeMismatch {
                at,
                body_span,
                body_size,
            } => write!(
                f,
                "reduction body size mismatch at {at}: declared {body_size}, body writes {body_span}"
            ),
            Violation::TempSizeMismatch { temp, need, have } => write!(
                f,
                "temp {temp} sized {have} but the reduction fill/fold traverse {need} elements"
            ),
            Violation::AccWithoutTemp { at, op, outer } => write!(
                f,
                "reduction '{}' at {at} accumulates under enclosing '{}' without a temp — elements would be combined before being set for '{}'",
                op.name(),
                outer.name(),
                op.name()
            ),
            Violation::Malformed(m) => write!(f, "malformed program: {m}"),
        }
    }
}

/// Destination region handed down the walk: which space, and the interval
/// of base offsets the enclosing loops can place the cursor at.
#[derive(Clone, Copy)]
struct Dest {
    space: usize,
    iv: Interval,
}

struct Checker<'p> {
    prog: &'p Program,
    n_inputs: usize,
    /// Offset interval of each track *at loop entry* (before the owning
    /// loop steps it) — what a same-loop sibling `Adv` would base on.
    entry: Vec<Option<Interval>>,
    /// Offset interval at read time (entry widened by the owning loop's
    /// `(extent-1) * stride` stepping).
    read: Vec<Option<Interval>>,
    spaces: Vec<SpaceUse>,
    leaf_evals: u64,
    violations: Vec<Violation>,
}

/// Run the full analysis. `Err` carries every violation found in one pass.
pub(super) fn check(prog: &Program) -> Result<Footprint, Vec<Violation>> {
    let n_inputs = prog.input_names.len();
    // Signature defects make the walk itself unsafe to run (the walk
    // indexes these tables); report them alone and bail.
    let mut pre = Vec::new();
    if prog.input_lens.len() != n_inputs {
        pre.push(Violation::Malformed(format!(
            "{} input_lens for {} inputs",
            prog.input_lens.len(),
            n_inputs
        )));
    }
    for (t, &slot) in prog.track_slot.iter().enumerate() {
        if slot >= n_inputs {
            pre.push(Violation::Malformed(format!(
                "track {t} backed by slot {slot}, but only {n_inputs} inputs exist"
            )));
        }
    }
    if !pre.is_empty() {
        return Err(pre);
    }
    let n_tracks = prog.n_tracks();
    let mut c = Checker {
        prog,
        n_inputs,
        entry: vec![None; n_tracks],
        read: vec![None; n_tracks],
        spaces: vec![SpaceUse::default(); n_inputs + 1 + prog.temp_sizes.len()],
        leaf_evals: 0,
        violations: Vec::new(),
    };
    let root = Dest {
        space: n_inputs,
        iv: Interval::point(0),
    };
    let span = c.walk(&prog.root, WriteMode::Set, root, 1, 0);
    if span != prog.out_size {
        c.violations.push(Violation::Malformed(format!(
            "root writes {span} elements but out_size is {}",
            prog.out_size
        )));
    }
    if c.violations.is_empty() {
        // The dependence analysis runs only on programs that passed the
        // bounds/initialization/disjointness walk above: `Parallel`
        // verdicts lean on those guarantees (body span == body_size).
        Ok(Footprint {
            spaces: c.spaces,
            n_inputs,
            leaf_evals: c.leaf_evals,
            par: super::depend::certify(prog),
        })
    } else {
        Err(c.violations)
    }
}

/// Output size a node *declares* (what the interpreter's identity fill and
/// cursor stepping use) — as opposed to the span its body actually writes,
/// which the walk computes and compares.
fn declared_size(n: &Node) -> usize {
    match n {
        Node::MapLoop {
            extent, body_size, ..
        } => extent.saturating_mul(*body_size),
        Node::RedLoop { body_size, .. } => *body_size,
        Node::Leaf(_) => 1,
    }
}

impl<'p> Checker<'p> {
    /// Human name of an access space for diagnostics.
    fn space_name(&self, space: usize) -> String {
        if space < self.n_inputs {
            format!("input '{}' (slot {space})", self.prog.input_names[space])
        } else if space == self.n_inputs {
            "output".into()
        } else {
            format!("temp {}", space - self.n_inputs - 1)
        }
    }

    fn space_len(&self, space: usize) -> usize {
        if space < self.n_inputs {
            self.prog.input_lens[space]
        } else if space == self.n_inputs {
            self.prog.out_size
        } else {
            self.prog.temp_sizes[space - self.n_inputs - 1]
        }
    }

    /// Describe a loop position for diagnostics ("depth 2 map(extent 4)").
    fn at(&self, depth: usize, kind: &str, extent: usize) -> String {
        format!("depth {depth} {kind}(extent {extent})")
    }

    /// Enter a loop's advances: compute each destination track's entry and
    /// read-time intervals. Mirrors `Ctx::enter` + per-iteration `step` in
    /// the interpreter: entry = src-at-entry + base, read time adds up to
    /// `(extent-1) * stride`.
    fn enter(&mut self, advances: &[Adv], extent: usize) {
        let step = extent.saturating_sub(1);
        for (i, a) in advances.iter().enumerate() {
            if a.dst >= self.entry.len() {
                self.violations.push(Violation::Malformed(format!(
                    "advance targets track {} but only {} tracks exist",
                    a.dst,
                    self.entry.len()
                )));
                continue;
            }
            if advances[..i].iter().any(|b| b.dst == a.dst) {
                self.violations.push(Violation::Malformed(format!(
                    "track {} advanced twice by one loop",
                    a.dst
                )));
                continue;
            }
            let parent = match a.src {
                None => Interval::point(0),
                Some(s) if s >= self.entry.len() => {
                    self.violations.push(Violation::Malformed(format!(
                        "advance for track {} bases on unknown track {s}",
                        a.dst
                    )));
                    Interval::point(0)
                }
                Some(s) => {
                    if advances[..i].iter().any(|b| b.dst == s) {
                        // Sibling entered by this same loop: at runtime the
                        // base is its entry value, before any stepping.
                        self.entry[s].unwrap_or(Interval::point(0))
                    } else {
                        // Enclosing-loop track, read at its current
                        // (stepped) value; never-entered tracks sit at 0.
                        self.read[s].unwrap_or(Interval::point(0))
                    }
                }
            };
            let entry = Interval {
                lo: parent.lo.saturating_add(a.base),
                hi: parent.hi.saturating_add(a.base),
            };
            self.entry[a.dst] = Some(entry);
            self.read[a.dst] = Some(entry.widen_hi(step.saturating_mul(a.stride)));
        }
    }

    fn record(&mut self, space: usize, kind: AccessKind, iv: Interval, count: u64) {
        self.spaces[space].record(kind, iv, count);
    }

    fn check_write(&mut self, dst: Dest, span: usize) {
        let max = dst.iv.hi.saturating_add(span.saturating_sub(1));
        let len = self.space_len(dst.space);
        if max >= len {
            self.violations.push(Violation::WriteBounds {
                space: self.space_name(dst.space),
                max_offset: max,
                len,
            });
        }
    }

    /// Validate a leaf kernel's bytecode against the interpreter's
    /// execution model: in-range operand/track indices, primitive arities
    /// the evaluator implements, stack discipline within the fixed buffer.
    fn check_kernel(&mut self, k: &Kernel) {
        for (i, &t) in k.tracks.iter().enumerate() {
            if t >= self.prog.n_tracks() {
                self.violations.push(Violation::Malformed(format!(
                    "kernel operand {i} reads unknown track {t}"
                )));
            }
        }
        let mut depth = 0usize;
        let mut max = 0usize;
        for op in &k.ops {
            match op {
                KernelOp::In(i) => {
                    if (*i as usize) >= k.tracks.len() {
                        self.violations.push(Violation::Malformed(format!(
                            "kernel In({i}) beyond its {} tracks",
                            k.tracks.len()
                        )));
                    }
                    depth += 1;
                }
                KernelOp::Const(_) => depth += 1,
                KernelOp::Prim(p) => {
                    let a = p.arity();
                    if !(1..=2).contains(&a) {
                        self.violations.push(Violation::Malformed(format!(
                            "kernel primitive '{}' has unsupported arity {a}",
                            p.name()
                        )));
                        return;
                    }
                    if depth < a {
                        self.violations.push(Violation::Malformed(format!(
                            "kernel stack underflow at '{}'",
                            p.name()
                        )));
                        return;
                    }
                    depth = depth + 1 - a;
                }
            }
            max = max.max(depth);
        }
        if depth != 1 {
            self.violations.push(Violation::Malformed(format!(
                "kernel leaves {depth} values on the stack (want 1)"
            )));
        }
        if max > MAX_KERNEL_STACK {
            self.violations.push(Violation::Malformed(format!(
                "kernel needs {max} stack slots, interpreter has {MAX_KERNEL_STACK}"
            )));
        }
    }

    /// Walk one node executing `mult` times with destination cursor
    /// anywhere in `dst.iv`; returns the span of elements the node writes
    /// per execution (its *actual* output size).
    fn walk(&mut self, node: &Node, mode: WriteMode, dst: Dest, mult: u64, depth: usize) -> usize {
        match node {
            Node::MapLoop {
                extent,
                advances,
                body_size,
                body,
            } => {
                let at = self.at(depth, "map", *extent);
                if *extent == 0 {
                    self.violations.push(Violation::Malformed(format!("{at} has extent 0")));
                    return 0;
                }
                self.enter(advances, *extent);
                // Per iteration the destination cursor advances by the
                // *declared* body_size (that is what the interpreter does),
                // so the body sees this widened base interval.
                let child = Dest {
                    space: dst.space,
                    iv: dst.iv.widen_hi((*extent - 1).saturating_mul(*body_size)),
                };
                let reps = mult.saturating_mul(*extent as u64);
                let span = self.walk(body, mode, child, reps, depth + 1);
                if span > *body_size {
                    self.violations.push(Violation::MapOverlap {
                        at,
                        body_span: span,
                        body_size: *body_size,
                    });
                } else if span < *body_size {
                    self.violations.push(Violation::MapGap {
                        at,
                        body_span: span,
                        body_size: *body_size,
                    });
                }
                extent.saturating_mul(*body_size)
            }
            Node::RedLoop {
                extent,
                advances,
                op,
                body_size,
                temp,
                body,
            } => {
                let at = self.at(depth, "red", *extent);
                if *extent == 0 {
                    self.violations.push(Violation::Malformed(format!("{at} has extent 0")));
                    return 0;
                }
                if !op.is_associative() {
                    self.violations.push(Violation::Malformed(format!(
                        "reduction operator '{}' at {at} is not associative",
                        op.name()
                    )));
                }
                match (temp, mode) {
                    (Some(t), WriteMode::Acc(_)) => {
                        // Private-region path: reduce into temp t with Set
                        // semantics, then fold the temp into dst with the
                        // enclosing operator, element by element.
                        if *t >= self.prog.temp_sizes.len() {
                            self.violations.push(Violation::Malformed(format!(
                                "reduction at {at} uses unknown temp {t}"
                            )));
                            return *body_size;
                        }
                        let temp_space = self.n_inputs + 1 + *t;
                        let temp_dst = Dest {
                            space: temp_space,
                            iv: Interval::point(0),
                        };
                        self.red_walk(
                            *extent,
                            advances,
                            *op,
                            body,
                            *body_size,
                            temp_dst,
                            WriteMode::Set,
                            mult,
                            depth,
                            &at,
                        );
                        let have = self.prog.temp_sizes[*t];
                        if have != *body_size {
                            self.violations.push(Violation::TempSizeMismatch {
                                temp: *t,
                                need: *body_size,
                                have,
                            });
                        }
                        if *body_size > 0 {
                            let n = mult.saturating_mul(*body_size as u64);
                            let temp_iv = Interval {
                                lo: 0,
                                hi: *body_size - 1,
                            };
                            let dst_iv = dst.iv.widen_hi(*body_size - 1);
                            self.record(temp_space, AccessKind::Read, temp_iv, n);
                            self.record(dst.space, AccessKind::Read, dst_iv, n);
                            self.record(dst.space, AccessKind::Write, dst_iv, n);
                            self.check_write(dst, *body_size);
                        }
                    }
                    _ => {
                        if let (None, WriteMode::Acc(outer)) = (temp, mode) {
                            // Accumulating straight into the enclosing
                            // region is only sound when both levels combine
                            // with the same commutative operator — exactly
                            // when lowering omits the temp.
                            if outer != *op || !op.is_commutative() {
                                self.violations.push(Violation::AccWithoutTemp {
                                    at: at.clone(),
                                    op: *op,
                                    outer,
                                });
                            }
                        }
                        self.red_walk(
                            *extent,
                            advances,
                            *op,
                            body,
                            *body_size,
                            dst,
                            mode,
                            mult,
                            depth,
                            &at,
                        );
                    }
                }
                *body_size
            }
            Node::Leaf(k) => {
                self.check_kernel(k);
                for &t in &k.tracks {
                    if t >= self.prog.n_tracks() {
                        continue; // reported by check_kernel
                    }
                    let iv = self.read[t].unwrap_or(Interval::point(0));
                    let slot = self.prog.track_slot[t];
                    self.record(slot, AccessKind::Read, iv, mult);
                    let len = self.prog.input_lens[slot];
                    if iv.hi >= len {
                        self.violations.push(Violation::ReadBounds {
                            space: self.space_name(slot),
                            track: t,
                            max_offset: iv.hi,
                            len,
                        });
                    }
                }
                if matches!(mode, WriteMode::Acc(_)) {
                    self.record(dst.space, AccessKind::Read, dst.iv, mult);
                }
                self.record(dst.space, AccessKind::Write, dst.iv, mult);
                self.check_write(dst, 1);
                self.leaf_evals = self.leaf_evals.saturating_add(mult);
                1
            }
        }
    }

    /// Shared reduction-loop model (mirrors the interpreter's `red_loop`
    /// and the tracer's `red_trace`): under `Set` the destination region is
    /// identity-filled over the body's *declared* size, then the body
    /// accumulates `extent` times.
    #[allow(clippy::too_many_arguments)]
    fn red_walk(
        &mut self,
        extent: usize,
        advances: &[Adv],
        op: Prim,
        body: &Node,
        declared: usize,
        dst: Dest,
        mode: WriteMode,
        mult: u64,
        depth: usize,
        at: &str,
    ) {
        self.enter(advances, extent);
        let fill = declared_size(body);
        if matches!(mode, WriteMode::Set) && fill > 0 {
            self.record(
                dst.space,
                AccessKind::Write,
                dst.iv.widen_hi(fill - 1),
                mult.saturating_mul(fill as u64),
            );
            self.check_write(dst, fill);
        }
        let span = self.walk(
            body,
            WriteMode::Acc(op),
            dst,
            mult.saturating_mul(extent as u64),
            depth + 1,
        );
        if span != declared {
            self.violations.push(Violation::RedSizeMismatch {
                at: at.to_string(),
                body_span: span,
                body_size: declared,
            });
        }
    }
}
