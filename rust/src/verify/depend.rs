//! Per-loop dependence analysis: the fourth property [`super::verify`]
//! proves, turning the pass/fail safety check into a typed parallelism
//! certificate ([`ParCert`]) the executor can consult.
//!
//! For every `MapLoop` in the nest — not just the root — the analysis
//! decides [`ParVerdict::Parallel`] vs [`ParVerdict::Serial`] by proving,
//! with the same interval machinery the bounds checker uses, that one
//! iteration's accesses stay inside the chunk the loop hands it:
//!
//! - **(a) write disjointness** — the iteration's writes to its
//!   destination space (output or an enclosing reduction temp) span at
//!   most `body_size` elements relative to the iteration's cursor. The
//!   cursor advances by exactly `body_size` per iteration, so relative
//!   containment in `[0, body_size)` makes absolute ranges disjoint
//!   across iterations.
//! - **(b) no cross-iteration read-after-write** — every read of the
//!   destination space (an `Acc`-mode leaf read, or a temp fold into the
//!   chunk) also lands inside `[0, body_size)` relative to the cursor.
//!   Combined with (a), iteration `i` can only read destination cells
//!   that iteration `i` itself writes — never a cell another iteration
//!   produces. Kernel *input* reads are irrelevant here: kernel tracks
//!   are backed exclusively by input slots, and inputs are never written.
//! - **(c) accumulator privacy** — enclosed `RedLoop` accumulators must
//!   be iteration-private. A reduction accumulating straight into the
//!   iteration's own destination chunk qualifies (covered by (a)/(b));
//!   one that declares a *temp* does not: the temp arena slot is shared
//!   by every iteration, so the loop is conservatively demoted to
//!   `Serial` naming the temp. (Per-thread temp privatization would make
//!   this safe — the executor already allocates private arenas — but the
//!   certificate stays conservative until the privacy argument is part
//!   of the proof; see the ROADMAP.)
//!
//! Every `Serial` verdict carries a [`SerialReason`] whose `Display`
//! names the offending space exactly like [`super::Violation`]
//! diagnostics. The certificate is only attached to a [`super::Footprint`]
//! that passed the other three properties, so `Parallel` verdicts inherit
//! their guarantees (in particular `MapOverlap`/`MapGap` have already
//! pinned the body span to `body_size`); the checks here re-derive the
//! containment facts from the node structure rather than assuming them.

use super::footprint::Interval;
use crate::exec::{Node, Program, WriteMode};

/// Parallel-safety certificate for one program: a verdict for every
/// `MapLoop` in the nest, in pre-order (so when the root is a `MapLoop`,
/// `loops[0]` with `depth == 0` is the loop the executor may chunk).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ParCert {
    pub loops: Vec<LoopCert>,
}

impl ParCert {
    /// Certificate for the root loop, if the program roots in a `MapLoop`.
    pub fn root(&self) -> Option<&LoopCert> {
        self.loops.first().filter(|l| l.depth == 0)
    }

    /// Number of map loops certified `Parallel`.
    pub fn parallel_loops(&self) -> usize {
        self.loops
            .iter()
            .filter(|l| matches!(l.verdict, ParVerdict::Parallel { .. }))
            .count()
    }

    /// Number of map loops demoted to `Serial`.
    pub fn serial_loops(&self) -> usize {
        self.loops.len() - self.parallel_loops()
    }
}

/// Dependence verdict for one `MapLoop`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LoopCert {
    /// Loop position in `Violation` grammar: "depth D map(extent E)".
    pub at: String,
    /// Nesting depth (0 = the program root).
    pub depth: usize,
    pub extent: usize,
    pub verdict: ParVerdict,
}

impl std::fmt::Display for LoopCert {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.verdict {
            ParVerdict::Parallel { chunks_disjoint } => {
                write!(f, "{}: parallel across {chunks_disjoint} disjoint chunks", self.at)
            }
            ParVerdict::Serial { reason } => write!(f, "{}: serial — {reason}", self.at),
        }
    }
}

/// Is one `MapLoop` safe to run with iterations split across threads?
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParVerdict {
    /// Iterations own disjoint destination ranges; the loop may be chunked
    /// into up to `chunks_disjoint` (= extent) independent pieces.
    Parallel { chunks_disjoint: usize },
    /// The analysis could not prove independence; the executor must run
    /// this loop serially. The reason names the offending space.
    Serial { reason: SerialReason },
}

/// Why a `MapLoop` was demoted to serial. `Display` names the offending
/// space (output, temp index) like [`super::Violation`] diagnostics do.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SerialReason {
    /// One iteration's writes to `space` span more elements than the loop
    /// advances the destination cursor by — adjacent iterations overlap.
    WriteOverlap {
        space: String,
        span: usize,
        body_size: usize,
    },
    /// One iteration reads `space` at relative offsets reaching
    /// `read_hi`, beyond its own `body_size`-element chunk — an earlier
    /// iteration's write could be observed.
    ReadEscapesIteration {
        space: String,
        read_hi: usize,
        body_size: usize,
    },
    /// The loop body stages a reduction through temp `temp`, a scratch
    /// arena slot shared across iterations.
    SharedTemp { temp: usize },
}

impl std::fmt::Display for SerialReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SerialReason::WriteOverlap {
                space,
                span,
                body_size,
            } => write!(
                f,
                "iteration writes to {space} span {span} elements but the loop advances by {body_size} — iterations would overlap"
            ),
            SerialReason::ReadEscapesIteration {
                space,
                read_hi,
                body_size,
            } => write!(
                f,
                "iteration reads {space} up to relative offset {read_hi}, outside its own {body_size}-element chunk — cross-iteration read-after-write"
            ),
            SerialReason::SharedTemp { temp } => write!(
                f,
                "loop body stages a reduction through temp {temp}, shared across iterations"
            ),
        }
    }
}

/// Human name of a destination space, matching the `Violation` grammar
/// (only the output and temps can be destinations — inputs are read-only).
fn space_name(n_inputs: usize, space: usize) -> String {
    if space == n_inputs {
        "output".into()
    } else {
        format!("temp {}", space - n_inputs - 1)
    }
}

/// Output size a node declares (identical to the bounds checker's notion;
/// after `MapOverlap`/`MapGap`/`RedSizeMismatch` pass, it equals the span
/// the body actually writes).
fn declared_size(n: &Node) -> usize {
    match n {
        Node::MapLoop {
            extent, body_size, ..
        } => extent.saturating_mul(*body_size),
        Node::RedLoop { body_size, .. } => *body_size,
        Node::Leaf(_) => 1,
    }
}

/// Relative footprint of one iteration of a candidate map: hulls of the
/// destination-space accesses, as offsets relative to the iteration's
/// destination-cursor entry. All destination accesses in this IR are
/// cursor-chained with the same per-iteration coefficient (`body_size`),
/// so relative intervals compare directly across iterations.
#[derive(Default)]
struct IterScan {
    write: Option<Interval>,
    read: Option<Interval>,
    /// First reduction temp declared anywhere in the body (active or not —
    /// a declared slot is shared across iterations either way).
    shared_temp: Option<usize>,
}

impl IterScan {
    fn record_write(&mut self, iv: Interval) {
        self.write = Some(self.write.map_or(iv, |old| old.hull(iv)));
    }

    fn record_read(&mut self, iv: Interval) {
        self.read = Some(self.read.map_or(iv, |old| old.hull(iv)));
    }

    /// Walk one body node. `rel` is the interval of destination-cursor
    /// offsets (relative to the candidate iteration's entry) the node can
    /// run at; `in_temp` means the current destination is a temp the
    /// candidate's own chunk does not own (accesses there are not
    /// destination-chain accesses of the candidate).
    fn scan(&mut self, node: &Node, mode: WriteMode, rel: Interval, in_temp: bool) {
        match node {
            Node::MapLoop {
                extent,
                body_size,
                body,
                ..
            } => {
                let child = rel.widen_hi(extent.saturating_sub(1).saturating_mul(*body_size));
                self.scan(body, mode, child, in_temp);
            }
            Node::RedLoop {
                op,
                body_size,
                temp,
                body,
                ..
            } => {
                if let Some(t) = temp {
                    self.shared_temp.get_or_insert(*t);
                }
                match (temp, mode) {
                    (Some(_), WriteMode::Acc(_)) => {
                        // Active temp path: fill/accumulate target the temp,
                        // then the fold reads the temp and read-modify-writes
                        // the destination chunk element by element.
                        if *body_size > 0 && !in_temp {
                            let iv = rel.widen_hi(*body_size - 1);
                            self.record_read(iv);
                            self.record_write(iv);
                        }
                        self.scan(body, WriteMode::Acc(*op), Interval::point(0), true);
                    }
                    _ => {
                        // Straight into the destination: identity fill under
                        // Set, then the body accumulates over the same region.
                        let fill = declared_size(body);
                        if matches!(mode, WriteMode::Set) && fill > 0 && !in_temp {
                            self.record_write(rel.widen_hi(fill - 1));
                        }
                        self.scan(body, WriteMode::Acc(*op), rel, in_temp);
                    }
                }
            }
            Node::Leaf(_) => {
                // Kernel operand reads only touch input slots (never
                // written); the destination access is the single element at
                // the cursor — read-modify-write under Acc.
                if !in_temp {
                    if matches!(mode, WriteMode::Acc(_)) {
                        self.record_read(rel);
                    }
                    self.record_write(rel);
                }
            }
        }
    }
}

struct Analyzer<'p> {
    prog: &'p Program,
    loops: Vec<LoopCert>,
}

impl<'p> Analyzer<'p> {
    /// Why one `MapLoop` writing `dst_space` under `mode` must stay
    /// serial, if the scan of a single iteration's relative footprint
    /// finds a reason (`None` = provably parallel).
    fn demote_reason(
        &self,
        body: &Node,
        body_size: usize,
        mode: WriteMode,
        dst_space: usize,
    ) -> Option<SerialReason> {
        let n_inputs = self.prog.input_names.len();
        let mut scan = IterScan::default();
        scan.scan(body, mode, Interval::point(0), false);
        if let Some(t) = scan.shared_temp {
            return Some(SerialReason::SharedTemp { temp: t });
        }
        if let Some(w) = scan.write {
            if w.hi >= body_size {
                return Some(SerialReason::WriteOverlap {
                    space: space_name(n_inputs, dst_space),
                    span: w.hi + 1,
                    body_size,
                });
            }
        }
        if let Some(r) = scan.read {
            if r.hi >= body_size {
                return Some(SerialReason::ReadEscapesIteration {
                    space: space_name(n_inputs, dst_space),
                    read_hi: r.hi,
                    body_size,
                });
            }
        }
        None
    }

    /// Pre-order walk mirroring the bounds checker's mode threading: map
    /// bodies inherit the mode, reduction bodies run under `Acc(op)`, and
    /// an *active* temp path switches the destination space to the temp.
    fn walk(&mut self, node: &Node, mode: WriteMode, dst_space: usize, depth: usize) {
        match node {
            Node::MapLoop {
                extent,
                body_size,
                body,
                ..
            } => {
                let verdict = match self.demote_reason(body, *body_size, mode, dst_space) {
                    Some(reason) => ParVerdict::Serial { reason },
                    None => ParVerdict::Parallel {
                        chunks_disjoint: *extent,
                    },
                };
                self.loops.push(LoopCert {
                    at: format!("depth {depth} map(extent {extent})"),
                    depth,
                    extent: *extent,
                    verdict,
                });
                self.walk(body, mode, dst_space, depth + 1);
            }
            Node::RedLoop { op, temp, body, .. } => match (temp, mode) {
                (Some(t), WriteMode::Acc(_)) => {
                    let n_inputs = self.prog.input_names.len();
                    self.walk(body, WriteMode::Acc(*op), n_inputs + 1 + *t, depth + 1);
                }
                _ => self.walk(body, WriteMode::Acc(*op), dst_space, depth + 1),
            },
            Node::Leaf(_) => {}
        }
    }
}

/// Run the dependence analysis over a program that already passed the
/// bounds/initialization/disjointness checks, producing its [`ParCert`].
pub(super) fn certify(prog: &Program) -> ParCert {
    let mut a = Analyzer {
        prog,
        loops: Vec::new(),
    };
    let root_space = prog.input_names.len(); // the output space
    a.walk(&prog.root, WriteMode::Set, root_space, 0);
    ParCert { loops: a.loops }
}
