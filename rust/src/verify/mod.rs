//! Static access-footprint verification for lowered programs.
//!
//! The paper's premise is that HoF programs have *statically analyzable*
//! access structure: every loop in the [`crate::exec::Program`] IR advances
//! its tracks by affine `base + i·stride` steps ([`crate::exec::Adv`]), so
//! the exact memory footprint of a program is computable without running
//! it. This module computes it — by abstract interpretation of the `Adv`
//! chains, propagating per-track offset intervals through the
//! `MapLoop`/`RedLoop` nesting — and proves four properties:
//!
//! 1. **Bounds** — every read offset reachable through any track stays
//!    below its slot's `input_lens` entry, and every write stays inside
//!    `out_size` / `temp_sizes`. This turns the `SAFETY` preconditions of
//!    the interpreter's `get_unchecked` fast paths into a machine-checked
//!    theorem: [`crate::exec::execute`] refuses to run a program that does
//!    not verify.
//! 2. **Initialization** — under [`crate::exec::WriteMode::Acc`] no output
//!    or temp element is combined before it is first set: reduction fills
//!    cover exactly the accumulated region (`RedSizeMismatch` /
//!    `TempSizeMismatch` otherwise), map iterations leave no gaps
//!    (`MapGap`), and a templess reduction may only accumulate under the
//!    same commutative operator (`AccWithoutTemp`).
//! 3. **Write-disjointness** — distinct iterations of each `MapLoop` write
//!    disjoint destination ranges (`MapOverlap` otherwise): the body's
//!    actual span must equal the loop's declared `body_size`, the amount
//!    the destination cursor advances per iteration. This is the invariant
//!    that licenses parallel execution of map loops.
//! 4. **Loop dependence** — a per-loop dependence analysis ([`depend`])
//!    turns property 3 into a consumable certificate: every `MapLoop` in
//!    the nest gets a typed [`ParVerdict`] — `Parallel { chunks_disjoint }`
//!    when one iteration's destination writes *and reads* provably stay
//!    inside its own `body_size` chunk and every enclosed reduction
//!    accumulator is iteration-private, `Serial { reason }` otherwise,
//!    with the reason naming the offending space like a [`Violation`]
//!    does. The certificate rides on the [`Footprint`]
//!    ([`Footprint::par`]); [`crate::exec::execute_threaded`] consults it
//!    and fails closed to serial execution on any `Serial` verdict.
//!
//! The analysis is exact for this IR (see [`absint`]'s module docs): the
//! reported [`Footprint`] intervals are attained, and its per-space access
//! *counts* replicate [`crate::exec::trace`] exactly. Two differential
//! suites pin that claim: `tests/verify_props.rs` checks every traced
//! access of every search-family variant lies inside the static footprint
//! (and that the counts match), and seeded mutation tests corrupt
//! strides/extents/temp sizes and assert rejection.
//!
//! Where it runs: [`crate::exec::lower`] / [`lower_id`](crate::exec::lower_id)
//! verify their output in debug/test builds; [`crate::exec::execute`]
//! verifies unconditionally (release builds fail closed instead of trusting
//! `debug_assert!`s); the coordinator's `verify` knob
//! ([`crate::coordinator::OptimizeSpec::verify`]) re-verifies the winning
//! candidate per job and surfaces counts through
//! [`crate::coordinator::Metrics`].

mod absint;
mod depend;
mod footprint;

pub use absint::{Violation, MAX_KERNEL_STACK};
pub use depend::{LoopCert, ParCert, ParVerdict, SerialReason};
pub use footprint::{Footprint, Interval, SpaceUse};

use crate::exec::Program;
use crate::{Error, Result};

/// Statically verify a lowered program, returning its certified
/// [`Footprint`] on success and every [`Violation`] found (joined into one
/// [`Error::Verify`] diagnostic) on failure.
pub fn verify(prog: &Program) -> Result<Footprint> {
    check(prog).map_err(|vs| {
        let msg = vs
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("; ");
        Error::Verify(msg)
    })
}

/// Structured-diagnostic variant of [`verify`]: the raw violation list.
pub fn check(prog: &Program) -> std::result::Result<Footprint, Vec<Violation>> {
    absint::check(prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{self, *};
    use crate::exec::{count_accesses, lower, Adv, Kernel, KernelOp, Node, Program};
    use crate::layout::Layout;
    use crate::typecheck::Env;

    fn matmul_env(n: usize) -> Env {
        Env::new()
            .with("A", Layout::row_major(&[n, n]))
            .with("B", Layout::row_major(&[n, n]))
    }

    fn matmul_prog(n: usize) -> Program {
        lower(&matmul_naive(input("A"), input("B")), &matmul_env(n)).unwrap()
    }

    #[test]
    fn matmul_footprint_is_exact() {
        let n = 4;
        let prog = matmul_prog(n);
        let fp = verify(&prog).unwrap();
        // Every input element is reachable, none beyond.
        assert_eq!(fp.input_required(0), n * n);
        assert_eq!(fp.input_required(1), n * n);
        // Output is written across its full extent.
        let out = fp.output();
        let last = n * n - 1;
        assert_eq!(out.write.unwrap(), Interval { lo: 0, hi: last });
        // One kernel evaluation per scalar multiply.
        assert_eq!(fp.leaf_evals, (n * n * n) as u64);
        // The static counts replicate the dynamic trace exactly.
        let (reads, writes) = count_accesses(&prog).unwrap();
        assert_eq!(fp.reads(), reads as u64);
        assert_eq!(fp.writes(), writes as u64);
    }

    #[test]
    fn temp_reduction_verifies_with_temp_footprint() {
        // max over rows of row-sums: inner add-reduction under max needs a
        // private temp region; its fill/fold traffic must be in the
        // footprint.
        let env = Env::new().with("A", Layout::row_major(&[3, 4]));
        let e = rnz(pmax(), lam1("r", reduce(add(), var("r"))), vec![input("A")]);
        let prog = lower(&e, &env).unwrap();
        assert_eq!(prog.temp_sizes, vec![1]);
        let fp = verify(&prog).unwrap();
        let temp = &fp.spaces[fp.n_inputs + 1];
        assert!(temp.reads > 0 && temp.writes > 0, "temp traffic missing");
        let (reads, writes) = count_accesses(&prog).unwrap();
        assert_eq!(fp.reads(), reads as u64);
        assert_eq!(fp.writes(), writes as u64);
    }

    #[test]
    fn matmul_cert_marks_every_map_parallel() {
        let n = 4;
        let prog = matmul_prog(n);
        let fp = verify(&prog).unwrap();
        assert!(!fp.par.loops.is_empty(), "matmul has map loops");
        assert_eq!(fp.par.serial_loops(), 0, "{:?}", fp.par);
        let root = fp.par.root().expect("matmul roots in a map");
        assert_eq!(root.depth, 0);
        assert_eq!(root.extent, n);
        assert_eq!(root.verdict, ParVerdict::Parallel { chunks_disjoint: n });
    }

    #[test]
    fn map_over_shared_temp_is_demoted_with_named_reason() {
        // Per row: max over 2-chunks of chunk-sums. The inner add-reduction
        // under max stages through a temp, whose arena slot the enclosing
        // map shares across iterations — demoted, naming the temp.
        let env = Env::new().with("A", Layout::row_major(&[3, 4]));
        let e = map(
            lam1(
                "r",
                rnz(
                    pmax(),
                    lam1("c", reduce(add(), var("c"))),
                    vec![subdiv(0, 2, var("r"))],
                ),
            ),
            input("A"),
        );
        let prog = lower(&e, &env).unwrap();
        assert_eq!(prog.temp_sizes.len(), 1);
        let fp = verify(&prog).unwrap();
        let root = fp.par.root().expect("roots in a map");
        let ParVerdict::Serial { reason } = &root.verdict else {
            panic!("expected a Serial verdict, got {:?}", root.verdict);
        };
        let msg = reason.to_string();
        assert!(msg.contains("temp 0"), "reason must name the temp: {msg}");
        assert_eq!(fp.par.serial_loops(), 1);
        // The certificate surfaces through Display like Violations do.
        assert!(root.to_string().contains("serial"), "{root}");
    }

    #[test]
    fn corrupt_stride_is_rejected_naming_input_and_track() {
        let mut prog = matmul_prog(4);
        fn first_strided_adv(node: &mut Node) -> Option<&mut Adv> {
            match node {
                Node::MapLoop { advances, body, .. }
                | Node::RedLoop { advances, body, .. } => {
                    if advances.iter().any(|a| a.stride > 0) {
                        advances.iter_mut().find(|a| a.stride > 0)
                    } else {
                        first_strided_adv(body)
                    }
                }
                Node::Leaf(_) => None,
            }
        }
        let a = first_strided_adv(&mut prog.root).expect("matmul has strided advances");
        a.stride *= 100;
        let err = verify(&prog).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("read out of bounds") && msg.contains("input '") && msg.contains("track"),
            "diagnostic must name the space and track: {msg}"
        );
    }

    #[test]
    fn corrupt_extent_is_rejected_naming_output() {
        let mut prog = matmul_prog(4);
        let Node::MapLoop { extent, .. } = &mut prog.root else {
            panic!("matmul roots in a map");
        };
        *extent += 1;
        let err = verify(&prog).unwrap_err().to_string();
        assert!(
            err.contains("output"),
            "diagnostic must name the output space: {err}"
        );
    }

    #[test]
    fn corrupt_temp_size_is_rejected_naming_temp() {
        let env = Env::new().with("A", Layout::row_major(&[3, 4]));
        let e = rnz(pmax(), lam1("r", reduce(add(), var("r"))), vec![input("A")]);
        let mut prog = lower(&e, &env).unwrap();
        prog.temp_sizes[0] += 1;
        let err = verify(&prog).unwrap_err().to_string();
        assert!(err.contains("temp 0"), "diagnostic must name the temp: {err}");
    }

    #[test]
    fn shrunk_out_size_is_rejected() {
        let mut prog = matmul_prog(4);
        prog.out_size -= 1;
        let err = verify(&prog).unwrap_err().to_string();
        assert!(err.contains("output") || err.contains("out_size"), "{err}");
    }

    #[test]
    fn templess_mixed_op_reduction_is_rejected() {
        // red(+) over red(max) without a temp: the inner reduction would
        // accumulate max-partials into add-initialized elements.
        let copy = Kernel {
            ops: vec![KernelOp::In(0)],
            tracks: vec![1],
        };
        let prog = Program {
            root: Node::RedLoop {
                extent: 2,
                advances: vec![Adv {
                    dst: 0,
                    src: None,
                    base: 0,
                    stride: 2,
                }],
                op: dsl::Prim::Add,
                body_size: 1,
                temp: None,
                body: Box::new(Node::RedLoop {
                    extent: 2,
                    advances: vec![Adv {
                        dst: 1,
                        src: Some(0),
                        base: 0,
                        stride: 1,
                    }],
                    op: dsl::Prim::Max,
                    body_size: 1,
                    temp: None,
                    body: Box::new(Node::Leaf(copy)),
                }),
            },
            input_names: vec!["u".into()],
            track_slot: vec![0, 0],
            input_lens: vec![4],
            out_size: 1,
            temp_sizes: vec![],
        };
        let err = verify(&prog).unwrap_err().to_string();
        assert!(err.contains("without a temp"), "{err}");
    }

    #[test]
    fn kernel_exceeding_interpreter_stack_is_rejected() {
        // 17 pushes before the first pop: one more slot than the
        // interpreter's fixed evaluation stack.
        let mut ops = vec![KernelOp::Const(1.0); 17];
        ops.extend(vec![KernelOp::Prim(dsl::Prim::Add); 16]);
        let prog = Program {
            root: Node::Leaf(Kernel {
                ops,
                tracks: vec![],
            }),
            input_names: vec![],
            track_slot: vec![],
            input_lens: vec![],
            out_size: 1,
            temp_sizes: vec![],
        };
        let err = verify(&prog).unwrap_err().to_string();
        assert!(err.contains("stack slots"), "{err}");
    }

    #[test]
    fn structured_check_reports_every_violation() {
        let mut prog = matmul_prog(4);
        prog.out_size -= 1; // root-size mismatch AND write bounds
        let vs = check(&prog).unwrap_err();
        assert!(vs.len() >= 2, "one pass should surface all defects: {vs:?}");
    }
}
