//! Analytical cost model — the paper's "early cut rule" substrate
//! (Conclusions / Future work: "an early cut rule is also necessary to
//! prune rearrangements which are not feasible").
//!
//! Without executing or even tracing a variant, we estimate from the loop
//! nest alone:
//!
//! - **stride badness** — for each leaf input, the stride of the innermost
//!   loop that advances it, penalising non-unit innermost strides (the
//!   paper's "consecutive reads are the best for the memory controller");
//! - **accumulator footprint** — the paper notes raising reductions
//!   outwards grows the temporaries ("1a uses only scalar accumulators,
//!   while 1b and 1c require full columns");
//! - **parallelism width** — the extent product of the map levels above
//!   the first reduction (§2.1's thread-spawn considerations).
//!
//! The estimate ranks variants for pruning; exact ranking comes from the
//! cache simulator or real execution.
//!
//! # Arena-native entry points
//!
//! The enumeration search scores every candidate it generates, so the
//! scoring path must never rebuild a `Box<Expr>` tree. [`estimate_id`]
//! lowers and estimates an interned expression straight from its
//! [`SharedArena`], and [`spine_lower_bound_id`] computes a *provable
//! lower bound* on the true score from the HoF spine alone — without
//! lowering — which is what the search's branch-and-bound compares
//! against the best-known score before paying for a full lower +
//! estimate. Both read the concurrent arena through `&self`, so every
//! search shard scores against the same store.

use crate::dsl::intern::{ExprId, Node as ENode, SharedArena};
use crate::exec::{lower_id, Node, Program};
use crate::layout::Layout;
use crate::rewrite::Ctx;
use crate::typecheck::{infer_id_scratch, Env};
use crate::Result;
use std::collections::HashMap;

/// Monotone version stamp of the analytical model. The coordinator mixes
/// this into its optimize-result cache generation, so bumping it whenever
/// [`estimate`]'s scoring changes invalidates every cached ranking
/// computed under the old model (ROADMAP: "needs a version stamp once the
/// cost model learns online").
///
/// Branch-and-bound pruning in [`crate::enumerate`] compares
/// [`spine_lower_bound_id`] against the best-known true score. The bound
/// charges the per-iteration destination write plus per-track input
/// traffic at the layout-implied strides ([`line_cost`]) — the same
/// constants [`estimate`]'s walk charges — so it stays a true lower bound
/// as long as the bound's charges mirror a subset of the walk's; keep
/// that invariant (or re-derive the bound) when changing these constants,
/// and bump this stamp whenever the scoring itself changes.
///
/// Version 2: the lower bound gained rearrangement-sensitive per-track
/// input-traffic terms (it previously charged only the destination
/// write), so rankings cached under version 1 could have been produced by
/// a search whose cut decisions no longer reproduce.
///
/// Version 3: the search went best-first/anytime on top of the bound
/// ([`spine_reachable_floor_id`] is the new gap denominator) and gained a
/// merge-time cut recheck, so the *kept set* and discovery order of a
/// pruned search — and therefore `variants_explored`/tie-breaking in
/// cached rankings — no longer reproduce what version 2 stored.
pub const COST_MODEL_VERSION: u64 = 3;

/// Cache-line cost charged per access at unit stride: one f64 out of an
/// 8-element (64-byte) line. Also the per-iteration destination-write
/// charge — fresh results are stored densely — which is what makes it the
/// substrate of [`spine_lower_bound_id`].
pub const UNIT_STRIDE_COST: f64 = 0.125;

/// Per-access cost of a register-resident input track (stride 0, or a
/// track advanced only by loops outside the innermost one).
pub const REG_REUSE_COST: f64 = 0.01;

/// Cache-line cost of one access to a track whose innermost advancing
/// loop has the given stride — the stride rule shared by [`estimate`]'s
/// walk and [`spine_lower_bound_id`] (which must charge *identical*
/// per-access constants to stay a bound). [`REG_REUSE_COST`] is the
/// floor: no stride costs less, which is what makes it the sound charge
/// for a track whose innermost stride is unknown.
#[inline]
pub fn line_cost(stride: usize) -> f64 {
    match stride {
        0 => REG_REUSE_COST,
        1 => UNIT_STRIDE_COST,
        s if s < 8 => s as f64 * UNIT_STRIDE_COST,
        _ => 1.0,
    }
}

/// Static cost estimate for one lowered variant.
#[derive(Clone, Debug, PartialEq)]
pub struct CostEstimate {
    /// Estimated memory traffic in cache-line units (lower is better).
    pub traffic: f64,
    /// Peak accumulator (reduction destination) footprint in elements.
    pub acc_footprint: usize,
    /// Product of map extents above the first reduction — available outer
    /// parallelism.
    pub outer_parallelism: usize,
    /// Total leaf evaluations (invariant across rearrangements of the same
    /// computation; sanity metric).
    pub flops: u64,
}

impl CostEstimate {
    /// Scalar ranking score (lower = better): traffic dominates; large
    /// accumulators are penalised lightly.
    pub fn score(&self) -> f64 {
        self.traffic + 0.1 * self.acc_footprint as f64
    }
}

/// Estimate the cost of a lowered program.
pub fn estimate(prog: &Program) -> CostEstimate {
    let mut est = CostEstimate {
        traffic: 0.0,
        acc_footprint: 0,
        outer_parallelism: 1,
        flops: 0,
    };
    walk(&prog.root, 1.0, &mut est, &mut Vec::new(), true);
    est
}

/// Estimate the cost of an interned expression straight from the arena:
/// [`crate::exec::lower_id`] + [`estimate`], with no `Box<Expr>` tree ever
/// materialized. This is the search's per-candidate scoring path; it
/// produces exactly `estimate(&lower(&arena.extract(id), env)?)` (pinned
/// by `tests/lower_id_props.rs`).
pub fn estimate_id(arena: &SharedArena, id: ExprId, env: &Env) -> Result<CostEstimate> {
    Ok(estimate(&lower_id(arena, id, env)?))
}

/// A provable lower bound on [`CostEstimate::score`] for the expression
/// behind `id`, computed from the HoF spine alone — no lowering, no
/// `Box<Expr>`, no per-leaf walk.
///
/// The descent multiplies the consumed (outermost) extents down the spine
/// — every spine level becomes a loop of exactly that extent — and, when
/// the spine bottoms out in a shape it can fully resolve, charges the
/// leaf *exactly* as [`estimate`]'s walk would:
///
/// - the destination write ([`UNIT_STRIDE_COST`]) per innermost
///   iteration, and
/// - per input track, the [`line_cost`] of the stride of the loop that
///   bound its scalar element — a quantity the descent reads off each
///   argument's layout at its binding level, with no lowering. This is
///   what makes the bound *rearrangement-sensitive*: permuting the spine
///   moves which level consumes a track last, so dominated rearrangements
///   (e.g. ones forced to stream a matrix at a large stride) now bound
///   strictly above the family's best score and the search's
///   branch-and-bound cut fires at [`crate::enumerate::DEFAULT_PRUNE_SLACK`].
///
/// Fully-resolved shapes are the search families' normal forms: every
/// operator a lambda (or a bare primitive zipper), every argument a view,
/// and the innermost body a scalar kernel or a view. For those the bound
/// equals the true `traffic` term — charges are accumulated in the exact
/// order the walk uses, so not even floating-point rounding can push the
/// bound above the score — and the true score only adds the non-negative
/// accumulator penalty. Anywhere the shape is *not* resolved (a redex
/// mid-rewrite, an unresolvable layout, a `lift`ed operator, a non-scalar
/// kernel), the descent stops and conservatively charges only the
/// destination writes of the levels seen so far, which every lowering of
/// the candidate must still pay. Either way
/// `spine_lower_bound_id(..) ≤ estimate_id(..).score()` whenever the
/// expression lowers at all (pinned by property tests in
/// `tests/lower_id_props.rs`; unlowerable candidates score `+∞`, which
/// bounds trivially).
///
/// *Partial spine*: because unresolved structure degrades the bound
/// instead of failing it, the function can be called on candidates in any
/// intermediate rewrite state — even raw exchange output, before
/// normalization, where `tests/lower_id_props.rs` pins the
/// cross-expression fact `bound(raw) ≤ score(normalize(raw))`. (The
/// search engine itself consults it on normalized candidates, where the
/// read can be memoized.)
pub fn spine_lower_bound_id(arena: &SharedArena, id: ExprId, ctx: &Ctx) -> f64 {
    spine_bound(arena, id, ctx, false)
}

/// A lower bound on [`CostEstimate::score`] that is *invariant under
/// rearrangement*: the same value (bit-identically — every charge is
/// accumulated in the same spine-descent order over the same extents) for
/// every member of the expression's exchange family, and `≤` every
/// member's true score. This is the sound denominator for the anytime
/// search's **certified optimality gap**.
///
/// [`spine_lower_bound_id`] cannot play that role: it is deliberately
/// rearrangement-*sensitive* (that is what makes the branch-and-bound cut
/// fire), so it bounds only the candidate it was computed on — and the
/// swap graph is connected and undirected, meaning *any* family member is
/// reachable from any open frontier node. A gap certified against the
/// sensitive bound could be beaten by an unexplored descendant.
///
/// The floor runs the identical spine descent but charges each input
/// track at [`REG_REUSE_COST`] — the global minimum of [`line_cost`]
/// over every stride — instead of the layout-implied stride cost, while
/// keeping the per-iteration destination-write charge
/// ([`UNIT_STRIDE_COST`]). Soundness across the family follows from two
/// facts:
///
/// - the exchange/subdivision rules permute spine levels without changing
///   the multiset of extents, so the innermost iteration count (and every
///   partial product the fallbacks charge) is family-invariant, and every
///   lowering pays one destination write per innermost iteration;
/// - every input-track read costs at least `REG_REUSE_COST` per access
///   regardless of which loop ends up binding it.
///
/// Hence `floor(n) ≤ spine_lower_bound_id(n) ≤ score(n)` for the node
/// itself, and `floor(n) = floor(m) ≤ score(m)` for every rearrangement
/// `m` — both pinned by the unit tests below and property-tested over
/// randomized families in `tests/anytime_props.rs`.
pub fn spine_reachable_floor_id(arena: &SharedArena, id: ExprId, ctx: &Ctx) -> f64 {
    spine_bound(arena, id, ctx, true)
}

/// Shared spine descent behind [`spine_lower_bound_id`] (`floor == false`:
/// layout-implied [`line_cost`] per track, rearrangement-sensitive) and
/// [`spine_reachable_floor_id`] (`floor == true`: [`REG_REUSE_COST`] per
/// track, rearrangement-invariant).
fn spine_bound(arena: &SharedArena, id: ExprId, ctx: &Ctx, floor: bool) -> f64 {
    // In floor mode every track charge collapses to the global per-access
    // minimum; otherwise charge the stride of the binding loop.
    let track_cost = |s: usize| if floor { REG_REUSE_COST } else { line_cost(s) };
    // The descent follows a single spine path, so one mutable binding map
    // (shadowing as it goes, never needing restoration) replaces a full
    // `Ctx` clone per level — this runs once per generated candidate on
    // the prune hot path. `var_cost` shadows in step with `vars`: the
    // per-access line cost of the loop that bound each variable (vars
    // inherited from `ctx` have no known binding loop and are floored at
    // REG_REUSE_COST, which every stride's line cost dominates).
    let mut vars = ctx.vars.clone();
    let mut var_cost: HashMap<String, f64> = ctx
        .vars
        .keys()
        .map(|k| (k.clone(), REG_REUSE_COST))
        .collect();
    let mut iters = 1.0f64;
    let mut cur = id;
    loop {
        // `get` hands out stable references into the arena's append-only
        // storage, so the borrows live across the level's work without
        // cloning the child-id list on this per-candidate hot path.
        let (fid, args) = match arena.get(cur) {
            ENode::Nzip { f, args } => (*f, args),
            ENode::Rnz { m, args, .. } => (*m, args),
            // Spine exhausted: charge the innermost body exactly where
            // its shape is fully known, destination-only otherwise.
            _ => return body_bound(arena, cur, &ctx.env, &mut vars, &var_cost, iters, floor),
        };
        let mut extent = None;
        let mut elems = Vec::with_capacity(args.len());
        let mut strides = Vec::with_capacity(args.len());
        for &a in args {
            let Ok(layout) = infer_id_scratch(arena, a, &ctx.env, &mut vars) else {
                return iters * UNIT_STRIDE_COST;
            };
            let Some(outer) = layout.outer() else {
                return iters * UNIT_STRIDE_COST;
            };
            if extent.is_none() {
                extent = Some(outer.extent as f64);
            }
            let Ok(elem) = layout.peel_outer() else {
                return iters * UNIT_STRIDE_COST;
            };
            strides.push(outer.stride);
            elems.push(elem);
        }
        let Some(extent) = extent else {
            return iters * UNIT_STRIDE_COST;
        };
        match arena.get(fid) {
            ENode::Lam { params, body } if params.len() == args.len() => {
                iters *= extent;
                for ((p, elem), &s) in params.iter().zip(elems).zip(&strides) {
                    vars.insert(p.clone(), elem);
                    var_cost.insert(p.clone(), track_cost(s));
                }
                cur = *body;
            }
            ENode::Prim(_) => {
                // `rnz r (*) u v`-style bare-primitive zipper: if this
                // lowers at all it lowers to exactly this loop nest with
                // one leaf reading each argument track at this level's
                // stride — replicate the walk's accumulation verbatim.
                iters *= extent;
                let mut traffic = 0.0;
                for &s in &strides {
                    traffic += iters * track_cost(s);
                }
                traffic += iters * UNIT_STRIDE_COST;
                return traffic;
            }
            // Unresolved operator (redex mid-rewrite, `lift`, arity
            // mismatch): this level still becomes at least one loop of
            // this extent around at least one destination write.
            _ => {
                iters *= extent;
                return iters * UNIT_STRIDE_COST;
            }
        }
    }
}

/// Charge the innermost body of a spine — the part below the last HoF
/// level — exactly as lowering + [`estimate`]'s walk would, or fall back
/// to the destination-only charge when its shape is not fully resolved.
/// `iters` is the enclosing-loop iteration product; `var_cost` maps each
/// bound variable to the [`line_cost`] of its binding loop ([`REG_REUSE_COST`]
/// throughout in `floor` mode — see [`spine_reachable_floor_id`]).
fn body_bound(
    arena: &SharedArena,
    id: ExprId,
    env: &Env,
    vars: &mut HashMap<String, Layout>,
    var_cost: &HashMap<String, f64>,
    iters: f64,
    floor: bool,
) -> f64 {
    match arena.get(id) {
        // A view body lowers to a copy nest (or a bare scalar read): one
        // loop per remaining dimension, one leaf reading the innermost
        // track at the innermost dimension's stride.
        ENode::Var(_)
        | ENode::Input(_)
        | ENode::Subdiv { .. }
        | ENode::Flatten { .. }
        | ENode::Flip { .. } => {
            let Ok(layout) = infer_id_scratch(arena, id, env, vars) else {
                return iters * UNIT_STRIDE_COST;
            };
            if layout.is_scalar() {
                let per = match arena.get(id) {
                    ENode::Var(x) => var_cost.get(x).copied().unwrap_or(REG_REUSE_COST),
                    // A constant-offset scalar view lowers to a stride-0
                    // advance: register reuse exactly.
                    _ => REG_REUSE_COST,
                };
                return iters * per + iters * UNIT_STRIDE_COST;
            }
            let mut it = iters;
            for d in layout.dims.iter().rev() {
                it *= d.extent as f64;
            }
            let per = if floor {
                REG_REUSE_COST
            } else {
                line_cost(layout.dims[0].stride)
            };
            it * per + it * UNIT_STRIDE_COST
        }
        // Anything else is a scalar kernel if it lowers at all: replicate
        // the kernel compiler's traversal, charging each variable read at
        // its binding loop's stride, in occurrence order.
        _ => {
            let mut traffic = 0.0;
            if kernel_charges(arena, id, vars, var_cost, iters, &mut traffic) {
                traffic += iters * UNIT_STRIDE_COST;
                traffic
            } else {
                iters * UNIT_STRIDE_COST
            }
        }
    }
}

/// Accumulate the per-read input charges of a scalar kernel in the exact
/// order `exec`'s kernel compiler emits its track reads. Returns `false`
/// — caller falls back to the destination-only charge — on any shape the
/// kernel compiler would reject (so the failure is either unreachable or
/// scores `+∞`, and the fallback is sound either way).
fn kernel_charges(
    arena: &SharedArena,
    id: ExprId,
    vars: &HashMap<String, Layout>,
    var_cost: &HashMap<String, f64>,
    iters: f64,
    traffic: &mut f64,
) -> bool {
    match arena.get(id) {
        ENode::Lit(_) => true,
        ENode::Var(x) => match vars.get(x) {
            Some(l) if l.is_scalar() => {
                *traffic += iters * var_cost.get(x).copied().unwrap_or(REG_REUSE_COST);
                true
            }
            _ => false,
        },
        ENode::App { f, args } => match arena.get(*f) {
            ENode::Prim(p) if args.len() == p.arity() => args
                .iter()
                .all(|&a| kernel_charges(arena, a, vars, var_cost, iters, traffic)),
            _ => false,
        },
        _ => false,
    }
}

/// `iters`: product of enclosing loop extents. `stack`: per-level advance
/// lists, innermost last, to find which loop moves each track.
fn walk(
    node: &Node,
    iters: f64,
    est: &mut CostEstimate,
    stack: &mut Vec<Vec<(usize, usize)>>,
    above_reduction: bool,
) {
    match node {
        Node::MapLoop {
            extent,
            advances,
            body,
            ..
        } => {
            if above_reduction {
                est.outer_parallelism *= extent;
            }
            stack.push(advances.iter().map(|a| (a.dst, a.stride)).collect());
            walk(body, iters * *extent as f64, est, stack, above_reduction);
            stack.pop();
        }
        Node::RedLoop {
            extent,
            advances,
            body_size,
            body,
            ..
        } => {
            est.acc_footprint = est.acc_footprint.max(*body_size);
            stack.push(advances.iter().map(|a| (a.dst, a.stride)).collect());
            walk(body, iters * *extent as f64, est, stack, false);
            stack.pop();
        }
        Node::Leaf(k) => {
            est.flops += iters as u64;
            // Per input track: the innermost loop that advances it decides
            // the per-access line cost. stride 0 → register reuse; stride 1
            // → 1/8 line per access; large stride → a fresh line each time.
            for &t in &k.tracks {
                let mut stride: Option<usize> = None;
                for level in stack.iter().rev() {
                    if let Some(&(_, s)) = level.iter().find(|&&(tt, _)| tt == t) {
                        stride = Some(s);
                        break;
                    }
                }
                let per_access = match stride {
                    None => REG_REUSE_COST,
                    Some(s) => line_cost(s),
                };
                est.traffic += iters * per_access;
            }
            est.traffic += iters * UNIT_STRIDE_COST; // destination
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::enumerate::{enumerate_all, starts};
    use crate::exec::lower;
    use crate::layout::Layout;
    use crate::rewrite::Ctx;
    use crate::typecheck::Env;

    fn variants(n: usize) -> Vec<(String, CostEstimate)> {
        let env = Env::new()
            .with("A", Layout::row_major(&[n, n]))
            .with("B", Layout::row_major(&[n, n]));
        let ctx = Ctx::new(env.clone());
        enumerate_all(&starts::matmul_naive_variant(), &ctx, 10)
            .unwrap()
            .iter()
            .map(|v| {
                let prog = lower(&v.expr, &env).unwrap();
                (v.display_key(), estimate(&prog))
            })
            .collect()
    }

    #[test]
    fn flops_invariant_across_rearrangements() {
        let vs = variants(16);
        let f0 = vs[0].1.flops;
        for (k, e) in &vs {
            assert_eq!(e.flops, f0, "{k}");
        }
    }

    #[test]
    fn best_known_variant_scores_best() {
        // Table 1: mapA rnz mapB wins; mapB rnz mapA loses.
        let vs: std::collections::HashMap<_, _> = variants(64).into_iter().collect();
        let best = vs["mapA rnz mapB"].score();
        let worst = vs["mapB rnz mapA"].score();
        let naive = vs["mapA mapB rnz"].score();
        assert!(best < naive, "best {best} naive {naive}");
        assert!(naive < worst, "naive {naive} worst {worst}");
    }

    #[test]
    fn flipped_variants_use_bigger_accumulators() {
        // paper: "1a uses only scalar accumulators, while 1b and 1c require
        // full columns"
        let vs: std::collections::HashMap<_, _> = variants(32).into_iter().collect();
        assert_eq!(vs["mapA mapB rnz"].acc_footprint, 1);
        assert!(vs["rnz mapA mapB"].acc_footprint > 1);
    }

    #[test]
    fn outer_parallelism_counts_maps_above_reduction() {
        let vs: std::collections::HashMap<_, _> = variants(32).into_iter().collect();
        assert_eq!(vs["mapA mapB rnz"].outer_parallelism, 32 * 32);
        assert_eq!(vs["rnz mapA mapB"].outer_parallelism, 1);
    }

    #[test]
    fn early_cut_keeps_best() {
        let mut vs = variants(32);
        vs.sort_by(|a, b| a.1.score().total_cmp(&b.1.score()));
        let kept: Vec<&String> = vs.iter().take(3).map(|(k, _)| k).collect();
        assert!(kept.contains(&&"mapA rnz mapB".to_string()));
    }

    #[test]
    fn estimate_id_matches_boxed_estimate() {
        use crate::dsl::intern::SharedArena;
        let env = Env::new()
            .with("A", Layout::row_major(&[8, 8]))
            .with("B", Layout::row_major(&[8, 8]));
        let e = crate::dsl::matmul_naive(crate::dsl::input("A"), crate::dsl::input("B"));
        let arena = SharedArena::new();
        let id = arena.intern(&e);
        let by_id = estimate_id(&arena, id, &env).unwrap();
        let boxed = estimate(&lower(&e, &env).unwrap());
        assert_eq!(by_id, boxed);
    }

    #[test]
    fn spine_lower_bound_never_exceeds_score() {
        use crate::dsl::intern::SharedArena;
        let env = Env::new()
            .with("A", Layout::row_major(&[16, 16]))
            .with("B", Layout::row_major(&[16, 16]));
        let ctx = Ctx::new(env.clone());
        let arena = SharedArena::new();
        for v in enumerate_all(&starts::matmul_naive_variant(), &ctx, 10).unwrap() {
            let id = arena.intern(&v.expr);
            let lb = spine_lower_bound_id(&arena, id, &ctx);
            let score = estimate_id(&arena, id, &env).unwrap().score();
            assert!(
                lb <= score,
                "{}: bound {lb} exceeds true score {score}",
                v.display_key()
            );
            assert!(lb > 0.0, "{}: bound should be positive", v.display_key());
        }
    }

    #[test]
    fn spine_lower_bound_is_exact_traffic_on_resolved_spines() {
        // On the search families' normal forms (lambda/primitive
        // operators, view args, scalar kernels) the bound replicates the
        // walk's traffic accumulation verbatim — bit-for-bit, not just
        // within epsilon. This is the tentpole of the branch-and-bound
        // cut: the bound is as tight as the model allows, short only of
        // the accumulator penalty.
        use crate::dsl::intern::SharedArena;
        let env = Env::new()
            .with("A", Layout::row_major(&[64, 64]))
            .with("B", Layout::row_major(&[64, 64]));
        let ctx = Ctx::new(env.clone());
        let arena = SharedArena::new();
        for start in [
            starts::matmul_naive_variant(),
            starts::matmul_rnz_subdivided_variant(4),
        ] {
            let id = arena.intern(&start.expr);
            let lb = spine_lower_bound_id(&arena, id, &ctx);
            let est = estimate_id(&arena, id, &env).unwrap();
            assert_eq!(
                lb,
                est.traffic,
                "{}: bound must equal the true traffic term",
                start.display_key()
            );
        }
    }

    #[test]
    fn spine_lower_bound_is_rearrangement_sensitive() {
        // The whole point of the per-track terms: permutations of one
        // family no longer share a single bound value, so dominated
        // rearrangements bound above the family's best score and the
        // search can cut them at slack 1.0.
        use crate::dsl::intern::SharedArena;
        let env = Env::new()
            .with("A", Layout::row_major(&[64, 64]))
            .with("B", Layout::row_major(&[64, 64]));
        let ctx = Ctx::new(env.clone());
        let arena = SharedArena::new();
        let variants =
            enumerate_all(&starts::matmul_rnz_subdivided_variant(4), &ctx, 100).unwrap();
        assert_eq!(variants.len(), 12);
        let bounds: std::collections::BTreeSet<u64> = variants
            .iter()
            .map(|v| spine_lower_bound_id(&arena, arena.intern(&v.expr), &ctx).to_bits())
            .collect();
        assert!(
            bounds.len() > 1,
            "bound collapsed to one value across the family — the cut is inert again"
        );
        // And at least one variant bounds strictly above the family's
        // best true score: a real cut exists at slack 1.0.
        let best = variants
            .iter()
            .map(|v| {
                estimate_id(&arena, arena.intern(&v.expr), &env)
                    .unwrap()
                    .score()
            })
            .fold(f64::INFINITY, f64::min);
        let max_bound = bounds
            .iter()
            .map(|&b| f64::from_bits(b))
            .fold(0.0f64, f64::max);
        assert!(
            max_bound > best,
            "no variant bounds above the best score ({max_bound} vs {best})"
        );
    }

    #[test]
    fn reachable_floor_is_family_invariant_and_bounds_every_member() {
        // The gap denominator's two load-bearing properties, on the deep
        // n=64/b=4 family the anytime search targets: (1) the floor is
        // bit-identical across every rearrangement (so it soundly bounds
        // *unexplored* family members reachable through the connected swap
        // graph), (2) it never exceeds the sensitive bound or any member's
        // true score.
        use crate::dsl::intern::SharedArena;
        let env = Env::new()
            .with("A", Layout::row_major(&[64, 64]))
            .with("B", Layout::row_major(&[64, 64]));
        let ctx = Ctx::new(env.clone());
        let arena = SharedArena::new();
        let variants =
            enumerate_all(&starts::matmul_rnz_subdivided_variant(4), &ctx, 100).unwrap();
        assert_eq!(variants.len(), 12);
        let floors: std::collections::BTreeSet<u64> = variants
            .iter()
            .map(|v| spine_reachable_floor_id(&arena, arena.intern(&v.expr), &ctx).to_bits())
            .collect();
        assert_eq!(
            floors.len(),
            1,
            "floor must collapse to one value across the family"
        );
        let floor = f64::from_bits(*floors.iter().next().unwrap());
        assert!(floor > 0.0);
        for v in &variants {
            let id = arena.intern(&v.expr);
            let lb = spine_lower_bound_id(&arena, id, &ctx);
            let score = estimate_id(&arena, id, &env).unwrap().score();
            assert!(
                floor <= lb && lb <= score,
                "{}: floor {floor} / bound {lb} / score {score} out of order",
                v.display_key()
            );
        }
    }

    #[test]
    fn verifier_footprint_witnesses_flops_exactly() {
        // The static verifier's abstract interpretation counts leaf
        // evaluations along the same walk `estimate` takes, but from the
        // lowered program's extents alone — an independent derivation.
        // Agreement pins both: a cost-model walk that miscounts loop
        // trip products and a verifier that mis-multiplies `mult`
        // through the nest would each break this, for every
        // rearrangement in the family.
        let env = Env::new()
            .with("A", Layout::row_major(&[16, 16]))
            .with("B", Layout::row_major(&[16, 16]));
        let ctx = Ctx::new(env.clone());
        for start in [
            starts::matmul_naive_variant(),
            starts::matmul_rnz_subdivided_variant(4),
        ] {
            for v in enumerate_all(&start, &ctx, 100).unwrap() {
                let prog = lower(&v.expr, &env).unwrap();
                let fp = crate::verify::verify(&prog)
                    .unwrap_or_else(|e| panic!("{}: {e}", v.display_key()));
                let est = estimate(&prog);
                assert_eq!(
                    fp.leaf_evals,
                    est.flops,
                    "{}: verifier leaf count vs cost-model flops",
                    v.display_key()
                );
            }
        }
    }
}
