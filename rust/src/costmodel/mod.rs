//! Analytical cost model — the paper's "early cut rule" substrate
//! (Conclusions / Future work: "an early cut rule is also necessary to
//! prune rearrangements which are not feasible").
//!
//! Without executing or even tracing a variant, we estimate from the loop
//! nest alone:
//!
//! - **stride badness** — for each leaf input, the stride of the innermost
//!   loop that advances it, penalising non-unit innermost strides (the
//!   paper's "consecutive reads are the best for the memory controller");
//! - **accumulator footprint** — the paper notes raising reductions
//!   outwards grows the temporaries ("1a uses only scalar accumulators,
//!   while 1b and 1c require full columns");
//! - **parallelism width** — the extent product of the map levels above
//!   the first reduction (§2.1's thread-spawn considerations).
//!
//! The estimate ranks variants for pruning; exact ranking comes from the
//! cache simulator or real execution.
//!
//! # Arena-native entry points
//!
//! The enumeration search scores every candidate it generates, so the
//! scoring path must never rebuild a `Box<Expr>` tree. [`estimate_id`]
//! lowers and estimates an interned expression straight from its
//! [`SharedArena`], and [`spine_lower_bound_id`] computes a *provable
//! lower bound* on the true score from the HoF spine alone — without
//! lowering — which is what the search's branch-and-bound compares
//! against the best-known score before paying for a full lower +
//! estimate. Both read the concurrent arena through `&self`, so every
//! search shard scores against the same store.

use crate::dsl::intern::{ExprId, Node as ENode, SharedArena};
use crate::exec::{lower_id, Node, Program};
use crate::layout::Layout;
use crate::rewrite::Ctx;
use crate::typecheck::{infer_id_scratch, Env};
use crate::Result;
use std::collections::HashMap;

/// Monotone version stamp of the analytical model. The coordinator mixes
/// this into its optimize-result cache generation, so bumping it whenever
/// [`estimate`]'s scoring changes invalidates every cached ranking
/// computed under the old model (ROADMAP: "needs a version stamp once the
/// cost model learns online").
///
/// Branch-and-bound pruning in [`crate::enumerate`] compares
/// [`spine_lower_bound_id`] against the best-known true score. The bound
/// charges only the per-iteration destination write
/// ([`UNIT_STRIDE_COST`]), so it stays a true lower bound for any
/// constants under which every leaf iteration writes its destination at
/// unit stride; keep that invariant (or re-derive the bound) when
/// changing these constants, and bump this stamp whenever the scoring
/// itself changes.
pub const COST_MODEL_VERSION: u64 = 1;

/// Cache-line cost charged per access at unit stride: one f64 out of an
/// 8-element (64-byte) line. Also the per-iteration destination-write
/// charge — fresh results are stored densely — which is what makes it the
/// substrate of [`spine_lower_bound_id`].
pub const UNIT_STRIDE_COST: f64 = 0.125;

/// Per-access cost of a register-resident input track (stride 0, or a
/// track advanced only by loops outside the innermost one).
pub const REG_REUSE_COST: f64 = 0.01;

/// Static cost estimate for one lowered variant.
#[derive(Clone, Debug, PartialEq)]
pub struct CostEstimate {
    /// Estimated memory traffic in cache-line units (lower is better).
    pub traffic: f64,
    /// Peak accumulator (reduction destination) footprint in elements.
    pub acc_footprint: usize,
    /// Product of map extents above the first reduction — available outer
    /// parallelism.
    pub outer_parallelism: usize,
    /// Total leaf evaluations (invariant across rearrangements of the same
    /// computation; sanity metric).
    pub flops: u64,
}

impl CostEstimate {
    /// Scalar ranking score (lower = better): traffic dominates; large
    /// accumulators are penalised lightly.
    pub fn score(&self) -> f64 {
        self.traffic + 0.1 * self.acc_footprint as f64
    }
}

/// Estimate the cost of a lowered program.
pub fn estimate(prog: &Program) -> CostEstimate {
    let mut est = CostEstimate {
        traffic: 0.0,
        acc_footprint: 0,
        outer_parallelism: 1,
        flops: 0,
    };
    walk(&prog.root, 1.0, &mut est, &mut Vec::new(), true);
    est
}

/// Estimate the cost of an interned expression straight from the arena:
/// [`crate::exec::lower_id`] + [`estimate`], with no `Box<Expr>` tree ever
/// materialized. This is the search's per-candidate scoring path; it
/// produces exactly `estimate(&lower(&arena.extract(id), env)?)` (pinned
/// by `tests/lower_id_props.rs`).
pub fn estimate_id(arena: &SharedArena, id: ExprId, env: &Env) -> Result<CostEstimate> {
    Ok(estimate(&lower_id(arena, id, env)?))
}

/// A provable lower bound on [`CostEstimate::score`] for the expression
/// behind `id`, computed from the HoF spine alone — no lowering, no
/// `Box<Expr>`, no per-leaf walk.
///
/// The bound multiplies the consumed (outermost) extents down the spine —
/// every spine level becomes a loop of exactly that extent, and whatever
/// the body lowers to executes at least once per iteration — and charges
/// only the destination write ([`UNIT_STRIDE_COST`]) for each of those
/// iterations. The true score additionally pays per-track input traffic,
/// inner-loop iterations and the accumulator penalty, so
/// `spine_lower_bound_id(..) ≤ estimate_id(..).score()` whenever the
/// expression lowers at all (pinned by a property test in
/// `tests/lower_id_props.rs`; unlowerable candidates score `+∞`, which
/// bounds trivially).
///
/// *Partial spine*: descent stops — returning the bound accumulated so
/// far, still sound — as soon as a level's operator is not a lambda or an
/// argument layout cannot be resolved, so the function can be called on
/// candidates in any intermediate rewrite state.
pub fn spine_lower_bound_id(arena: &SharedArena, id: ExprId, ctx: &Ctx) -> f64 {
    // The descent follows a single spine path, so one mutable binding map
    // (shadowing as it goes, never needing restoration) replaces a full
    // `Ctx` clone per level — this runs once per generated candidate on
    // the prune hot path.
    fn spine_iters(
        arena: &SharedArena,
        id: ExprId,
        env: &Env,
        vars: &mut HashMap<String, Layout>,
        acc: f64,
    ) -> f64 {
        let (fid, args) = match arena.get(id) {
            ENode::Nzip { f, args } => (*f, args),
            ENode::Rnz { m, args, .. } => (*m, args),
            _ => return acc,
        };
        let mut extent = None;
        let mut elem_tys = Vec::with_capacity(args.len());
        for &a in args {
            let Ok(layout) = infer_id_scratch(arena, a, env, vars) else {
                return acc;
            };
            let Some(outer) = layout.outer() else {
                return acc;
            };
            if extent.is_none() {
                extent = Some(outer.extent as f64);
            }
            let Ok(elem) = layout.peel_outer() else {
                return acc;
            };
            elem_tys.push(elem);
        }
        let Some(extent) = extent else {
            return acc;
        };
        if let ENode::Lam { params, body } = arena.get(fid) {
            if params.len() == args.len() {
                for (p, elem) in params.iter().zip(elem_tys) {
                    vars.insert(p.clone(), elem);
                }
                return spine_iters(arena, *body, env, vars, acc * extent);
            }
        }
        acc * extent
    }
    let mut vars = ctx.vars.clone();
    spine_iters(arena, id, &ctx.env, &mut vars, 1.0) * UNIT_STRIDE_COST
}

/// `iters`: product of enclosing loop extents. `stack`: per-level advance
/// lists, innermost last, to find which loop moves each track.
fn walk(
    node: &Node,
    iters: f64,
    est: &mut CostEstimate,
    stack: &mut Vec<Vec<(usize, usize)>>,
    above_reduction: bool,
) {
    match node {
        Node::MapLoop {
            extent,
            advances,
            body,
            ..
        } => {
            if above_reduction {
                est.outer_parallelism *= extent;
            }
            stack.push(advances.iter().map(|a| (a.dst, a.stride)).collect());
            walk(body, iters * *extent as f64, est, stack, above_reduction);
            stack.pop();
        }
        Node::RedLoop {
            extent,
            advances,
            body_size,
            body,
            ..
        } => {
            est.acc_footprint = est.acc_footprint.max(*body_size);
            stack.push(advances.iter().map(|a| (a.dst, a.stride)).collect());
            walk(body, iters * *extent as f64, est, stack, false);
            stack.pop();
        }
        Node::Leaf(k) => {
            est.flops += iters as u64;
            // Per input track: the innermost loop that advances it decides
            // the per-access line cost. stride 0 → register reuse; stride 1
            // → 1/8 line per access; large stride → a fresh line each time.
            for &t in &k.tracks {
                let mut stride: Option<usize> = None;
                for level in stack.iter().rev() {
                    if let Some(&(_, s)) = level.iter().find(|&&(tt, _)| tt == t) {
                        stride = Some(s);
                        break;
                    }
                }
                let per_access = match stride {
                    None | Some(0) => REG_REUSE_COST,
                    Some(1) => UNIT_STRIDE_COST,
                    Some(s) if s < 8 => s as f64 * UNIT_STRIDE_COST,
                    _ => 1.0,
                };
                est.traffic += iters * per_access;
            }
            est.traffic += iters * UNIT_STRIDE_COST; // destination
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::enumerate::{enumerate_all, starts};
    use crate::exec::lower;
    use crate::layout::Layout;
    use crate::rewrite::Ctx;
    use crate::typecheck::Env;

    fn variants(n: usize) -> Vec<(String, CostEstimate)> {
        let env = Env::new()
            .with("A", Layout::row_major(&[n, n]))
            .with("B", Layout::row_major(&[n, n]));
        let ctx = Ctx::new(env.clone());
        enumerate_all(&starts::matmul_naive_variant(), &ctx, 10)
            .unwrap()
            .iter()
            .map(|v| {
                let prog = lower(&v.expr, &env).unwrap();
                (v.display_key(), estimate(&prog))
            })
            .collect()
    }

    #[test]
    fn flops_invariant_across_rearrangements() {
        let vs = variants(16);
        let f0 = vs[0].1.flops;
        for (k, e) in &vs {
            assert_eq!(e.flops, f0, "{k}");
        }
    }

    #[test]
    fn best_known_variant_scores_best() {
        // Table 1: mapA rnz mapB wins; mapB rnz mapA loses.
        let vs: std::collections::HashMap<_, _> = variants(64).into_iter().collect();
        let best = vs["mapA rnz mapB"].score();
        let worst = vs["mapB rnz mapA"].score();
        let naive = vs["mapA mapB rnz"].score();
        assert!(best < naive, "best {best} naive {naive}");
        assert!(naive < worst, "naive {naive} worst {worst}");
    }

    #[test]
    fn flipped_variants_use_bigger_accumulators() {
        // paper: "1a uses only scalar accumulators, while 1b and 1c require
        // full columns"
        let vs: std::collections::HashMap<_, _> = variants(32).into_iter().collect();
        assert_eq!(vs["mapA mapB rnz"].acc_footprint, 1);
        assert!(vs["rnz mapA mapB"].acc_footprint > 1);
    }

    #[test]
    fn outer_parallelism_counts_maps_above_reduction() {
        let vs: std::collections::HashMap<_, _> = variants(32).into_iter().collect();
        assert_eq!(vs["mapA mapB rnz"].outer_parallelism, 32 * 32);
        assert_eq!(vs["rnz mapA mapB"].outer_parallelism, 1);
    }

    #[test]
    fn early_cut_keeps_best() {
        let mut vs = variants(32);
        vs.sort_by(|a, b| a.1.score().total_cmp(&b.1.score()));
        let kept: Vec<&String> = vs.iter().take(3).map(|(k, _)| k).collect();
        assert!(kept.contains(&&"mapA rnz mapB".to_string()));
    }

    #[test]
    fn estimate_id_matches_boxed_estimate() {
        use crate::dsl::intern::SharedArena;
        let env = Env::new()
            .with("A", Layout::row_major(&[8, 8]))
            .with("B", Layout::row_major(&[8, 8]));
        let e = crate::dsl::matmul_naive(crate::dsl::input("A"), crate::dsl::input("B"));
        let arena = SharedArena::new();
        let id = arena.intern(&e);
        let by_id = estimate_id(&arena, id, &env).unwrap();
        let boxed = estimate(&lower(&e, &env).unwrap());
        assert_eq!(by_id, boxed);
    }

    #[test]
    fn spine_lower_bound_never_exceeds_score() {
        use crate::dsl::intern::SharedArena;
        let env = Env::new()
            .with("A", Layout::row_major(&[16, 16]))
            .with("B", Layout::row_major(&[16, 16]));
        let ctx = Ctx::new(env.clone());
        let arena = SharedArena::new();
        for v in enumerate_all(&starts::matmul_naive_variant(), &ctx, 10).unwrap() {
            let id = arena.intern(&v.expr);
            let lb = spine_lower_bound_id(&arena, id, &ctx);
            let score = estimate_id(&arena, id, &env).unwrap().score();
            assert!(
                lb <= score,
                "{}: bound {lb} exceeds true score {score}",
                v.display_key()
            );
            assert!(lb > 0.0, "{}: bound should be positive", v.display_key());
        }
    }
}
