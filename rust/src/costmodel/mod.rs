//! Analytical cost model — the paper's "early cut rule" substrate
//! (Conclusions / Future work: "an early cut rule is also necessary to
//! prune rearrangements which are not feasible").
//!
//! Without executing or even tracing a variant, we estimate from the loop
//! nest alone:
//!
//! - **stride badness** — for each leaf input, the stride of the innermost
//!   loop that advances it, penalising non-unit innermost strides (the
//!   paper's "consecutive reads are the best for the memory controller");
//! - **accumulator footprint** — the paper notes raising reductions
//!   outwards grows the temporaries ("1a uses only scalar accumulators,
//!   while 1b and 1c require full columns");
//! - **parallelism width** — the extent product of the map levels above
//!   the first reduction (§2.1's thread-spawn considerations).
//!
//! The estimate ranks variants for pruning; exact ranking comes from the
//! cache simulator or real execution.

use crate::exec::{Node, Program};

/// Monotone version stamp of the analytical model. The coordinator mixes
/// this into its optimize-result cache generation, so bumping it whenever
/// [`estimate`]'s scoring changes invalidates every cached ranking
/// computed under the old model (ROADMAP: "needs a version stamp once the
/// cost model learns online").
///
/// Branch-and-bound pruning in [`crate::enumerate`] also leans on a
/// property of the current constants: per leaf iteration, each input
/// track costs between 0.01 (register reuse) and 1.0 (fresh line), plus
/// a fixed 0.125 for the destination, so for kernels with ≤ ~20 input
/// tracks no rearrangement can score worse than ~64× the best one. Keep
/// [`crate::enumerate::DEFAULT_PRUNE_SLACK`] above that ratio when
/// changing these constants.
pub const COST_MODEL_VERSION: u64 = 1;

/// Static cost estimate for one lowered variant.
#[derive(Clone, Debug, PartialEq)]
pub struct CostEstimate {
    /// Estimated memory traffic in cache-line units (lower is better).
    pub traffic: f64,
    /// Peak accumulator (reduction destination) footprint in elements.
    pub acc_footprint: usize,
    /// Product of map extents above the first reduction — available outer
    /// parallelism.
    pub outer_parallelism: usize,
    /// Total leaf evaluations (invariant across rearrangements of the same
    /// computation; sanity metric).
    pub flops: u64,
}

impl CostEstimate {
    /// Scalar ranking score (lower = better): traffic dominates; large
    /// accumulators are penalised lightly.
    pub fn score(&self) -> f64 {
        self.traffic + 0.1 * self.acc_footprint as f64
    }
}

/// Estimate the cost of a lowered program.
pub fn estimate(prog: &Program) -> CostEstimate {
    let mut est = CostEstimate {
        traffic: 0.0,
        acc_footprint: 0,
        outer_parallelism: 1,
        flops: 0,
    };
    walk(&prog.root, 1.0, &mut est, &mut Vec::new(), true);
    est
}

/// `iters`: product of enclosing loop extents. `stack`: per-level advance
/// lists, innermost last, to find which loop moves each track.
fn walk(
    node: &Node,
    iters: f64,
    est: &mut CostEstimate,
    stack: &mut Vec<Vec<(usize, usize)>>,
    above_reduction: bool,
) {
    match node {
        Node::MapLoop {
            extent,
            advances,
            body,
            ..
        } => {
            if above_reduction {
                est.outer_parallelism *= extent;
            }
            stack.push(advances.iter().map(|a| (a.dst, a.stride)).collect());
            walk(body, iters * *extent as f64, est, stack, above_reduction);
            stack.pop();
        }
        Node::RedLoop {
            extent,
            advances,
            body_size,
            body,
            ..
        } => {
            est.acc_footprint = est.acc_footprint.max(*body_size);
            stack.push(advances.iter().map(|a| (a.dst, a.stride)).collect());
            walk(body, iters * *extent as f64, est, stack, false);
            stack.pop();
        }
        Node::Leaf(k) => {
            est.flops += iters as u64;
            // Per input track: the innermost loop that advances it decides
            // the per-access line cost. stride 0 → register reuse; stride 1
            // → 1/8 line per access; large stride → a fresh line each time.
            for &t in &k.tracks {
                let mut stride: Option<usize> = None;
                for level in stack.iter().rev() {
                    if let Some(&(_, s)) = level.iter().find(|&&(tt, _)| tt == t) {
                        stride = Some(s);
                        break;
                    }
                }
                let per_access = match stride {
                    None | Some(0) => 0.01,
                    Some(1) => 0.125,
                    Some(s) if s < 8 => s as f64 * 0.125,
                    _ => 1.0,
                };
                est.traffic += iters * per_access;
            }
            est.traffic += iters * 0.125; // destination
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::enumerate::{enumerate_all, starts};
    use crate::exec::lower;
    use crate::layout::Layout;
    use crate::rewrite::Ctx;
    use crate::typecheck::Env;

    fn variants(n: usize) -> Vec<(String, CostEstimate)> {
        let env = Env::new()
            .with("A", Layout::row_major(&[n, n]))
            .with("B", Layout::row_major(&[n, n]));
        let ctx = Ctx::new(env.clone());
        enumerate_all(&starts::matmul_naive_variant(), &ctx, 10)
            .unwrap()
            .iter()
            .map(|v| {
                let prog = lower(&v.expr, &env).unwrap();
                (v.display_key(), estimate(&prog))
            })
            .collect()
    }

    #[test]
    fn flops_invariant_across_rearrangements() {
        let vs = variants(16);
        let f0 = vs[0].1.flops;
        for (k, e) in &vs {
            assert_eq!(e.flops, f0, "{k}");
        }
    }

    #[test]
    fn best_known_variant_scores_best() {
        // Table 1: mapA rnz mapB wins; mapB rnz mapA loses.
        let vs: std::collections::HashMap<_, _> = variants(64).into_iter().collect();
        let best = vs["mapA rnz mapB"].score();
        let worst = vs["mapB rnz mapA"].score();
        let naive = vs["mapA mapB rnz"].score();
        assert!(best < naive, "best {best} naive {naive}");
        assert!(naive < worst, "naive {naive} worst {worst}");
    }

    #[test]
    fn flipped_variants_use_bigger_accumulators() {
        // paper: "1a uses only scalar accumulators, while 1b and 1c require
        // full columns"
        let vs: std::collections::HashMap<_, _> = variants(32).into_iter().collect();
        assert_eq!(vs["mapA mapB rnz"].acc_footprint, 1);
        assert!(vs["rnz mapA mapB"].acc_footprint > 1);
    }

    #[test]
    fn outer_parallelism_counts_maps_above_reduction() {
        let vs: std::collections::HashMap<_, _> = variants(32).into_iter().collect();
        assert_eq!(vs["mapA mapB rnz"].outer_parallelism, 32 * 32);
        assert_eq!(vs["rnz mapA mapB"].outer_parallelism, 1);
    }

    #[test]
    fn early_cut_keeps_best() {
        let mut vs = variants(32);
        vs.sort_by(|a, b| a.1.score().total_cmp(&b.1.score()));
        let kept: Vec<&String> = vs.iter().take(3).map(|(k, _)| k).collect();
        assert!(kept.contains(&&"mapA rnz mapB".to_string()));
    }
}
