//! Ergonomic combinators for constructing [`Expr`] trees in Rust code.
//!
//! These mirror the paper's surface syntax: `map`/`zip` are the 1- and
//! 2-ary cases of `nzip`, `dot u v = rnz (+) (*) u v`, etc.

use super::expr::{Expr, Prim};

pub fn var(name: &str) -> Expr {
    Expr::Var(name.to_string())
}

pub fn lit(x: f64) -> Expr {
    Expr::Lit(x)
}

pub fn input(name: &str) -> Expr {
    Expr::Input(name.to_string())
}

pub fn add() -> Expr {
    Expr::Prim(Prim::Add)
}

pub fn sub() -> Expr {
    Expr::Prim(Prim::Sub)
}

pub fn mul() -> Expr {
    Expr::Prim(Prim::Mul)
}

pub fn div() -> Expr {
    Expr::Prim(Prim::Div)
}

pub fn pmax() -> Expr {
    Expr::Prim(Prim::Max)
}

pub fn lam(params: &[&str], body: Expr) -> Expr {
    Expr::Lam {
        params: params.iter().map(|s| s.to_string()).collect(),
        body: Box::new(body),
    }
}

pub fn lam1(p: &str, body: Expr) -> Expr {
    lam(&[p], body)
}

pub fn lam2(p1: &str, p2: &str, body: Expr) -> Expr {
    lam(&[p1, p2], body)
}

pub fn lam3(p1: &str, p2: &str, p3: &str, body: Expr) -> Expr {
    lam(&[p1, p2, p3], body)
}

pub fn app(f: Expr, args: Vec<Expr>) -> Expr {
    Expr::App {
        f: Box::new(f),
        args,
    }
}

pub fn app1(f: Expr, a: Expr) -> Expr {
    app(f, vec![a])
}

pub fn app2(f: Expr, a: Expr, b: Expr) -> Expr {
    app(f, vec![a, b])
}

/// `nzip f xs` — variadic map/zip.
pub fn nzip(f: Expr, args: Vec<Expr>) -> Expr {
    Expr::Nzip {
        f: Box::new(f),
        args,
    }
}

/// `map f x` — unary nzip.
pub fn map(f: Expr, x: Expr) -> Expr {
    nzip(f, vec![x])
}

/// `zip f x y` — binary nzip (Haskell `zipWith`).
pub fn zip(f: Expr, x: Expr, y: Expr) -> Expr {
    nzip(f, vec![x, y])
}

/// `rnz r m xs` — reduce-of-n-ary-zip.
pub fn rnz(r: Expr, m: Expr, args: Vec<Expr>) -> Expr {
    Expr::Rnz {
        r: Box::new(r),
        m: Box::new(m),
        args,
    }
}

/// `reduce r x = rnz r id x` with the identity zipper.
pub fn reduce(r: Expr, x: Expr) -> Expr {
    rnz(r, lam1("e%id", var("e%id")), vec![x])
}

/// `dot u v = rnz (+) (*) u v` (paper eq. 29).
pub fn dot(u: Expr, v: Expr) -> Expr {
    rnz(add(), mul(), vec![u, v])
}

/// `lift f` — apply `f` elementwise one container level down. `lift (+)`
/// is the paper's `zip (+)` reduction operator for vector accumulators.
pub fn lift(f: Expr) -> Expr {
    Expr::Lift { f: Box::new(f) }
}

/// `lift^k f`.
pub fn lift_n(f: Expr, k: usize) -> Expr {
    (0..k).fold(f, |acc, _| lift(acc))
}

pub fn subdiv(d: usize, b: usize, arg: Expr) -> Expr {
    Expr::Subdiv {
        d,
        b,
        arg: Box::new(arg),
    }
}

pub fn flatten(d: usize, arg: Expr) -> Expr {
    Expr::Flatten {
        d,
        arg: Box::new(arg),
    }
}

pub fn flip2(d1: usize, d2: usize, arg: Expr) -> Expr {
    Expr::Flip {
        d1,
        d2,
        arg: Box::new(arg),
    }
}

/// `flip d` with the default second dimension `d+1` (paper convention).
pub fn flip(d: usize, arg: Expr) -> Expr {
    flip2(d, d + 1, arg)
}

/// The textbook matrix–vector product `map (\r -> dot r v) A`
/// (paper eq. 39/46). `a` must be a row-major matrix input, `v` a vector.
pub fn matvec_naive(a: Expr, v: Expr) -> Expr {
    map(lam1("r", dot(var("r"), v)), a)
}

/// The textbook matrix–matrix product
/// `map (\rA -> map (\cB -> dot rA cB) (flip 0 B)) A` (paper eq. 51;
/// the flip makes "columns of B" explicit for a row-major `B`).
pub fn matmul_naive(a: Expr, b: Expr) -> Expr {
    map(
        lam1("rA", map(lam1("cB", dot(var("rA"), var("cB"))), flip(0, b))),
        a,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_builds_rnz() {
        let e = dot(input("u"), input("v"));
        match e {
            Expr::Rnz { r, m, args } => {
                assert_eq!(*r, add());
                assert_eq!(*m, mul());
                assert_eq!(args.len(), 2);
            }
            _ => panic!("expected rnz"),
        }
    }

    #[test]
    fn matmul_shape() {
        let e = matmul_naive(input("A"), input("B"));
        assert_eq!(e.inputs(), vec!["B".to_string(), "A".to_string()]);
        assert!(e.size() > 5);
    }

    #[test]
    fn lift_n_nests() {
        let e = lift_n(add(), 2);
        match e {
            Expr::Lift { f } => match *f {
                Expr::Lift { .. } => {}
                _ => panic!("expected nested lift"),
            },
            _ => panic!("expected lift"),
        }
    }
}
