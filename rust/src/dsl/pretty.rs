//! S-expression pretty printer for [`Expr`]. Output round-trips through
//! [`super::parser::parse`].

use super::expr::{Expr, Prim};

/// Render an expression as a single-line s-expression.
pub fn pretty(e: &Expr) -> String {
    let mut s = String::new();
    go(e, &mut s);
    s
}

fn go(e: &Expr, out: &mut String) {
    match e {
        Expr::Var(x) => out.push_str(x),
        Expr::Lit(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{:.1}", x));
            } else {
                out.push_str(&format!("{}", x));
            }
        }
        Expr::Prim(p) => out.push_str(prim_name(*p)),
        Expr::Lam { params, body } => {
            out.push_str("(lam (");
            for (i, p) in params.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                out.push_str(p);
            }
            out.push_str(") ");
            go(body, out);
            out.push(')');
        }
        Expr::App { f, args } => {
            out.push_str("(app ");
            go(f, out);
            for a in args {
                out.push(' ');
                go(a, out);
            }
            out.push(')');
        }
        Expr::Nzip { f, args } => {
            out.push_str("(nzip ");
            go(f, out);
            for a in args {
                out.push(' ');
                go(a, out);
            }
            out.push(')');
        }
        Expr::Rnz { r, m, args } => {
            out.push_str("(rnz ");
            go(r, out);
            out.push(' ');
            go(m, out);
            for a in args {
                out.push(' ');
                go(a, out);
            }
            out.push(')');
        }
        Expr::Lift { f } => {
            out.push_str("(lift ");
            go(f, out);
            out.push(')');
        }
        Expr::Subdiv { d, b, arg } => {
            out.push_str(&format!("(subdiv {d} {b} "));
            go(arg, out);
            out.push(')');
        }
        Expr::Flatten { d, arg } => {
            out.push_str(&format!("(flatten {d} "));
            go(arg, out);
            out.push(')');
        }
        Expr::Flip { d1, d2, arg } => {
            out.push_str(&format!("(flip {d1} {d2} "));
            go(arg, out);
            out.push(')');
        }
        Expr::Input(n) => {
            out.push_str("(in ");
            out.push_str(n);
            out.push(')');
        }
    }
}

pub(super) fn prim_name(p: Prim) -> &'static str {
    p.name()
}

#[cfg(test)]
mod tests {
    use crate::dsl::builder::*;
    use crate::dsl::pretty;

    #[test]
    fn pretty_matvec() {
        let e = matvec_naive(input("A"), input("v"));
        assert_eq!(
            pretty(&e),
            "(nzip (lam (r) (rnz + * r (in v))) (in A))"
        );
    }

    #[test]
    fn pretty_layout_ops() {
        let e = subdiv(0, 16, flip(0, input("A")));
        assert_eq!(pretty(&e), "(subdiv 0 16 (flip 0 1 (in A)))");
    }

    #[test]
    fn pretty_literals() {
        assert_eq!(pretty(&lit(2.0)), "2.0");
        assert_eq!(pretty(&lit(2.5)), "2.5");
    }
}
