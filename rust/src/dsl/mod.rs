//! The functional DSL of the paper: a lambda calculus extended with the
//! variadic higher-order functions `nzip` and `rnz`, the applicative `lift`,
//! and the layout operators `subdiv` / `flatten` / `flip`.
//!
//! `map` and `zip` are the 1- and 2-ary special cases of [`Expr::Nzip`]
//! (paper eq. 20); `reduce f xs = rnz f id xs` and the fused
//! `dot u v = rnz (+) (*) u v` (paper eq. 29).

mod builder;
mod expr;
pub mod intern;
mod parser;
mod pretty;

pub use builder::*;
pub use expr::{fresh_var, Expr, Prim};
pub use parser::parse;
pub use pretty::pretty;
