//! S-expression parser for the DSL — the textual front end of the
//! optimization service (`hofdla optimize <file>`).
//!
//! Grammar (s-expressions):
//!
//! ```text
//! expr ::= number
//!        | prim                      ; + - * / max min neg exp sqrt tanh relu
//!        | symbol                    ; variable
//!        | (in NAME)                 ; named array input
//!        | (lam (x y ...) expr)
//!        | (app expr expr ...)
//!        | (nzip f a b ...)          ; (map f a) and (zip f a b) are sugar
//!        | (rnz r m a b ...)
//!        | (reduce r a)              ; sugar: rnz r id a
//!        | (dot a b)                 ; sugar: rnz + * a b
//!        | (lift f)
//!        | (subdiv d b expr)
//!        | (flatten d expr)
//!        | (flip d1 [d2] expr)
//! ```

use super::expr::{Expr, Prim};
use crate::{Error, Result};

/// Parse a single DSL expression from source text.
pub fn parse(src: &str) -> Result<Expr> {
    let toks = tokenize(src)?;
    let mut pos = 0;
    let sexp = parse_sexp(&toks, &mut pos)?;
    if pos != toks.len() {
        return Err(Error::Parse(format!(
            "trailing tokens after expression (at token {pos})"
        )));
    }
    to_expr(&sexp)
}

#[derive(Debug, Clone)]
enum Sexp {
    Atom(String),
    List(Vec<Sexp>),
}

fn tokenize(src: &str) -> Result<Vec<String>> {
    let mut toks = Vec::new();
    let mut cur = String::new();
    let mut chars = src.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            ';' => {
                // comment to end of line
                for c2 in chars.by_ref() {
                    if c2 == '\n' {
                        break;
                    }
                }
            }
            '(' | ')' => {
                if !cur.is_empty() {
                    toks.push(std::mem::take(&mut cur));
                }
                toks.push(c.to_string());
            }
            c if c.is_whitespace() => {
                if !cur.is_empty() {
                    toks.push(std::mem::take(&mut cur));
                }
            }
            c => cur.push(c),
        }
    }
    if !cur.is_empty() {
        toks.push(cur);
    }
    if toks.is_empty() {
        return Err(Error::Parse("empty input".into()));
    }
    Ok(toks)
}

fn parse_sexp(toks: &[String], pos: &mut usize) -> Result<Sexp> {
    match toks.get(*pos) {
        None => Err(Error::Parse("unexpected end of input".into())),
        Some(t) if t == "(" => {
            *pos += 1;
            let mut items = Vec::new();
            loop {
                match toks.get(*pos) {
                    None => return Err(Error::Parse("unclosed '('".into())),
                    Some(t) if t == ")" => {
                        *pos += 1;
                        return Ok(Sexp::List(items));
                    }
                    _ => items.push(parse_sexp(toks, pos)?),
                }
            }
        }
        Some(t) if t == ")" => Err(Error::Parse("unexpected ')'".into())),
        Some(t) => {
            *pos += 1;
            Ok(Sexp::Atom(t.clone()))
        }
    }
}

fn prim_of(name: &str) -> Option<Prim> {
    Some(match name {
        "+" => Prim::Add,
        "-" => Prim::Sub,
        "*" => Prim::Mul,
        "/" => Prim::Div,
        "max" => Prim::Max,
        "min" => Prim::Min,
        "neg" => Prim::Neg,
        "exp" => Prim::Exp,
        "sqrt" => Prim::Sqrt,
        "tanh" => Prim::Tanh,
        "relu" => Prim::Relu,
        _ => return None,
    })
}

fn to_expr(s: &Sexp) -> Result<Expr> {
    match s {
        Sexp::Atom(a) => {
            if let Ok(x) = a.parse::<f64>() {
                return Ok(Expr::Lit(x));
            }
            if let Some(p) = prim_of(a) {
                return Ok(Expr::Prim(p));
            }
            Ok(Expr::Var(a.clone()))
        }
        Sexp::List(items) => {
            let head = match items.first() {
                Some(Sexp::Atom(h)) => h.as_str(),
                Some(Sexp::List(_)) => {
                    // ((lam ...) a b) — implicit application
                    let f = to_expr(&items[0])?;
                    let args = items[1..].iter().map(to_expr).collect::<Result<_>>()?;
                    return Ok(Expr::App {
                        f: Box::new(f),
                        args,
                    });
                }
                None => return Err(Error::Parse("empty list".into())),
            };
            let rest = &items[1..];
            match head {
                "in" => {
                    let name = atom(rest, 0, "in")?;
                    expect_len(rest, 1, "in")?;
                    Ok(Expr::Input(name))
                }
                "lam" => {
                    expect_len(rest, 2, "lam")?;
                    let params = match &rest[0] {
                        Sexp::List(ps) => ps
                            .iter()
                            .map(|p| match p {
                                Sexp::Atom(a) => Ok(a.clone()),
                                _ => Err(Error::Parse("lam: parameter must be a symbol".into())),
                            })
                            .collect::<Result<Vec<_>>>()?,
                        Sexp::Atom(a) => vec![a.clone()],
                    };
                    Ok(Expr::Lam {
                        params,
                        body: Box::new(to_expr(&rest[1])?),
                    })
                }
                "app" => {
                    if rest.is_empty() {
                        return Err(Error::Parse("app: needs a function".into()));
                    }
                    Ok(Expr::App {
                        f: Box::new(to_expr(&rest[0])?),
                        args: rest[1..].iter().map(to_expr).collect::<Result<_>>()?,
                    })
                }
                "nzip" | "map" | "zip" => {
                    if rest.len() < 2 {
                        return Err(Error::Parse(format!("{head}: needs f and ≥1 array")));
                    }
                    let f = to_expr(&rest[0])?;
                    let args: Vec<Expr> =
                        rest[1..].iter().map(to_expr).collect::<Result<_>>()?;
                    if head == "map" && args.len() != 1 {
                        return Err(Error::Parse("map: exactly one array".into()));
                    }
                    if head == "zip" && args.len() != 2 {
                        return Err(Error::Parse("zip: exactly two arrays".into()));
                    }
                    Ok(Expr::Nzip {
                        f: Box::new(f),
                        args,
                    })
                }
                "rnz" => {
                    if rest.len() < 3 {
                        return Err(Error::Parse("rnz: needs r, m and ≥1 array".into()));
                    }
                    Ok(Expr::Rnz {
                        r: Box::new(to_expr(&rest[0])?),
                        m: Box::new(to_expr(&rest[1])?),
                        args: rest[2..].iter().map(to_expr).collect::<Result<_>>()?,
                    })
                }
                "reduce" => {
                    expect_len(rest, 2, "reduce")?;
                    Ok(crate::dsl::builder::reduce(
                        to_expr(&rest[0])?,
                        to_expr(&rest[1])?,
                    ))
                }
                "dot" => {
                    expect_len(rest, 2, "dot")?;
                    Ok(crate::dsl::builder::dot(
                        to_expr(&rest[0])?,
                        to_expr(&rest[1])?,
                    ))
                }
                "lift" => {
                    expect_len(rest, 1, "lift")?;
                    Ok(Expr::Lift {
                        f: Box::new(to_expr(&rest[0])?),
                    })
                }
                "subdiv" => {
                    expect_len(rest, 3, "subdiv")?;
                    Ok(Expr::Subdiv {
                        d: usize_atom(rest, 0, "subdiv")?,
                        b: usize_atom(rest, 1, "subdiv")?,
                        arg: Box::new(to_expr(&rest[2])?),
                    })
                }
                "flatten" => {
                    expect_len(rest, 2, "flatten")?;
                    Ok(Expr::Flatten {
                        d: usize_atom(rest, 0, "flatten")?,
                        arg: Box::new(to_expr(&rest[1])?),
                    })
                }
                "flip" => match rest.len() {
                    2 => {
                        let d = usize_atom(rest, 0, "flip")?;
                        Ok(Expr::Flip {
                            d1: d,
                            d2: d + 1,
                            arg: Box::new(to_expr(&rest[1])?),
                        })
                    }
                    3 => Ok(Expr::Flip {
                        d1: usize_atom(rest, 0, "flip")?,
                        d2: usize_atom(rest, 1, "flip")?,
                        arg: Box::new(to_expr(&rest[2])?),
                    }),
                    n => Err(Error::Parse(format!("flip: 2 or 3 args, got {n}"))),
                },
                _ => {
                    // (f a b ...) — implicit application
                    let f = to_expr(&items[0])?;
                    Ok(Expr::App {
                        f: Box::new(f),
                        args: rest.iter().map(to_expr).collect::<Result<_>>()?,
                    })
                }
            }
        }
    }
}

fn atom(rest: &[Sexp], i: usize, ctx: &str) -> Result<String> {
    match rest.get(i) {
        Some(Sexp::Atom(a)) => Ok(a.clone()),
        _ => Err(Error::Parse(format!("{ctx}: expected symbol at arg {i}"))),
    }
}

fn usize_atom(rest: &[Sexp], i: usize, ctx: &str) -> Result<usize> {
    atom(rest, i, ctx)?
        .parse()
        .map_err(|_| Error::Parse(format!("{ctx}: expected integer at arg {i}")))
}

fn expect_len(rest: &[Sexp], n: usize, ctx: &str) -> Result<()> {
    if rest.len() != n {
        return Err(Error::Parse(format!(
            "{ctx}: expected {n} args, got {}",
            rest.len()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::builder::*;
    use crate::dsl::pretty;

    #[test]
    fn roundtrip_matvec() {
        let e = matvec_naive(input("A"), input("v"));
        let s = pretty(&e);
        let back = parse(&s).unwrap();
        assert!(back.alpha_eq(&e), "{s}");
    }

    #[test]
    fn roundtrip_matmul() {
        let e = matmul_naive(input("A"), input("B"));
        let back = parse(&pretty(&e)).unwrap();
        assert!(back.alpha_eq(&e));
    }

    #[test]
    fn sugar_forms() {
        assert!(parse("(dot (in u) (in v))")
            .unwrap()
            .alpha_eq(&dot(input("u"), input("v"))));
        assert!(parse("(map (lam (x) (app * x 2.0)) (in v))").unwrap().alpha_eq(
            &map(lam1("x", app2(mul(), var("x"), lit(2.0))), input("v"))
        ));
        // default flip second arg
        assert!(parse("(flip 0 (in A))")
            .unwrap()
            .alpha_eq(&flip(0, input("A"))));
    }

    #[test]
    fn comments_and_whitespace() {
        let e = parse("; the dot product\n(dot (in u) ; u\n  (in v))").unwrap();
        assert!(e.alpha_eq(&dot(input("u"), input("v"))));
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("(").is_err());
        assert!(parse(")").is_err());
        assert!(parse("(dot (in u))").is_err());
        assert!(parse("(map f a b)").is_err());
        assert!(parse("(subdiv x 2 (in A))").is_err());
        assert!(parse("(in a) extra").is_err());
    }

    #[test]
    fn numbers_and_prims() {
        assert_eq!(parse("3.5").unwrap(), lit(3.5));
        assert_eq!(parse("+").unwrap(), add());
        assert_eq!(parse("relu").unwrap(), Expr::Prim(Prim::Relu));
    }
}
