//! Core expression AST and the lambda-calculus plumbing (free variables,
//! capture-avoiding substitution, alpha-equivalence) that the rewrite engine
//! is built on.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};

/// Scalar primitive operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Prim {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Neg,
    Exp,
    Sqrt,
    Tanh,
    Relu,
}

impl Prim {
    /// Number of arguments the primitive consumes.
    pub fn arity(self) -> usize {
        match self {
            Prim::Add | Prim::Sub | Prim::Mul | Prim::Div | Prim::Max | Prim::Min => 2,
            Prim::Neg | Prim::Exp | Prim::Sqrt | Prim::Tanh | Prim::Relu => 1,
        }
    }

    /// Apply to scalar values.
    pub fn apply(self, args: &[f64]) -> f64 {
        debug_assert_eq!(args.len(), self.arity());
        match self {
            Prim::Add => args[0] + args[1],
            Prim::Sub => args[0] - args[1],
            Prim::Mul => args[0] * args[1],
            Prim::Div => args[0] / args[1],
            Prim::Max => args[0].max(args[1]),
            Prim::Min => args[0].min(args[1]),
            Prim::Neg => -args[0],
            Prim::Exp => args[0].exp(),
            Prim::Sqrt => args[0].sqrt(),
            Prim::Tanh => args[0].tanh(),
            Prim::Relu => args[0].max(0.0),
        }
    }

    /// `true` for operators that are associative (allows reduction
    /// regrouping, paper §2.1).
    pub fn is_associative(self) -> bool {
        matches!(self, Prim::Add | Prim::Mul | Prim::Max | Prim::Min)
    }

    /// `true` for operators that are also commutative (allows reduction
    /// reordering).
    pub fn is_commutative(self) -> bool {
        matches!(self, Prim::Add | Prim::Mul | Prim::Max | Prim::Min)
    }

    pub fn name(self) -> &'static str {
        match self {
            Prim::Add => "+",
            Prim::Sub => "-",
            Prim::Mul => "*",
            Prim::Div => "/",
            Prim::Max => "max",
            Prim::Min => "min",
            Prim::Neg => "neg",
            Prim::Exp => "exp",
            Prim::Sqrt => "sqrt",
            Prim::Tanh => "tanh",
            Prim::Relu => "relu",
        }
    }
}

/// The expression language (paper §2.1 / §3).
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// Bound variable.
    Var(String),
    /// Scalar literal.
    Lit(f64),
    /// Scalar primitive (used curried: `App(Prim(Add), [x, y])`).
    Prim(Prim),
    /// Multi-parameter lambda abstraction.
    Lam { params: Vec<String>, body: Box<Expr> },
    /// Application (possibly partial for binary prims inside `lift`).
    App { f: Box<Expr>, args: Vec<Expr> },
    /// `nzip f xs` — the variadic map/zip (paper eq. 20): consumes the
    /// outermost dimension of each argument in lock-step and applies `f`.
    Nzip { f: Box<Expr>, args: Vec<Expr> },
    /// `rnz r m xs` — reduce-of-n-ary-zip (paper eq. 26): reduces
    /// `m x0[i] … xn[i]` over `i` with the (at least associative) `r`.
    Rnz {
        r: Box<Expr>,
        m: Box<Expr>,
        args: Vec<Expr>,
    },
    /// `lift f` — raise `f` to operate elementwise over one container
    /// level (paper eq. 41). `lift (+)` is the paper's `zip (+)`.
    Lift { f: Box<Expr> },
    /// `subdiv d b s` — split dimension `d` into blocks of `b`.
    Subdiv { d: usize, b: usize, arg: Box<Expr> },
    /// `flatten d s` — merge dimensions `d` and `d+1`.
    Flatten { d: usize, arg: Box<Expr> },
    /// `flip d1 d2 s` — swap two dimensions of the logical layout.
    Flip { d1: usize, d2: usize, arg: Box<Expr> },
    /// Named external array input; its layout lives in the environment.
    Input(String),
}

static FRESH: AtomicU64 = AtomicU64::new(0);

/// Generate a globally fresh variable name (used by capture-avoiding
/// substitution and by rewrite rules that must invent binders).
pub fn fresh_var(hint: &str) -> String {
    let n = FRESH.fetch_add(1, Ordering::Relaxed);
    format!("{hint}%{n}")
}

impl Expr {
    /// Free variables of the expression.
    pub fn free_vars(&self) -> HashSet<String> {
        let mut out = HashSet::new();
        self.collect_free(&mut Vec::new(), &mut out);
        out
    }

    fn collect_free(&self, bound: &mut Vec<String>, out: &mut HashSet<String>) {
        match self {
            Expr::Var(x) => {
                if !bound.iter().any(|b| b == x) {
                    out.insert(x.clone());
                }
            }
            Expr::Lit(_) | Expr::Prim(_) | Expr::Input(_) => {}
            Expr::Lam { params, body } => {
                let n = params.len();
                bound.extend(params.iter().cloned());
                body.collect_free(bound, out);
                bound.truncate(bound.len() - n);
            }
            Expr::App { f, args } => {
                f.collect_free(bound, out);
                for a in args {
                    a.collect_free(bound, out);
                }
            }
            Expr::Nzip { f, args } => {
                f.collect_free(bound, out);
                for a in args {
                    a.collect_free(bound, out);
                }
            }
            Expr::Rnz { r, m, args } => {
                r.collect_free(bound, out);
                m.collect_free(bound, out);
                for a in args {
                    a.collect_free(bound, out);
                }
            }
            Expr::Lift { f } => f.collect_free(bound, out),
            Expr::Subdiv { arg, .. } | Expr::Flatten { arg, .. } | Expr::Flip { arg, .. } => {
                arg.collect_free(bound, out)
            }
        }
    }

    /// Capture-avoiding substitution `self[x := val]`.
    pub fn subst(&self, x: &str, val: &Expr) -> Expr {
        match self {
            Expr::Var(y) => {
                if y == x {
                    val.clone()
                } else {
                    self.clone()
                }
            }
            Expr::Lit(_) | Expr::Prim(_) | Expr::Input(_) => self.clone(),
            Expr::Lam { params, body } => {
                if params.iter().any(|p| p == x) {
                    // x is shadowed; nothing to do below.
                    return self.clone();
                }
                let val_free = val.free_vars();
                if params.iter().any(|p| val_free.contains(p)) {
                    // Rename clashing binders to fresh names first.
                    let mut new_params = Vec::with_capacity(params.len());
                    let mut new_body = (**body).clone();
                    for p in params {
                        if val_free.contains(p) {
                            let np = fresh_var(p.split('%').next().unwrap_or(p));
                            new_body = new_body.subst(p, &Expr::Var(np.clone()));
                            new_params.push(np);
                        } else {
                            new_params.push(p.clone());
                        }
                    }
                    Expr::Lam {
                        params: new_params,
                        body: Box::new(new_body.subst(x, val)),
                    }
                } else {
                    Expr::Lam {
                        params: params.clone(),
                        body: Box::new(body.subst(x, val)),
                    }
                }
            }
            Expr::App { f, args } => Expr::App {
                f: Box::new(f.subst(x, val)),
                args: args.iter().map(|a| a.subst(x, val)).collect(),
            },
            Expr::Nzip { f, args } => Expr::Nzip {
                f: Box::new(f.subst(x, val)),
                args: args.iter().map(|a| a.subst(x, val)).collect(),
            },
            Expr::Rnz { r, m, args } => Expr::Rnz {
                r: Box::new(r.subst(x, val)),
                m: Box::new(m.subst(x, val)),
                args: args.iter().map(|a| a.subst(x, val)).collect(),
            },
            Expr::Lift { f } => Expr::Lift {
                f: Box::new(f.subst(x, val)),
            },
            Expr::Subdiv { d, b, arg } => Expr::Subdiv {
                d: *d,
                b: *b,
                arg: Box::new(arg.subst(x, val)),
            },
            Expr::Flatten { d, arg } => Expr::Flatten {
                d: *d,
                arg: Box::new(arg.subst(x, val)),
            },
            Expr::Flip { d1, d2, arg } => Expr::Flip {
                d1: *d1,
                d2: *d2,
                arg: Box::new(arg.subst(x, val)),
            },
        }
    }

    /// Structural equality up to renaming of bound variables.
    pub fn alpha_eq(&self, other: &Expr) -> bool {
        fn go(a: &Expr, b: &Expr, env: &mut Vec<(String, String)>) -> bool {
            match (a, b) {
                (Expr::Var(x), Expr::Var(y)) => {
                    // Find the innermost binding of either side.
                    for (bx, by) in env.iter().rev() {
                        let hit_x = bx == x;
                        let hit_y = by == y;
                        if hit_x || hit_y {
                            return hit_x && hit_y;
                        }
                    }
                    x == y
                }
                (Expr::Lit(x), Expr::Lit(y)) => x == y,
                (Expr::Prim(x), Expr::Prim(y)) => x == y,
                (Expr::Input(x), Expr::Input(y)) => x == y,
                (
                    Expr::Lam { params: p1, body: b1 },
                    Expr::Lam { params: p2, body: b2 },
                ) => {
                    if p1.len() != p2.len() {
                        return false;
                    }
                    let n = p1.len();
                    for (x, y) in p1.iter().zip(p2) {
                        env.push((x.clone(), y.clone()));
                    }
                    let r = go(b1, b2, env);
                    env.truncate(env.len() - n);
                    r
                }
                (Expr::App { f: f1, args: a1 }, Expr::App { f: f2, args: a2 }) => {
                    go(f1, f2, env)
                        && a1.len() == a2.len()
                        && a1.iter().zip(a2).all(|(x, y)| go(x, y, env))
                }
                (Expr::Nzip { f: f1, args: a1 }, Expr::Nzip { f: f2, args: a2 }) => {
                    go(f1, f2, env)
                        && a1.len() == a2.len()
                        && a1.iter().zip(a2).all(|(x, y)| go(x, y, env))
                }
                (
                    Expr::Rnz { r: r1, m: m1, args: a1 },
                    Expr::Rnz { r: r2, m: m2, args: a2 },
                ) => {
                    go(r1, r2, env)
                        && go(m1, m2, env)
                        && a1.len() == a2.len()
                        && a1.iter().zip(a2).all(|(x, y)| go(x, y, env))
                }
                (Expr::Lift { f: f1 }, Expr::Lift { f: f2 }) => go(f1, f2, env),
                (
                    Expr::Subdiv { d: d1, b: b1, arg: x },
                    Expr::Subdiv { d: d2, b: b2, arg: y },
                ) => d1 == d2 && b1 == b2 && go(x, y, env),
                (Expr::Flatten { d: d1, arg: x }, Expr::Flatten { d: d2, arg: y }) => {
                    d1 == d2 && go(x, y, env)
                }
                (
                    Expr::Flip { d1: a1, d2: b1, arg: x },
                    Expr::Flip { d1: a2, d2: b2, arg: y },
                ) => a1 == a2 && b1 == b2 && go(x, y, env),
                _ => false,
            }
        }
        go(self, other, &mut Vec::new())
    }

    /// Number of AST nodes (used by rewrite strategies and tests).
    pub fn size(&self) -> usize {
        1 + match self {
            Expr::Var(_) | Expr::Lit(_) | Expr::Prim(_) | Expr::Input(_) => 0,
            Expr::Lam { body, .. } => body.size(),
            Expr::App { f, args } => f.size() + args.iter().map(Expr::size).sum::<usize>(),
            Expr::Nzip { f, args } => f.size() + args.iter().map(Expr::size).sum::<usize>(),
            Expr::Rnz { r, m, args } => {
                r.size() + m.size() + args.iter().map(Expr::size).sum::<usize>()
            }
            Expr::Lift { f } => f.size(),
            Expr::Subdiv { arg, .. } | Expr::Flatten { arg, .. } | Expr::Flip { arg, .. } => {
                arg.size()
            }
        }
    }

    /// Names of all `Input`s referenced by the expression.
    pub fn inputs(&self) -> Vec<String> {
        fn go(e: &Expr, out: &mut Vec<String>) {
            match e {
                Expr::Input(n) => {
                    if !out.contains(n) {
                        out.push(n.clone());
                    }
                }
                Expr::Var(_) | Expr::Lit(_) | Expr::Prim(_) => {}
                Expr::Lam { body, .. } => go(body, out),
                Expr::App { f, args } | Expr::Nzip { f, args } => {
                    go(f, out);
                    args.iter().for_each(|a| go(a, out));
                }
                Expr::Rnz { r, m, args } => {
                    go(r, out);
                    go(m, out);
                    args.iter().for_each(|a| go(a, out));
                }
                Expr::Lift { f } => go(f, out),
                Expr::Subdiv { arg, .. } | Expr::Flatten { arg, .. } | Expr::Flip { arg, .. } => {
                    go(arg, out)
                }
            }
        }
        let mut out = Vec::new();
        go(self, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::builder::*;

    #[test]
    fn prim_arity_and_apply() {
        assert_eq!(Prim::Add.arity(), 2);
        assert_eq!(Prim::Neg.arity(), 1);
        assert_eq!(Prim::Add.apply(&[2.0, 3.0]), 5.0);
        assert_eq!(Prim::Mul.apply(&[2.0, 3.0]), 6.0);
        assert_eq!(Prim::Relu.apply(&[-1.0]), 0.0);
        assert_eq!(Prim::Max.apply(&[1.0, 7.0]), 7.0);
    }

    #[test]
    fn free_vars_respects_binding() {
        // \x -> x + y  has free var y only
        let e = lam1("x", app2(add(), var("x"), var("y")));
        let fv = e.free_vars();
        assert!(fv.contains("y"));
        assert!(!fv.contains("x"));
    }

    #[test]
    fn subst_avoids_capture() {
        // (\y -> x + y)[x := y]  must NOT become \y -> y + y
        let e = lam1("y", app2(add(), var("x"), var("y")));
        let s = e.subst("x", &var("y"));
        if let Expr::Lam { params, body } = &s {
            assert_ne!(params[0], "y", "binder must have been renamed");
            // body is y + <renamed>
            if let Expr::App { args, .. } = &**body {
                assert_eq!(args[0], var("y"));
                assert_eq!(args[1], var(&params[0]));
            } else {
                panic!("unexpected body");
            }
        } else {
            panic!("expected lambda");
        }
    }

    #[test]
    fn subst_shadowed_is_noop() {
        let e = lam1("x", var("x"));
        assert_eq!(e.subst("x", &lit(1.0)), e);
    }

    #[test]
    fn alpha_eq_renamed_binders() {
        let a = lam1("x", app2(add(), var("x"), var("c")));
        let b = lam1("z", app2(add(), var("z"), var("c")));
        assert!(a.alpha_eq(&b));
        let c = lam1("z", app2(add(), var("c"), var("z")));
        assert!(!a.alpha_eq(&c));
    }

    #[test]
    fn alpha_eq_distinguishes_free_vars() {
        assert!(var("x").alpha_eq(&var("x")));
        assert!(!var("x").alpha_eq(&var("y")));
    }

    #[test]
    fn inputs_collects_unique_in_order() {
        let e = nzip(
            lam1("r", rnz(add(), mul(), vec![var("r"), input("v")])),
            vec![input("A")],
        );
        // f is visited before the args, so "v" (inside the lambda) comes first
        assert_eq!(e.inputs(), vec!["v".to_string(), "A".to_string()]);
    }

    #[test]
    fn fresh_vars_are_distinct() {
        assert_ne!(fresh_var("a"), fresh_var("a"));
    }

    #[test]
    fn size_counts_nodes() {
        assert_eq!(var("x").size(), 1);
        assert_eq!(app2(add(), var("x"), var("y")).size(), 4);
    }
}
