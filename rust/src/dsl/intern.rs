//! Hash-consed expression arena: structurally-equal subtrees stored once.
//!
//! # Why (paper §3–4)
//!
//! The paper's search enumerates every rearrangement of the HoF spine —
//! all permutations reachable by adjacent exchanges, each paired with
//! layout `flip`s — and every candidate is normalized and typechecked
//! before ranking. The subdivided reductions of §4 (Table 2) multiply the
//! variant count, and the variants share almost all of their subtrees:
//! two rearrangements of a subdivided matmul differ only along the spine
//! path that was swapped. With the plain [`Box<Expr>`](crate::dsl::Expr)
//! representation, every normalize / dedup step re-traverses and re-clones
//! those shared subtrees, so an optimize job does
//! O(variants × tree-size) redundant work.
//!
//! Hash-consing fixes the asymptotics at the representation level:
//!
//! - [`ExprArena::intern`] maps a tree to an [`ExprId`] such that two
//!   structurally-equal trees get the *same* id — equality and hashing of
//!   interned expressions are O(1) integer operations;
//! - [`crate::rewrite::MemoRewriter`] keys a rewrite memo table by
//!   `ExprId`, so a shared subtree is normalized once per rule set, no
//!   matter how many variants (or optimize jobs on the same worker
//!   thread) contain it;
//! - [`crate::enumerate::enumerate_all`] uses interned ids to recognise
//!   already-visited candidate expressions without structural comparison.
//!
//! This is the same dedup/memoization move that makes generate-and-rank
//! search tractable in Linnea (Barthels et al.) and in e-graph-based
//! array compilers: the expression *space* is a DAG, so represent it as
//! one.
//!
//! The arena is deliberately a thin layer: the `Box<Expr>` API remains
//! the lingua franca of the parser, interpreter, typechecker and Python
//! side. [`ExprArena::intern`] / [`ExprArena::extract`] convert at the
//! boundary.
//!
//! # Notes
//!
//! - Interning is *structural*, not alpha-equivalence: `λx.x` and `λy.y`
//!   get different ids. That is what the memoized rewriter needs (rules
//!   see concrete names) and what dedup wants (display keys are computed
//!   from labels, not ids).
//! - `f64` literals are stored by bit pattern so nodes are `Eq + Hash`;
//!   `extract` restores the exact bits.

use super::expr::{Expr, Prim};
use std::cell::Cell;
use std::collections::HashMap;

/// Identity of an interned expression. Two `ExprId`s from the same arena
/// are equal iff the expressions are structurally equal.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExprId(u32);

impl ExprId {
    /// Index into the owning arena.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One expression level with interned children — the arena's node type.
/// Mirrors [`Expr`] except that children are [`ExprId`]s and literals are
/// stored by bit pattern (so the node is `Eq + Hash`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Node {
    Var(String),
    /// `f64::to_bits` of the literal.
    Lit(u64),
    Prim(Prim),
    Lam { params: Vec<String>, body: ExprId },
    App { f: ExprId, args: Vec<ExprId> },
    Nzip { f: ExprId, args: Vec<ExprId> },
    Rnz { r: ExprId, m: ExprId, args: Vec<ExprId> },
    Lift { f: ExprId },
    Subdiv { d: usize, b: usize, arg: ExprId },
    Flatten { d: usize, arg: ExprId },
    Flip { d1: usize, d2: usize, arg: ExprId },
    Input(String),
}

impl Node {
    /// Rebuild the node with each child id transformed by `f`.
    pub fn map_children(&self, mut f: impl FnMut(ExprId) -> ExprId) -> Node {
        match self {
            Node::Var(_) | Node::Lit(_) | Node::Prim(_) | Node::Input(_) => self.clone(),
            Node::Lam { params, body } => Node::Lam {
                params: params.clone(),
                body: f(*body),
            },
            Node::App { f: g, args } => Node::App {
                f: f(*g),
                args: args.iter().map(|&a| f(a)).collect(),
            },
            Node::Nzip { f: g, args } => Node::Nzip {
                f: f(*g),
                args: args.iter().map(|&a| f(a)).collect(),
            },
            Node::Rnz { r, m, args } => Node::Rnz {
                r: f(*r),
                m: f(*m),
                args: args.iter().map(|&a| f(a)).collect(),
            },
            Node::Lift { f: g } => Node::Lift { f: f(*g) },
            Node::Subdiv { d, b, arg } => Node::Subdiv {
                d: *d,
                b: *b,
                arg: f(*arg),
            },
            Node::Flatten { d, arg } => Node::Flatten {
                d: *d,
                arg: f(*arg),
            },
            Node::Flip { d1, d2, arg } => Node::Flip {
                d1: *d1,
                d2: *d2,
                arg: f(*arg),
            },
        }
    }
}

/// The hash-consing arena. Structurally-equal subtrees are stored exactly
/// once; [`intern`](ExprArena::intern) of equal trees returns equal ids.
#[derive(Debug, Default)]
pub struct ExprArena {
    nodes: Vec<Node>,
    dedup: HashMap<Node, ExprId>,
}

impl ExprArena {
    pub fn new() -> Self {
        ExprArena::default()
    }

    /// Number of distinct nodes stored.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Intern a node whose children are already interned, returning the
    /// canonical id for it.
    pub fn insert(&mut self, node: Node) -> ExprId {
        if let Some(&id) = self.dedup.get(&node) {
            return id;
        }
        let id = ExprId(self.nodes.len() as u32);
        self.nodes.push(node.clone());
        self.dedup.insert(node, id);
        id
    }

    /// The node behind an id.
    pub fn get(&self, id: ExprId) -> &Node {
        &self.nodes[id.index()]
    }

    /// Intern a whole tree bottom-up.
    pub fn intern(&mut self, e: &Expr) -> ExprId {
        let node = match e {
            Expr::Var(x) => Node::Var(x.clone()),
            Expr::Lit(v) => Node::Lit(v.to_bits()),
            Expr::Prim(p) => Node::Prim(*p),
            Expr::Lam { params, body } => Node::Lam {
                params: params.clone(),
                body: self.intern(body),
            },
            Expr::App { f, args } => Node::App {
                f: self.intern(f),
                args: args.iter().map(|a| self.intern(a)).collect(),
            },
            Expr::Nzip { f, args } => Node::Nzip {
                f: self.intern(f),
                args: args.iter().map(|a| self.intern(a)).collect(),
            },
            Expr::Rnz { r, m, args } => Node::Rnz {
                r: self.intern(r),
                m: self.intern(m),
                args: args.iter().map(|a| self.intern(a)).collect(),
            },
            Expr::Lift { f } => Node::Lift { f: self.intern(f) },
            Expr::Subdiv { d, b, arg } => Node::Subdiv {
                d: *d,
                b: *b,
                arg: self.intern(arg),
            },
            Expr::Flatten { d, arg } => Node::Flatten {
                d: *d,
                arg: self.intern(arg),
            },
            Expr::Flip { d1, d2, arg } => Node::Flip {
                d1: *d1,
                d2: *d2,
                arg: self.intern(arg),
            },
        };
        self.insert(node)
    }

    /// Reconstruct the `Box<Expr>` tree behind an id (the conversion layer
    /// back to the parser/interpreter representation).
    pub fn extract(&self, id: ExprId) -> Expr {
        match self.get(id).clone() {
            Node::Var(x) => Expr::Var(x),
            Node::Lit(bits) => Expr::Lit(f64::from_bits(bits)),
            Node::Prim(p) => Expr::Prim(p),
            Node::Lam { params, body } => Expr::Lam {
                params,
                body: Box::new(self.extract(body)),
            },
            Node::App { f, args } => Expr::App {
                f: Box::new(self.extract(f)),
                args: args.iter().map(|&a| self.extract(a)).collect(),
            },
            Node::Nzip { f, args } => Expr::Nzip {
                f: Box::new(self.extract(f)),
                args: args.iter().map(|&a| self.extract(a)).collect(),
            },
            Node::Rnz { r, m, args } => Expr::Rnz {
                r: Box::new(self.extract(r)),
                m: Box::new(self.extract(m)),
                args: args.iter().map(|&a| self.extract(a)).collect(),
            },
            Node::Lift { f } => Expr::Lift {
                f: Box::new(self.extract(f)),
            },
            Node::Subdiv { d, b, arg } => Expr::Subdiv {
                d,
                b,
                arg: Box::new(self.extract(arg)),
            },
            Node::Flatten { d, arg } => Expr::Flatten {
                d,
                arg: Box::new(self.extract(arg)),
            },
            Node::Flip { d1, d2, arg } => Expr::Flip {
                d1,
                d2,
                arg: Box::new(self.extract(arg)),
            },
        }
    }
}

thread_local! {
    static MEMO_ENABLED: Cell<bool> = const { Cell::new(true) };
}

/// Whether rewrite memoization is enabled on this thread (it is by
/// default). Differential tests disable it to reproduce the unmemoized
/// seed behavior.
pub fn memo_enabled() -> bool {
    MEMO_ENABLED.with(|c| c.get())
}

/// Run `f` with rewrite memoization disabled on this thread — the rewrite
/// engine falls back to the plain (seed) bottom-up strategy. Used by the
/// differential tests that compare the interned and uninterned paths.
pub fn with_memo_disabled<R>(f: impl FnOnce() -> R) -> R {
    let prev = MEMO_ENABLED.with(|c| c.replace(false));
    let out = f();
    MEMO_ENABLED.with(|c| c.set(prev));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::builder::*;
    use crate::dsl::Expr;

    #[test]
    fn intern_is_stable_and_shares() {
        let mut arena = ExprArena::new();
        let e = matmul_naive(input("A"), input("B"));
        let id1 = arena.intern(&e);
        let id2 = arena.intern(&e.clone());
        assert_eq!(id1, id2);
        // Far fewer nodes than two copies of the tree.
        assert!(arena.len() <= e.size());
    }

    #[test]
    fn extract_round_trips() {
        let mut arena = ExprArena::new();
        let e = rnz(
            add(),
            lam2("x", "y", app2(mul(), var("x"), var("y"))),
            vec![subdiv(0, 4, input("u")), flip(0, input("v"))],
        );
        let id = arena.intern(&e);
        assert_eq!(arena.extract(id), e);
    }

    #[test]
    fn literal_bits_round_trip() {
        let mut arena = ExprArena::new();
        for v in [0.0, -0.0, 1.5, -3.25, f64::MIN_POSITIVE] {
            let id = arena.intern(&lit(v));
            let Expr::Lit(back) = arena.extract(id) else {
                panic!("expected literal")
            };
            assert_eq!(back.to_bits(), v.to_bits());
        }
        // -0.0 and 0.0 have distinct bits, hence distinct ids.
        let a = arena.intern(&lit(0.0));
        let b = arena.intern(&lit(-0.0));
        assert_ne!(a, b);
    }

    #[test]
    fn distinct_structure_distinct_ids() {
        let mut arena = ExprArena::new();
        let a = arena.intern(&lam1("x", var("x")));
        let b = arena.intern(&lam1("y", var("y")));
        // Structural interning distinguishes binder names (alpha-variants
        // are distinct on purpose).
        assert_ne!(a, b);
    }

    #[test]
    fn shared_subtrees_stored_once() {
        let mut arena = ExprArena::new();
        let shared = dot(input("u"), input("v"));
        let e = zip(add(), shared.clone(), shared.clone());
        arena.intern(&e);
        // dot + 2 inputs + prim(+)/prim(*) + the zip node ≪ 2 full copies.
        assert!(arena.len() < e.size());
    }

    #[test]
    fn memo_toggle_restores() {
        assert!(memo_enabled());
        let inner = with_memo_disabled(|| {
            assert!(!memo_enabled());
            7
        });
        assert_eq!(inner, 7);
        assert!(memo_enabled());
    }
}
