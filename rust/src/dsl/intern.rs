//! Hash-consed expression arena: structurally-equal subtrees stored once.
//!
//! # Why (paper §3–4)
//!
//! The paper's search enumerates every rearrangement of the HoF spine —
//! all permutations reachable by adjacent exchanges, each paired with
//! layout `flip`s — and every candidate is normalized and typechecked
//! before ranking. The subdivided reductions of §4 (Table 2) multiply the
//! variant count, and the variants share almost all of their subtrees:
//! two rearrangements of a subdivided matmul differ only along the spine
//! path that was swapped. With the plain [`Box<Expr>`](crate::dsl::Expr)
//! representation, every normalize / dedup step re-traverses and re-clones
//! those shared subtrees, so an optimize job does
//! O(variants × tree-size) redundant work.
//!
//! Hash-consing fixes the asymptotics at the representation level:
//!
//! - [`ExprArena::intern`] maps a tree to an [`ExprId`] such that two
//!   structurally-equal trees get the *same* id — equality and hashing of
//!   interned expressions are O(1) integer operations;
//! - [`crate::rewrite::MemoRewriter`] keys a rewrite memo table by
//!   `ExprId`, so a shared subtree is normalized once per rule set, no
//!   matter how many variants (or optimize jobs on the same worker
//!   thread) contain it;
//! - [`crate::enumerate::enumerate_all`] uses interned ids to recognise
//!   already-visited candidate expressions without structural comparison.
//!
//! This is the same dedup/memoization move that makes generate-and-rank
//! search tractable in Linnea (Barthels et al.) and in e-graph-based
//! array compilers: the expression *space* is a DAG, so represent it as
//! one.
//!
//! The arenas are deliberately a thin layer: the `Box<Expr>` API remains
//! the lingua franca of the parser, interpreter, typechecker and Python
//! side. `intern` / `extract` convert at the boundary.
//!
//! # Two arenas
//!
//! - [`ExprArena`] — the original single-threaded arena (`&mut self`
//!   interning, `Cell` counters). It remains the substrate of the
//!   `Box<Expr>`-rule memo path ([`crate::rewrite::MemoRewriter`]) and of
//!   one-off interning jobs that never cross a thread.
//! - [`SharedArena`] — the concurrent, hash-sharded arena (ISSUE 4). The
//!   node space is split across [`SharedArena::SEGMENTS`] lock-striped
//!   segments addressed by node hash; all operations take `&self`, so one
//!   arena can be shared by every BFS shard of a search and frontier
//!   variants cross shard (and level) boundaries as plain [`ExprId`]s —
//!   no extract/re-intern at level boundaries. The whole id-native engine
//!   ([`crate::rewrite::IdRule`] rules, [`crate::typecheck::infer_id`],
//!   [`crate::exec::lower_id`], [`crate::costmodel::estimate_id`]) runs
//!   against it.
//!
//! ## `SharedArena` ownership and id-stability contract
//!
//! - **Ids are arena-scoped.** An [`ExprId`] is only meaningful against
//!   the arena that produced it; the search owns one `SharedArena` per
//!   `enumerate_search` call and every per-shard cache (rewrite memo,
//!   typecheck/score/bound maps) keyed by those ids lives no longer than
//!   the arena. Never persist ids or mix them across arenas.
//! - **Ids are stable across threads.** Interning structurally-equal
//!   trees returns the *same* id no matter which thread interns first —
//!   the segment is chosen by a fixed (per-process-deterministic) node
//!   hash and insertion is double-checked under the segment lock. Once
//!   returned, an id never moves, and [`SharedArena::get`] hands out a
//!   `&Node` that stays valid for the arena's whole lifetime (nodes are
//!   append-only and individually boxed).
//! - **Id *values* are scheduling-dependent.** Which integer a tree gets
//!   depends on global arrival order, so deterministic consumers (the
//!   search's dedup and merge) must never order or key results on raw id
//!   values — they dedup on label tokens and order on (shard, seq) merge
//!   tags instead.
//!
//! # Notes
//!
//! - Interning is *structural*, not alpha-equivalence: `λx.x` and `λy.y`
//!   get different ids. That is what the memoized rewriter needs (rules
//!   see concrete names) and what dedup wants (display keys are computed
//!   from labels, not ids).
//! - `f64` literals are stored by bit pattern so nodes are `Eq + Hash`;
//!   `extract` restores the exact bits.

use super::expr::{fresh_var, Expr, Prim};
use std::cell::Cell;
use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, RwLock};

/// Identity of an interned expression. Two `ExprId`s from the same arena
/// are equal iff the expressions are structurally equal.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExprId(u32);

impl ExprId {
    /// Raw index into an [`ExprArena`]'s node table. Only meaningful for
    /// ids produced by an `ExprArena`; [`SharedArena`] ids pack a
    /// (segment, slot) pair into the same word and are opaque — resolve
    /// them through [`SharedArena::get`].
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One expression level with interned children — the arena's node type.
/// Mirrors [`Expr`] except that children are [`ExprId`]s and literals are
/// stored by bit pattern (so the node is `Eq + Hash`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Node {
    Var(String),
    /// `f64::to_bits` of the literal.
    Lit(u64),
    Prim(Prim),
    Lam { params: Vec<String>, body: ExprId },
    App { f: ExprId, args: Vec<ExprId> },
    Nzip { f: ExprId, args: Vec<ExprId> },
    Rnz { r: ExprId, m: ExprId, args: Vec<ExprId> },
    Lift { f: ExprId },
    Subdiv { d: usize, b: usize, arg: ExprId },
    Flatten { d: usize, arg: ExprId },
    Flip { d1: usize, d2: usize, arg: ExprId },
    Input(String),
}

impl Node {
    /// Short kind name for diagnostics (`"lambda"`, `"nzip"`, …).
    /// Deliberately shallow: error paths that run per candidate on the
    /// search hot path (id-native typecheck, lowering) must not
    /// pretty-print, which would extract a `Box<Expr>` subtree.
    pub fn kind(&self) -> &'static str {
        match self {
            Node::Var(_) => "variable",
            Node::Lit(_) => "literal",
            Node::Prim(_) => "primitive",
            Node::Lam { .. } => "lambda",
            Node::App { .. } => "application",
            Node::Nzip { .. } => "nzip",
            Node::Rnz { .. } => "rnz",
            Node::Lift { .. } => "lift",
            Node::Subdiv { .. } => "subdiv",
            Node::Flatten { .. } => "flatten",
            Node::Flip { .. } => "flip",
            Node::Input(_) => "input",
        }
    }

    /// Rebuild the node with each child id transformed by `f`.
    pub fn map_children(&self, mut f: impl FnMut(ExprId) -> ExprId) -> Node {
        match self {
            Node::Var(_) | Node::Lit(_) | Node::Prim(_) | Node::Input(_) => self.clone(),
            Node::Lam { params, body } => Node::Lam {
                params: params.clone(),
                body: f(*body),
            },
            Node::App { f: g, args } => Node::App {
                f: f(*g),
                args: args.iter().map(|&a| f(a)).collect(),
            },
            Node::Nzip { f: g, args } => Node::Nzip {
                f: f(*g),
                args: args.iter().map(|&a| f(a)).collect(),
            },
            Node::Rnz { r, m, args } => Node::Rnz {
                r: f(*r),
                m: f(*m),
                args: args.iter().map(|&a| f(a)).collect(),
            },
            Node::Lift { f: g } => Node::Lift { f: f(*g) },
            Node::Subdiv { d, b, arg } => Node::Subdiv {
                d: *d,
                b: *b,
                arg: f(*arg),
            },
            Node::Flatten { d, arg } => Node::Flatten {
                d: *d,
                arg: f(*arg),
            },
            Node::Flip { d1, d2, arg } => Node::Flip {
                d1: *d1,
                d2: *d2,
                arg: f(*arg),
            },
        }
    }
}

/// The hash-consing arena. Structurally-equal subtrees are stored exactly
/// once; [`intern`](ExprArena::intern) of equal trees returns equal ids.
#[derive(Debug, Default)]
pub struct ExprArena {
    nodes: Vec<Node>,
    dedup: HashMap<Node, ExprId>,
    /// How many times [`extract`](ExprArena::extract) rebuilt a
    /// `Box<Expr>` tree from this arena (root calls, not per node). The
    /// search surfaces this through `SearchStats` so "no extraction on the
    /// per-candidate hot path" is observable, not just asserted in tests.
    extractions: Cell<u64>,
}

impl ExprArena {
    pub fn new() -> Self {
        ExprArena::default()
    }

    /// Number of distinct nodes stored.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Intern a node whose children are already interned, returning the
    /// canonical id for it.
    pub fn insert(&mut self, node: Node) -> ExprId {
        if let Some(&id) = self.dedup.get(&node) {
            return id;
        }
        let id = ExprId(self.nodes.len() as u32);
        self.nodes.push(node.clone());
        self.dedup.insert(node, id);
        id
    }

    /// The node behind an id.
    pub fn get(&self, id: ExprId) -> &Node {
        &self.nodes[id.index()]
    }

    /// Intern a whole tree bottom-up.
    pub fn intern(&mut self, e: &Expr) -> ExprId {
        let node = match e {
            Expr::Var(x) => Node::Var(x.clone()),
            Expr::Lit(v) => Node::Lit(v.to_bits()),
            Expr::Prim(p) => Node::Prim(*p),
            Expr::Lam { params, body } => Node::Lam {
                params: params.clone(),
                body: self.intern(body),
            },
            Expr::App { f, args } => Node::App {
                f: self.intern(f),
                args: args.iter().map(|a| self.intern(a)).collect(),
            },
            Expr::Nzip { f, args } => Node::Nzip {
                f: self.intern(f),
                args: args.iter().map(|a| self.intern(a)).collect(),
            },
            Expr::Rnz { r, m, args } => Node::Rnz {
                r: self.intern(r),
                m: self.intern(m),
                args: args.iter().map(|a| self.intern(a)).collect(),
            },
            Expr::Lift { f } => Node::Lift { f: self.intern(f) },
            Expr::Subdiv { d, b, arg } => Node::Subdiv {
                d: *d,
                b: *b,
                arg: self.intern(arg),
            },
            Expr::Flatten { d, arg } => Node::Flatten {
                d: *d,
                arg: self.intern(arg),
            },
            Expr::Flip { d1, d2, arg } => Node::Flip {
                d1: *d1,
                d2: *d2,
                arg: self.intern(arg),
            },
            Expr::Input(n) => Node::Input(n.clone()),
        };
        self.insert(node)
    }

    /// Reconstruct the `Box<Expr>` tree behind an id (the conversion layer
    /// back to the parser/interpreter representation). Counted: see
    /// [`extractions`](ExprArena::extractions).
    pub fn extract(&self, id: ExprId) -> Expr {
        self.extractions.set(self.extractions.get() + 1);
        self.extract_tree(id)
    }

    /// Number of [`extract`](ExprArena::extract) calls made against this
    /// arena so far — the count of `Box<Expr>` trees rebuilt from it.
    pub fn extractions(&self) -> u64 {
        self.extractions.get()
    }

    fn extract_tree(&self, id: ExprId) -> Expr {
        match self.get(id).clone() {
            Node::Var(x) => Expr::Var(x),
            Node::Lit(bits) => Expr::Lit(f64::from_bits(bits)),
            Node::Prim(p) => Expr::Prim(p),
            Node::Lam { params, body } => Expr::Lam {
                params,
                body: Box::new(self.extract_tree(body)),
            },
            Node::App { f, args } => Expr::App {
                f: Box::new(self.extract_tree(f)),
                args: args.iter().map(|&a| self.extract_tree(a)).collect(),
            },
            Node::Nzip { f, args } => Expr::Nzip {
                f: Box::new(self.extract_tree(f)),
                args: args.iter().map(|&a| self.extract_tree(a)).collect(),
            },
            Node::Rnz { r, m, args } => Expr::Rnz {
                r: Box::new(self.extract_tree(r)),
                m: Box::new(self.extract_tree(m)),
                args: args.iter().map(|&a| self.extract_tree(a)).collect(),
            },
            Node::Lift { f } => Expr::Lift {
                f: Box::new(self.extract_tree(f)),
            },
            Node::Subdiv { d, b, arg } => Expr::Subdiv {
                d,
                b,
                arg: Box::new(self.extract_tree(arg)),
            },
            Node::Flatten { d, arg } => Expr::Flatten {
                d,
                arg: Box::new(self.extract_tree(arg)),
            },
            Node::Flip { d1, d2, arg } => Expr::Flip {
                d1,
                d2,
                arg: Box::new(self.extract_tree(arg)),
            },
            Node::Input(n) => Expr::Input(n),
        }
    }

    /// Alpha-invariant structural hash of the expression behind `id`.
    ///
    /// Bound variables hash by their de Bruijn index (distance to the
    /// innermost enclosing binder), so binder *names* do not contribute:
    /// `λx.x` and `λy.y` hash identically while `λx.λy.x` and `λx.λy.y`
    /// stay distinct. Free variables and [`Node::Input`]s hash by name
    /// (they are the kernel's interface), literals by bit pattern. The
    /// hasher is [`DefaultHasher`] with its fixed default keys — the same
    /// per-process-deterministic choice the segment hash relies on.
    ///
    /// This is the source half of the coordinator's canonical cache key
    /// (ISSUE 8): α-equivalent and reformatted sources of the same kernel
    /// collapse to one entry. Note the contrast with [`intern`]
    /// (structural, name-sensitive — what the rewriter needs): the
    /// canonical hash is a *view* for keying, not a change to interning.
    ///
    /// [`intern`]: ExprArena::intern
    pub fn canonical_hash_id(&self, id: ExprId) -> u64 {
        let mut h = DefaultHasher::new();
        self.canonical_hash_rec(id, &mut Vec::new(), &mut h);
        h.finish()
    }

    fn canonical_hash_rec<'a>(
        &'a self,
        id: ExprId,
        bound: &mut Vec<&'a str>,
        h: &mut DefaultHasher,
    ) {
        match self.get(id) {
            Node::Var(x) => {
                // rposition: innermost binding wins under shadowing.
                if let Some(pos) = bound.iter().rposition(|b| *b == x) {
                    0u8.hash(h);
                    ((bound.len() - 1 - pos) as u64).hash(h);
                } else {
                    1u8.hash(h);
                    x.hash(h);
                }
            }
            Node::Lit(bits) => {
                2u8.hash(h);
                bits.hash(h);
            }
            Node::Prim(p) => {
                3u8.hash(h);
                p.hash(h);
            }
            Node::Lam { params, body } => {
                4u8.hash(h);
                params.len().hash(h);
                for p in params {
                    bound.push(p);
                }
                self.canonical_hash_rec(*body, bound, h);
                bound.truncate(bound.len() - params.len());
            }
            Node::App { f, args } => {
                5u8.hash(h);
                self.canonical_hash_rec(*f, bound, h);
                args.len().hash(h);
                for &a in args {
                    self.canonical_hash_rec(a, bound, h);
                }
            }
            Node::Nzip { f, args } => {
                6u8.hash(h);
                self.canonical_hash_rec(*f, bound, h);
                args.len().hash(h);
                for &a in args {
                    self.canonical_hash_rec(a, bound, h);
                }
            }
            Node::Rnz { r, m, args } => {
                7u8.hash(h);
                self.canonical_hash_rec(*r, bound, h);
                self.canonical_hash_rec(*m, bound, h);
                args.len().hash(h);
                for &a in args {
                    self.canonical_hash_rec(a, bound, h);
                }
            }
            Node::Lift { f } => {
                8u8.hash(h);
                self.canonical_hash_rec(*f, bound, h);
            }
            Node::Subdiv { d, b, arg } => {
                9u8.hash(h);
                d.hash(h);
                b.hash(h);
                self.canonical_hash_rec(*arg, bound, h);
            }
            Node::Flatten { d, arg } => {
                10u8.hash(h);
                d.hash(h);
                self.canonical_hash_rec(*arg, bound, h);
            }
            Node::Flip { d1, d2, arg } => {
                11u8.hash(h);
                d1.hash(h);
                d2.hash(h);
                self.canonical_hash_rec(*arg, bound, h);
            }
            Node::Input(n) => {
                12u8.hash(h);
                n.hash(h);
            }
        }
    }
}

/// Alpha-invariant hash of a `Box<Expr>` tree — convenience wrapper that
/// interns into a throwaway [`ExprArena`] and delegates to
/// [`ExprArena::canonical_hash_id`]. Equal for α-equivalent trees,
/// regardless of the source formatting they were parsed from.
pub fn canonical_hash(e: &Expr) -> u64 {
    let mut arena = ExprArena::new();
    let id = arena.intern(e);
    arena.canonical_hash_id(id)
}

/// log2 of [`SharedArena::SEGMENTS`]: the low `SEG_BITS` of an id select
/// the segment, the high bits are the index within it.
const SEG_BITS: u32 = 4;

/// Debug builds stamp every [`SharedArena`] id with the arena's reset
/// epoch in the top `EPOCH_BITS` of the word, so an id that outlives a
/// [`SharedArena::reset`] (arena-pool reuse, ISSUE 8) fails closed with a
/// clear panic instead of silently resolving to an unrelated node. The
/// epoch wraps modulo `2^EPOCH_BITS`; the guard is a debug tripwire, not
/// a cryptographic fence. Release ids carry no epoch — their values are
/// identical to the pre-pooling scheme.
#[cfg(debug_assertions)]
const EPOCH_BITS: u32 = 6;
#[cfg(debug_assertions)]
const EPOCH_MASK: u32 = (1 << EPOCH_BITS) - 1;

/// Bits available for the within-segment slot index.
#[cfg(debug_assertions)]
const LOCAL_BITS: u32 = 32 - SEG_BITS - EPOCH_BITS;
#[cfg(not(debug_assertions))]
const LOCAL_BITS: u32 = 32 - SEG_BITS;

/// One lock stripe of a [`SharedArena`]: the dedup map plus the node
/// storage for every node whose hash lands here.
///
/// Nodes are individually boxed (`Vec<Box<Node>>`, hence the lint allow)
/// on purpose: pushing to the vector moves the *boxes*, never the nodes
/// themselves, which is what lets [`SharedArena::get`] hand out `&Node`
/// references that outlive the segment lock.
#[allow(clippy::vec_box)]
#[derive(Default)]
struct Segment {
    nodes: Vec<Box<Node>>,
    dedup: HashMap<Node, u32>,
}

/// The concurrent hash-consing arena (ISSUE 4): [`SharedArena::SEGMENTS`]
/// interior lock-striped segments addressed by node hash, with global
/// [`ExprId`]s that are stable across threads. All operations take
/// `&self`, so one arena is shared by every BFS shard of a search —
/// frontier variants cross shard and level boundaries as plain ids
/// instead of extracted `Box<Expr>` trees.
///
/// See the [module docs](self) for the ownership and id-stability
/// contract. Functionally this is [`ExprArena`] plus thread safety; the
/// differential tests hold the two engines built on them equivalent.
pub struct SharedArena {
    segments: Vec<RwLock<Segment>>,
    /// Total distinct nodes across segments (kept separately so `len`
    /// does not sweep every stripe).
    len: AtomicUsize,
    /// Root [`extract`](SharedArena::extract) calls, as on [`ExprArena`].
    extractions: AtomicU64,
    /// How many times this arena has been [`reset`](SharedArena::reset)
    /// (arena-pool reuse). Debug builds stamp it into every issued id so
    /// stale ids from a previous job fail closed.
    epoch: u32,
}

impl Default for SharedArena {
    fn default() -> Self {
        SharedArena::new()
    }
}

impl SharedArena {
    /// Number of lock stripes. A fixed power of two: enough that 8-way
    /// shard fan-out rarely contends on one stripe, small enough that an
    /// empty arena stays cheap to build per search.
    pub const SEGMENTS: usize = 1 << SEG_BITS;

    pub fn new() -> Self {
        SharedArena {
            segments: (0..Self::SEGMENTS).map(|_| RwLock::default()).collect(),
            len: AtomicUsize::new(0),
            extractions: AtomicU64::new(0),
            epoch: 0,
        }
    }

    /// Reset epoch: 0 for a fresh arena, bumped by every
    /// [`reset`](SharedArena::reset). Debug-build ids are stamped with it
    /// (modulo `2^EPOCH_BITS`); release ids are epoch-free.
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Clear every node while keeping segment and dedup-map capacity, and
    /// advance the reset epoch — the arena-pool reuse primitive (ISSUE 8):
    /// a pooled arena is reset on acquire so a warm job pays neither
    /// segment construction nor map rehash growth from zero.
    ///
    /// Taking `&mut self` is what makes dropping nodes sound against
    /// [`get`](SharedArena::get)'s long-lived `&Node` references: those
    /// borrows are tied to `&self`, so the borrow checker only grants the
    /// `&mut` once none are alive. Ids from before the reset are invalid;
    /// debug builds trip a "stale ExprId" panic on use (epoch stamp),
    /// release builds must rely on the pool discipline (one job per
    /// checkout, ids never escape the job — the existing arena-scoped id
    /// contract in the module docs).
    pub fn reset(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        for seg in &mut self.segments {
            let st = seg.get_mut().unwrap_or_else(|e| e.into_inner());
            st.nodes.clear();
            st.dedup.clear();
        }
        *self.len.get_mut() = 0;
        *self.extractions.get_mut() = 0;
    }

    /// Number of distinct nodes stored (across all segments).
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Which segment a node lives in: a fixed, per-process-deterministic
    /// hash — the same node hashes to the same stripe from every thread,
    /// which is what makes ids agree across threads.
    fn segment_of(node: &Node) -> usize {
        let mut h = DefaultHasher::new();
        node.hash(&mut h);
        (h.finish() as usize) & (Self::SEGMENTS - 1)
    }

    fn pack(&self, seg: usize, local: u32) -> ExprId {
        let raw = (local << SEG_BITS) | seg as u32;
        #[cfg(debug_assertions)]
        let raw = raw | ((self.epoch & EPOCH_MASK) << (32 - EPOCH_BITS));
        ExprId(raw)
    }

    fn unpack(&self, id: ExprId) -> (usize, usize) {
        let raw = id.0;
        #[cfg(debug_assertions)]
        let raw = {
            let tag = raw >> (32 - EPOCH_BITS);
            assert_eq!(
                tag,
                self.epoch & EPOCH_MASK,
                "stale ExprId: id carries epoch {tag} but the arena is at epoch {} — \
                 ids must not outlive a SharedArena::reset (arena-pool reuse)",
                self.epoch & EPOCH_MASK,
            );
            raw & !(EPOCH_MASK << (32 - EPOCH_BITS))
        };
        ((raw as usize) & (Self::SEGMENTS - 1), (raw >> SEG_BITS) as usize)
    }

    /// A segment read guard; lock poisoning is recovered rather than
    /// propagated — inserts keep `nodes`/`dedup` consistent at every
    /// await-free step, so a panicked peer cannot leave torn state.
    fn read(&self, seg: usize) -> std::sync::RwLockReadGuard<'_, Segment> {
        self.segments[seg].read().unwrap_or_else(|e| e.into_inner())
    }

    /// Intern a node whose children are already interned, returning the
    /// canonical id for it. Double-checked under the segment lock: the
    /// common case (already present) takes only the read lock.
    pub fn insert(&self, node: Node) -> ExprId {
        let seg = Self::segment_of(&node);
        if let Some(&local) = self.read(seg).dedup.get(&node) {
            return self.pack(seg, local);
        }
        let mut st = self.segments[seg].write().unwrap_or_else(|e| e.into_inner());
        if let Some(&local) = st.dedup.get(&node) {
            return self.pack(seg, local);
        }
        let local = st.nodes.len() as u32;
        assert!(local < 1 << LOCAL_BITS, "SharedArena segment {seg} overflow");
        st.nodes.push(Box::new(node.clone()));
        st.dedup.insert(node, local);
        self.len.fetch_add(1, Ordering::Relaxed);
        self.pack(seg, local)
    }

    /// The node behind an id. The reference stays valid for the arena's
    /// whole lifetime even while other threads intern concurrently.
    pub fn get(&self, id: ExprId) -> &Node {
        let (seg, local) = self.unpack(id);
        let st = self.read(seg);
        let ptr: *const Node = &*st.nodes[local];
        drop(st);
        // SAFETY: nodes are individually boxed and, under `&self` access,
        // append-only — a node is never moved, mutated, or dropped after
        // insertion, so the heap allocation behind `ptr` lives as long as
        // this shared borrow of `self`. Concurrent pushes may reallocate
        // the `Vec` of boxes, but that moves the boxes, not the nodes
        // they point to. The only operation that does drop nodes is
        // `reset`, and it takes `&mut self`, which the borrow checker
        // grants only once every `&Node` returned here (tied to `&self`)
        // is dead.
        unsafe { &*ptr }
    }

    /// Intern a whole tree bottom-up (the thread-safe twin of
    /// [`ExprArena::intern`]): structurally-equal trees get the same id
    /// no matter which thread interns them, or in which order.
    pub fn intern(&self, e: &Expr) -> ExprId {
        let node = match e {
            Expr::Var(x) => Node::Var(x.clone()),
            Expr::Lit(v) => Node::Lit(v.to_bits()),
            Expr::Prim(p) => Node::Prim(*p),
            Expr::Lam { params, body } => Node::Lam {
                params: params.clone(),
                body: self.intern(body),
            },
            Expr::App { f, args } => Node::App {
                f: self.intern(f),
                args: args.iter().map(|a| self.intern(a)).collect(),
            },
            Expr::Nzip { f, args } => Node::Nzip {
                f: self.intern(f),
                args: args.iter().map(|a| self.intern(a)).collect(),
            },
            Expr::Rnz { r, m, args } => Node::Rnz {
                r: self.intern(r),
                m: self.intern(m),
                args: args.iter().map(|a| self.intern(a)).collect(),
            },
            Expr::Lift { f } => Node::Lift { f: self.intern(f) },
            Expr::Subdiv { d, b, arg } => Node::Subdiv {
                d: *d,
                b: *b,
                arg: self.intern(arg),
            },
            Expr::Flatten { d, arg } => Node::Flatten {
                d: *d,
                arg: self.intern(arg),
            },
            Expr::Flip { d1, d2, arg } => Node::Flip {
                d1: *d1,
                d2: *d2,
                arg: self.intern(arg),
            },
            Expr::Input(n) => Node::Input(n.clone()),
        };
        self.insert(node)
    }

    /// Free variables of the expression behind `id` (shadow-aware), the
    /// arena twin of [`Expr::free_vars`]. Used by the id-native rewrite
    /// rules so pattern guards never have to extract a `Box<Expr>` tree.
    pub fn free_vars_id(&self, id: ExprId) -> HashSet<String> {
        let mut out = HashSet::new();
        self.collect_free(id, &mut Vec::new(), &mut out);
        out
    }

    fn collect_free(&self, id: ExprId, bound: &mut Vec<String>, out: &mut HashSet<String>) {
        match self.get(id) {
            Node::Var(x) => {
                if !bound.iter().any(|b| b == x) {
                    out.insert(x.clone());
                }
            }
            Node::Lit(_) | Node::Prim(_) | Node::Input(_) => {}
            Node::Lam { params, body } => {
                let n = params.len();
                bound.extend(params.iter().cloned());
                self.collect_free(*body, bound, out);
                bound.truncate(bound.len() - n);
            }
            Node::App { f, args } | Node::Nzip { f, args } => {
                self.collect_free(*f, bound, out);
                for &a in args {
                    self.collect_free(a, bound, out);
                }
            }
            Node::Rnz { r, m, args } => {
                self.collect_free(*r, bound, out);
                self.collect_free(*m, bound, out);
                for &a in args {
                    self.collect_free(a, bound, out);
                }
            }
            Node::Lift { f } => self.collect_free(*f, bound, out),
            Node::Subdiv { arg, .. } | Node::Flatten { arg, .. } | Node::Flip { arg, .. } => {
                self.collect_free(*arg, bound, out)
            }
        }
    }

    /// `true` iff `x` occurs free in the expression behind `id` — the
    /// cheap membership query the rule guards use (no set allocation).
    pub fn contains_free(&self, id: ExprId, x: &str) -> bool {
        match self.get(id) {
            Node::Var(v) => v == x,
            Node::Lit(_) | Node::Prim(_) | Node::Input(_) => false,
            Node::Lam { params, body } => {
                !params.iter().any(|p| p == x) && self.contains_free(*body, x)
            }
            Node::App { f, args } | Node::Nzip { f, args } => {
                self.contains_free(*f, x) || args.iter().any(|&a| self.contains_free(a, x))
            }
            Node::Rnz { r, m, args } => {
                self.contains_free(*r, x)
                    || self.contains_free(*m, x)
                    || args.iter().any(|&a| self.contains_free(a, x))
            }
            Node::Lift { f } => self.contains_free(*f, x),
            Node::Subdiv { arg, .. } | Node::Flatten { arg, .. } | Node::Flip { arg, .. } => {
                self.contains_free(*arg, x)
            }
        }
    }

    /// Capture-avoiding substitution `id[x := val]` performed entirely in
    /// the arena — the id-native twin of [`Expr::subst`]. Shared subtrees
    /// that do not mention `x` come back as the *same* id, so the result
    /// stays maximally shared.
    pub fn subst_id(&self, id: ExprId, x: &str, val: ExprId) -> ExprId {
        match self.get(id).clone() {
            Node::Var(ref y) => {
                if y == x {
                    val
                } else {
                    id
                }
            }
            Node::Lit(_) | Node::Prim(_) | Node::Input(_) => id,
            Node::Lam { params, body } => {
                if params.iter().any(|p| p == x) {
                    // x is shadowed; nothing to do below.
                    return id;
                }
                let val_free = self.free_vars_id(val);
                if params.iter().any(|p| val_free.contains(p)) {
                    // Rename clashing binders to fresh names first.
                    let mut new_params = Vec::with_capacity(params.len());
                    let mut new_body = body;
                    for p in &params {
                        if val_free.contains(p) {
                            let np = fresh_var(p.split('%').next().unwrap_or(p));
                            let npv = self.insert(Node::Var(np.clone()));
                            new_body = self.subst_id(new_body, p, npv);
                            new_params.push(np);
                        } else {
                            new_params.push(p.clone());
                        }
                    }
                    let nb = self.subst_id(new_body, x, val);
                    self.insert(Node::Lam {
                        params: new_params,
                        body: nb,
                    })
                } else {
                    let nb = self.subst_id(body, x, val);
                    self.insert(Node::Lam { params, body: nb })
                }
            }
            other => {
                // Lam is handled above, so map_children never sees a binder.
                let rebuilt = other.map_children(|c| self.subst_id(c, x, val));
                self.insert(rebuilt)
            }
        }
    }

    /// Reconstruct the `Box<Expr>` tree behind an id. Counted (root
    /// calls, atomically): the search surfaces the counter through
    /// `SearchStats` so "extraction happens at the output boundary only,
    /// never at BFS level boundaries" stays observable.
    pub fn extract(&self, id: ExprId) -> Expr {
        self.extractions.fetch_add(1, Ordering::Relaxed);
        self.extract_tree(id)
    }

    /// Number of [`extract`](SharedArena::extract) root calls made
    /// against this arena so far, across all threads.
    pub fn extractions(&self) -> u64 {
        self.extractions.load(Ordering::Relaxed)
    }

    fn extract_tree(&self, id: ExprId) -> Expr {
        match self.get(id).clone() {
            Node::Var(x) => Expr::Var(x),
            Node::Lit(bits) => Expr::Lit(f64::from_bits(bits)),
            Node::Prim(p) => Expr::Prim(p),
            Node::Lam { params, body } => Expr::Lam {
                params,
                body: Box::new(self.extract_tree(body)),
            },
            Node::App { f, args } => Expr::App {
                f: Box::new(self.extract_tree(f)),
                args: args.iter().map(|&a| self.extract_tree(a)).collect(),
            },
            Node::Nzip { f, args } => Expr::Nzip {
                f: Box::new(self.extract_tree(f)),
                args: args.iter().map(|&a| self.extract_tree(a)).collect(),
            },
            Node::Rnz { r, m, args } => Expr::Rnz {
                r: Box::new(self.extract_tree(r)),
                m: Box::new(self.extract_tree(m)),
                args: args.iter().map(|&a| self.extract_tree(a)).collect(),
            },
            Node::Lift { f } => Expr::Lift {
                f: Box::new(self.extract_tree(f)),
            },
            Node::Subdiv { d, b, arg } => Expr::Subdiv {
                d,
                b,
                arg: Box::new(self.extract_tree(arg)),
            },
            Node::Flatten { d, arg } => Expr::Flatten {
                d,
                arg: Box::new(self.extract_tree(arg)),
            },
            Node::Flip { d1, d2, arg } => Expr::Flip {
                d1,
                d2,
                arg: Box::new(self.extract_tree(arg)),
            },
            Node::Input(n) => Expr::Input(n),
        }
    }
}

impl std::fmt::Debug for SharedArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedArena")
            .field("len", &self.len())
            .field("segments", &Self::SEGMENTS)
            .field("extractions", &self.extractions())
            .field("epoch", &self.epoch())
            .finish()
    }
}

/// Cap on *idle* arenas retained by the process-wide pool. Checked-out
/// arenas are unbounded (one per concurrently-running search); beyond the
/// cap, returned arenas are simply dropped. Sized to the widest worker
/// fan-out the coordinator configures plus bench headroom.
const ARENA_POOL_CAP: usize = 8;

/// Idle arenas waiting for reuse. Plain `Mutex<Vec<_>>`: acquire/release
/// happen once per optimize job, never on the per-candidate hot path.
static ARENA_POOL: Mutex<Vec<SharedArena>> = Mutex::new(Vec::new());
/// Arenas built fresh because the pool was empty.
static POOL_CREATED: AtomicU64 = AtomicU64::new(0);
/// Acquires served by resetting a previously-used arena.
static POOL_REUSED: AtomicU64 = AtomicU64::new(0);
/// Currently checked-out arenas.
static POOL_IN_USE: AtomicU64 = AtomicU64::new(0);
/// Peak of `POOL_IN_USE` — the pool high-water mark surfaced through
/// coordinator metrics.
static POOL_HIGH_WATER: AtomicU64 = AtomicU64::new(0);

/// A [`SharedArena`] checked out of the process-wide pool. Dereferences
/// to the arena; returning it to the pool is the `Drop` impl, so the
/// arena goes back even when the job panics (the coordinator's
/// `catch_unwind` unwinds through the owning search frame).
pub struct PooledArena {
    arena: Option<SharedArena>,
}

impl std::ops::Deref for PooledArena {
    type Target = SharedArena;

    fn deref(&self) -> &SharedArena {
        self.arena.as_ref().expect("PooledArena already returned")
    }
}

impl Drop for PooledArena {
    fn drop(&mut self) {
        let Some(arena) = self.arena.take() else {
            return;
        };
        POOL_IN_USE.fetch_sub(1, Ordering::Relaxed);
        let mut pool = ARENA_POOL.lock().unwrap_or_else(|e| e.into_inner());
        if pool.len() < ARENA_POOL_CAP {
            pool.push(arena);
        }
    }
}

impl std::fmt::Debug for PooledArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.arena {
            Some(a) => f.debug_tuple("PooledArena").field(a).finish(),
            None => f.write_str("PooledArena(returned)"),
        }
    }
}

/// Check an arena out of the process-wide pool (ISSUE 8 arena pooling).
///
/// Reused arenas are [`reset`](SharedArena::reset) *on acquire*, not on
/// release: the reset is paid by the job that benefits from the retained
/// capacity, and a panicking job's `Drop`-path return stays trivially
/// cheap. Every acquire bumps either the created or the reused counter
/// and updates the in-use high-water mark; see [`arena_pool_stats`].
pub fn arena_acquire() -> PooledArena {
    let recycled = ARENA_POOL.lock().unwrap_or_else(|e| e.into_inner()).pop();
    let arena = match recycled {
        Some(mut a) => {
            a.reset();
            POOL_REUSED.fetch_add(1, Ordering::Relaxed);
            a
        }
        None => {
            POOL_CREATED.fetch_add(1, Ordering::Relaxed);
            SharedArena::new()
        }
    };
    let in_use = POOL_IN_USE.fetch_add(1, Ordering::Relaxed) + 1;
    POOL_HIGH_WATER.fetch_max(in_use, Ordering::Relaxed);
    PooledArena { arena: Some(arena) }
}

/// Snapshot of the process-wide arena-pool counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaPoolStats {
    /// Arenas constructed because no idle one was available.
    pub created: u64,
    /// Acquires served by resetting a pooled arena.
    pub reused: u64,
    /// Arenas currently checked out.
    pub in_use: u64,
    /// Peak concurrent checkouts over the process lifetime.
    pub high_water: u64,
    /// Idle arenas currently parked in the pool.
    pub idle: usize,
}

/// Read the pool counters. Monotonic except `in_use`/`idle`; the
/// coordinator folds `high_water` into its metrics after each fresh
/// search so the pool's working set is observable in `serve` output and
/// `BENCH_coordinator.json`.
pub fn arena_pool_stats() -> ArenaPoolStats {
    ArenaPoolStats {
        created: POOL_CREATED.load(Ordering::Relaxed),
        reused: POOL_REUSED.load(Ordering::Relaxed),
        in_use: POOL_IN_USE.load(Ordering::Relaxed),
        high_water: POOL_HIGH_WATER.load(Ordering::Relaxed),
        idle: ARENA_POOL.lock().unwrap_or_else(|e| e.into_inner()).len(),
    }
}

thread_local! {
    static MEMO_ENABLED: Cell<bool> = const { Cell::new(true) };
}

/// Whether rewrite memoization is enabled on this thread (it is by
/// default). Differential tests disable it to reproduce the unmemoized
/// seed behavior.
pub fn memo_enabled() -> bool {
    MEMO_ENABLED.with(|c| c.get())
}

/// Run `f` with rewrite memoization disabled on this thread — the rewrite
/// engine falls back to the plain (seed) bottom-up strategy. Used by the
/// differential tests that compare the interned and uninterned paths.
pub fn with_memo_disabled<R>(f: impl FnOnce() -> R) -> R {
    let prev = MEMO_ENABLED.with(|c| c.replace(false));
    let out = f();
    MEMO_ENABLED.with(|c| c.set(prev));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::builder::*;
    use crate::dsl::Expr;

    #[test]
    fn intern_is_stable_and_shares() {
        let mut arena = ExprArena::new();
        let e = matmul_naive(input("A"), input("B"));
        let id1 = arena.intern(&e);
        let id2 = arena.intern(&e.clone());
        assert_eq!(id1, id2);
        // Far fewer nodes than two copies of the tree.
        assert!(arena.len() <= e.size());
    }

    #[test]
    fn extract_round_trips() {
        let mut arena = ExprArena::new();
        let e = rnz(
            add(),
            lam2("x", "y", app2(mul(), var("x"), var("y"))),
            vec![subdiv(0, 4, input("u")), flip(0, input("v"))],
        );
        let id = arena.intern(&e);
        assert_eq!(arena.extract(id), e);
    }

    #[test]
    fn literal_bits_round_trip() {
        let mut arena = ExprArena::new();
        for v in [0.0, -0.0, 1.5, -3.25, f64::MIN_POSITIVE] {
            let id = arena.intern(&lit(v));
            let Expr::Lit(back) = arena.extract(id) else {
                panic!("expected literal")
            };
            assert_eq!(back.to_bits(), v.to_bits());
        }
        // -0.0 and 0.0 have distinct bits, hence distinct ids.
        let a = arena.intern(&lit(0.0));
        let b = arena.intern(&lit(-0.0));
        assert_ne!(a, b);
    }

    #[test]
    fn distinct_structure_distinct_ids() {
        let mut arena = ExprArena::new();
        let a = arena.intern(&lam1("x", var("x")));
        let b = arena.intern(&lam1("y", var("y")));
        // Structural interning distinguishes binder names (alpha-variants
        // are distinct on purpose).
        assert_ne!(a, b);
    }

    #[test]
    fn shared_subtrees_stored_once() {
        let mut arena = ExprArena::new();
        let shared = dot(input("u"), input("v"));
        let e = zip(add(), shared.clone(), shared.clone());
        arena.intern(&e);
        // dot + 2 inputs + prim(+)/prim(*) + the zip node ≪ 2 full copies.
        assert!(arena.len() < e.size());
    }

    #[test]
    fn free_vars_id_matches_expr_free_vars() {
        let arena = SharedArena::new();
        let e = lam1("x", app2(add(), var("x"), var("y")));
        let id = arena.intern(&e);
        assert_eq!(arena.free_vars_id(id), e.free_vars());
        assert!(arena.contains_free(id, "y"));
        assert!(!arena.contains_free(id, "x"));
    }

    #[test]
    fn subst_id_avoids_capture_like_expr_subst() {
        // (\y -> x + y)[x := y] must rename the binder, exactly as the
        // Box<Expr> substitution does (checked up to alpha).
        let arena = SharedArena::new();
        let e = lam1("y", app2(add(), var("x"), var("y")));
        let id = arena.intern(&e);
        let val = arena.intern(&var("y"));
        let out = arena.subst_id(id, "x", val);
        let expected = e.subst("x", &var("y"));
        assert!(
            arena.extract(out).alpha_eq(&expected),
            "{} vs {}",
            crate::dsl::pretty(&arena.extract(out)),
            crate::dsl::pretty(&expected)
        );
    }

    #[test]
    fn subst_id_shadowed_is_identity() {
        let arena = SharedArena::new();
        let id = arena.intern(&lam1("x", var("x")));
        let val = arena.intern(&lit(1.0));
        assert_eq!(arena.subst_id(id, "x", val), id);
    }

    #[test]
    fn shared_arena_intern_is_stable_and_shares() {
        let arena = SharedArena::new();
        let e = matmul_naive(input("A"), input("B"));
        let id1 = arena.intern(&e);
        let id2 = arena.intern(&e.clone());
        assert_eq!(id1, id2);
        assert!(arena.len() <= e.size());
        assert_eq!(arena.extract(id1), e);
    }

    #[test]
    fn shared_arena_matches_expr_arena_semantics() {
        // Same dedup behavior as the single-threaded arena: equal trees
        // collapse, distinct structures stay distinct, literals keep bits.
        let shared = SharedArena::new();
        let a = shared.intern(&lam1("x", var("x")));
        let b = shared.intern(&lam1("y", var("y")));
        assert_ne!(a, b);
        let z1 = shared.intern(&lit(0.0));
        let z2 = shared.intern(&lit(-0.0));
        assert_ne!(z1, z2);
        let Expr::Lit(back) = shared.extract(z2) else {
            panic!("expected literal")
        };
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn shared_arena_extraction_counter_counts_root_calls() {
        let arena = SharedArena::new();
        let e = matmul_naive(input("A"), input("B"));
        let id = arena.intern(&e);
        assert_eq!(arena.extractions(), 0, "interning must not extract");
        let _ = arena.extract(id);
        assert_eq!(arena.extractions(), 1, "one root call, not one per node");
        let _ = arena.extract(id);
        assert_eq!(arena.extractions(), 2);
    }

    #[test]
    fn shared_arena_ids_agree_across_threads() {
        // The id-stability contract: structurally-equal trees intern to
        // the same id no matter which thread gets there first.
        let arena = SharedArena::new();
        let exprs = [
            matmul_naive(input("A"), input("B")),
            dot(input("u"), input("v")),
            lam1("x", app2(add(), var("x"), lit(1.0))),
        ];
        let ids: Vec<Vec<ExprId>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    let arena = &arena;
                    let exprs = &exprs;
                    s.spawn(move || {
                        // Rotate the order per thread so insertions race.
                        (0..exprs.len())
                            .map(|j| {
                                let i = (j + t) % exprs.len();
                                (i, arena.intern(&exprs[i]))
                            })
                            .fold(vec![ExprId(0); exprs.len()], |mut acc, (i, id)| {
                                acc[i] = id;
                                acc
                            })
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let reference: Vec<ExprId> = exprs.iter().map(|e| arena.intern(e)).collect();
        for (t, thread_ids) in ids.iter().enumerate() {
            assert_eq!(thread_ids, &reference, "thread {t} saw different ids");
        }
    }

    #[test]
    fn extraction_counter_counts_root_calls() {
        let mut arena = ExprArena::new();
        let e = matmul_naive(input("A"), input("B"));
        let id = arena.intern(&e);
        assert_eq!(arena.extractions(), 0, "interning must not extract");
        let _ = arena.extract(id);
        assert_eq!(arena.extractions(), 1, "one root call, not one per node");
        let _ = arena.extract(id);
        assert_eq!(arena.extractions(), 2);
    }

    #[test]
    fn canonical_hash_is_alpha_invariant() {
        // Binder names don't contribute…
        assert_eq!(
            canonical_hash(&lam1("x", var("x"))),
            canonical_hash(&lam1("y", var("y")))
        );
        assert_eq!(
            canonical_hash(&lam2("x", "y", app2(add(), var("x"), var("y")))),
            canonical_hash(&lam2("a", "b", app2(add(), var("a"), var("b"))))
        );
        // …but binding *structure* does.
        assert_ne!(
            canonical_hash(&lam2("x", "y", var("x"))),
            canonical_hash(&lam2("x", "y", var("y")))
        );
        // Free variables and inputs hash by name (kernel interface).
        assert_ne!(canonical_hash(&var("x")), canonical_hash(&var("y")));
        assert_ne!(canonical_hash(&input("A")), canonical_hash(&input("B")));
        // Shadowing resolves to the innermost binder.
        assert_eq!(
            canonical_hash(&lam1("x", lam1("x", var("x")))),
            canonical_hash(&lam1("x", lam1("y", var("y"))))
        );
        assert_ne!(
            canonical_hash(&lam1("x", lam1("y", var("x")))),
            canonical_hash(&lam1("x", lam1("y", var("y"))))
        );
    }

    #[test]
    fn canonical_hash_id_matches_free_fn_and_intern_stays_structural() {
        let e = matmul_naive(input("A"), input("B"));
        let mut arena = ExprArena::new();
        let id = arena.intern(&e);
        assert_eq!(arena.canonical_hash_id(id), canonical_hash(&e));
        // The canonical hash is a view: α-variants still intern to
        // *distinct* ids (the rewriter contract is untouched).
        let a = arena.intern(&lam1("x", var("x")));
        let b = arena.intern(&lam1("y", var("y")));
        assert_ne!(a, b);
        assert_eq!(arena.canonical_hash_id(a), arena.canonical_hash_id(b));
    }

    #[test]
    fn reset_clears_nodes_counters_and_bumps_epoch() {
        let mut arena = SharedArena::new();
        let e = matmul_naive(input("A"), input("B"));
        let id = arena.intern(&e);
        let _ = arena.extract(id);
        assert!(!arena.is_empty());
        assert_eq!(arena.epoch(), 0);
        arena.reset();
        assert!(arena.is_empty());
        assert_eq!(arena.extractions(), 0);
        assert_eq!(arena.epoch(), 1);
        // The reset arena interns and extracts like a fresh one.
        let id2 = arena.intern(&e);
        assert_eq!(arena.extract(id2), e);
        assert_eq!(arena.len(), {
            let fresh = SharedArena::new();
            fresh.intern(&e);
            fresh.len()
        });
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "stale ExprId")]
    fn stale_id_after_reset_fails_closed_in_debug() {
        let mut arena = SharedArena::new();
        let id = arena.intern(&matmul_naive(input("A"), input("B")));
        arena.reset();
        let _ = arena.get(id);
    }

    #[test]
    fn arena_pool_resets_on_reuse_and_tracks_high_water() {
        // The pool is process-global and other tests may touch it
        // concurrently, so assert counter deltas and invariants, not
        // which branch (create vs reuse) served each acquire.
        let before = arena_pool_stats();
        {
            let a = arena_acquire();
            let _ = a.intern(&input("A"));
            let mid = arena_pool_stats();
            assert!(mid.high_water >= 1);
            assert!(mid.created + mid.reused > before.created + before.reused);
        }
        let b = arena_acquire();
        assert!(b.is_empty(), "acquired arenas must come back reset");
        let after = arena_pool_stats();
        assert!(after.created + after.reused >= before.created + before.reused + 2);
        assert!(after.high_water >= after.in_use);
    }

    #[test]
    fn memo_toggle_restores() {
        assert!(memo_enabled());
        let inner = with_memo_disabled(|| {
            assert!(!memo_enabled());
            7
        });
        assert_eq!(inner, 7);
        assert!(memo_enabled());
    }
}
