//! Trace-driven, set-associative, multi-level cache simulator.
//!
//! This is the substitute substrate for the paper's hardware (a Core i5
//! 7300HQ for Tables 1-2 / Figures 4-6, an AMD HD7970 for the GPU note):
//! the paper's effect *is* the memory-hierarchy behaviour of different loop
//! orders and tilings, and a simulated hierarchy reproduces the miss-ratio
//! *ordering* of the variants without the authors' testbed (see DESIGN.md
//! §3).
//!
//! The simulator consumes the element-access stream produced by
//! [`crate::exec::trace`] and reports per-level hits/misses. Inclusive,
//! write-allocate, LRU replacement — the standard textbook model.

use crate::exec::{Access, AccessKind, Program};
use crate::Result;

/// One cache level's geometry.
#[derive(Clone, Copy, Debug)]
pub struct LevelConfig {
    pub name: &'static str,
    /// Total capacity in bytes.
    pub size: usize,
    /// Associativity (ways per set).
    pub ways: usize,
    /// Line size in bytes.
    pub line: usize,
}

impl LevelConfig {
    pub fn sets(&self) -> usize {
        self.size / (self.ways * self.line)
    }
}

/// A full hierarchy configuration.
#[derive(Clone, Debug)]
pub struct HierarchyConfig {
    pub levels: Vec<LevelConfig>,
}

impl HierarchyConfig {
    /// The paper's CPU testbed class (Core i5 7300HQ / Kaby Lake):
    /// 32 KiB / 8-way L1D, 256 KiB / 4-way L2, 3 MiB / 12-way L3, 64-byte
    /// lines.
    pub fn cpu_i5_7300hq() -> Self {
        HierarchyConfig {
            levels: vec![
                LevelConfig { name: "L1D", size: 32 << 10, ways: 8, line: 64 },
                LevelConfig { name: "L2", size: 256 << 10, ways: 4, line: 64 },
                LevelConfig { name: "L3", size: 3 << 20, ways: 12, line: 64 },
            ],
        }
    }

    /// A scaled-down hierarchy for fast unit tests and small problem sizes
    /// (scaling extents and caches together keeps the regime).
    pub fn scaled(factor: usize) -> Self {
        let base = Self::cpu_i5_7300hq();
        HierarchyConfig {
            levels: base
                .levels
                .iter()
                .map(|l| LevelConfig {
                    name: l.name,
                    size: (l.size / factor).max(l.ways * l.line),
                    ways: l.ways,
                    line: l.line,
                })
                .collect(),
        }
    }

    /// GPU-like hierarchy for the paper's HD7970 note: a small fast level
    /// standing for the per-CU LDS and a moderate chip-wide L2, global
    /// memory behind. LDS is a banked scratchpad with no set-indexing, so
    /// it is modeled **fully associative** (ways = lines) — a
    /// low-associativity model would inject set-aliasing pathologies for
    /// power-of-two tile strides that staged local-memory copies (which
    /// the paper's GPU code uses) do not suffer.
    pub fn gpu_hd7970() -> Self {
        let lds = 16 << 10;
        HierarchyConfig {
            levels: vec![
                LevelConfig { name: "LDS", size: lds, ways: lds / 64, line: 64 },
                LevelConfig { name: "L2", size: 768 << 10, ways: 16, line: 64 },
            ],
        }
    }
}

/// Per-level hit/miss counts.
#[derive(Clone, Debug, Default)]
pub struct LevelStats {
    pub name: &'static str,
    pub hits: u64,
    pub misses: u64,
}

impl LevelStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn miss_ratio(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses() as f64
        }
    }
}

/// Simulation result: per-level stats plus a weighted cycle cost (the
/// ranking metric standing in for wallclock on simulated targets).
#[derive(Clone, Debug)]
pub struct SimResult {
    pub levels: Vec<LevelStats>,
    pub total_accesses: u64,
}

impl SimResult {
    /// Approximate access cost in cycles: L1 hit 4, L2 hit 12, L3 hit 40,
    /// memory 200 (typical for the paper's CPU class); 2-level (GPU)
    /// configs use 4 / 40 / 400.
    pub fn cost_cycles(&self) -> f64 {
        let lat: &[f64] = match self.levels.len() {
            2 => &[4.0, 40.0, 400.0],
            _ => &[4.0, 12.0, 40.0, 200.0],
        };
        let mut cost = 0.0;
        for (i, l) in self.levels.iter().enumerate() {
            cost += l.hits as f64 * lat[i.min(lat.len() - 1)];
        }
        if let Some(last) = self.levels.last() {
            cost += last.misses as f64 * lat[self.levels.len().min(lat.len() - 1)];
        }
        cost
    }
}

/// One set-associative cache level with LRU replacement.
struct Level {
    cfg: LevelConfig,
    /// tags[set * ways + way]; `u64::MAX` = invalid.
    tags: Vec<u64>,
    /// LRU stamps, parallel to `tags`.
    stamp: Vec<u64>,
    clock: u64,
    stats: LevelStats,
}

impl Level {
    fn new(cfg: LevelConfig) -> Self {
        let n = cfg.sets() * cfg.ways;
        Level {
            cfg,
            tags: vec![u64::MAX; n],
            stamp: vec![0; n],
            clock: 0,
            stats: LevelStats {
                name: cfg.name,
                ..Default::default()
            },
        }
    }

    /// Access a line address; `true` on hit.
    fn access(&mut self, line_addr: u64) -> bool {
        self.clock += 1;
        let sets = self.cfg.sets() as u64;
        let set = (line_addr % sets) as usize;
        let tag = line_addr / sets;
        let base = set * self.cfg.ways;
        if let Some(w) = self.tags[base..base + self.cfg.ways]
            .iter()
            .position(|&t| t == tag)
        {
            self.stamp[base + w] = self.clock;
            self.stats.hits += 1;
            return true;
        }
        self.stats.misses += 1;
        let mut victim = 0usize;
        let mut oldest = u64::MAX;
        for w in 0..self.cfg.ways {
            if self.tags[base + w] == u64::MAX {
                victim = w;
                break;
            }
            if self.stamp[base + w] < oldest {
                oldest = self.stamp[base + w];
                victim = w;
            }
        }
        self.tags[base + victim] = tag;
        self.stamp[base + victim] = self.clock;
        false
    }
}

/// A running simulation over a hierarchy.
pub struct Simulator {
    levels: Vec<Level>,
    line: u64,
    total: u64,
}

impl Simulator {
    pub fn new(cfg: &HierarchyConfig) -> Self {
        assert!(!cfg.levels.is_empty());
        let line = cfg.levels[0].line as u64;
        Simulator {
            levels: cfg.levels.iter().map(|&l| Level::new(l)).collect(),
            line,
            total: 0,
        }
    }

    /// Feed one byte address (element accesses are 8 bytes; line masking
    /// handles alignment). Misses propagate to the next level.
    pub fn touch(&mut self, byte_addr: u64) {
        self.total += 1;
        let line_addr = byte_addr / self.line;
        for level in &mut self.levels {
            if level.access(line_addr) {
                return;
            }
        }
    }

    pub fn finish(self) -> SimResult {
        SimResult {
            levels: self.levels.into_iter().map(|l| l.stats).collect(),
            total_accesses: self.total,
        }
    }
}

/// Simulate a lowered program's full access stream on a hierarchy.
/// Address spaces (inputs / output / temps) are laid out contiguously with
/// line-aligned gaps, mimicking separate allocations.
pub fn simulate(prog: &Program, cfg: &HierarchyConfig) -> Result<SimResult> {
    let mut bases: Vec<u64> =
        Vec::with_capacity(prog.input_names.len() + 1 + prog.temp_sizes.len());
    let mut cur = 0u64;
    let push_space = |len_elems: usize, cur: &mut u64, bases: &mut Vec<u64>| {
        bases.push(*cur);
        let bytes = (len_elems as u64) * 8;
        *cur += (bytes + 63) / 64 * 64 + 64;
    };
    for len in &prog.input_lens {
        push_space(*len, &mut cur, &mut bases);
    }
    push_space(prog.out_size, &mut cur, &mut bases);
    for t in &prog.temp_sizes {
        push_space(*t, &mut cur, &mut bases);
    }
    let mut sim = Simulator::new(cfg);
    crate::exec::trace(prog, &mut |a: Access| {
        let addr = bases[a.space] + (a.offset as u64) * 8;
        let _ = matches!(a.kind, AccessKind::Write); // write-allocate: same path
        sim.touch(addr);
    })?;
    Ok(sim.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> HierarchyConfig {
        HierarchyConfig {
            levels: vec![LevelConfig { name: "L1", size: 1024, ways: 2, line: 64 }],
        }
    }

    #[test]
    fn sequential_sweep_miss_ratio_is_line_granular() {
        // 8-byte elements, 64-byte lines → 1 miss per 8 accesses.
        let mut sim = Simulator::new(&tiny());
        for i in 0..8192u64 {
            sim.touch(i * 8);
        }
        let r = sim.finish();
        assert_eq!(r.levels[0].misses, 1024);
        assert_eq!(r.levels[0].hits, 7168);
    }

    #[test]
    fn repeated_small_working_set_hits() {
        let mut sim = Simulator::new(&tiny());
        for _ in 0..100 {
            for i in 0..64u64 {
                sim.touch(i * 8); // 512-byte working set fits
            }
        }
        let r = sim.finish();
        assert_eq!(r.levels[0].misses, 8); // only the first pass misses
    }

    #[test]
    fn large_stride_thrashes() {
        // 8 lines mapping to one set with 2 ways → steady-state misses
        let mut sim = Simulator::new(&tiny());
        for _ in 0..10 {
            for i in 0..8u64 {
                sim.touch(i * 1024);
            }
        }
        assert!(sim.finish().levels[0].miss_ratio() > 0.9);
    }

    #[test]
    fn bigger_cache_never_misses_more() {
        let small = HierarchyConfig {
            levels: vec![LevelConfig { name: "s", size: 512, ways: 2, line: 64 }],
        };
        let big = HierarchyConfig {
            levels: vec![LevelConfig { name: "b", size: 8192, ways: 2, line: 64 }],
        };
        let mut rng = crate::util::Rng::new(3);
        let addrs: Vec<u64> = (0..5000).map(|_| (rng.below(4096) as u64) * 8).collect();
        let mut s1 = Simulator::new(&small);
        let mut s2 = Simulator::new(&big);
        for &a in &addrs {
            s1.touch(a);
            s2.touch(a);
        }
        assert!(s2.finish().levels[0].misses <= s1.finish().levels[0].misses);
    }

    #[test]
    fn miss_latency_orders_cost() {
        let mut hit_heavy = SimResult {
            levels: vec![LevelStats { name: "L1", hits: 1000, misses: 10 }],
            total_accesses: 1010,
        };
        let miss_heavy = SimResult {
            levels: vec![LevelStats { name: "L1", hits: 10, misses: 1000 }],
            total_accesses: 1010,
        };
        assert!(hit_heavy.cost_cycles() < miss_heavy.cost_cycles());
        hit_heavy.levels[0].hits = 0;
        assert_eq!(hit_heavy.levels[0].accesses(), 10);
    }

    #[test]
    fn matmul_variants_rank_by_locality() {
        // Table 1's ordering on a scaled hierarchy: the flipped-inner
        // variant (mapA rnz mapB) beats naive, which beats the worst
        // (mapB rnz mapA).
        use crate::enumerate::{enumerate_all, starts};
        use crate::exec::lower;
        use crate::layout::Layout;
        use crate::rewrite::Ctx;
        use crate::typecheck::Env;
        let n = 48usize;
        let env = Env::new()
            .with("A", Layout::row_major(&[n, n]))
            .with("B", Layout::row_major(&[n, n]));
        let ctx = Ctx::new(env.clone());
        let variants = enumerate_all(&starts::matmul_naive_variant(), &ctx, 10).unwrap();
        let cfg = HierarchyConfig::scaled(64);
        let mut results = std::collections::HashMap::new();
        for v in &variants {
            let prog = lower(&v.expr, &env).unwrap();
            let r = simulate(&prog, &cfg).unwrap();
            results.insert(v.display_key(), r.levels[0].misses);
        }
        let best = results["mapA rnz mapB"];
        let naive = results["mapA mapB rnz"];
        let worst = results["mapB rnz mapA"];
        assert!(best < naive, "best {best} vs naive {naive}");
        assert!(naive < worst, "naive {naive} vs worst {worst}");
    }
}
