//! The optimization service — Layer 3's front end.
//!
//! The paper's system is a compiler, so the coordinator is the part a
//! downstream user deploys: a threaded service that accepts *optimize*
//! jobs (DSL source + input shapes → enumerate, rank, pick the best
//! rearrangement) and *execute* jobs (run an AOT artifact through the PJRT
//! runtime), with
//!
//! - a worker pool for CPU-bound optimization pipelines,
//! - a dedicated runtime thread owning the (non-`Send`) PJRT client, with
//!   an executable cache and request batching,
//! - response routing back to each submitter via per-job channels,
//! - service metrics.
//!
//! Python never appears anywhere here — artifacts were compiled ahead of
//! time by `make artifacts`.

mod metrics;
mod pipeline;

pub use metrics::Metrics;
pub use pipeline::{optimize, OptimizeResult, OptimizeSpec, RankBy};

use crate::{Error, Result};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;

/// Best-effort description of a panic payload (the `Box<dyn Any>` a
/// worker catches from a panicking pipeline run).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Optimization worker threads.
    pub workers: usize,
    /// Maximum artifact-execution requests drained per batch.
    pub max_batch: usize,
    /// Artifact directory for the runtime thread.
    pub artifact_dir: PathBuf,
    /// Capacity of the optimize-result LRU (entries keyed by the current
    /// cache generation plus the full [`OptimizeSpec`]); repeated service
    /// traffic short-circuits the pipeline entirely. `0` keeps the floor
    /// of one entry.
    pub opt_cache_cap: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: 2,
            max_batch: 8,
            artifact_dir: crate::runtime::artifact_dir(),
            opt_cache_cap: 128,
        }
    }
}

/// A request to the service.
#[derive(Clone, Debug)]
pub enum Request {
    /// Run the optimization pipeline on DSL source.
    Optimize(OptimizeSpec),
    /// Execute a named AOT artifact with f32 inputs.
    ExecArtifact {
        name: String,
        inputs: Vec<(Vec<f32>, Vec<usize>)>,
    },
}

/// A response from the service.
#[derive(Clone, Debug)]
pub enum Response {
    Optimized(OptimizeResult),
    Executed { output: Vec<f32> },
}

/// Handle to a submitted job; resolves exactly once.
pub struct JobHandle {
    pub id: u64,
    rx: Receiver<Result<Response>>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<Response> {
        self.rx
            .recv()
            .map_err(|_| Error::Coordinator("worker dropped without responding".into()))?
    }
}

enum Work {
    Opt {
        spec: OptimizeSpec,
        reply: Sender<Result<Response>>,
    },
    Stop,
}

enum RtWork {
    Exec {
        name: String,
        inputs: Vec<(Vec<f32>, Vec<usize>)>,
        reply: Sender<Result<Response>>,
    },
    Stop,
}

/// The running service.
pub struct Coordinator {
    next_id: std::sync::atomic::AtomicU64,
    opt_tx: SyncSender<Work>,
    rt_tx: SyncSender<RtWork>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    rt_thread: Option<JoinHandle<()>>,
    n_workers: usize,
    /// Generation stamp mixed into every optimize-cache key. Seeded from
    /// [`crate::costmodel::COST_MODEL_VERSION`] (so a cost-model bump
    /// invalidates results cached under the old model) and advanced by
    /// [`Coordinator::flush_opt_cache`]; old-generation entries simply
    /// stop matching and age out of the LRU.
    opt_generation: Arc<std::sync::atomic::AtomicU64>,
}

impl Coordinator {
    /// Start the service threads.
    pub fn start(cfg: Config) -> Result<Self> {
        let metrics = Arc::new(Metrics::default());
        let (opt_tx, opt_rx) = sync_channel::<Work>(1024);
        let opt_rx = Arc::new(Mutex::new(opt_rx));
        // Result LRU shared by all workers: repeated optimize traffic
        // (same source, shapes, metric) short-circuits the pipeline.
        // Keys carry the cache generation so a flush (or a cost-model
        // version bump) invalidates without touching entries.
        let opt_cache = Arc::new(Mutex::new(
            crate::util::Lru::<(u64, OptimizeSpec), OptimizeResult>::new(cfg.opt_cache_cap),
        ));
        let opt_generation = Arc::new(std::sync::atomic::AtomicU64::new(
            crate::costmodel::COST_MODEL_VERSION,
        ));
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers.max(1) {
            let rx = opt_rx.clone();
            let m = metrics.clone();
            let cache = opt_cache.clone();
            let generation = opt_generation.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hofdla-opt-{w}"))
                    .spawn(move || loop {
                        // Recover from poisoned locks: a panic in any
                        // worker must not cascade into every other worker
                        // dying on `unwrap()` — which used to strand
                        // queued jobs forever (their reply senders sit in
                        // the channel, so callers block, not error).
                        let job = { rx.lock().unwrap_or_else(PoisonError::into_inner).recv() };
                        match job {
                            Ok(Work::Opt { spec, reply }) => {
                                let stamp = generation.load(Ordering::Relaxed);
                                let key = (stamp, spec);
                                let cached = cache
                                    .lock()
                                    .unwrap_or_else(PoisonError::into_inner)
                                    .get(&key);
                                let r = match cached {
                                    Some(hit) => {
                                        m.opt_cache_hits.fetch_add(1, Ordering::Relaxed);
                                        Ok(Response::Optimized(hit))
                                    }
                                    None => {
                                        // A panicking pipeline run fails
                                        // its own job (counted in
                                        // `failed`, reply delivered) and
                                        // leaves the worker alive.
                                        let r = std::panic::catch_unwind(
                                            std::panic::AssertUnwindSafe(|| {
                                                pipeline::optimize(&key.1)
                                            }),
                                        )
                                        .unwrap_or_else(|payload| {
                                            Err(Error::Coordinator(format!(
                                                "optimize job panicked: {}",
                                                panic_message(payload.as_ref())
                                            )))
                                        });
                                        if let Ok(res) = &r {
                                            // Fold the fresh run's search
                                            // counters into the service
                                            // metrics (cache hits describe
                                            // no new search work and are
                                            // not re-recorded).
                                            m.record_search(&res.stats);
                                            m.verify_passed.fetch_add(
                                                res.programs_verified as u64,
                                                Ordering::Relaxed,
                                            );
                                            cache
                                                .lock()
                                                .unwrap_or_else(PoisonError::into_inner)
                                                .put(key, res.clone());
                                        } else if let Err(Error::Verify(_)) = &r {
                                            // A verifier rejection is a
                                            // soundness catch, not a user
                                            // error — count it separately
                                            // so operators see it.
                                            m.verify_rejects.fetch_add(1, Ordering::Relaxed);
                                        }
                                        r.map(Response::Optimized)
                                    }
                                };
                                if r.is_ok() {
                                    m.completed.fetch_add(1, Ordering::Relaxed);
                                } else {
                                    m.failed.fetch_add(1, Ordering::Relaxed);
                                }
                                let _ = reply.send(r);
                            }
                            Ok(Work::Stop) | Err(_) => break,
                        }
                    })
                    .map_err(|e| Error::Coordinator(format!("spawn: {e}")))?,
            );
        }

        // Runtime thread: owns the PJRT client; batches artifact requests.
        let (rt_tx, rt_rx) = sync_channel::<RtWork>(1024);
        let m = metrics.clone();
        let max_batch = cfg.max_batch.max(1);
        let art_dir = cfg.artifact_dir.clone();
        let rt_thread = std::thread::Builder::new()
            .name("hofdla-runtime".into())
            .spawn(move || {
                let mut rt = match crate::runtime::Runtime::cpu() {
                    Ok(rt) => rt,
                    Err(e) => {
                        while let Ok(w) = rt_rx.recv() {
                            match w {
                                RtWork::Exec { reply, .. } => {
                                    let _ = reply.send(Err(Error::Runtime(format!(
                                        "PJRT unavailable: {e}"
                                    ))));
                                }
                                RtWork::Stop => break,
                            }
                        }
                        return;
                    }
                };
                'outer: loop {
                    let first = match rt_rx.recv() {
                        Ok(w) => w,
                        Err(_) => break,
                    };
                    let mut batch = Vec::with_capacity(max_batch);
                    match first {
                        RtWork::Stop => break,
                        w => batch.push(w),
                    }
                    let mut stop_after = false;
                    while batch.len() < max_batch {
                        match rt_rx.try_recv() {
                            Ok(RtWork::Stop) => {
                                stop_after = true;
                                break;
                            }
                            Ok(w) => batch.push(w),
                            Err(_) => break,
                        }
                    }
                    m.exec_batches.fetch_add(1, Ordering::Relaxed);
                    m.max_batch_seen
                        .fetch_max(batch.len() as u64, Ordering::Relaxed);
                    Self::run_batch(&mut rt, &art_dir, batch, &m);
                    if stop_after {
                        break 'outer;
                    }
                }
            })
            .map_err(|e| Error::Coordinator(format!("spawn runtime: {e}")))?;

        Ok(Coordinator {
            next_id: std::sync::atomic::AtomicU64::new(1),
            opt_tx,
            rt_tx,
            metrics,
            n_workers: cfg.workers.max(1),
            workers,
            rt_thread: Some(rt_thread),
            opt_generation,
        })
    }

    /// Invalidate every cached optimize result by advancing the cache
    /// generation (ROADMAP: cache invalidation policy for the coordinator
    /// LRU). Call after anything that changes ranking semantics — e.g. a
    /// cost model that learns online. In-flight jobs are unaffected; stale
    /// entries age out of the LRU on their own.
    pub fn flush_opt_cache(&self) {
        self.opt_generation.fetch_add(1, Ordering::Relaxed);
        self.metrics.opt_cache_flushes.fetch_add(1, Ordering::Relaxed);
    }

    /// The current optimize-cache generation (diagnostics / tests).
    pub fn opt_cache_generation(&self) -> u64 {
        self.opt_generation.load(Ordering::Relaxed)
    }

    fn run_batch(
        rt: &mut crate::runtime::Runtime,
        art_dir: &std::path::Path,
        batch: Vec<RtWork>,
        m: &Metrics,
    ) {
        for w in batch {
            let RtWork::Exec {
                name,
                inputs,
                reply,
            } = w
            else {
                continue;
            };
            let path = art_dir.join(format!("{name}.hlo.txt"));
            let before = rt.cache_len();
            let r = rt.load(&path).and_then(|exe| {
                if rt.cache_len() == before {
                    m.exec_cache_hits.fetch_add(1, Ordering::Relaxed);
                }
                let refs: Vec<(&[f32], &[usize])> = inputs
                    .iter()
                    .map(|(d, s)| (d.as_slice(), s.as_slice()))
                    .collect();
                rt.run_f32(&exe, &refs)
            });
            match r {
                Ok(output) => {
                    m.completed.fetch_add(1, Ordering::Relaxed);
                    let _ = reply.send(Ok(Response::Executed { output }));
                }
                Err(e) => {
                    m.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = reply.send(Err(e));
                }
            }
        }
    }

    /// Submit a job; returns a handle that resolves exactly once.
    pub fn submit(&self, req: Request) -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        match req {
            Request::Optimize(spec) => self
                .opt_tx
                .send(Work::Opt { spec, reply: tx })
                .map_err(|_| Error::Coordinator("service stopped".into()))?,
            Request::ExecArtifact { name, inputs } => self
                .rt_tx
                .send(RtWork::Exec {
                    name,
                    inputs,
                    reply: tx,
                })
                .map_err(|_| Error::Coordinator("service stopped".into()))?,
        }
        Ok(JobHandle { id, rx })
    }

    /// Convenience: submit and wait.
    pub fn call(&self, req: Request) -> Result<Response> {
        self.submit(req)?.wait()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for _ in 0..self.n_workers {
            let _ = self.opt_tx.send(Work::Stop);
        }
        let _ = self.rt_tx.send(RtWork::Stop);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(t) = self.rt_thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opt_spec(n: usize) -> OptimizeSpec {
        OptimizeSpec {
            source:
                "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))"
                    .into(),
            inputs: vec![("A".into(), vec![n, n]), ("B".into(), vec![n, n])],
            rank_by: RankBy::CostModel,
            subdivide_rnz: None,
            top_k: 6,
            prune: false,
            verify: true,
            budget: 0,
            deadline_ms: 0,
        }
    }

    #[test]
    fn optimize_roundtrip() {
        let c = Coordinator::start(Config {
            workers: 2,
            ..Default::default()
        })
        .unwrap();
        let Response::Optimized(r) = c.call(Request::Optimize(opt_spec(16))).unwrap() else {
            panic!("wrong response type")
        };
        assert_eq!(r.variants_explored, 6);
        assert_eq!(r.ranking.first().unwrap().0, r.best);
        assert_eq!(r.best, "map1 rnz map2"); // Table 1 winner
        // The spec's verify knob is on: the winner was certified, and the
        // service counter saw it.
        assert_eq!(r.programs_verified, 1);
        assert_eq!(c.metrics.verify_passed.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.verify_rejects.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn jobs_route_to_matching_requests() {
        // Distinct problem sizes in flight concurrently; every response
        // must carry its own request's size.
        let c = Coordinator::start(Config {
            workers: 4,
            ..Default::default()
        })
        .unwrap();
        let sizes = [4usize, 8, 16, 32, 4, 8, 16, 32, 64, 64];
        let handles: Vec<(usize, JobHandle)> = sizes
            .iter()
            .map(|&n| (n, c.submit(Request::Optimize(opt_spec(n))).unwrap()))
            .collect();
        for (n, h) in handles {
            let Response::Optimized(r) = h.wait().unwrap() else { panic!() };
            assert_eq!(r.input_elems, 2 * n * n, "routing mixed up sizes");
        }
        let m = &c.metrics;
        assert_eq!(m.submitted.load(Ordering::Relaxed), 10);
        assert_eq!(m.completed.load(Ordering::Relaxed), 10);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
        assert_eq!(m.in_flight(), 0);
    }

    #[test]
    fn optimize_results_are_cached() {
        let c = Coordinator::start(Config {
            workers: 1,
            ..Default::default()
        })
        .unwrap();
        let mut after_first = 0;
        for i in 0..3 {
            let Response::Optimized(r) = c.call(Request::Optimize(opt_spec(16))).unwrap() else {
                panic!("wrong response type")
            };
            assert_eq!(r.variants_explored, 6);
            assert_eq!(r.best, "map1 rnz map2");
            if i == 0 {
                after_first = c.metrics.search_generated.load(Ordering::Relaxed);
                assert!(after_first > 0, "fresh run must record search work");
            }
        }
        // Serial identical calls: first misses, the rest hit the LRU.
        assert_eq!(c.metrics.opt_cache_hits.load(Ordering::Relaxed), 2);
        assert_eq!(c.metrics.completed.load(Ordering::Relaxed), 3);
        // Cache hits describe no new search work: counters are unchanged.
        assert_eq!(
            c.metrics.search_generated.load(Ordering::Relaxed),
            after_first
        );
        // A different spec misses — and records fresh search work.
        let Response::Optimized(_) = c.call(Request::Optimize(opt_spec(8))).unwrap() else {
            panic!("wrong response type")
        };
        assert_eq!(c.metrics.opt_cache_hits.load(Ordering::Relaxed), 2);
        assert!(c.metrics.search_generated.load(Ordering::Relaxed) > after_first);
    }

    #[test]
    fn flush_invalidates_optimize_cache() {
        let c = Coordinator::start(Config {
            workers: 1,
            ..Default::default()
        })
        .unwrap();
        let g0 = c.opt_cache_generation();
        assert_eq!(g0, crate::costmodel::COST_MODEL_VERSION);
        // Warm the cache, hit it once.
        c.call(Request::Optimize(opt_spec(16))).unwrap();
        c.call(Request::Optimize(opt_spec(16))).unwrap();
        assert_eq!(c.metrics.opt_cache_hits.load(Ordering::Relaxed), 1);
        // Flush: the same spec must re-run the pipeline (no new hit), and
        // the refreshed entry must serve hits again afterwards.
        c.flush_opt_cache();
        assert_eq!(c.opt_cache_generation(), g0 + 1);
        assert_eq!(c.metrics.opt_cache_flushes.load(Ordering::Relaxed), 1);
        c.call(Request::Optimize(opt_spec(16))).unwrap();
        assert_eq!(c.metrics.opt_cache_hits.load(Ordering::Relaxed), 1);
        c.call(Request::Optimize(opt_spec(16))).unwrap();
        assert_eq!(c.metrics.opt_cache_hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn worker_pool_survives_panicking_jobs() {
        // A panicking `pipeline::optimize` used to unwind the worker with
        // the job's reply channel still queued behind poisoned locks:
        // every other worker then died on `lock().unwrap()` and later
        // callers blocked forever. The pool must instead fail the job and
        // keep serving.
        let c = Coordinator::start(Config {
            workers: 1,
            ..Default::default()
        })
        .unwrap();
        // Shapes whose stride/extent products overflow `usize` panic in
        // debug builds (the profile `cargo test` runs); in release the
        // wrapped layout fails shape checking instead. Either way the job
        // must resolve — promptly and with an error — instead of hanging.
        let poison = OptimizeSpec {
            source:
                "(map (lam (rA) (map (lam (cB) (rnz + * rA cB)) (flip 0 (in B)))) (in A))"
                    .into(),
            inputs: vec![
                ("A".into(), vec![usize::MAX, usize::MAX]),
                ("B".into(), vec![usize::MAX, usize::MAX]),
            ],
            rank_by: RankBy::CostModel,
            subdivide_rnz: None,
            top_k: 4,
            prune: false,
            verify: false,
            budget: 0,
            deadline_ms: 0,
        };
        for _ in 0..3 {
            let r = c.call(Request::Optimize(poison.clone()));
            if cfg!(debug_assertions) {
                assert!(r.is_err(), "panicking job must surface as an error");
            }
        }
        if cfg!(debug_assertions) {
            assert_eq!(c.metrics.failed.load(Ordering::Relaxed), 3);
        }
        // The single worker survived all three panics and still serves.
        let Response::Optimized(r) = c.call(Request::Optimize(opt_spec(8))).unwrap() else {
            panic!("wrong response type")
        };
        assert_eq!(r.best, "map1 rnz map2");
        assert_eq!(c.metrics.in_flight(), 0);
    }

    #[test]
    fn parse_errors_fail_cleanly() {
        let c = Coordinator::start(Config::default()).unwrap();
        let bad = OptimizeSpec {
            source: "(map (lam".into(),
            inputs: vec![],
            rank_by: RankBy::CostModel,
            subdivide_rnz: None,
            top_k: 3,
            prune: false,
            verify: false,
            budget: 0,
            deadline_ms: 0,
        };
        assert!(c.call(Request::Optimize(bad)).is_err());
        assert_eq!(c.metrics.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn artifact_execution_and_batching() {
        if !crate::runtime::artifact_path("matmul_xla_256").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        if !crate::runtime::pjrt_available() {
            eprintln!("skipping: PJRT runtime unavailable");
            return;
        }
        let c = Coordinator::start(Config {
            workers: 1,
            max_batch: 4,
            ..Default::default()
        })
        .unwrap();
        let n = 256usize;
        let a = vec![1f32; n * n];
        let b = vec![2f32; n * n];
        let mk = || Request::ExecArtifact {
            name: "matmul_xla_256".into(),
            inputs: vec![(a.clone(), vec![n, n]), (b.clone(), vec![n, n])],
        };
        let handles: Vec<JobHandle> = (0..6).map(|_| c.submit(mk()).unwrap()).collect();
        for h in handles {
            let Response::Executed { output } = h.wait().unwrap() else { panic!() };
            assert_eq!(output.len(), n * n);
            assert!((output[0] - (2 * n) as f32).abs() < 1e-2);
        }
        let m = &c.metrics;
        assert!(m.max_batch_seen.load(Ordering::Relaxed) <= 4);
        assert!(m.exec_cache_hits.load(Ordering::Relaxed) >= 5);
        let missing = Request::ExecArtifact {
            name: "no_such_artifact".into(),
            inputs: vec![],
        };
        assert!(c.call(missing).is_err());
    }
}
